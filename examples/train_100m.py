"""End-to-end training example: a ~100M-parameter llama-family model with
checkpointing, preemption-safe resume, straggler detection and HMU embedding
tiering — the full production loop at laptop scale.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

(Single CPU core runs ~1 step/6 s at these dims; pass --steps 20 for a
smoke run.  On a real accelerator this config trains a few hundred steps in
minutes.)
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import dataclasses

from repro.configs.llama3_2_3b import config as llama_config
from repro.launch import train as train_driver
import repro.configs as cfgs


def config_100m():
    base = llama_config()
    return dataclasses.replace(
        base, name="llama-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32_000,
        tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = config_100m()
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M")

    # reuse the production driver with our config injected
    orig_get = train_driver.get_config
    orig_smoke = train_driver.get_smoke_config
    train_driver.get_config = lambda a: cfg
    train_driver.get_smoke_config = lambda a: cfg
    try:
        train_driver.main([
            "--arch", "llama3.2-3b", "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--ckpt-dir", args.ckpt_dir, "--resume",
        ])
    finally:
        train_driver.get_config = orig_get
        train_driver.get_smoke_config = orig_smoke


if __name__ == "__main__":
    main()
