"""Expert tiering for MoE — the paper's DLRM sparsity argument applied to
expert weights (DESIGN.md §2): with 384 experts top-8, ~2% of expert bytes
are live per token; the router's expert counters ARE memory-side telemetry
(full coverage, zero extra cost), so hot experts can live in HBM and cold
ones in the capacity tier.

Runs the reduced Kimi-style MoE, collects per-layer expert counts from the
forward pass, plans placement per telemetry source, and models decode-time
expert-weight fetch cost.

    PYTHONPATH=src python examples/expert_tiering_moe.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import TPU_V5E_SYSTEM
from repro.core.metrics import accuracy, true_top_k
from repro.models.model import forward, init_params

cfg = get_smoke_config("kimi-k2-1t-a32b")
params = init_params(cfg, jax.random.key(0))
rng = np.random.default_rng(0)

# skewed token stream (popular tokens route to the same experts)
fwd = jax.jit(lambda p, t: forward(p, cfg, tokens=t)[1]["expert_counts"])
counts = np.zeros((cfg.n_layers, cfg.moe.n_experts), np.int64)
for _ in range(16):
    zipf = np.minimum(rng.zipf(1.3, size=(4, 64)) - 1, cfg.vocab_size - 1)
    counts += np.asarray(fwd(params, jnp.asarray(zipf, jnp.int32)))

per_expert = counts.sum(0)
e = cfg.moe.n_experts
k_fast = max(e // 4, 1)                      # HBM capacity: 25% of experts
print(f"experts={e} top_k={cfg.moe.top_k}; counts over 16 batches:")
print("  per-expert activation counts:", per_expert.tolist())

hot = true_top_k(per_expert, k_fast)
print(f"\nHMU (router) telemetry -> promote {k_fast} experts: {sorted(hot.tolist())}")

# placement quality & modeled expert-weight fetch time at decode
bytes_per_expert = 3 * cfg.d_model * cfg.moe.d_expert * 2   # gate/up/down bf16
total = per_expert.sum()
fast_traffic = per_expert[hot].sum()
sysm = TPU_V5E_SYSTEM
t_tier = sysm.access_time_s(fast_traffic, total - fast_traffic, bytes_per_expert)
t_hbm = sysm.access_time_s(total, 0, bytes_per_expert)
t_host = sysm.access_time_s(0, total, bytes_per_expert)
print(f"hot-expert traffic share: {fast_traffic/total:.1%} at "
      f"{k_fast/e:.0%} of expert bytes resident in HBM")
print(f"modeled expert-weight fetch: tiered={t_tier*1e6:.0f}us "
      f"all-HBM={t_hbm*1e6:.0f}us all-host={t_host*1e6:.0f}us")
print(f"=> {t_host/t_tier:.1f}x faster than full offload, "
      f"{bytes_per_expert*(e-k_fast)/1e6:.0f} MB of HBM freed per layer")

# ---- online epoch runtime: routing mix shifts mid-run (new traffic pattern
# routes to different experts).  The router's per-epoch counters feed the
# EpochRuntime; proactive/EWMA re-promotes the new hot experts within an
# epoch while NB-style recency tracking lags.
from repro.core.runtime import EpochRuntime                     # noqa: E402

N_EPOCHS, BATCHES_PER_EPOCH, SHIFT_AT = 6, 4, 3
LANES = ("proactive_ewma", "nb_two_touch")
rt = EpochRuntime(
    e, k_hot=k_fast, policies=LANES, system=TPU_V5E_SYSTEM,
    bytes_per_access=bytes_per_expert,
    block_bytes=bytes_per_expert * cfg.n_layers,
    nb_scan_rate=max(e // 2, 1),
    ewma_alpha=0.9,     # few experts -> little history needed; adapt fast
)


def expert_stream(shift: bool) -> np.ndarray:
    """One batch's expert-access stream from the router (layer-summed)."""
    zipf = np.minimum(rng.zipf(1.3, size=(4, 64)) - 1, cfg.vocab_size - 1)
    if shift:   # rotate token popularity -> different experts become hot
        zipf = (zipf + cfg.vocab_size // 2) % cfg.vocab_size
    c = np.asarray(fwd(params, jnp.asarray(zipf, jnp.int32))).sum(0)
    return np.repeat(np.arange(e), c)       # constant length: tokens*top_k*L


print(f"\nonline expert tiering: {N_EPOCHS} epochs, routing shift at "
      f"epoch {SHIFT_AT} (modeled fetch us / placement accuracy)")
for ep in range(N_EPOCHS):
    epoch = np.stack([expert_stream(ep >= SHIFT_AT)
                      for _ in range(BATCHES_PER_EPOCH)])
    recs = rt.step(epoch)
    mark = "<- shift" if ep == SHIFT_AT else ""
    print(f"  epoch {ep}: " + "  ".join(
        f"{n}={recs[n].time_s*1e6:7.0f}us/acc={recs[n].accuracy:.2f}"
        for n in LANES) + f"  {mark}")
traj = rt.trajectory()
pro, nb = traj.times("proactive_ewma"), traj.times("nb_two_touch")


def recovery(lane):
    acc = [r.accuracy for r in traj.lane(lane)][SHIFT_AT:]
    hits = [i for i, a in enumerate(acc) if a >= 0.5]
    return hits[0] if hits else None


print(f"=> post-shift mean fetch: proactive={float(pro[SHIFT_AT:].mean())*1e6:.0f}us "
      f"nb={float(nb[SHIFT_AT:].mean())*1e6:.0f}us; recovery to >=50% placement "
      f"accuracy: proactive={recovery('proactive_ewma')} epochs "
      f"nb={recovery('nb_two_touch')} epochs "
      f"(at {e} experts both signals are cheap — the gap widens with scale; "
      f"see dlrm_tiering.py at 16k pages)")
