"""Expert tiering for MoE — the paper's DLRM sparsity argument applied to
expert weights (DESIGN.md §2): with 384 experts top-8, ~2% of expert bytes
are live per token; the router's expert counters ARE memory-side telemetry
(full coverage, zero extra cost), so hot experts can live in HBM and cold
ones in the capacity tier.

Part 1 sizes the opportunity offline (traffic share, modeled fetch time).
Part 2 places the expert banks ONLINE through the workload-agnostic scenario
layer: ``repro.scenarios.MoEExpertScenario`` turns the router's per-epoch
counters into EpochRuntime access batches and ``run_scenario`` drives all
six policy lanes over a mid-run routing shift — the same runtime, epoch
loop, and dispatch accounting as the DLRM and KV-cache workloads.

    PYTHONPATH=src python examples/expert_tiering_moe.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import TPU_V5E_SYSTEM
from repro.core.metrics import true_top_k
from repro.models.model import forward, init_params

cfg = get_smoke_config("kimi-k2-1t-a32b")
params = init_params(cfg, jax.random.key(0))
rng = np.random.default_rng(0)

# ---- part 1: size the opportunity (skewed token stream -> skewed routing)
fwd = jax.jit(lambda p, t: forward(p, cfg, tokens=t)[1]["expert_counts"])
counts = np.zeros((cfg.n_layers, cfg.moe.n_experts), np.int64)
for _ in range(16):
    zipf = np.minimum(rng.zipf(1.3, size=(4, 64)) - 1, cfg.vocab_size - 1)
    counts += np.asarray(fwd(params, jnp.asarray(zipf, jnp.int32)))

per_expert = counts.sum(0)
e = cfg.moe.n_experts
k_fast = max(e // 4, 1)                      # HBM capacity: 25% of experts
print(f"experts={e} top_k={cfg.moe.top_k}; counts over 16 batches:")
print("  per-expert activation counts:", per_expert.tolist())

hot = true_top_k(per_expert, k_fast)
print(f"\nHMU (router) telemetry -> promote {k_fast} experts: {sorted(hot.tolist())}")

# placement quality & modeled expert-weight fetch time at decode
bytes_per_expert = 3 * cfg.d_model * cfg.moe.d_expert * 2   # gate/up/down bf16
total = per_expert.sum()
fast_traffic = per_expert[hot].sum()
sysm = TPU_V5E_SYSTEM
t_tier = sysm.access_time_s(fast_traffic, total - fast_traffic, bytes_per_expert)
t_hbm = sysm.access_time_s(total, 0, bytes_per_expert)
t_host = sysm.access_time_s(0, total, bytes_per_expert)
print(f"hot-expert traffic share: {fast_traffic/total:.1%} at "
      f"{k_fast/e:.0%} of expert bytes resident in HBM")
print(f"modeled expert-weight fetch: tiered={t_tier*1e6:.0f}us "
      f"all-HBM={t_hbm*1e6:.0f}us all-host={t_host*1e6:.0f}us")
print(f"=> {t_host/t_tier:.1f}x faster than full offload, "
      f"{bytes_per_expert*(e-k_fast)/1e6:.0f} MB of HBM freed per layer")

# ---- part 2: online epoch placement via the scenario layer.  The routing
# mix shifts mid-run (token popularity rotates -> different experts hot);
# per-epoch frequency tracking (proactive/EWMA) re-promotes the new hot
# experts within an epoch while NB-style cumulative recency lags.
from repro.scenarios import MoEExpertScenario, run_scenario   # noqa: E402

LANES = ("proactive_ewma", "nb_two_touch")
scenario = MoEExpertScenario(n_epochs=6, batches_per_epoch=4, shift_at=3,
                             seed=3)
SHIFT_AT = scenario.shift_at
print(f"\nonline expert tiering (scenario='{scenario.name}', "
      f"{scenario.n_blocks} expert banks, k_hot={scenario.k_hot}): "
      f"{scenario.n_epochs} epochs, routing shift at epoch {SHIFT_AT}")
# few experts -> little history needed; adapt fast
out = run_scenario(scenario, policies=LANES, ewma_alpha=0.9)
lanes = out["trajectory"]["lanes"]
for ep in range(scenario.n_epochs):
    mark = "<- shift" if ep == SHIFT_AT else ""
    print(f"  epoch {ep}: " + "  ".join(
        f"{n}={lanes[n][ep]['time_s']*1e6:7.0f}us"
        f"/acc={lanes[n][ep]['accuracy']:.2f}"
        for n in LANES) + f"  {mark}")

s = out["summary"]
print(f"=> post-shift mean fetch: "
      f"proactive={s['proactive_ewma']['post_shift_mean_time_us']:.0f}us "
      f"nb={s['nb_two_touch']['post_shift_mean_time_us']:.0f}us "
      f"({s['proactive_vs_nb_post_shift']:.2f}x); recovery to >=50% "
      f"placement accuracy: "
      f"proactive={s['proactive_ewma']['post_shift_recovery_epochs']} epochs "
      f"nb={s['nb_two_touch']['post_shift_recovery_epochs']} epochs "
      f"(at {e} experts both signals are cheap — the gap widens with scale; "
      f"see dlrm_tiering.py at 16k pages)")
