"""Degraded telemetry: fault injection + the hardened runtime (PR 7).

The paper measures telemetry *limits* on healthy collectors.  At rack scale
the collectors themselves fail: HMU drain races wipe counter state, PEBS
sheds samples under interrupt pressure, the NB scan thread stalls.  A
tiering daemon that trusts a degraded signal keeps migrating on noise.

This walkthrough injects the worst HMU fault — a collector reset every
epoch (`reset_p=1.0`: every drain races, deltas turn to garbage) — into
the §III.B DLRM trace with a mid-run phase shift, and runs the oracle lane
three ways:

* **healthy**  — no faults: the ceiling (~0.87 coverage, instant recovery);
* **naive**    — faults on, runtime unchanged: the lane keeps ranking the
  wrecked HMU deltas and its coverage collapses;
* **hardened** — same faults plus `repro.faults.Hardening`: an on-device
  quality estimator (observed mass vs expected, EWMA-smoothed) watches the
  HMU signal crater and branchlessly swaps the lane's decision input to
  the healthy PEBS collector; demotion hysteresis stops one garbage epoch
  from flushing the resident hot set.

Everything — injection, quality, fallback — runs inside the same fused
2-dispatch epoch; a fault-free FaultModel reproduces the healthy run bit
for bit.

    PYTHONPATH=src python examples/degraded_telemetry.py
"""
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import runtime as rtmod
from repro.dlrm import datagen
from repro.faults import FaultModel, Hardening
from repro.scenarios import DLRMScenario, run_scenario

LANE = "hmu_oracle"
N_EPOCHS, SHIFT = 10, 5
spec = dataclasses.replace(datagen.SMALL, lookups_per_batch=30_000)


def scenario():
    return DLRMScenario(spec=spec, n_epochs=N_EPOCHS, batches_per_epoch=2,
                        shift_at=SHIFT)


def hmu_resets():
    """Every epoch's drain races: HMU counts wiped before the observes."""
    return FaultModel.create(reset_p=np.array([1.0, 0.0, 0.0], np.float32),
                             seed=7, n_blocks=scenario().n_blocks)


# pebs_period sized so the fallback target actually resolves the hot set
# (~2.6k samples/epoch for k_hot=250): the point is degraded-HMU vs
# healthy-PEBS, not PEBS undersampling
RUN_KW = dict(policies=(LANE, "hinted"), hints=False, pebs_period=23)

healthy = run_scenario(scenario(), **RUN_KW)

# fault-free FaultModel == no FaultModel, bit for bit (the neutral gate CI
# enforces across single-device / sharded / fleet / every sync_every=K)
neutral = run_scenario(scenario(), faults=FaultModel.create(
    n_blocks=scenario().n_blocks), **RUN_KW)
assert neutral["trajectory"] == healthy["trajectory"]

naive = run_scenario(scenario(), faults=hmu_resets(), **RUN_KW)
with rtmod.counting() as counts:
    hard = run_scenario(
        scenario(), faults=hmu_resets(),
        hardening=Hardening.make(fallback={LANE: "pebs"},
                                 demote_hysteresis=2), **RUN_KW)
dispatches = (counts.dispatch["observe_all"]
              + counts.dispatch["epoch_step"]) / N_EPOCHS

lanes = {name: out["trajectory"]["lanes"][LANE]
         for name, out in (("healthy", healthy), ("naive", naive),
                           ("hardened", hard))}
sc = scenario()
print(f"DLRM {sc.n_blocks} pages, k_hot={sc.k_hot}, phase shift at epoch "
      f"{SHIFT}; HMU collector reset every epoch (drain race, reset_p=1.0); "
      f"'{LANE}' lane\n")
print(f"{'epoch':>5s} {'healthy':>8s} {'naive':>8s} {'hardened':>9s} "
      f"{'quality':>8s}")
for e in range(N_EPOCHS):
    q = lanes["hardened"][e]["quality"]
    print(f"{e:>5d} {lanes['healthy'][e]['coverage']:>8.2f} "
          f"{lanes['naive'][e]['coverage']:>8.2f} "
          f"{lanes['hardened'][e]['coverage']:>9.2f} {q:>8.2f}")

# post-warmup means, shift epochs excluded (coverage is 0 there by
# construction: the hot set moved under every variant)
steady = [e for e in range(2, N_EPOCHS) if e not in (SHIFT, SHIFT + 1)]
cov = {name: float(np.mean([rows[e]["coverage"] for e in steady]))
       for name, rows in lanes.items()}
q_final = lanes["hardened"][-1]["quality"]

print("\n== Robustness ==")
print(f"naive: every drain races, so the lane ranks deltas of wrecked "
      f"counters — coverage {cov['healthy']:.2f} (healthy) -> "
      f"{cov['naive']:.2f} ✗")
print(f"hardened: the on-device quality estimator reads the HMU's observed "
      f"mass at {q_final:.2f} (floor 0.5) and swaps the lane's input to "
      f"PEBS — coverage holds at {cov['hardened']:.2f} ✓")
print(f"same fused epoch throughout: {dispatches:.0f} dispatches/epoch, "
      f"fault injection and fallback both live inside the traced step")

assert cov["naive"] < cov["healthy"] - 0.3    # the fault really bites
assert cov["hardened"] > cov["naive"] + 0.1   # the fallback really helps
assert q_final < 0.2                          # and the estimator saw it
assert dispatches == 2.0
