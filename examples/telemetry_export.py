"""Telemetry export plane: stream a fleet run's records to JSONL + Prometheus.

The paper's device-telemetry case only pays off if the telemetry is
consumable by ops tooling, so this walkthrough runs a two-tenant fleet with
a `repro.export.ExportClient` attached and shows all three sink styles:

* **JSONL** — one schema-validated wire record per line (the durable
  cross-run format; every record conforms to the frozen
  `telemetry.schema.json`, units encoded in field names),
* **Prometheus text exposition** — last-value gauges for
  coverage/accuracy/quality/epoch-time labelled by scenario/lane/tenant,
  plus the runtime's dispatch counters published as monotone counters,
* **circuit breaker** — the same run against a sink that fails every
  write: the breaker trips, the client degrades to noop, and the run's
  trajectory is still bit-identical — export can never hurt the epoch
  loop.

    PYTHONPATH=src python examples/telemetry_export.py
"""
import json
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import runtime as rtmod
from repro.export import (CircuitBreaker, ExportClient, JsonlSink,
                          MemorySink, PrometheusTextSink)
from repro.fleet import FleetScenario, TenantSpec, run_fleet
from repro.scenarios import KVCacheScenario

N_EPOCHS = 4


def make_fleet():
    return FleetScenario([
        TenantSpec(KVCacheScenario(batch=2, n_epochs=N_EPOCHS,
                                   batches_per_epoch=2,
                                   accesses_per_batch=2_048), name="kv_a"),
        TenantSpec(KVCacheScenario(batch=2, n_epochs=N_EPOCHS,
                                   batches_per_epoch=2,
                                   accesses_per_batch=2_048, seed=7),
                   name="kv_b"),
    ], capacity="weighted")


def main():
    out_dir = Path(tempfile.mkdtemp(prefix="repro_export_"))
    jsonl_path = out_dir / "telemetry.jsonl"

    # --- 1. fleet run exporting to JSONL ---------------------------------
    client = ExportClient(JsonlSink(jsonl_path))
    with rtmod.counting() as c:
        out = run_fleet(make_fleet(), hints=False, sync_every=2,
                        export=client)
        dispatches = dict(c.dispatch)
    client.flush()
    stats = client.stats()
    print(f"exported {stats['exported']} records -> {jsonl_path}")
    print(f"  dropped={stats['dropped_queue_full']} "
          f"breaker={stats['breaker_state']} "
          f"dispatches={dispatches['observe_all'] + dispatches['epoch_step']}"
          f" ({N_EPOCHS} epochs x 2)")
    lines = jsonl_path.read_text().splitlines()
    kinds = {}
    for line in lines:
        kinds.setdefault(json.loads(line)["record_type"], []).append(line)
    for kind, rows in sorted(kinds.items()):
        print(f"  {kind}: {len(rows)} records")
    print("  sample:", lines[0][:100], "...")
    client.close()

    # --- 2. Prometheus-style exposition ----------------------------------
    prom = PrometheusTextSink()
    client = ExportClient(prom)
    run_fleet(make_fleet(), hints=False, sync_every=2, export=client)
    client.flush()
    for name, count in rtmod.DISPATCH_COUNTS.items():
        prom.set_counter("repro_dispatch_total", count, kind=name)
    text = prom.render()
    print("\nPrometheus exposition (first 12 lines):")
    for line in text.splitlines()[:12]:
        print(" ", line)
    client.close()

    # --- 3. dead sink: breaker -> noop, run unharmed ---------------------
    baseline = run_fleet(make_fleet(), hints=False, sync_every=2)
    dead = ExportClient(
        MemorySink(fail_always=True), batch_size=1,
        breaker=CircuitBreaker(failure_threshold=1, cooldown_s=0.0),
        degrade_after_trips=2)
    broken = run_fleet(make_fleet(), hints=False, sync_every=2, export=dead)
    dead.flush()
    st = dead.stats()
    identical = (json.dumps(baseline["trajectory"], sort_keys=True)
                 == json.dumps(broken["trajectory"], sort_keys=True))
    print(f"\ndead sink: breaker_trips={st['breaker_trips']} "
          f"degraded={st['degraded']} exported={st['exported']} "
          f"run_bit_identical={identical}")
    dead.close()
    assert identical, "export must never change the run"


if __name__ == "__main__":
    main()
