"""DLRM embedding-bag inference with a tiered table — the paper's §III.B
evaluation as a *running JAX model* (scaled down from 20.48 GB to ~64 MB so
it executes on CPU; the full-scale trace-driven numbers are in
``python -m benchmarks.run --only table1_dlrm``).

Flow (paper Fig. 2): allocate table in the slow tier -> profile batches with
the HMU-instrumented embedding-bag -> promote top-K blocks -> measure the
per-tier access mix and model the speedup.

    PYTHONPATH=src python examples/dlrm_tiering.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import TieredStore, CXL_SYSTEM
from repro.core import policy as policy_lib
from repro.dlrm.datagen import DLRMTraceSpec, ZipfPageSampler
from repro.kernels.embedding_bag import embedding_bag

# ---- scaled table: 256k rows x 64 dims (fp32) = 64 MB, block = 16 rows
N_ROWS, DIM, BLOCK_ROWS = 262_144, 64, 16
N_BLOCKS = N_ROWS // BLOCK_ROWS
FAST_FRACTION = 0.09                       # the paper's 9% top-tier footprint
BATCH, BAG = 256, 16

rng = np.random.default_rng(0)
table = jnp.asarray(rng.normal(size=(N_ROWS, DIM)) * 0.05, jnp.float32)
store = TieredStore.create(table, block_rows=BLOCK_ROWS,
                           n_slots=int(N_BLOCKS * FAST_FRACTION))

spec = DLRMTraceSpec(n_params=N_ROWS * DIM, emb_dim=DIM, alpha=1.31,
                     lookups_per_batch=BATCH * BAG, page_bytes=BLOCK_ROWS * DIM * 4)
sampler = ZipfPageSampler(spec, seed=1)


def batch_indices():
    pages = sampler.sample(BATCH * BAG)
    rows = pages * BLOCK_ROWS + rng.integers(0, BLOCK_ROWS, BATCH * BAG)
    return jnp.asarray(rows.reshape(BATCH, BAG), jnp.int32)


# ---- profiling phase: HMU counters ride along the embedding-bag kernel
counts = jnp.zeros((N_BLOCKS,), jnp.int32)
bag = jax.jit(lambda st, idx, c: embedding_bag(st, idx, c,
                                               block_rows=BLOCK_ROWS))
t0 = time.time()
for _ in range(20):
    idx = batch_indices()
    pooled, counts = bag(store.storage[store.fast_rows:], idx, counts)
print(f"profiled 20 batches in {time.time()-t0:.1f}s; "
      f"HMU saw {int(np.asarray(counts).sum())} accesses "
      f"across {int((np.asarray(counts) > 0).sum())} blocks")

# ---- promote the top-K hot blocks (oracle methodology)
plan = policy_lib.oracle_top_k(counts, k=store.n_slots)
store = store.promote(plan.promote)
print(f"promoted {int(store.fast_occupancy())} blocks "
      f"({FAST_FRACTION:.0%} of table) to the fast tier")

# ---- measurement: tier-aware gather + modeled time per batch
eval_counts = np.zeros(N_BLOCKS, np.int64)
for _ in range(5):
    idx = batch_indices()
    rows_flat = idx.reshape(-1)
    pooled = store.gather(rows_flat)             # tier-transparent data plane
    np.testing.assert_allclose(np.asarray(pooled),
                               np.asarray(table)[np.asarray(rows_flat)])
    np.add.at(eval_counts, np.asarray(rows_flat) // BLOCK_ROWS, 1)

fast_mask = np.asarray(store.block_to_slot) >= 0
n_fast = float(eval_counts[fast_mask].sum())
n_slow = float(eval_counts.sum() - n_fast)
bpa = DIM * 4
t_tier = CXL_SYSTEM.access_time_s(n_fast, n_slow, bpa)
t_fast = CXL_SYSTEM.access_time_s(n_fast + n_slow, 0, bpa)
t_slow = CXL_SYSTEM.access_time_s(0, n_fast + n_slow, bpa)
print(f"\nfast-tier hit rate: {n_fast/(n_fast+n_slow):.1%}")
print(f"modeled lookup time/eval: tiered={t_tier*1e6:.0f}us "
      f"dram-only={t_fast*1e6:.0f}us cxl-only={t_slow*1e6:.0f}us")
print(f"=> tiered within {t_tier/t_fast:.2f}x of DRAM-only at "
      f"{FAST_FRACTION:.0%} footprint (paper: 1.03x at 9%)")

# ---- online multi-epoch runtime (paper §VI): the hot set rotates mid-run.
# One fused jit dispatch observes each epoch; every policy lane migrates per
# epoch; proactive/EWMA re-converges after the shift while NB's cumulative
# two-touch signal keeps serving the stale hot set.
from repro.core.runtime import EpochRuntime                     # noqa: E402
from repro.dlrm.datagen import phase_shift_epochs               # noqa: E402

N_EPOCHS, BATCHES_PER_EPOCH, SHIFT_AT = 8, 4, 4
LANES = ("hmu_oracle", "proactive_ewma", "nb_two_touch")
rt = EpochRuntime(
    N_BLOCKS, k_hot=store.n_slots, policies=LANES, system=CXL_SYSTEM,
    bytes_per_access=DIM * 4, block_bytes=BLOCK_ROWS * DIM * 4,
    nb_scan_rate=N_BLOCKS // BATCHES_PER_EPOCH,
)
print(f"\nonline epoch runtime: {N_EPOCHS} epochs, hot-set rotation at "
      f"epoch {SHIFT_AT}")
print("epoch | " + " | ".join(f"{n:>20s}" for n in LANES) + "   (time us / acc)")
for e, epoch in enumerate(phase_shift_epochs(
        spec, n_epochs=N_EPOCHS, batches_per_epoch=BATCHES_PER_EPOCH,
        shift_at=SHIFT_AT, seed=2)):
    recs = rt.step(epoch)
    mark = "<- shift" if e == SHIFT_AT else ""
    print(f"  {e:3d} | " + " | ".join(
        f"{recs[n].time_s*1e6:12.0f} /{recs[n].accuracy:5.2f}"
        for n in LANES) + f"   {mark}")
traj = rt.trajectory()
pro, nb = traj.times("proactive_ewma"), traj.times("nb_two_touch")
print(f"=> post-shift: proactive/EWMA {np.mean(nb[SHIFT_AT:]/pro[SHIFT_AT:]):.1f}x "
      f"faster than Linux-NB in every epoch "
      f"({'yes' if (pro[SHIFT_AT:] < nb[SHIFT_AT:]).all() else 'NO'})")
