"""Runtime self-observability: trace the epoch loop, render the timeline.

The fused runtime claims its `sync_every=K` record sync is *pipelined* —
the host keeps dispatching new epochs while a previous window's records
are still being pulled off the device. `repro.obs` makes that claim
visible instead of argued: span-trace a run, write a Chrome trace, and
open it in chrome://tracing or https://ui.perfetto.dev to watch the
`record_sync` span overlap the next epoch's `observe_all` on the
synthesized device track. This walkthrough:

* runs the same workload obs-off and obs-on (tracing + metrics registry
  + runtime_span/runtime_metric export) and checks nothing changed —
  dispatch counts equal, records bit-identical,
* prints the span accounting (exactly one observe_all + one epoch_step
  per epoch, ceil(n_epochs/K) record_syncs),
* writes the Chrome trace artifact and asserts the pipelining is
  structurally visible in it,
* renders the metrics registry as Prometheus text exposition.

    PYTHONPATH=src python examples/runtime_timeline.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import runtime as rtmod
from repro.core.runtime import EpochRuntime
from repro.export import ExportClient, MemorySink, PrometheusTextSink
from repro.obs import chrometrace
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

N_BLOCKS, K_HOT, N_EPOCHS, SYNC_EVERY = 2_000, 200, 6, 3
POLICIES = ("hmu_oracle", "hinted", "nb_two_touch")


def run(eps, export=None):
    rt = EpochRuntime(N_BLOCKS, K_HOT, policies=POLICIES, pebs_period=16,
                      nb_scan_rate=N_BLOCKS // 4, fused=True,
                      sync_every=SYNC_EVERY, export=export)
    with rtmod.counting() as c:
        rt.run(iter(eps))
        return rt, dict(c.dispatch)


def main():
    rng = np.random.default_rng(31)
    eps = [(rng.zipf(1.3, size=(2, 8_000)) % N_BLOCKS).astype(np.int32)
           for _ in range(N_EPOCHS)]

    # --- 1. obs off: the baseline the watcher must not perturb -----------
    run(eps)                                       # warm the jit caches
    off_rt, off_disp = run(eps)

    # --- 2. obs on: tracing + registry mirror + export --------------------
    registry = obs_metrics.MetricsRegistry()
    sink = MemorySink()
    client = ExportClient(sink)
    with obs_trace.tracing(metrics=registry) as tracer:
        on_rt, on_disp = run(eps, export=client)
    for span in tracer.spans:
        client.export_runtime_span(span)
    client.export_metrics(registry)
    client.flush()
    stats = client.stats()
    client.close()

    identical = all(
        [a.to_dict() for a in off_rt.records[lane]]
        == [b.to_dict() for b in on_rt.records[lane]]
        for lane in POLICIES)
    print(f"non-interference: dispatches_equal={on_disp == off_disp} "
          f"records_bit_identical={identical} "
          f"({(on_disp['observe_all'] + on_disp['epoch_step']) // N_EPOCHS}"
          f" dispatches/epoch)")
    assert on_disp == off_disp and identical, "observability changed the run"

    by_name = {}
    for s in tracer.spans:
        by_name[s.name] = by_name.get(s.name, 0) + 1
    print("span accounting:", dict(sorted(by_name.items())))
    print(f"exported {stats['exported']} records "
          f"({sum(1 for r in sink.snapshot() if r['record_type'] == 'runtime_span')}"
          f" runtime_span, "
          f"{sum(1 for r in sink.snapshot() if r['record_type'] == 'runtime_metric')}"
          f" runtime_metric)")

    # --- 3. the timeline ---------------------------------------------------
    trace_path = Path(tempfile.mkdtemp(prefix="repro_obs_")) / "trace.json"
    doc = chrometrace.write_chrome_trace(
        trace_path, tracer.spans,
        metadata={"example": "runtime_timeline", "sync_every": SYNC_EVERY})
    visible = chrometrace.pipelining_visible(tracer.spans)
    device_spans = [e for e in doc["traceEvents"] if e["tid"] == "device"]
    print(f"\nchrome trace -> {trace_path}")
    print(f"  {len(doc['traceEvents'])} events, device windows: "
          f"{[e['name'] for e in device_spans]}")
    print(f"  pipelining visible (sync_every={SYNC_EVERY}): {visible}")
    assert visible, "sync_every>1 must make record_sync overlap dispatch"
    print("  open in chrome://tracing or https://ui.perfetto.dev")

    # --- 4. the registry as a Prometheus scrape ---------------------------
    prom = PrometheusTextSink()
    registry.publish(prom)
    print("\nPrometheus exposition (span-duration histogram excerpt):")
    lines = prom.render().splitlines()
    wanted = [ln for ln in lines if "repro_span_duration_s" in ln]
    for line in wanted[:10]:
        print(" ", line)


if __name__ == "__main__":
    main()
