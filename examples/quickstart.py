"""Quickstart: memory-side tiering telemetry in 60 lines.

Builds a two-tier store, runs a skewed workload through the three telemetry
emulators (HMU / PEBS / NUMA-balancing), promotes with each one's hot list,
and prints the resulting accuracy / coverage / modeled speed — the paper's
core experiment at toy scale.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np
import jax.numpy as jnp

from repro.core import TieredStore, TieringManager, CXL_SYSTEM

# ---- a table with a hot head: 4096 blocks, the first 400 are 90% of traffic
N_BLOCKS, K_HOT = 4096, 400
rng = np.random.default_rng(0)

mgr = TieringManager(n_blocks=N_BLOCKS, k_hot=K_HOT,
                     pebs_period=997, nb_scan_rate=N_BLOCKS // 4)
for _ in range(32):
    hot = rng.integers(0, K_HOT, 18_000)          # 90% of accesses
    cold = rng.integers(K_HOT, N_BLOCKS, 2_000)   # 10%
    mgr.observe(np.concatenate([hot, cold]))

results = mgr.evaluate(CXL_SYSTEM, bytes_per_access=256.0)
print(f"{'strategy':<10s}{'accuracy':>9s}{'coverage':>9s}"
      f"{'host ev.':>10s}{'time':>10s}")
for name in ("hmu", "pebs", "nb", "dram-only", "cxl-only"):
    r = results[name.replace("cxl-only", "slow-only")]
    print(f"{name:<10s}{r.accuracy:>9.2f}{r.coverage:>9.2f}"
          f"{r.host_events:>10d}{r.time_s*1e6:>9.0f}us")

# ---- and the actual data plane: a TieredStore gather is tier-transparent
data = jnp.arange(N_BLOCKS * 4 * 8, dtype=jnp.float32).reshape(N_BLOCKS * 4, 8)
store = TieredStore.create(data, block_rows=4, n_slots=K_HOT)
store = store.promote(jnp.asarray(results["hmu"].promoted[:K_HOT]))
rows = jnp.asarray(rng.integers(0, N_BLOCKS * 4, 64))
assert bool(jnp.all(store.gather(rows) == data[rows]))
print(f"\nTieredStore: {int(store.fast_occupancy())}/{K_HOT} fast slots "
      "filled; reads identical before/after promotion ✓")
