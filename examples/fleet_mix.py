"""Multi-tenant fleet: four workloads, one fast tier, three capacity policies.

The paper's HMU case is ultimately a datacenter case: device-level telemetry
matters most when many workloads contend for one bounded fast tier (the TPP /
Telescope regime).  This walkthrough co-locates four tenants in one
`repro.fleet.FleetScenario`:

* **dlrm**    — the §III.B embedding-page trace (the tenant worth protecting),
* **kv**      — a tiered LLM KV cache fed by decode-time attention mass,
* **moe**     — MoE expert banks placed from router counters,
* **scanner** — mmap-bench (§III.A) cranked into a noisy neighbour: a wide,
  internally-uniform region scanned at high volume, whose loud counters
  out-rank everyone else's hot sets.

and runs the six-lane EpochRuntime over the interleaved mix twice:

* ``capacity="shared"``   — one pool, no quotas: the scanner's counters crowd
  the DLRM hot set out of every lane's top-k selection and its coverage
  craters.
* ``capacity="weighted"`` — weighted-fair quotas sized so the DLRM quota
  covers its solo hot set: every lane's selection is segment-capped per
  tenant on device, and DLRM holds within a few points of its solo run while
  the scanner is pinned to its slice.

    PYTHONPATH=src python examples/fleet_mix.py
"""
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.dlrm import datagen
from repro.fleet import FleetScenario, TenantSpec, run_fleet
from repro.scenarios import (DLRMScenario, KVCacheScenario, MmapBenchScenario,
                             MoEExpertScenario)
from repro.workloads import mmap_bench

N_EPOCHS, LANE = 6, "hmu_oracle"
K_HOT = 340                           # < combined demand: contention is real

# one scenario instance per tenant, shared by every fleet below, so the
# model-backed streams (kv decode, moe routing) generate once and replay
dlrm = DLRMScenario(
    spec=dataclasses.replace(datagen.SMALL, lookups_per_batch=30_000),
    n_epochs=N_EPOCHS, batches_per_epoch=2, shift_at=0)       # stationary
kv = KVCacheScenario(batch=2, n_epochs=N_EPOCHS, batches_per_epoch=2,
                     accesses_per_batch=2_048)
moe = MoEExpertScenario(n_epochs=N_EPOCHS, batches_per_epoch=2, batch=2,
                        shift_at=3)
scanner = MmapBenchScenario(
    spec=mmap_bench.MmapBenchSpec(total_bytes=640 * 4096,
                                  hot_bytes=512 * 4096),
    n_epochs=N_EPOCHS, batches_per_epoch=2, accesses_per_batch=60_000)


def tenants():
    # weights are the operator's SLO knob: demand-sized for the protected
    # tenants, deliberately small for the scanner
    return [
        TenantSpec(dlrm, weight=250.0, name="dlrm"),
        TenantSpec(kv, weight=float(kv.k_hot), name="kv"),
        TenantSpec(moe, weight=float(moe.k_hot), name="moe"),
        TenantSpec(scanner, weight=60.0, name="scanner"),
    ]


runs = {}
for capacity in ("shared", "weighted"):
    fleet = FleetScenario(tenants(), k_hot=K_HOT, capacity=capacity)
    runs[capacity] = run_fleet(fleet, hints=True,
                               solo=(capacity == "weighted"))
solo = runs["weighted"]["solo"]

fleet_blocks = sum(t.scenario.n_blocks for t in tenants())
print(f"fleet: {fleet_blocks} blocks across 4 tenants, k_hot={K_HOT} shared "
      f"slots, {N_EPOCHS} interleaved epochs; '{LANE}' lane shown\n")
print(f"{'tenant':>8s} {'solo cov':>9s} | {'shared cov':>10s} "
      f"{'weighted cov':>12s} {'quota':>6s}")
for name in ("dlrm", "kv", "moe", "scanner"):
    s = solo[name]["summary"][LANE]["final_coverage"]
    sh = runs["shared"]["tenants"][name]["lanes"][LANE]["final_coverage"]
    wf = runs["weighted"]["tenants"][name]["lanes"][LANE]["final_coverage"]
    cap = runs["weighted"]["tenants"][name]["cap"]
    print(f"{name:>8s} {s:>9.2f} | {sh:>10.2f} {wf:>12.2f} {cap:>6d}")

solo_cov = solo["dlrm"]["summary"][LANE]["final_coverage"]
shared_cov = runs["shared"]["tenants"]["dlrm"]["lanes"][LANE][
    "final_coverage"]
fair_cov = runs["weighted"]["tenants"]["dlrm"]["lanes"][LANE][
    "final_coverage"]
assert runs["weighted"]["tenants"]["dlrm"]["cap"] >= dlrm.k_hot
assert shared_cov < solo_cov - 0.3    # the scanner craters the shared pool
assert fair_cov > solo_cov - 0.05     # weighted-fair holds DLRM near solo

print(f"\nshared pool: the scanner's {scanner.spec.k_hot}-page arena at "
      f"{scanner.accesses_per_batch * scanner.batches_per_epoch} "
      f"accesses/epoch out-counts the DLRM hot head — DLRM coverage "
      f"{solo_cov:.2f} (solo) -> {shared_cov:.2f} (shared) ✗")
print(f"weighted-fair: DLRM quota "
      f"{runs['weighted']['tenants']['dlrm']['cap']} >= its solo hot set "
      f"({dlrm.k_hot}); segment-capped selection keeps its blocks in every "
      f"lane's top-k — coverage {fair_cov:.2f}, within "
      f"{abs(solo_cov - fair_cov):.2f} of solo ✓")

# the runtime invariants survive multi-tenancy: same epoch loop, same
# 2-dispatch fused step, per-tenant accounting rides the existing sync
mean_t = {name: runs["weighted"]["tenants"][name]["lanes"][LANE][
    "mean_time_us"] for name in ("dlrm", "kv", "moe", "scanner")}
print("\nper-tenant mean epoch time (weighted, native byte geometry): "
      + "  ".join(f"{n}={t:.0f}us" for n, t in mean_t.items()))
