"""Hinted vs lookahead-prefetch tiering on a phase-shifting DLRM trace.

The paper's §VI triad is reactive placement, proactive movement, and
*compiler hints*.  This walkthrough runs the online EpochRuntime with the
`repro.hints` pipeline attached and compares the two hint-fed lanes:

* ``hinted``   — PEBS telemetry blended with *static* hints from the
  embedding-table structure (Zipf prior + the compiler's rank->page layout).
  Exact before the hot set rotates; stale after — the EWMA phase-change
  detector then down-weights it.
* ``prefetch`` — *lookahead* hints: the dataloader's queued next-epoch
  batches, promoted before the accesses land.  Covers the rotation in the
  very epoch it happens, and its migration streams under the access stream
  (overlap-aware accounting, `MemSystem.overlapped_epoch_time_s`).

    PYTHONPATH=src python examples/hinted_prefetch.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.dlrm import datagen, tracesim

SPEC = datagen.SMALL
N_EPOCHS, SHIFT_AT = 8, 4

# ---- one trajectory, hints on: static + lookahead + phase detector
out = tracesim.run_online(spec=SPEC, n_epochs=N_EPOCHS, shift_at=SHIFT_AT,
                          hints=True, seed=0)
lanes = out["trajectory"]["lanes"]

print(f"phase-shift trace: {SPEC.n_pages} pages, hot set rotates at epoch "
      f"{SHIFT_AT}\n")
print(f"{'epoch':>5s} {'hinted cov':>11s} {'prefetch cov':>13s} "
      f"{'prefetch hidden':>16s}")
for e in range(N_EPOCHS):
    h, p = lanes["hinted"][e], lanes["prefetch"][e]
    marker = "  <- shift" if e == SHIFT_AT else ""
    print(f"{e:>5d} {h['coverage']:>11.2f} {p['coverage']:>13.2f} "
          f"{p['hidden_s']*1e6:>14.1f}us{marker}")

s = out["summary"]
print(f"\npost-shift mean coverage: hinted "
      f"{s['hinted']['post_shift_mean_coverage']:.2f} vs prefetch "
      f"{s['prefetch']['post_shift_mean_coverage']:.2f} "
      f"(lookahead sees the rotation in the epoch it happens; the static "
      f"table prior goes stale)")

# ---- overlap-aware migration accounting: same lane, overlap on vs off
times = {}
for overlap in (1.0, 0.0):
    r = tracesim.run_online(spec=SPEC, n_epochs=N_EPOCHS, shift_at=SHIFT_AT,
                            hints=True, prefetch_overlap=overlap, seed=0)
    times[overlap] = np.array(
        [rec["time_s"] for rec in r["trajectory"]["lanes"]["prefetch"]])
assert (times[1.0] <= times[0.0]).all()
saved = (times[0.0] - times[1.0]).sum()
print(f"\noverlapped migration saves {saved*1e6:.0f}us over the trajectory "
      f"({(times[0.0].sum() / times[1.0].sum() - 1) * 100:.1f}% of epoch "
      f"time vs stop-the-world migration) ✓")
