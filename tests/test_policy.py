"""Unit tests for the §VI policies that had no dedicated coverage:
reactive_watermark (capacity clamping), proactive_ewma (rotating hot-set
prediction), hinted (rank-blend monotonicity), coldest_victims (empty-slot
handling)."""
import numpy as np
import jax.numpy as jnp

from repro.core import policy


def ids_of(plan) -> list:
    a = np.asarray(plan.promote).reshape(-1)
    return [int(x) for x in a if x >= 0]


# ---------------------------------------------------------- reactive_watermark
def test_reactive_clamps_to_free_slots():
    counts = jnp.asarray([50, 40, 30, 20, 10, 0])
    plan = policy.reactive_watermark(counts, hot_threshold=5,
                                     free_slots=jnp.asarray(3), max_moves=6)
    assert ids_of(plan) == [0, 1, 2]      # 5 candidates, only 3 slots


def test_reactive_zero_free_slots_promotes_nothing():
    counts = jnp.asarray([50, 40, 30])
    plan = policy.reactive_watermark(counts, hot_threshold=1,
                                     free_slots=jnp.asarray(0), max_moves=3)
    assert ids_of(plan) == []


def test_reactive_threshold_gates_promotion():
    counts = jnp.asarray([50, 9, 30, 2])
    plan = policy.reactive_watermark(counts, hot_threshold=10,
                                     free_slots=jnp.asarray(4), max_moves=4)
    assert set(ids_of(plan)) == {0, 2}    # 9 and 2 are below the watermark


def test_reactive_free_slots_beyond_candidates_is_safe():
    counts = jnp.asarray([7, 0, 0, 0])
    plan = policy.reactive_watermark(counts, hot_threshold=5,
                                     free_slots=jnp.asarray(100), max_moves=4)
    assert ids_of(plan) == [0]


# ------------------------------------------------------------- proactive_ewma
def test_proactive_predicts_rotating_hot_set_before_retouch():
    """Hot set alternates A={0,1} / B={2,3} per epoch.  After an A epoch,
    EWMA memory still ranks B above never-touched blocks — B is promoted
    *before* it is re-touched (the §VI 'proactive movement' claim)."""
    n, k = 6, 4
    a = jnp.asarray([100.0, 90.0, 0.0, 0.0, 0.0, 0.0])
    b = jnp.asarray([0.0, 0.0, 100.0, 90.0, 0.0, 0.0])
    pred = jnp.zeros(n)
    for counts in (a, b, a, b, a):        # last observation is phase A
        pred, plan = policy.proactive_ewma(pred, counts, k=k, alpha=0.5)
    got = ids_of(plan)
    assert set(got) == {0, 1, 2, 3}       # B predicted hot though untouched now
    assert 4 not in got and 5 not in got


def test_proactive_alpha_one_is_memoryless():
    pred, plan = policy.proactive_ewma(
        jnp.asarray([1000.0, 0.0, 0.0]), jnp.asarray([0.0, 5.0, 1.0]),
        k=1, alpha=1.0)
    assert ids_of(plan) == [1]            # history fully discounted


def test_proactive_never_promotes_zero_prediction():
    pred, plan = policy.proactive_ewma(
        jnp.zeros(4), jnp.asarray([0.0, 0.0, 3.0, 0.0]), k=4, alpha=0.5)
    assert ids_of(plan) == [2]


# -------------------------------------------------------------------- hinted
def test_hinted_rank_blend_monotone_in_hint():
    """Raising one block's hint (all else equal) never lowers its position
    in the promotion order."""
    counts = jnp.asarray([10, 20, 30, 40])
    n = counts.shape[0]

    def position(hint_val: float, block: int = 0) -> int:
        hints = jnp.zeros((n,)).at[block].set(hint_val)
        order = ids_of(policy.hinted(counts, hints, k=n, hint_weight=0.5))
        return order.index(block)

    positions = [position(h) for h in (0.0, 0.25, 0.5, 0.75, 1.0)]
    assert positions == sorted(positions, reverse=True)  # strictly no demotion
    assert positions[-1] <= positions[0]


def test_hinted_zero_weight_is_pure_telemetry_order():
    counts = jnp.asarray([1, 4, 3, 2])
    hints = jnp.asarray([1.0, 0.0, 0.0, 0.0])
    plan = policy.hinted(counts, hints, k=4, hint_weight=0.0)
    assert ids_of(plan) == [1, 2, 3, 0]


def test_hinted_full_weight_is_pure_hint_order():
    counts = jnp.asarray([1000, 0, 0, 0])
    hints = jnp.asarray([0.0, 0.3, 1.0, 0.6])
    plan = policy.hinted(counts, hints, k=2, hint_weight=1.0)
    assert ids_of(plan) == [2, 3]


# ----------------------------------------------------------- coldest_victims
def test_coldest_victims_skips_empty_slots():
    est = jnp.asarray([5, 50, 7, 90])
    s2b = jnp.asarray([1, -1, 3, -1, 0])   # two empty slots interleaved
    vic = np.asarray(policy.coldest_victims(est, s2b, n=2))
    # resident blocks are {1, 3, 0}; the coldest two are 0 (est 5), 1 (est 50)
    assert [int(x) for x in vic] == [0, 1]


def test_coldest_victims_all_empty_returns_padding():
    est = jnp.asarray([5, 50])
    s2b = jnp.asarray([-1, -1, -1])
    vic = np.asarray(policy.coldest_victims(est, s2b, n=2))
    assert (vic == -1).all()


def test_coldest_victims_n_exceeds_occupancy_pads_with_minus_one():
    est = jnp.asarray([5, 50, 7])
    s2b = jnp.asarray([2, -1, -1, -1])
    vic = np.asarray(policy.coldest_victims(est, s2b, n=3))
    assert int(vic[0]) == 2
    assert (vic[1:] == -1).all()


# ------------------------------------------------------------------ prefetch
def test_prefetch_promotes_window_blocks_heaviest_first():
    rank = jnp.asarray([0.0, 0.5, 1.0, 0.0, 0.25])
    plan = policy.prefetch(rank, k=5)
    assert ids_of(plan) == [2, 1, 4]      # rank order; rank-0 never promoted


def test_prefetch_empty_window_is_noop():
    plan = policy.prefetch(jnp.zeros((6,)), k=4)
    assert ids_of(plan) == []


def test_prefetch_k_caps_promotion():
    rank = jnp.asarray([0.9, 0.8, 0.7, 0.6])
    plan = policy.prefetch(rank, k=2)
    assert ids_of(plan) == [0, 1]
