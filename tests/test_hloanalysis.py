"""Unit tests for the trip-count-aware HLO analyzer (the §Perf profiler)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hloanalysis as H


def compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_counts_multiply_dot_flops():
    """A scanned matmul must be charged trips x per-iteration flops —
    XLA cost_analysis counts it once; our analyzer must not."""
    n, trips = 128, 12
    w = jnp.ones((n, n), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=trips)
        return y

    x = jnp.ones((n, n), jnp.float32)
    res = H.analyze(compiled_text(f, x))
    expected = 2.0 * n * n * n * trips
    assert res["flops"] == pytest.approx(expected, rel=0.05), \
        (res["flops"], expected)


def test_unlooped_dot_counted_once():
    n = 256
    f = lambda a, b: a @ b
    a = jnp.ones((n, n), jnp.float32)
    res = H.analyze(compiled_text(f, a, a))
    assert res["flops"] == pytest.approx(2.0 * n ** 3, rel=0.05)


def test_dus_charged_as_update_not_buffer():
    """In-place dynamic-update-slice: bytes ~ update size, not buffer size."""
    big = jnp.zeros((4096, 1024), jnp.float32)      # 16 MB
    upd = jnp.ones((1, 1024), jnp.float32)          # 4 KB

    def f(buf, u):
        def body(c, i):
            return jax.lax.dynamic_update_slice(c, u, (i, 0)), None
        out, _ = jax.lax.scan(body, buf, jnp.arange(64))
        return out

    res = H.analyze(compiled_text(f, big, upd))
    # 64 updates x ~8KB (read+write) plus epsilon — far below one buffer copy
    assert res["hbm_bytes"] < big.size * 4 * 0.5, res["hbm_bytes"]


def test_gather_charged_as_slice():
    table = jnp.zeros((100_000, 64), jnp.float32)   # 25.6 MB
    idx = jnp.arange(16, dtype=jnp.int32)

    def f(t, i):
        return jnp.take(t, i, axis=0).sum()

    res = H.analyze(compiled_text(f, table, idx))
    assert res["hbm_bytes"] < 1e6, res["hbm_bytes"]  # reads 16 rows, not 25MB


def test_shape_parsing():
    assert H._tuple_bytes("bf16[256,4096]{1,0}") == 256 * 4096 * 2
    assert H._tuple_bytes("(f32[8,8], s32[4])") == 8 * 8 * 4 + 4 * 4
    assert H._tuple_bytes("pred[]") == 1
