"""Kernel dispatch policy + fused-runtime integration of the Pallas
telemetry kernels (hist_select / observe_scatter).

The runtime promise: ``use_pallas=True`` changes the *implementation* of
the selection and observe scatters, never a bit of the results, and the
epoch loop still costs exactly 2 dispatches and one trace."""
import numpy as np
import jax
import pytest

from repro.core import runtime as rt
from repro.faults.model import FaultModel
from repro.kernels.dispatch import PallasBackend, resolve_backend


def test_resolve_backend_policy_off_tpu():
    # this suite runs on CPU: default resolves to the XLA path, an explicit
    # opt-in resolves to the interpreter unless interpret=False is forced
    assert jax.default_backend() != "tpu"
    assert resolve_backend() is None
    assert resolve_backend(False) is None
    b = resolve_backend(True)
    assert isinstance(b, PallasBackend) and b.interpret
    assert resolve_backend(True, False) == PallasBackend(interpret=False)
    assert resolve_backend(True, select_tile_n=256).select_tile_n == 256


def test_runtime_rejects_pallas_with_mesh_or_reference_path():
    with pytest.raises(ValueError, match="mesh"):
        rt.EpochRuntime(64, 8, use_pallas=True, mesh=object())
    with pytest.raises(ValueError, match="fused"):
        rt.EpochRuntime(64, 8, use_pallas=True, fused=False)
    # quiet default: no kernels off-TPU, no error
    assert rt.EpochRuntime(64, 8)._pallas is None


def _run(n, k, eps, use_pallas, **kw):
    run = rt.EpochRuntime(n, k, policies=("hmu_oracle", "hinted",
                                          "nb_two_touch"),
                          pebs_period=7, nb_scan_rate=n // 4, fused=True,
                          use_pallas=use_pallas, **kw)
    with rt.counting() as c:
        for e in eps:
            run.step(e)
        disp = c.dispatch["observe_all"] + c.dispatch["epoch_step"]
        traces = c.trace["epoch_step"]
    return run, disp / len(eps), traces


@pytest.mark.parametrize("variant", ["plain", "quotas", "faults"])
def test_fused_runtime_pallas_bit_identical_two_dispatches(variant):
    rng = np.random.default_rng(11)
    n, k, n_epochs = 256, 32, 3
    eps = [(rng.zipf(1.3, size=(2, 1024)) % n).astype(np.int32)
           for _ in range(n_epochs)]
    kw = {}
    if variant == "quotas":
        kw["tenancy"] = rt.Tenancy(offsets=(0, 100, n), hot_k=(8, 8),
                                   caps=(8, 16))
    elif variant == "faults":
        kw["faults"] = FaultModel.create(hmu_counter_bits=9,
                                         pebs_drop_p=0.25, nb_stall_p=0.2,
                                         seed=11, n_blocks=n)
    off, _, _ = _run(n, k, eps, use_pallas=False, **kw)
    on, disp, traces = _run(n, k, eps, use_pallas=True, **kw)
    assert on._pallas is not None and on._pallas.interpret
    assert disp == 2 and traces <= 1
    for lane in off.records:
        assert [a.to_dict() for a in off.records[lane]] \
            == [b.to_dict() for b in on.records[lane]], lane
        np.testing.assert_array_equal(
            np.asarray(off.lanes[lane].slot_to_block),
            np.asarray(on.lanes[lane].slot_to_block))
    if variant == "quotas":
        for ra, rb in zip(off.tenant_records, on.tenant_records):
            for key in ra:
                np.testing.assert_array_equal(ra[key], rb[key], err_msg=key)
