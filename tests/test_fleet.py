"""Fleet-layer tests: many workloads co-located in one runtime.

The tentpole invariants must survive multi-tenancy — fused-vs-reference
bit-identity and exactly 2 jit dispatches/epoch for a >=3-tenant mix with
hints AND quotas — plus the fleet's own plumbing: global<->local id
round-trips, per-tenant accounting conservation against the global record,
deterministic stream interleaving, quota isolation, and the mmap-bench
scenario satellite."""
import dataclasses
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import runtime as rtmod
from repro.core.runtime import ALL_POLICIES, EpochRuntime, Tenancy
from repro.dlrm import datagen
from repro.fleet import (FleetScenario, TenantSpec, fair_quotas, make_tenancy,
                         run_fleet, tenant_trajectories)
from repro.scenarios import (DLRMScenario, MmapBenchScenario, build_hints,
                             run_scenario)
from repro.workloads import mmap_bench

REPO = Path(__file__).resolve().parent.parent
SUBPROC_ENV = dict(os.environ, PYTHONPATH=str(REPO / "src"),
                   XLA_FLAGS="--xla_force_host_platform_device_count=8",
                   JAX_PLATFORMS="cpu")

SMALL_SPEC = dataclasses.replace(datagen.SMALL, lookups_per_batch=8_000)


def small_dlrm(**kw):
    kw.setdefault("spec", SMALL_SPEC)
    kw.setdefault("n_epochs", 4)
    kw.setdefault("batches_per_epoch", 2)
    kw.setdefault("shift_at", 2)
    return DLRMScenario(**kw)


def small_scanner(**kw):
    kw.setdefault("n_epochs", 4)
    kw.setdefault("batches_per_epoch", 2)
    kw.setdefault("accesses_per_batch", 8_000)
    return MmapBenchScenario(**kw)


def small_moe(**kw):
    from repro.scenarios import MoEExpertScenario

    kw.setdefault("n_epochs", 4)
    kw.setdefault("batches_per_epoch", 2)
    kw.setdefault("shift_at", 2)
    kw.setdefault("batch", 2)
    return MoEExpertScenario(**kw)


def small_fleet(capacity="weighted", k_hot=300, **kw):
    return FleetScenario(
        [TenantSpec(small_dlrm(), weight=10.0, name="dlrm"),
         TenantSpec(small_scanner(), weight=1.0, name="scanner"),
         TenantSpec(small_moe(), weight=1.0, name="moe")],
        k_hot=k_hot, capacity=capacity, **kw)


# ----------------------------------------------------------- mmap satellite
def test_mmap_scenario_protocol_and_stream():
    sc = small_scanner()
    assert sc.n_blocks == sc.spec.n_pages
    assert sc.k_hot == sc.spec.k_hot
    eps1, eps2 = list(sc.epochs()), list(sc.epochs())
    assert len(eps1) == sc.n_epochs
    for a, b in zip(eps1, eps2):
        np.testing.assert_array_equal(a, b)          # deterministic per call
    for ep in eps1:
        assert ep.shape == (sc.batches_per_epoch, sc.accesses_per_batch)
        assert 0 <= ep.min() and ep.max() < sc.n_blocks
    # the 90/10 region split: hot pages dominate the stream
    hist = np.bincount(np.concatenate([e.ravel() for e in eps1]),
                       minlength=sc.n_blocks)
    hot_share = hist[: sc.spec.k_hot].sum() / hist.sum()
    assert 0.85 < hot_share < 0.95


def test_mmap_scenario_static_hints_mark_the_declared_arena():
    sc = small_scanner()
    layout = sc.hint_layout()
    assert layout.rank_to_page is not None
    pipe = build_hints(sc, clip_rank=sc.spec.k_hot)
    rank = pipe._static_rank
    assert (rank[: sc.spec.k_hot] == 1.0).all()      # flat within-arena prior
    assert (rank[sc.spec.k_hot:] == 0.0).all()


def test_mmap_scenario_runs_the_online_loop():
    """§III.A on the six-lane loop: the oracle lane converges onto the hot
    region, and both runtime invariants hold (bit-identity, 2 dispatches)."""
    sc = small_scanner()
    eps = list(sc.epochs())
    with rtmod.counting() as counts:
        fused = run_scenario(sc, hints=True, epochs=iter(eps))
        assert counts.dispatch["observe_all"] == sc.n_epochs
        assert counts.dispatch["epoch_step"] == sc.n_epochs
        assert counts.dispatch["reference"] == 0
    reference = run_scenario(sc, hints=True, fused=False, epochs=iter(eps))
    assert fused["trajectory"] == reference["trajectory"]
    assert fused["summary"]["hmu_oracle"]["final_coverage"] > 0.9


# ------------------------------------------------------------- id plumbing
@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=400), min_size=2,
                max_size=5),
       st.lists(st.integers(min_value=0, max_value=1 << 30), min_size=1,
                max_size=32))
def test_tenant_id_space_round_trip(sizes, raw_ids):
    """Property: global->local->global is the identity on every valid global
    id, the recovered tenant matches the owning range, and out-of-range ids
    raise."""
    scenarios = [small_scanner(
        spec=mmap_bench.MmapBenchSpec(total_bytes=s * 4096,
                                      hot_bytes=max(s // 2, 1) * 4096))
        for s in sizes]
    fleet = FleetScenario([TenantSpec(sc, name=f"t{i}")
                           for i, sc in enumerate(scenarios)])
    ids = np.asarray(raw_ids) % fleet.n_blocks
    tenant, local = fleet.to_local(ids)
    for g, t, l in zip(ids, tenant, local):
        assert fleet.offsets[t] <= g < fleet.offsets[t + 1]
        assert fleet.to_global(int(t), int(l))[()] == g
    with pytest.raises(ValueError):
        fleet.to_local(np.array([fleet.n_blocks]))
    with pytest.raises(ValueError):
        fleet.to_global(0, np.array([scenarios[0].n_blocks]))


def test_interleaver_is_deterministic_and_conserves_tenant_traffic():
    fleet = small_fleet()
    eps1 = [e.copy() for e in fleet.epochs()]
    eps2 = list(fleet.epochs())
    assert len(eps1) == fleet.n_epochs
    for a, b in zip(eps1, eps2):
        np.testing.assert_array_equal(a, b)
    # per-epoch per-tenant access counts survive the shuffle (up to the
    # deterministic sub-row tail drop)
    streams = [list(t.scenario.epochs()) for t in fleet.tenants]
    for e, ep in enumerate(eps1):
        assert ep.shape[0] == fleet.batches_per_epoch
        tenant, _ = fleet.to_local(ep.ravel())
        got = np.bincount(tenant, minlength=len(fleet.tenants))
        want = np.array([streams[i][e].size
                         for i in range(len(fleet.tenants))])
        dropped = want.sum() - got.sum()
        assert 0 <= dropped < fleet.batches_per_epoch
        assert (np.abs(got - want) <= dropped).all()


def test_fleet_rejects_bad_configs():
    with pytest.raises(ValueError, match="two tenants"):
        FleetScenario([TenantSpec(small_scanner())])
    with pytest.raises(ValueError, match="unique"):
        FleetScenario([TenantSpec(small_scanner()),
                       TenantSpec(small_scanner())])
    with pytest.raises(ValueError, match="min_quota"):
        FleetScenario([TenantSpec(small_scanner(), name="a"),
                       TenantSpec(small_scanner(seed=1), name="b")],
                      capacity="weighted", k_hot=1)
    with pytest.raises(ValueError, match="weight"):
        TenantSpec(small_scanner(), weight=0.0)


# ---------------------------------------------------------------- capacity
def test_fair_quotas_exact_sum_proportional_and_floored():
    q = fair_quotas([3.0, 1.0, 4.0], 800)
    assert q.sum() == 800
    np.testing.assert_allclose(q / 800, np.array([3, 1, 4]) / 8, atol=1 / 800)
    # min-quota floor: a tiny tenant still gets a slot
    q = fair_quotas([1000.0, 1.0, 1.0], 10)
    assert q.sum() == 10 and (q >= 1).all()
    with pytest.raises(ValueError):
        fair_quotas([1.0, -1.0], 10)
    with pytest.raises(ValueError):
        fair_quotas([1.0, 1.0, 1.0], 2)              # cannot floor 3 tenants


def test_make_tenancy_policies():
    offs, hot = (0, 100, 300), (10, 50)
    assert make_tenancy(offs, hot, 60, "shared").caps is None
    part = make_tenancy(offs, hot, 60, "partition")
    assert part.caps == (10, 50)                     # demand-proportional
    wgt = make_tenancy(offs, hot, 60, "weighted", weights=[1.0, 1.0])
    assert wgt.caps == (30, 30)
    with pytest.raises(ValueError, match="weights"):
        make_tenancy(offs, hot, 60, "weighted")
    with pytest.raises(ValueError, match="capacity"):
        make_tenancy(offs, hot, 60, "fair-ish")


def test_tenancy_validation():
    with pytest.raises(ValueError, match="offsets"):
        EpochRuntime(100, 10, policies=("hmu_oracle",),
                     tenancy=Tenancy(offsets=(0, 50, 90), hot_k=(5, 5)))
    with pytest.raises(ValueError, match="hot_k"):
        EpochRuntime(100, 10, policies=("hmu_oracle",),
                     tenancy=Tenancy(offsets=(0, 50, 100), hot_k=(5, 60)))
    with pytest.raises(ValueError, match="caps"):
        EpochRuntime(100, 10, policies=("hmu_oracle",),
                     tenancy=Tenancy(offsets=(0, 50, 100), hot_k=(5, 5),
                                     caps=(8, 8)))    # sum > k_hot


# ------------------------------------------- tentpole: both invariants
@pytest.mark.parametrize("capacity", ["shared", "weighted"])
def test_fleet_fused_bit_identical_to_reference(capacity):
    """ISSUE acceptance: a 3-tenant mix (DLRM + scanner + MoE) with hints
    AND quotas is fused-vs-reference bit-identical — every EpochRecord field
    of every lane and epoch, every per-tenant raw counter row, and the
    derived tenant summaries."""
    fleet = small_fleet(capacity=capacity)
    eps = [e.copy() for e in fleet.epochs()]
    fused = run_fleet(fleet, hints=True, epochs=iter(eps))
    reference = run_fleet(fleet, hints=True, fused=False, epochs=iter(eps))
    assert set(fused["trajectory"]["lanes"]) == set(ALL_POLICIES)
    assert fused["trajectory"] == reference["trajectory"]
    assert fused["summary"] == reference["summary"]
    assert fused["tenants"] == reference["tenants"]


def test_fleet_epoch_is_two_dispatches():
    """ISSUE acceptance: a quota-enforcing, hint-enabled fleet epoch is
    exactly observe_all + epoch_step — the segment-capped select and the
    per-tenant reductions ride inside the one fused dispatch."""
    fleet = small_fleet()
    eps = [e.copy() for e in fleet.epochs()]        # data-gen outside counter
    with rtmod.counting() as counts:
        run_fleet(fleet, hints=True, epochs=iter(eps))
        assert counts.dispatch["observe_all"] == fleet.n_epochs
        assert counts.dispatch["epoch_step"] == fleet.n_epochs
        assert counts.dispatch["reference"] == 0
        assert counts.trace["epoch_step"] <= 1       # one trace, reused


def test_run_scenario_generic_path_inherits_tenancy():
    """The fleet is an AccessScenario: the plain run_scenario packaging
    installs its Tenancy through EpochRuntime.for_scenario (quotas active,
    composed pipeline attached)."""
    fleet = small_fleet()
    rt = EpochRuntime.for_scenario(fleet, policies=("hmu_oracle",))
    assert rt.tenancy is fleet.tenancy
    assert rt.tenancy.caps is not None
    out = run_scenario(fleet, policies=("hmu_oracle",), hints=True)
    assert out["trajectory"]["scenario"] == "fleet"


# --------------------------------------------------------- accounting
def test_per_tenant_accounting_conserves_the_global_record():
    """ISSUE acceptance: tenant numerators sum to the global record — every
    conservable column (n_fast / n_slow / resident / promoted / demoted)
    exactly, host tax to float tolerance via the access-share split."""
    fleet = small_fleet()
    eps = [e.copy() for e in fleet.epochs()]
    rt = EpochRuntime.for_scenario(fleet, policies=ALL_POLICIES,
                                   hints=fleet.build_pipeline())
    rt.run(iter(eps))
    trajs = tenant_trajectories(rt, fleet)
    lanes = list(rt.records)
    assert len(rt.tenant_records) == fleet.n_epochs
    for e in range(fleet.n_epochs):
        for lane in lanes:
            g = rt.records[lane][e]
            rows = [trajs[t.name][lane][e] for t in fleet.tenants]
            # the tenants' access counts partition the epoch's stream, and
            # re-pricing their sum with the fleet geometry recovers the
            # global record's access time exactly
            n_fast = sum(r.n_fast for r in rows)
            n_slow = sum(r.n_slow for r in rows)
            assert n_fast + n_slow == eps[e].size
            np.testing.assert_allclose(
                rt.system.access_time_s(n_fast, n_slow,
                                        fleet.bytes_per_access),
                g.access_s, rtol=1e-12)
            assert sum(r.resident for r in rows) == g.resident
            assert sum(r.promoted for r in rows) == g.promoted
            assert sum(r.demoted for r in rows) == g.demoted
            np.testing.assert_allclose(
                sum(r.host_tax_s for r in rows), g.host_tax_s, rtol=1e-9)
            for r in rows:
                assert 0.0 <= r.coverage <= 1.0
                assert 0.0 <= r.accuracy <= 1.0
                assert r.time_s >= r.access_s >= 0.0


def test_quota_caps_bound_admissions_and_converge_residency():
    """With sum(caps) <= k_hot every tenant's per-epoch admissions respect
    its cap (hard guarantee: each lane's select is segment-capped), and
    residency converges to the quota split up to the slack left by tenants
    whose cap exceeds their whole block space — quotas are work-conserving,
    so unused slots are reusable, but a tenant's own top-cap want is always
    admitted regardless."""
    fleet = small_fleet(capacity="weighted", k_hot=300)
    caps = np.asarray(fleet.tenancy.caps)
    sizes = np.asarray(fleet.tenancy.sizes)
    rt = EpochRuntime.for_scenario(fleet, policies=("hmu_oracle",))
    rt.run(fleet.epochs())
    for raw in rt.tenant_records:
        assert (raw["promoted"][0] <= caps).all()
    slack = int(np.maximum(caps - sizes, 0).sum())
    final = rt.tenant_records[-1]["resident"][0]
    assert final.sum() <= fleet.k_hot
    assert (final <= caps + slack).all()
    # the protected tenant holds its full quota under contention
    assert final[0] == caps[0]


# --------------------------------------------- interference vs isolation
def test_shared_pool_interference_vs_weighted_fair_isolation():
    """ISSUE acceptance (headline, small scale): a loud scanner under a
    shared pool craters the DLRM tenant's oracle-lane coverage; weighted-fair
    quotas sized to the DLRM solo hot set restore it to within a few points
    of the solo run."""
    spec = dataclasses.replace(datagen.SMALL, lookups_per_batch=30_000)

    def tenants():
        return [
            TenantSpec(DLRMScenario(spec=spec, n_epochs=5,
                                    batches_per_epoch=2, shift_at=0),
                       weight=250.0, name="dlrm"),
            TenantSpec(small_scanner(
                n_epochs=5,
                spec=mmap_bench.MmapBenchSpec(total_bytes=640 * 4096,
                                              hot_bytes=512 * 4096),
                accesses_per_batch=60_000), weight=30.0, name="scanner"),
        ]

    solo = run_scenario(DLRMScenario(spec=spec, n_epochs=5,
                                     batches_per_epoch=2, shift_at=0),
                        policies=("hmu_oracle",), hints=False)
    solo_cov = solo["summary"]["hmu_oracle"]["final_coverage"]

    k_hot = 300                                     # < combined demand
    shared = run_fleet(FleetScenario(tenants(), k_hot=k_hot,
                                     capacity="shared"),
                       policies=("hmu_oracle",), hints=False)
    fair = run_fleet(FleetScenario(tenants(), k_hot=k_hot,
                                   capacity="weighted"),
                     policies=("hmu_oracle",), hints=False)
    cov_shared = shared["tenants"]["dlrm"]["lanes"]["hmu_oracle"][
        "final_coverage"]
    cov_fair = fair["tenants"]["dlrm"]["lanes"]["hmu_oracle"][
        "final_coverage"]
    assert fair["tenants"]["dlrm"]["cap"] >= 250    # quota covers solo k_hot
    assert solo_cov > 0.8
    assert cov_shared < solo_cov - 0.3              # noisy neighbour craters
    assert cov_fair > solo_cov - 0.05               # quotas isolate


# ----------------------------------------------------------- sharded parity
@pytest.mark.slow
def test_sharded_fleet_parity():
    """ISSUE acceptance: the quota-enforcing fleet epoch with all per-block
    state (tenant_id leaf included) sharded over an 8-device mesh equals the
    single-device run exactly."""
    r = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import dataclasses, json
        import numpy as np
        from repro.dlrm import datagen
        from repro.fleet import FleetScenario, TenantSpec, run_fleet
        from repro.launch.mesh import make_telemetry_mesh, use_mesh
        from repro.scenarios import DLRMScenario, MmapBenchScenario

        spec = dataclasses.replace(datagen.SMALL, lookups_per_batch=8_000)
        def tenants():
            return [
                TenantSpec(DLRMScenario(spec=spec, n_epochs=3,
                                        batches_per_epoch=2, shift_at=2),
                           weight=10.0, name="dlrm"),
                TenantSpec(MmapBenchScenario(n_epochs=3, batches_per_epoch=2,
                                             accesses_per_batch=8_000),
                           weight=1.0, name="scanner"),
            ]
        kw = dict(k_hot=280, capacity="weighted")
        ref = run_fleet(FleetScenario(tenants(), **kw), hints=True)
        mesh = make_telemetry_mesh(8)
        with use_mesh(mesh):
            shd = run_fleet(FleetScenario(tenants(), **kw), hints=True,
                            mesh=mesh)
        assert json.dumps(ref["trajectory"], sort_keys=True) == \\
            json.dumps(shd["trajectory"], sort_keys=True)
        assert json.dumps(ref["tenants"], sort_keys=True) == \\
            json.dumps(shd["tenants"], sort_keys=True)
        print("OK")
    """)], capture_output=True, text=True, env=SUBPROC_ENV, timeout=480,
        cwd=REPO)
    assert "OK" in r.stdout, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
