"""Unit tests for the O(n) selection kernels (selectk) and the unified
Placement substrate — the pieces the fused epoch_step is built from.

selectk's contract is *bit-equivalence* with the sort-based primitives it
replaces (lax.top_k / stable argsort), including tie-breaks, so these tests
compare against those references directly on tie-heavy inputs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from functools import partial

from repro.core import policy, selectk
from repro.core.placement import Placement, apply_plan, demote_idle, plan_promotion


# ------------------------------------------------------------------ selectk
@pytest.mark.parametrize("n,k,lo,hi", [
    (10_000, 500, 0, 5),        # heavy ties
    (10_000, 500, -3, 3),       # negatives
    (5_000, 5_000, 0, 1),       # k == n, near-constant
    (10_000, 1, -100, 100),
    (777, 77, 0, 1_000_000),    # wide range, odd length (cumsum fallback)
])
def test_select_top_k_matches_lax_top_k(n, k, lo, hi):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(lo, hi + 1, n).astype(np.int32))
    v_ref, i_ref = jax.lax.top_k(x, k)
    v, i = jax.jit(partial(selectk.select_top_k, k=k))(x)
    np.testing.assert_array_equal(np.asarray(v_ref), np.asarray(v))
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i))


def test_select_top_k_batched_and_mask():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(0, 4, (3, 2_000)).astype(np.int32))
    v, i, sel = jax.jit(partial(selectk.select_top_k, k=150,
                                return_mask=True))(x)
    for row in range(3):
        v_ref, i_ref = jax.lax.top_k(x[row], 150)
        np.testing.assert_array_equal(np.asarray(v_ref), np.asarray(v[row]))
        np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i[row]))
        mask_ref = np.zeros(2_000, bool)
        mask_ref[np.asarray(i_ref)] = True
        np.testing.assert_array_equal(mask_ref, np.asarray(sel[row]))


def test_select_top_k_float_keys_via_bitcast():
    """Non-negative float scores select identically through sortable_key —
    the order isomorphism the proactive/hinted lanes rely on."""
    rng = np.random.default_rng(2)
    xf = jnp.asarray(np.abs(rng.normal(size=4_096)).astype(np.float32)
                     * (rng.random(4_096) < 0.5))
    _, i_ref = jax.lax.top_k(xf, 400)
    _, i = jax.jit(partial(selectk.select_top_k, k=400))(
        selectk.sortable_key(xf))
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i))


def test_bottom_k_mask_matches_stable_argsort_prefix():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(0, 6, 3_000).astype(np.int32))
    for cnt in (0, 1, 700, 3_000):
        ref = np.zeros(3_000, bool)
        ref[np.argsort(np.asarray(x), kind="stable")[:cnt]] = True
        got = np.asarray(jax.jit(selectk.bottom_k_mask)(x, jnp.asarray(cnt)))
        np.testing.assert_array_equal(ref, got, err_msg=str(cnt))


def test_stable_rank_sparse_matches_double_argsort():
    rng = np.random.default_rng(4)
    for n, n_pos in ((2_048, 0), (2_048, 1), (2_048, 37), (1_000, 1_000)):
        x = np.zeros(n, np.int32)
        pos = rng.choice(n, n_pos, replace=False)
        x[pos] = rng.integers(1, 5, n_pos)       # duplicate positive values
        xj = jnp.asarray(x)
        ref = np.asarray(jnp.argsort(jnp.argsort(xj)))
        got = np.asarray(jax.jit(partial(
            selectk.stable_rank_sparse, max_positive=max(n_pos, 1)))(xj))
        np.testing.assert_array_equal(ref, got)


def test_prefix_sum_matches_cumsum():
    rng = np.random.default_rng(5)
    for shape in ((1_024,), (3, 2_048), (5, 1_000)):   # incl. fallback path
        x = jnp.asarray((rng.random(shape) < 0.4))
        np.testing.assert_array_equal(
            np.asarray(jnp.cumsum(x.astype(jnp.int32), axis=-1)),
            np.asarray(jax.jit(selectk.prefix_sum)(x)))


# ---------------------------------------------------------------- placement
def _check_maps(p: Placement):
    s2b = np.asarray(p.slot_to_block)
    b2s = np.asarray(p.block_to_slot)
    for lane in range(s2b.shape[0]) if s2b.ndim == 2 else [slice(None)]:
        s, b = s2b[lane], b2s[lane]
        assert (s >= 0).sum() == (b >= 0).sum()
        for slot, blk in enumerate(s):
            if blk >= 0:
                assert b[blk] == slot
        for blk, slot in enumerate(b):
            if slot >= 0:
                assert s[slot] == blk


def test_apply_plan_fills_free_slots_in_priority_order():
    p = Placement.create(16, 4)
    est = jnp.zeros((16,), jnp.float32)
    want = jnp.asarray([7, 3, 9, -1, -1, -1], jnp.int32)
    p2, promoted, demoted = jax.jit(apply_plan)(p, want, est)
    assert int(promoted) == 3 and int(demoted) == 0
    np.testing.assert_array_equal(np.asarray(p2.slot_to_block), [7, 3, 9, -1])
    _check_maps(p2)


def test_apply_plan_evicts_coldest_never_wanted():
    """Full tier + a plan that keeps one resident: the eviction must take
    the coldest non-wanted residents, never the still-wanted one."""
    p = Placement.create(16, 3)
    est0 = jnp.zeros((16,), jnp.float32)
    p, _, _ = apply_plan(p, jnp.asarray([5, 6, 7], jnp.int32), est0)
    est = jnp.zeros((16,), jnp.float32).at[5].set(1.0).at[6].set(50.0).at[7].set(10.0)
    want = jnp.asarray([6, 0, 1], jnp.int32)     # 6 already fast, 0/1 new
    p2, promoted, demoted = jax.jit(apply_plan)(p, want, est)
    assert int(promoted) == 2 and int(demoted) == 2
    s2b = set(np.asarray(p2.slot_to_block).tolist())
    assert s2b == {6, 0, 1}                      # 5 and 7 evicted, 6 kept
    _check_maps(p2)


def test_apply_plan_lane_stacked_matches_per_lane():
    rng = np.random.default_rng(6)
    n, k, L = 64, 8, 4
    s2b = np.full((L, k), -1, np.int32)
    b2s = np.full((L, n), -1, np.int32)
    for lane in range(L):                        # random consistent placements
        blocks = rng.choice(n, rng.integers(0, k + 1), replace=False)
        for slot, blk in enumerate(blocks):
            s2b[lane, slot] = blk
            b2s[lane, blk] = slot
    stacked = Placement(slot_to_block=jnp.asarray(s2b),
                        block_to_slot=jnp.asarray(b2s))
    # unique ids with -1 padding interleaved (apply_plan's contract: plans
    # come from top_k, so ids never repeat)
    want_np = np.stack([rng.permutation(n)[:k] for _ in range(L)])
    want_np[rng.random((L, k)) < 0.3] = -1
    want = jnp.asarray(want_np.astype(np.int32))
    est = jnp.asarray(rng.integers(0, 10, (L, n)).astype(np.float32))
    out, promoted, demoted = jax.jit(apply_plan)(stacked, want, est)
    for lane in range(L):
        single = Placement(slot_to_block=jnp.asarray(s2b[lane]),
                           block_to_slot=jnp.asarray(b2s[lane]))
        o, pr, de = apply_plan(single, want[lane], est[lane])
        np.testing.assert_array_equal(np.asarray(o.slot_to_block),
                                      np.asarray(out.slot_to_block)[lane])
        np.testing.assert_array_equal(np.asarray(o.block_to_slot),
                                      np.asarray(out.block_to_slot)[lane])
        assert int(pr) == int(np.asarray(promoted)[lane])
        assert int(de) == int(np.asarray(demoted)[lane])
    _check_maps(out)


def test_demote_idle_frees_untouched_residents_only_when_enabled():
    p = Placement.create(8, 3)
    p, _, _ = apply_plan(p, jnp.asarray([1, 2, 4], jnp.int32),
                         jnp.zeros((8,), jnp.float32))
    est = jnp.zeros((8,), jnp.float32).at[2].set(3.0)
    p_on, n_on = jax.jit(demote_idle)(p, est, True)
    assert int(n_on) == 2                        # blocks 1 and 4 idle
    assert set(np.asarray(p_on.slot_to_block).tolist()) == {2, -1}
    p_off, n_off = jax.jit(demote_idle)(p, est, False)
    assert int(n_off) == 0
    np.testing.assert_array_equal(np.asarray(p_off.slot_to_block),
                                  np.asarray(p.slot_to_block))
    _check_maps(p_on)


def test_plan_promotion_host_helper_guards_wanted_blocks():
    """The host control-plane variant (TieredEmbedding's path) applies the
    same plan_eviction invariant: victims are coldest non-wanted residents,
    sized to exactly cover the shortfall."""
    p = Placement.create(16, 3)
    p, _, _ = apply_plan(p, jnp.asarray([5, 6, 7], jnp.int32),
                         jnp.zeros((16,), jnp.float32))
    est = np.zeros(16); est[[5, 6, 7]] = [1.0, 50.0, 10.0]
    want, victims = plan_promotion(
        p, jnp.asarray([6, 0, 1, -1], jnp.int32), est)
    assert want.tolist() == [6, 0, 1]
    v = np.asarray(victims)
    assert set(v[v >= 0].tolist()) == {5, 7}
    # nothing to evict when promotions fit
    _, none_victims = plan_promotion(p, jnp.asarray([6], jnp.int32), est)
    assert none_victims is None


def test_policy_hinted_gates_unhinted_untouched_blocks():
    """Satellite: zero-telemetry zero-hint blocks are never promoted just to
    fill k — they would churn migration traffic for no signal."""
    counts = jnp.asarray([0, 9, 0, 0, 3, 0], jnp.int32)
    hints = jnp.zeros((6,), jnp.float32).at[2].set(0.8)
    plan = policy.hinted(counts, hints, k=6, hint_weight=0.5)
    got = [int(x) for x in np.asarray(plan.promote) if x >= 0]
    assert set(got) == {1, 2, 4}                 # only telemetry or hint
    # all-cold, no hints -> empty plan
    empty = policy.hinted(jnp.zeros((6,), jnp.int32),
                          jnp.zeros((6,), jnp.float32), k=4)
    assert (np.asarray(empty.promote) == -1).all()
