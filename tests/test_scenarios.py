"""Scenario-layer tests: one workload-agnostic EpochRuntime packaging, three
workloads.  The tentpole invariants — fused-vs-reference bit-identity and
exactly 2 jit dispatches/epoch — must hold for the non-DLRM scenarios too,
and the DLRM packaging must reproduce what ``tracesim.run_online`` always
did."""
import dataclasses

import numpy as np
import pytest

from repro.core import runtime as rtmod
from repro.core.runtime import ALL_POLICIES, EpochRuntime
from repro.dlrm import datagen, tracesim
from repro.scenarios import (DLRMScenario, KVCacheScenario, MoEExpertScenario,
                             build_hints, run_scenario)
from repro.scenarios.kv_cache import quantize_access_counts

SMALL_SPEC = dataclasses.replace(datagen.SMALL, lookups_per_batch=8_000)


def small_dlrm(**kw):
    kw.setdefault("spec", SMALL_SPEC)
    kw.setdefault("n_epochs", 4)
    kw.setdefault("batches_per_epoch", 2)
    kw.setdefault("shift_at", 2)
    return DLRMScenario(**kw)


def small_kv(**kw):
    kw.setdefault("batch", 2)
    kw.setdefault("n_epochs", 3)
    kw.setdefault("batches_per_epoch", 2)
    kw.setdefault("accesses_per_batch", 1_024)
    return KVCacheScenario(**kw)


def small_moe(**kw):
    kw.setdefault("n_epochs", 4)
    kw.setdefault("batches_per_epoch", 2)
    kw.setdefault("shift_at", 2)
    kw.setdefault("batch", 2)
    return MoEExpertScenario(**kw)


SCENARIO_FACTORIES = {
    "dlrm": small_dlrm,
    "kv_cache": small_kv,
    "moe_experts": small_moe,
}


# --------------------------------------------------------------- DLRM parity
def test_run_online_is_the_dlrm_scenario():
    """The tracesim entry point and the scenario layer are ONE packaging:
    identical trajectory and summary for identical parameters."""
    kw = dict(n_epochs=4, batches_per_epoch=2, shift_at=2, seed=0, hints=True)
    old = tracesim.run_online(spec=SMALL_SPEC, **kw)
    sc = small_dlrm()
    new = run_scenario(sc, hints=True)
    assert old["trajectory"] == new["trajectory"]
    assert old["summary"] == new["summary"]
    assert new["trajectory"]["scenario"] == "dlrm"


def test_for_scenario_pulls_geometry_from_the_scenario():
    sc = small_dlrm()
    rt = EpochRuntime.for_scenario(sc, policies=("hmu_oracle",))
    assert rt.n_blocks == sc.n_blocks == SMALL_SPEC.n_pages
    assert rt.k_hot == sc.k_hot
    assert rt.bytes_per_access == float(SMALL_SPEC.row_bytes)
    assert rt.block_bytes == float(SMALL_SPEC.page_bytes)
    assert rt.system is sc.system
    # overrides replace scenario-provided kwargs
    rt2 = EpochRuntime.for_scenario(sc, policies=("hmu_oracle",),
                                    ewma_alpha=0.9, nb_scan_rate=7)
    assert rt2.ewma_alpha == 0.9


def test_dlrm_hint_layout_matches_for_dlrm_pipeline():
    """build_hints on the DLRM scenario == HintPipeline.for_dlrm: the static
    rank arrays agree element-for-element (same layout, prior, clip)."""
    from repro.hints import HintPipeline

    sc = small_dlrm()
    a = build_hints(sc)
    b = HintPipeline.for_dlrm(SMALL_SPEC, seed=0)
    np.testing.assert_array_equal(a._static_rank, b._static_rank)
    assert a.lookahead_depth == b.lookahead_depth == 1


# ------------------------------------------------ tentpole: both invariants
@pytest.mark.parametrize("name", ["kv_cache", "moe_experts"])
def test_scenario_fused_bit_identical_to_reference(name):
    """ISSUE acceptance: the non-DLRM workloads run through the SAME runtime
    with fused-vs-reference bit-identical trajectories (every EpochRecord
    field of every lane and epoch, hint-enabled)."""
    sc = SCENARIO_FACTORIES[name]()
    fused = run_scenario(sc, hints=True)
    reference = run_scenario(sc, hints=True, fused=False)
    assert set(fused["trajectory"]["lanes"]) == set(ALL_POLICIES)
    assert fused["trajectory"] == reference["trajectory"]
    assert fused["summary"] == reference["summary"]


@pytest.mark.parametrize("name", ["kv_cache", "moe_experts"])
def test_scenario_epoch_is_two_dispatches(name):
    """ISSUE acceptance: a hint-enabled epoch of any scenario is exactly
    observe_all + epoch_step — hint refreshes are transfers, not
    dispatches."""
    sc = SCENARIO_FACTORIES[name]()
    sc.epochs()                                   # model runs outside counter
    with rtmod.counting() as counts:
        run_scenario(sc, hints=True)
        assert counts.dispatch["observe_all"] == sc.n_epochs
        assert counts.dispatch["epoch_step"] == sc.n_epochs
        assert counts.dispatch["reference"] == 0
        assert counts.dispatch["hint_refresh"] >= 1


# ----------------------------------------------------------- kv_cache stream
def test_kv_scenario_geometry_has_ragged_final_page():
    sc = small_kv()
    assert sc.max_len % sc.page_size != 0         # default geometry IS ragged
    assert sc.pages_per_seq == -(-sc.max_len // sc.page_size)
    assert sc.n_blocks == (sc.cfg.n_layers * sc.batch * sc.pages_per_seq)


def test_kv_scenario_epochs_are_deterministic_equal_shape_batches():
    sc = small_kv()
    eps1 = list(sc.epochs())
    eps2 = list(sc.epochs())                      # cached replay
    assert len(eps1) == sc.n_epochs
    for a, b in zip(eps1, eps2):
        np.testing.assert_array_equal(a, b)
    for ep in eps1:
        assert ep.shape == (sc.batches_per_epoch, sc.accesses_per_batch)
        assert ep.dtype == np.int32
        assert ep.min() >= 0 and ep.max() < sc.n_blocks


def test_kv_scenario_accesses_follow_attention_mass():
    """The quantized stream apportions each step's accesses by page mass:
    pages holding the prefill carry mass, pages past the decode frontier
    carry none."""
    sc = small_kv()
    eps = list(sc.epochs())
    hist = np.bincount(eps[0].ravel(), minlength=sc.n_blocks)
    # the final pages of every sequence are beyond the decode frontier in
    # epoch 0 -> zero mass -> zero accesses
    last_page_ids = [(l * sc.batch + b) * sc.pages_per_seq
                     + (sc.pages_per_seq - 1)
                     for l in range(sc.cfg.n_layers) for b in range(sc.batch)]
    assert hist[last_page_ids].sum() == 0
    # prefill pages absorb attention from the first decode step
    first_page_ids = [(l * sc.batch + b) * sc.pages_per_seq
                      for l in range(sc.cfg.n_layers)
                      for b in range(sc.batch)]
    assert (hist[first_page_ids] > 0).all()


def test_quantize_access_counts_exact_total_and_proportionality():
    w = np.array([3.0, 1.0, 0.0, 4.0])
    c = quantize_access_counts(w, 800)
    assert c.sum() == 800
    assert c[2] == 0                               # zero weight, zero access
    np.testing.assert_allclose(c / 800, w / w.sum(), atol=1 / 800)
    assert (quantize_access_counts(np.zeros(4), 100) == 0).all()
    assert (quantize_access_counts(w, 0) == 0).all()


# -------------------------------------------------------- moe_experts stream
def test_moe_scenario_stream_shape_and_shift():
    sc = small_moe()
    eps = list(sc.epochs())
    assert len(eps) == sc.n_epochs
    for ep in eps:
        assert ep.shape == (sc.batches_per_epoch, sc.batch_len)
        assert ep.min() >= 0 and ep.max() < sc.n_blocks
    # the routing shift re-concentrates traffic: pre- and post-shift expert
    # histograms differ
    pre = np.bincount(eps[0].ravel(), minlength=sc.n_blocks)
    post = np.bincount(eps[-1].ravel(), minlength=sc.n_blocks)
    assert pre.sum() == post.sum()                # constant stream length
    assert not np.array_equal(pre, post)


def test_moe_scenario_rejects_dense_arch():
    with pytest.raises(ValueError, match="MoE"):
        MoEExpertScenario(arch="internlm2-1.8b")


def test_expert_access_batch_shapes():
    from repro.models.moe import expert_access_batch

    out = expert_access_batch(np.array([[1, 0, 2], [0, 1, 0]]))
    np.testing.assert_array_equal(out, [0, 1, 2, 2])
    assert out.dtype == np.int32
    with pytest.raises(ValueError, match="counts"):
        expert_access_batch(np.zeros((2, 2, 2)))


# ------------------------------------------------------------ hint layouts
def test_runtime_only_scenarios_build_lookahead_only_pipelines():
    for factory in (small_kv, small_moe):
        sc = factory()
        assert sc.hint_layout() is None
        pipe = build_hints(sc)
        assert (pipe._static_rank == 0).all()     # hinted lane: pure telemetry
        assert pipe.lookahead_depth == 1          # prefetch lane: live
