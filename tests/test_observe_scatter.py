"""observe_scatter: fused telemetry scatter vs its oracle, and the fused
``observe_all`` epoch path with the kernel swapped in.

The kernel must reproduce the XLA scatter-adds bit for bit — including the
``mode="drop"`` semantics where a negative id wraps once (NumPy-style) and
only ids still outside ``[0, n_blocks)`` are dropped — because its two
histograms feed every collector update in the epoch scan."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import telemetry as tel
from repro.faults.model import FaultModel
from repro.kernels.dispatch import PallasBackend
from repro.kernels.observe_scatter import (MAX_BLOCKS, observe_scatter,
                                           observe_scatter_ref)

BACKEND = PallasBackend(interpret=True, scatter_tile_m=256)


def _bundles_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# ----------------------------------------------------------- kernel parity
@pytest.mark.parametrize("m,n_blocks,period,cursor", [
    (512, 100, 37, 0),
    (1000, 997, 7, 11),        # ragged final tile
    (37, 50, 1, 3),            # every position sampled
    (256, 64, 10007, 10006),   # cursor wraps mid-batch
])
def test_observe_scatter_matches_ref(m, n_blocks, period, cursor):
    rng = np.random.default_rng(0)
    # ids straddle the valid range on both sides: negatives wrap once,
    # >= n_blocks drops — exactly XLA's .at[ids].add(mode="drop")
    ids = jnp.asarray(
        rng.integers(-3, n_blocks + 3, size=(m,)).astype(np.int32))
    keep = jnp.asarray(rng.random(m) < 0.6)
    cur = jnp.asarray(cursor, jnp.int32)
    for km in (None, keep):
        h_ref, p_ref = observe_scatter_ref(ids, cur, n_blocks=n_blocks,
                                           period=period, keep=km)
        h_pal, p_pal = observe_scatter(ids, cur, n_blocks=n_blocks,
                                       period=period, keep=km,
                                       tile_m=BACKEND.scatter_tile_m,
                                       use_pallas=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(h_ref), np.asarray(h_pal))
        np.testing.assert_array_equal(np.asarray(p_ref), np.asarray(p_pal))


def test_observe_scatter_ref_matches_telemetry_scatters():
    """The oracle IS the telemetry path: same histograms the per-collector
    .at[].add scatters produce."""
    rng = np.random.default_rng(1)
    n_blocks, m = 200, 777
    ids = jnp.asarray(rng.integers(0, n_blocks, m).astype(np.int32))
    cur = jnp.asarray(5, jnp.int32)
    period = 13
    h, p = observe_scatter_ref(ids, cur, n_blocks=n_blocks, period=period)
    np.testing.assert_array_equal(
        np.asarray(h),
        np.bincount(np.asarray(ids), minlength=n_blocks))
    hit = (np.asarray(cur) + np.arange(m)) % period == 0
    np.testing.assert_array_equal(
        np.asarray(p),
        np.bincount(np.asarray(ids)[hit], minlength=n_blocks))


def test_observe_scatter_falls_back_past_max_blocks():
    ids = jnp.zeros((8,), jnp.int32)
    h, p = observe_scatter(ids, jnp.asarray(0, jnp.int32),
                           n_blocks=MAX_BLOCKS + 1, period=3,
                           use_pallas=True, interpret=True)
    assert h.shape == (MAX_BLOCKS + 1,) and int(h[0]) == 8


# ------------------------------------------------- fused observe_all parity
def test_observe_all_pallas_bit_identical_fault_free():
    rng = np.random.default_rng(2)
    n_blocks = 313
    batches = jnp.asarray(
        rng.integers(0, n_blocks, size=(3, 257)).astype(np.int32))
    b0 = tel.bundle_init(n_blocks, pebs_period=31, nb_scan_rate=17)
    b1 = tel.bundle_init(n_blocks, pebs_period=31, nb_scan_rate=17)
    r0 = tel.observe_all(b0, batches)
    r1 = tel.observe_all(b1, batches, pallas=BACKEND)
    assert _bundles_equal(r0, r1)


def test_observe_all_pallas_bit_identical_with_faults():
    """The faulty path draws its keep mask in XLA and hands it to the
    kernel; drop accounting, saturation, resets and stalls must all land
    identically."""
    rng = np.random.default_rng(3)
    n_blocks = 200
    batches = jnp.asarray(
        rng.integers(0, n_blocks, size=(4, 300)).astype(np.int32))
    fm = FaultModel.create(hmu_counter_bits=5, pebs_drop_p=0.4,
                           nb_stall_p=0.3, reset_p=0.2, seed=9,
                           n_blocks=n_blocks)
    b0 = tel.bundle_init(n_blocks, pebs_period=11, nb_scan_rate=9, faults=fm)
    b1 = tel.bundle_init(n_blocks, pebs_period=11, nb_scan_rate=9, faults=fm)
    r0 = tel.observe_all(b0, batches)
    r1 = tel.observe_all(b1, batches, pallas=BACKEND)
    assert _bundles_equal(r0, r1)
    assert int(r1.faults.pebs_dropped.lo) > 0       # faults actually fired


def test_observe_all_pallas_traces_once():
    """Swapping the kernel in must not retrace per epoch: pallas is static
    config, so repeated calls reuse one trace per (shape, backend)."""
    n_blocks = 64
    batches = jnp.zeros((2, 128), jnp.int32)
    bundle = tel.bundle_init(n_blocks, pebs_period=7, nb_scan_rate=3)
    before = tel.TRACE_COUNTS["observe_all"]
    for _ in range(3):
        bundle = tel.observe_all(bundle, batches, pallas=BACKEND)
    assert tel.TRACE_COUNTS["observe_all"] - before == 1
