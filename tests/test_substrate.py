"""Substrate tests: data pipeline, checkpointing, fault-tolerance runtime,
gradient compression."""
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import DataConfig, TokenPipeline
from repro.checkpoint import CheckpointManager
from repro.runtime import ElasticPlanner, PreemptionGuard, StragglerDetector
from repro.runtime.failure import Heartbeat
from repro.train import compression as comp
from repro.optim import get_optimizer, cosine_schedule


# ---------------------------------------------------------------------- data
def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8, seed=7)
    p1 = TokenPipeline(cfg)
    b5 = p1.batch(5)
    # fresh pipeline (simulating restart) reproduces the identical batch
    p2, step = TokenPipeline.resume(cfg, p1.state(5))
    np.testing.assert_array_equal(p2.batch(step)["tokens"], b5["tokens"])
    # different steps differ
    assert not np.array_equal(p1.batch(6)["tokens"], b5["tokens"])


def test_pipeline_rank_sharding_partitions_batch():
    cfg = DataConfig(vocab_size=1000, seq_len=8, global_batch=8, n_ranks=4)
    batches = [TokenPipeline(
        DataConfig(vocab_size=1000, seq_len=8, global_batch=8,
                   n_ranks=4, rank=r)).batch(0) for r in range(4)]
    assert all(b["tokens"].shape == (2, 8) for b in batches)
    # ranks see different data
    assert not np.array_equal(batches[0]["tokens"], batches[1]["tokens"])


def test_pipeline_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=100, seq_len=12, global_batch=2)
    b = TokenPipeline(cfg).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_zipf_skew():
    cfg = DataConfig(vocab_size=10_000, seq_len=512, global_batch=64,
                     zipf_alpha=1.1)
    toks = TokenPipeline(cfg).batch(0)["tokens"].reshape(-1)
    counts = np.bincount(toks, minlength=10_000)
    top = np.sort(counts)[::-1]
    # top 1% of tokens carry > 30% of occurrences
    assert top[:100].sum() / counts.sum() > 0.3


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.int32), jnp.zeros((2, 2))]}
    for s in (1, 2, 3):
        mgr.save(s, tree, extra={"data_state": {"step": s}}, block=True)
    assert mgr.latest_step() == 3
    restored, extra = mgr.restore()
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"][0], tree["b"][0])
    assert extra["data_state"]["step"] == 3
    # retention: step 1 gone
    with pytest.raises(Exception):
        mgr.restore(step=1)


def test_checkpoint_async_overlaps_and_waits(tmp_path):
    mgr = CheckpointManager(tmp_path)
    big = {"x": jnp.ones((512, 512))}
    mgr.save(10, big)             # async
    mgr.wait()
    r, _ = mgr.restore(10)
    assert float(r["x"].sum()) == 512 * 512


def test_checkpoint_atomicity_no_partial_reads(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"x": jnp.ones((8,))}, block=True)
    # a crashed tmp dir must not be visible as a checkpoint
    (tmp_path / "step_00000002.tmp").mkdir()
    assert mgr.latest_step() == 1


def test_checkpoint_restore_into_train_state(tmp_path):
    """End-to-end: save params+opt state, restore, resume exactly."""
    opt = get_optimizer("adamw")
    params = {"w": jnp.ones((4, 4))}
    st_ = opt.init(params)
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, {"params": params, "opt": st_}, block=True)
    restored, _ = mgr.restore(5)
    np.testing.assert_array_equal(restored["params"]["w"], params["w"])
    assert int(restored["opt"]["step"]) == 0
    np.testing.assert_array_equal(restored["opt"]["inner"]["m"]["w"],
                                  np.zeros((4, 4)))


# -------------------------------------------------------------------- runtime
def test_preemption_guard_flag():
    g = PreemptionGuard(install=False)
    assert not g.preempted
    g.trigger()
    assert g.preempted


def test_straggler_detector_flags_slow_steps():
    det = StragglerDetector(threshold_sigma=3.0, patience=2, warmup_steps=5)
    rng = np.random.default_rng(0)
    actions = []
    for i in range(50):
        t = 0.10 + rng.normal(0, 0.004)
        actions.append(det.observe(i, t))
    assert all(a is None for a in actions[10:])      # steady state: quiet
    # a persistent straggler escalates
    acts = [det.observe(100 + j, 0.5) for j in range(6)]
    assert "retry_host" in acts
    assert "propose_exclusion" in acts


def test_straggler_detector_ignores_single_blip():
    det = StragglerDetector(patience=3, warmup_steps=5)
    for i in range(30):
        det.observe(i, 0.1)
    a = det.observe(31, 0.9)
    assert a in ("log", None)
    assert det.observe(32, 0.1) is None              # recovered


def test_heartbeat_detects_dead_hosts():
    hb = Heartbeat(timeout_s=10)
    hb.beat("host0", now=100.0)
    hb.beat("host1", now=105.0)
    assert hb.dead_hosts(now=112.0) == ["host0"]


def test_elastic_planner_shrinks_data_axis():
    pl = ElasticPlanner(model_axis=16, global_batch=256)
    base = pl.plan(256, baseline_data_axis=16)
    assert base.shape == (16, 16) and base.grad_accum_factor == 1
    # lose 32 devices -> data axis 14 doesn't divide 256 -> falls to 8
    p2 = pl.replan_on_failure(base, failed_devices=32)
    assert p2.shape[1] == 16
    assert 256 % p2.shape[0] == 0
    assert p2.devices_used <= 224
    assert p2.grad_accum_factor >= 2


def test_elastic_planner_fails_fast_below_model_axis():
    pl = ElasticPlanner(model_axis=16, global_batch=256)
    with pytest.raises(RuntimeError):
        pl.plan(8, baseline_data_axis=16)


# ---------------------------------------------------------------- compression
def test_int8_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(size=(256,)) * 1e-3)}
    ef = comp.init_error_feedback(g_true)
    # accumulate the same gradient many times: with EF the mean compressed
    # gradient converges to the true gradient
    acc = np.zeros(256)
    for _ in range(50):
        g, ef, wire = comp.int8_compress_grads(g_true, ef)
        acc += np.asarray(g["w"], np.float64)
    np.testing.assert_allclose(acc / 50, np.asarray(g_true["w"]),
                               rtol=0.02, atol=1e-6)
    assert wire == 256     # 1 byte per element on the wire


def test_topk_error_feedback_conserves_gradient_mass():
    """EF invariant: what was sent + what is still carried == everything
    that arrived.  No gradient signal is ever lost, only delayed."""
    rng = np.random.default_rng(1)
    g_true = {"w": jnp.asarray(rng.normal(size=(1000,)))}
    ef = comp.init_error_feedback(g_true)
    acc = np.zeros(1000)
    n = 50
    for _ in range(n):
        g, ef, _ = comp.topk_compress_grads(g_true, ef, k_fraction=0.02)
        acc += np.asarray(g["w"], np.float64)
    total = acc + np.asarray(ef["w"], np.float64)
    np.testing.assert_allclose(total, n * np.asarray(g_true["w"], np.float64),
                               rtol=1e-4, atol=1e-4)
    # and per round only ~k entries are non-zero on the wire
    nz = np.count_nonzero(np.asarray(g["w"]))
    assert nz <= 0.03 * 1000


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=1, max_value=6))
def test_property_int8_quantization_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(128,)) * 10.0 ** int(rng.integers(-4, 3)))
    q, s = comp.quantize_int8(x)
    err = np.abs(np.asarray(comp.dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-9
