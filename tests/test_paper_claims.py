"""End-to-end validation of the paper's claims (Fig. 3 + Table 1).

Fast variants run the reduced specs; the full paper-scale runs execute in
benchmarks/ (see bench_output.txt) and are marked slow here.  Tolerance bands
are intentionally wide where our NB/PEBS emulators, not the paper, define the
exact value — the *qualitative* ordering is the paper's headline claim.
"""
import dataclasses

import numpy as np
import pytest

from repro.dlrm import datagen, tracesim
from repro.workloads import mmap_bench


@pytest.fixture(scope="module")
def table1_small():
    return tracesim.run_table1(
        datagen.SMALL, k_hot=500, batches_per_iteration=5,
        eval_batches=8, dram_only_target_us=633.24,
    )


@pytest.fixture(scope="module")
def fig3_small():
    return tracesim.run_fig3(
        mmap_bench.SMALL, total_accesses=2_000_000, pebs_period=401, n_batches=16,
    )


class TestTable1Qualitative:
    def test_hmu_faster_than_nb(self, table1_small):
        assert table1_small["hmu"].speed_vs_nb > 1.3

    def test_hmu_close_to_dram_only(self, table1_small):
        ratio = table1_small["hmu"].avg_inference_us / table1_small["dram-only"].avg_inference_us
        assert ratio < 1.30

    def test_hmu_small_footprint(self, table1_small):
        frac = table1_small["hmu"].pages_promoted / datagen.SMALL.n_pages
        assert frac <= 0.11  # >= ~90% of pages stay in the slow tier

    def test_ordering(self, table1_small):
        t = {k: v.avg_inference_us for k, v in table1_small.items()}
        assert t["dram-only"] <= t["hmu"] < t["nb"]
        assert t["hmu"] < t["cxl-only"]

    def test_nb_less_accurate_than_hmu(self, table1_small):
        assert table1_small["nb"].accuracy < table1_small["hmu"].accuracy


class TestFig3Qualitative:
    def test_hotness_skew(self, fig3_small):
        # ~10% of pages account for ~90% of accesses
        assert 0.05 <= fig3_small["hotness"]["pages_for_90pct"] <= 0.15

    def test_hmu_exact_coverage_and_accuracy(self, fig3_small):
        m = fig3_small["methods"]["hmu"]
        assert m["accuracy"] == pytest.approx(1.0)
        assert m["coverage"] == pytest.approx(1.0)

    def test_hmu_beats_nb(self, fig3_small):
        assert fig3_small["methods"]["hmu"]["speedup_vs_nb"] > 1.2

    def test_hmu_zero_host_collection_cost_vs_pebs_nb(self, fig3_small):
        # HMU host events = log drain only; PEBS/NB pay per sample/fault.
        m = fig3_small["methods"]
        assert m["nb"]["host_events"] > 0
        assert m["pebs"]["host_events"] > 0


@pytest.mark.slow
class TestPaperScale:
    """Full paper-scale reproductions (≈1 min total)."""

    @pytest.fixture(scope="class")
    def table1(self):
        return tracesim.run_table1()

    @pytest.fixture(scope="class")
    def fig3(self):
        return tracesim.run_fig3()

    def test_table1_speedup_band(self, table1):
        # paper: 1.94x
        assert 1.5 <= table1["hmu"].speed_vs_nb <= 2.5

    def test_table1_hmu_within_paper_band_of_dram(self, table1):
        # paper: 3% slower
        ratio = table1["hmu"].avg_inference_us / table1["dram-only"].avg_inference_us
        assert ratio <= 1.08

    def test_table1_footprint(self, table1):
        # paper: 486,587 pages = 1.99 GB of 20.48 GB (~9%)
        assert table1["hmu"].pages_promoted == 486_587
        assert table1["hmu"].top_tier_gb / table1["dram-only"].top_tier_gb <= 0.11

    def test_table1_nb_time_band(self, table1):
        # paper: 127,294 us
        assert 100_000 <= table1["nb"].avg_inference_us <= 160_000

    def test_fig3_pebs_coverage_and_accuracy(self, fig3):
        m = fig3["methods"]["pebs"]
        assert m["coverage"] <= 0.12           # paper: 6%
        assert m["accuracy"] >= 0.70           # paper: 87%

    def test_fig3_speedups(self, fig3):
        m = fig3["methods"]["hmu"]
        assert 2.2 <= m["speedup_vs_pebs"] <= 4.0   # paper: 2.94x
        assert 1.4 <= m["speedup_vs_nb"] <= 2.3     # paper: 1.73x

    def test_fig3_overlap(self, fig3):
        assert 0.6 <= fig3["overlap_nb_hmu"] <= 1.0  # paper: 0.75

    def test_fig3_hotness_distribution(self, fig3):
        assert fig3["hotness"]["pages_for_90pct"] == pytest.approx(0.10, abs=0.02)

    def test_dataset_stats_match_meta(self):
        st = datagen.trace_stats(datagen.PAPER, n_batches=30)
        assert st["table_gb"] == pytest.approx(20.48)
        assert 0.10 <= st["touched_fraction"] <= 0.20   # paper: 14%
        assert st["topk_traffic_share"] >= 0.95
