"""Distribution tests.

* sharded-vs-single-device numerical equivalence on an 8-device CPU mesh
  (subprocess: device count must be set before jax initializes),
* dry-run cell smoke on a small mesh (lower+compile+analyze in-process is
  not possible after jax init, so these also go through subprocesses),
* sharding-rule unit checks that don't need devices.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
ENV = dict(os.environ, PYTHONPATH=str(REPO / "src"),
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           JAX_PLATFORMS="cpu")


def run_py(code: str, timeout=480):
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=ENV,
                          timeout=timeout, cwd=REPO)


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    r = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        import dataclasses
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.launch import sharding as sh
        from repro.launch.mesh import make_mesh, use_mesh
        from repro.models.model import init_params
        from repro.optim import get_optimizer, cosine_schedule
        from repro.train.steps import make_train_step

        cfg = get_smoke_config("llama3.2-3b")
        params = init_params(cfg, jax.random.key(0))
        opt = get_optimizer("adamw")
        state = opt.init(params)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32))),
        }
        step = make_train_step(cfg, opt, cosine_schedule(1e-3, 10, 100))

        # single device reference
        p1, s1, m1 = jax.jit(step)(params, state, batch)

        # 2x4 mesh, sharded
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg2 = dataclasses.replace(cfg, act_batch_axes=("data",))
        step2 = make_train_step(cfg2, opt, cosine_schedule(1e-3, 10, 100))
        with use_mesh(mesh):
            pspecs = sh.model_pspecs(mesh, cfg2)
            ospecs = sh.opt_pspecs(pspecs, state)
            bspecs = sh.batch_specs(mesh, cfg2, batch)
            jitted = jax.jit(
                step2,
                in_shardings=sh.named(mesh, (pspecs, ospecs, bspecs)),
                out_shardings=(*sh.named(mesh, (pspecs, ospecs)), None))
            p2, s2, m2 = jitted(params, state, batch)

        assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2, \\
            (float(m1["loss"]), float(m2["loss"]))
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
        worst = max(jax.tree.leaves(d))
        assert worst < 5e-2, worst
        print("OK", float(m1["loss"]), worst)
    """)
    assert "OK" in r.stdout, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


@pytest.mark.slow
def test_dryrun_cell_compiles_on_small_mesh():
    """The dry-run machinery end-to-end on a 2x4 mesh with a smoke config
    (the production 16x16/2x16x16 sweep runs via launch.dryrun --all)."""
    r = run_py("""
        import jax, dataclasses
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_mesh, use_mesh
        from repro.launch import sharding as sh, hloanalysis
        from repro.launch.shapes import ShapeSpec
        from repro.launch.dryrun import build_step

        for arch in ("mixtral-8x22b", "rwkv6-3b", "zamba2-2.7b"):
            cfg = get_smoke_config(arch)
            mesh = make_mesh((2, 4), ("data", "model"))
            shape = ShapeSpec("t", 64, 8, "train")
            with use_mesh(mesh):
                jitted, args = build_step(cfg, shape, mesh, {})
                compiled = jitted.lower(*args).compile()
                res = hloanalysis.analyze(compiled.as_text())
                assert res["flops"] > 0
            print("OK", arch, f"{res['flops']:.2e}")
    """)
    assert r.stdout.count("OK") == 3, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


@pytest.mark.slow
def test_serve_decode_compiles_sharded():
    r = run_py("""
        import jax
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_mesh, use_mesh
        from repro.launch.shapes import ShapeSpec
        from repro.launch.dryrun import build_step

        cfg = get_smoke_config("internlm2-1.8b")
        mesh = make_mesh((2, 4), ("data", "model"))
        shape = ShapeSpec("d", 128, 8, "decode")
        with use_mesh(mesh):
            jitted, args = build_step(cfg, shape, mesh, {})
            compiled = jitted.lower(*args).compile()
        print("OK")
    """)
    assert "OK" in r.stdout, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


# ------------------------------------------------------- rule units (no devices)
def test_sharding_rules_divisibility():
    from repro.configs import get_config
    from repro.models.model import param_pspecs

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    cfg = get_config("mixtral-8x22b")
    from repro.launch.sharding import default_rules
    rules = default_rules(FakeMesh(), cfg)
    assert rules["experts"] is None          # 8 experts don't divide 16
    assert rules["expert_mlp"] == "model"    # TP inside experts instead

    cfg2 = get_config("kimi-k2-1t-a32b")
    rules2 = default_rules(FakeMesh(), cfg2)
    assert rules2["experts"] == "model"      # 384 divides 16 -> EP
    assert rules2["kv_heads"] is None        # 8 kv heads don't divide 16

    # every pspec entry only references real axes
    specs = param_pspecs(cfg2, rules2)
    import jax
    from jax.sharding import PartitionSpec as P
    for leaf in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        for ax in leaf:
            assert ax in (None, "data", "model", "pod"), leaf


def test_batch_axes_divisibility():
    from repro.launch.sharding import batch_axes

    class M:
        shape = {"pod": 2, "data": 16, "model": 16}

    assert batch_axes(M(), 256) == ("pod", "data")
    assert batch_axes(M(), 16) == "data"
    assert batch_axes(M(), 1) is None


@pytest.mark.slow
def test_shard_map_moe_matches_reference():
    """The expert-parallel shard_map dispatch must be numerically identical
    to the single-program sort/scatter path (same capacity-per-group)."""
    r = run_py("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_mesh, use_mesh
        from repro.launch import sharding as sh
        from repro.models.model import init_params, forward

        base = get_smoke_config("kimi-k2-1t-a32b")
        params = init_params(base, jax.random.key(0))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, base.vocab_size, (4, 16)))

        # reference: single program, but with per-group capacity semantics:
        # emulate by running the sharded config on a (2,4) mesh and comparing
        # against the same grouped math traced WITHOUT the mesh is not
        # possible; instead check mesh-run vs mesh-run with expert_sharded
        # False (pure GSPMD) — dispatch math must agree where no tokens drop.
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg_ep = dataclasses.replace(
            base, act_batch_axes=("data",), moe_groups=(2, 4),
            moe_expert_sharded=True,
            moe=dataclasses.replace(base.moe, capacity_factor=8.0))
        cfg_ref = dataclasses.replace(
            base, act_batch_axes=("data",),
            moe=dataclasses.replace(base.moe, capacity_factor=8.0))
        with use_mesh(mesh):
            pspecs = sh.model_pspecs(mesh, cfg_ep)
            bspec = sh.batch_specs(mesh, cfg_ep, {"tokens": toks})["tokens"]
            shardings = sh.named(mesh, (pspecs, bspec))
            f_ep = jax.jit(lambda p, t: forward(p, cfg_ep, tokens=t)[0],
                           in_shardings=shardings)
            f_ref = jax.jit(lambda p, t: forward(p, cfg_ref, tokens=t)[0],
                            in_shardings=shardings)
            h_ep = np.asarray(f_ep(params, toks), np.float32)
            h_ref = np.asarray(f_ref(params, toks), np.float32)
        err = np.abs(h_ep - h_ref).max()
        # bf16 activations: one ulp at |h|~2 is 2^-5 = 0.03125, and the two
        # dispatch formulations sum expert outputs in different orders
        assert err <= 2 ** -4, err
        print("OK", err)
    """)
    assert "OK" in r.stdout, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
