"""Telemetry edge cases: PEBS cursor continuity across batch boundaries, HMU
log overflow accounting + drain reset, NB scanner wrap-around at n_blocks."""
import numpy as np
import jax.numpy as jnp

from repro.core import telemetry as tel


# ------------------------------------------------------ PEBS cursor continuity
def test_pebs_cursor_continues_across_batch_boundaries():
    """Sampling every period-th access of the *stream* must be invariant to
    how the stream is chopped into batches (the cursor carries the phase)."""
    period = 7
    rng = np.random.default_rng(0)
    stream = rng.integers(0, 50, 305).astype(np.int32)  # 305 % 7 != 0

    one = tel.pebs_init(50, period=period)
    one = tel.pebs_observe(one, jnp.asarray(stream))

    # uneven batch boundaries, none aligned to the period
    chopped = tel.pebs_init(50, period=period)
    for part in np.split(stream, [13, 100, 150, 296]):
        chopped = tel.pebs_observe(chopped, jnp.asarray(part))

    np.testing.assert_array_equal(np.asarray(one.sampled),
                                  np.asarray(chopped.sampled))
    # the cursor is an exact int32 carried modulo the period (a float cursor
    # drifts once the stream passes 2^24 accesses)
    assert int(one.cursor) == int(chopped.cursor) == 305 % period
    assert one.cursor.dtype == np.int32
    assert float(one.host_events) == float(chopped.host_events)


def test_pebs_cursor_phase_exact_beyond_float32_range():
    """A float32 cursor is only exact below 2^24; the int32 modulo cursor
    keeps the sampling phase exact for arbitrarily long streams.  Simulate a
    long-run state directly (cursor mid-phase, as after ~2^24 accesses) and
    check the next sample lands exactly on the period boundary."""
    period = 10_007
    st = tel.pebs_init(50, period=period)
    # as-if 2^24 + 3 accesses already observed: phase = (2**24 + 3) % period
    import dataclasses
    st = dataclasses.replace(
        st, cursor=jnp.asarray((2 ** 24 + 3) % period, jnp.int32))
    gap = period - int(st.cursor)            # accesses until the next sample
    st = tel.pebs_observe(st, jnp.zeros((gap + 1,), jnp.int32))
    assert int(np.asarray(st.sampled)[0]) == 1   # sampled exactly once
    assert int(st.cursor) == 1
    assert 0 <= int(st.cursor) < period


def test_pebs_samples_exactly_every_period_positions():
    period = 5
    st = tel.pebs_init(100, period=period)
    stream = jnp.asarray(np.arange(12, dtype=np.int32))  # block i at position i
    st = tel.pebs_observe(st, stream)
    sampled = np.asarray(st.sampled)
    # positions 0, 5, 10 sampled -> blocks 0, 5, 10
    expect = np.zeros(100, np.int32)
    expect[[0, 5, 10]] = 1
    np.testing.assert_array_equal(sampled, expect)


# --------------------------------------------------------- HMU log overflow
def test_hmu_overflow_drops_accumulate_across_batches():
    st = tel.hmu_init(4, log_capacity=10)
    st = tel.hmu_observe(st, jnp.zeros((6,), jnp.int32))    # 6 in log
    st = tel.hmu_observe(st, jnp.zeros((6,), jnp.int32))    # 4 fit, 2 dropped
    st = tel.hmu_observe(st, jnp.zeros((6,), jnp.int32))    # all 6 dropped
    assert float(st.log_used) == 10.0
    assert float(st.log_dropped) == 8.0
    # counter mode never loses events even when the log overflows
    assert int(np.asarray(st.counts)[0]) == 18


def test_hmu_drain_resets_log_and_charges_only_drained_records():
    st = tel.hmu_init(4, log_capacity=10)
    st = tel.hmu_observe(st, jnp.zeros((25,), jnp.int32))
    st = tel.hmu_drain_cost(st, per_record_cost=2.0)
    assert float(st.log_used) == 0.0            # drained
    assert float(st.host_events) == 20.0        # 10 records x cost 2
    assert float(st.log_dropped) == 15.0        # drops are NOT un-dropped
    # post-drain capacity is available again
    st = tel.hmu_observe(st, jnp.zeros((4,), jnp.int32))
    assert float(st.log_used) == 4.0
    assert float(st.log_dropped) == 15.0


# ---------------------------------------------------------- NB wrap-around
def test_nb_scan_ptr_wraps_at_n_blocks():
    n = 10
    st = tel.nb_init(n, scan_rate=7)
    empty = jnp.zeros((0,), jnp.int32)
    st = tel.nb_observe(st, empty)              # unmaps 0..6
    assert int(st.scan_ptr) == 7
    mapped = np.asarray(st.mapped)
    np.testing.assert_array_equal(mapped, np.r_[np.zeros(7, bool), np.ones(3, bool)])
    st = tel.nb_observe(st, empty)              # unmaps 7,8,9 then wraps to 0..3
    assert int(st.scan_ptr) == 4                # (7 + 7) % 10
    assert not np.asarray(st.mapped).any()      # full pass completed


def test_nb_wrapped_scan_faults_exactly_once_per_touch():
    n = 10
    st = tel.nb_init(n, scan_rate=7)
    empty = jnp.zeros((0,), jnp.int32)
    st = tel.nb_observe(st, empty)
    st = tel.nb_observe(st, empty)              # everything unmapped via wrap
    # touching a block twice in one batch faults once and re-maps it
    st = tel.nb_observe(st, jnp.asarray([9, 9, 2], jnp.int32))
    faults = np.asarray(st.faults)
    assert faults[9] == 1 and faults[2] == 1
    assert faults.sum() == 2
    mapped = np.asarray(st.mapped)
    assert mapped[9] and mapped[2]
    # host paid exactly one event per faulted block
    assert float(st.host_events) == 2.0


def test_nb_scan_rate_equal_n_blocks_unmaps_everything_each_call():
    n = 8
    st = tel.nb_init(n, scan_rate=n)
    st = tel.nb_observe(st, jnp.asarray([3], jnp.int32))
    assert int(st.scan_ptr) == 0                # full cycle lands back at 0
    faults = np.asarray(st.faults)
    assert faults[3] == 1 and faults.sum() == 1


def test_nb_scan_rate_above_n_blocks_wraps_cleanly():
    """scan_rate > n_blocks: one tick covers the whole space (possibly more
    than once) — every block unmapped, cursor at (rate % n), and a touch
    still faults exactly once."""
    n = 6
    st = tel.nb_init(n, scan_rate=15)               # 2.5 passes per tick
    st = tel.nb_observe(st, jnp.zeros((0,), jnp.int32))
    assert not np.asarray(st.mapped).any()
    assert int(st.scan_ptr) == 15 % n
    st = tel.nb_observe(st, jnp.asarray([2, 2, 5], jnp.int32))
    faults = np.asarray(st.faults)
    assert faults[2] == 1 and faults[5] == 1 and faults.sum() == 2
    assert float(st.host_events) == 2.0


def test_nb_zero_batch_epoch_keeps_ptr_and_host_events_consistent():
    """An epoch with no accesses still ticks the scanner (the kernel thread
    does not care whether the workload ran): scan_ptr advances, pages
    unmap, but host_events stays put — faults only fire on touches, and
    host_events must equal the all-time fault total exactly."""
    n, rate = 12, 5
    st = tel.nb_init(n, scan_rate=rate)
    empty = jnp.zeros((0,), jnp.int32)
    for tick in range(1, 5):
        st = tel.nb_observe(st, empty)
        assert int(st.scan_ptr) == (tick * rate) % n
        assert float(st.host_events) == 0.0
    st = tel.nb_observe(st, jnp.asarray([0, 1, 2], jnp.int32))
    assert float(st.host_events) == float(np.asarray(st.faults).sum())


def test_hmu_drain_cost_zero_cost_still_resets_log():
    st = tel.hmu_init(4, log_capacity=100)
    st = tel.hmu_observe(st, jnp.zeros((30,), jnp.int32))
    st = tel.hmu_drain_cost(st, per_record_cost=0.0)
    assert float(st.log_used) == 0.0
    assert float(st.host_events) == 0.0             # free drain charges nothing


def test_hmu_drain_cost_rejects_inexact_scales():
    """The exact hi/lo counter math only supports small integer scales; a
    fractional or huge cost must fail loudly, not silently round."""
    import pytest
    st = tel.hmu_init(4, log_capacity=100)
    with pytest.raises(ValueError, match="per_record_cost"):
        tel.hmu_drain_cost(st, per_record_cost=1.5)
    with pytest.raises(ValueError, match="per_record_cost"):
        tel.hmu_drain_cost(st, per_record_cost=64.0)
    with pytest.raises(ValueError, match="per_record_cost"):
        tel.hmu_drain_cost(st, per_record_cost=-1.0)


def test_hmu_event_scalars_exact_past_float32_range():
    """Satellite regression: the old float32 scalars stopped incrementing at
    2^24 (16.7M); the hi/lo int32 pair stays exact.  March log_used across
    the 2^24 boundary in 4M-access chunks and check the recombined value."""
    st = tel.hmu_init(8, log_capacity=1 << 33)
    step = 4_000_000
    for _ in range(5):                              # 20M > 2^24
        st = tel.hmu_observe(st, jnp.zeros((step,), jnp.int32), weight=1)
    assert float(st.log_used) == 5.0 * step         # exact, not 16_777_216
    st = tel.hmu_drain_cost(st, per_record_cost=2.0)
    assert float(st.host_events) == 10.0 * step     # exact scaled add
    assert float(st.log_used) == 0.0
