"""Optional-``hypothesis`` shim for the property tests.

The tier-1 suite must collect (and pass) on machines without the
``hypothesis`` package.  When hypothesis is installed we re-export the real
``given`` / ``settings`` / ``strategies``; otherwise a minimal deterministic
fallback generates ``max_examples`` pseudo-random examples per test from the
same two strategy combinators the suite actually uses (``st.integers`` and
``st.lists``).  The fallback is not a shrinker — a failing example is reported
as a plain assertion with the drawn arguments in the message.
"""
from __future__ import annotations

import itertools

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import zlib as _zlib

    import numpy as _np

    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        def example(self, rng) -> object:
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, min_value=0, max_value=1 << 16):
            self.lo, self.hi = int(min_value), int(max_value)

        def example(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _Lists(_Strategy):
        def __init__(self, elements, min_size=0, max_size=16):
            self.elements = elements
            self.lo, self.hi = int(min_size), int(max_size)

        def example(self, rng):
            n = int(rng.integers(self.lo, self.hi + 1))
            return [self.elements.example(rng) for _ in range(n)]

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=1 << 16):
            return _Integers(min_value, max_value)

        @staticmethod
        def lists(elements, min_size=0, max_size=16):
            return _Lists(elements, min_size, max_size)

    st = _Strategies()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._compat_max_examples = int(max_examples)
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            # NOTE: no functools.wraps — pytest would follow __wrapped__ to
            # the original signature and demand fixtures for the drawn args.
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples",
                            _DEFAULT_MAX_EXAMPLES)
                # deterministic per-test seed so failures reproduce
                # (crc32, not hash(): str hashing is randomized per process)
                seed = _zlib.crc32(fn.__name__.encode())
                rng = _np.random.default_rng(seed)
                for i in itertools.count():
                    if i >= n:
                        break
                    drawn_a = [s.example(rng) for s in arg_strategies]
                    drawn_kw = {k: s.example(rng)
                                for k, s in kw_strategies.items()}
                    try:
                        fn(*args, *drawn_a, **{**kwargs, **drawn_kw})
                    except Exception as e:  # noqa: BLE001 - re-raise annotated
                        raise AssertionError(
                            f"falsifying example #{i} for {fn.__name__}: "
                            f"args={drawn_a} kwargs={drawn_kw}"
                        ) from e

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._compat_max_examples = getattr(
                fn, "_compat_max_examples", _DEFAULT_MAX_EXAMPLES)
            return wrapper

        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
