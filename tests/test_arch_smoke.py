"""Per-architecture smoke tests: reduced config of the same family runs one
forward + train step + prefill/decode on CPU; asserts shapes and no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke_config, get_optimizer_name
from repro.models.model import init_params, forward, loss_fn, abstract_params
from repro.optim import get_optimizer, cosine_schedule
from repro.train.steps import make_train_step
from repro.serve import engine


def make_batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))}
    if cfg.frontend == "embeddings":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)) * 0.1, jnp.float32)
        if cfg.rope == "mrope":
            pos = np.broadcast_to(np.arange(s)[None, None], (3, b, s)).copy()
            batch["positions"] = jnp.asarray(pos)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    return request.param


def test_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    hidden, aux = forward(params, cfg, tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"),
                          positions=batch.get("positions"))
    assert hidden.shape == (2, 32, cfg.d_model)
    assert not np.any(np.isnan(np.asarray(hidden, np.float32)))
    loss = loss_fn(params, cfg, hidden, batch["labels"])
    assert np.isfinite(float(loss))
    if cfg.family == "moe":
        counts = np.asarray(aux["expert_counts"])
        assert counts.shape == (cfg.n_layers, cfg.moe.n_experts)
        assert counts.sum() == cfg.n_layers * 2 * 32 * cfg.moe.top_k


def test_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(1))
    opt = get_optimizer(get_optimizer_name(arch))
    step = make_train_step(cfg, opt, cosine_schedule(1e-3, 10, 100))
    opt_state = opt.init(params)
    batch = make_batch(cfg, seed=1)
    params2, opt_state2, metrics = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    diff = jax.tree.map(lambda a, b_: float(jnp.abs(a.astype(jnp.float32)
                                                    - b_.astype(jnp.float32)).max()),
                        params, params2)
    assert max(jax.tree.leaves(diff)) > 0
    assert int(opt_state2.step) == 1


def test_prefill_decode_consistency(arch):
    """Greedy decode after prefill == teacher-forced forward on same tokens."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(2))
    rng = np.random.default_rng(3)
    b, s = 2, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))

    # full forward logits at the last position
    hidden, _ = forward(params, cfg, tokens=tokens)
    from repro.models.model import logits_fn
    full_logits = np.asarray(logits_fn(params, cfg, hidden[:, -1:])[:, 0],
                             np.float32)

    logits_p, cache = engine.prefill(params, cfg, tokens=tokens, max_len=s + 4)
    np.testing.assert_allclose(np.asarray(logits_p, np.float32), full_logits,
                               rtol=3e-2, atol=3e-2)

    # decode one token and verify it matches teacher-forcing the same token
    nxt = jnp.argmax(logits_p, -1).astype(jnp.int32)
    logits_d, cache, _ = engine.decode_step(params, cfg, cache, nxt)
    tokens2 = jnp.concatenate([tokens, nxt[:, None]], axis=1)
    hidden2, _ = forward(params, cfg, tokens=tokens2)
    full2 = np.asarray(logits_fn(params, cfg, hidden2[:, -1:])[:, 0], np.float32)
    np.testing.assert_allclose(np.asarray(logits_d, np.float32), full2,
                               rtol=6e-2, atol=6e-2)


def test_abstract_params_match_init(arch):
    cfg = get_smoke_config(arch)
    abs_ = abstract_params(cfg)
    real = init_params(cfg, jax.random.key(0))
    flat_a = jax.tree.leaves(jax.tree.map(lambda x: (x.shape, str(x.dtype)), abs_))
    flat_r = jax.tree.leaves(jax.tree.map(lambda x: (x.shape, str(x.dtype)), real))
    assert flat_a == flat_r
