"""DLRM trace-generator tests: per-phase trace_stats for PhaseShiftSampler
and the rotate_by >= n_pages wraparound regression (rotations are modular —
rotate_by == n_pages is the identity, n_pages + r behaves like r)."""
import numpy as np
import pytest

from repro.dlrm import datagen

SPEC = datagen.SMALL


def test_trace_stats_default_is_single_distribution():
    st = datagen.trace_stats(SPEC, n_batches=10)
    assert {"table_gb", "touched_fraction", "touched_gb",
            "topk_traffic_share", "traffic_gb_per_batch"} <= set(st)
    assert "phases" not in st
    assert 0.0 < st["touched_fraction"] <= 1.0
    assert 0.0 < st["topk_traffic_share"] <= 1.0


def test_trace_stats_reports_per_phase_rows():
    n = SPEC.n_pages
    st = datagen.trace_stats(SPEC, n_batches=10, phases=3, rotate_by=n // 3)
    assert st["rotate_by"] == n // 3
    # distribution stats are phase-invariant (a rotation permutes the same
    # Zipf mass), so they are reported once at the top level
    assert 0.0 < st["topk_traffic_share"] <= 1.0
    rows = st["phases"]
    assert [r["phase"] for r in rows] == [0, 1, 2]
    for r in rows:
        assert 0.0 <= r["hot_overlap_prev"] <= 1.0
    assert rows[0]["hot_overlap_prev"] == 1.0      # phase 0 vs itself
    assert rows[0]["hot_overlap_phase0"] == 1.0
    # a third-of-the-table rotation moves (most of) the hot head each phase
    assert rows[1]["hot_overlap_prev"] < 0.5
    assert rows[2]["hot_overlap_phase0"] < 0.5


def test_rotate_by_full_table_is_identity_rotation():
    n = SPEC.n_pages
    s = datagen.PhaseShiftSampler(SPEC, rotate_by=n, seed=0)
    np.testing.assert_array_equal(s.true_top_k_pages(100, phase=0),
                                  s.true_top_k_pages(100, phase=1))
    st = datagen.trace_stats(SPEC, n_batches=5, phases=2, rotate_by=n)
    assert st["phases"][1]["hot_overlap_prev"] == 1.0


def test_rotate_by_beyond_n_pages_wraps():
    n = SPEC.n_pages
    k = 100
    wrapped = datagen.PhaseShiftSampler(SPEC, rotate_by=n + 7, seed=0)
    plain = datagen.PhaseShiftSampler(SPEC, rotate_by=7, seed=0)
    for phase in (1, 2, 5):
        np.testing.assert_array_equal(wrapped.true_top_k_pages(k, phase=phase),
                                      plain.true_top_k_pages(k, phase=phase))
    # sampling stays in-bounds and concentrates on the wrapped hot head
    pages = wrapped.sample(20_000, phase=1)
    assert pages.min() >= 0 and pages.max() < n
    hot = set(wrapped.true_top_k_pages(k, phase=1).tolist())
    assert np.isin(pages, list(hot)).mean() > 0.5


def test_page_probabilities_rotate_with_the_phase():
    n = SPEC.n_pages
    s = datagen.PhaseShiftSampler(SPEC, rotate_by=n // 2, seed=0)
    p0, p1 = s.page_probabilities(0), s.page_probabilities(1)
    assert p0.sum() == pytest.approx(1.0)
    assert p1.sum() == pytest.approx(1.0)
    # same mass, rotated support: sorted spectra match, assignments differ
    np.testing.assert_allclose(np.sort(p0), np.sort(p1))
    assert not np.allclose(p0, p1)
    # each phase's most probable page is that phase's top-1 page
    assert int(np.argmax(p0)) == int(s.true_top_k_pages(1, phase=0)[0])
    assert int(np.argmax(p1)) == int(s.true_top_k_pages(1, phase=1)[0])
    # phase-0 probabilities match the base sampler's
    np.testing.assert_allclose(
        p0, datagen.ZipfPageSampler(SPEC, seed=0).page_probabilities())
