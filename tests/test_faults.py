"""repro.faults — fault injection + degradation-aware tiering (PR 7).

The contract under test, in the ISSUE's words: a fault-free
:class:`FaultModel` is **bit-identical** to running with none (single
device, sharded, fleet, every ``sync_every=K``); with faults *on* the
epoch still costs exactly 2 dispatches and one trace; and each injected
fault degrades its collector the way the real mechanism does — saturation
pins counters, drops starve PEBS, resets wipe HMU deltas, stalls freeze
the NB scanner, staleness serves estimates ``d`` epochs late — while the
hardened runtime (quality-gated fallback + demotion hysteresis) holds
coverage where the naive lane collapses."""
import dataclasses
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import runtime as rtmod
from repro.core import telemetry as tel
from repro.core.runtime import ALL_POLICIES, EpochRuntime
from repro.faults import (COLLECTORS, Counter64, FaultModel, Hardening,
                          LANE_COLLECTOR, counter_add, counter_init,
                          counter_scaled_add)
from repro.fleet import FleetScenario, TenantSpec, run_fleet
from repro.scenarios import DLRMScenario, KVCacheScenario, run_scenario
from repro.dlrm import datagen

REPO = Path(__file__).resolve().parent.parent
SUBPROC_ENV = dict(os.environ, PYTHONPATH=str(REPO / "src"),
                   XLA_FLAGS="--xla_force_host_platform_device_count=8",
                   JAX_PLATFORMS="cpu")
SMALL_SPEC = dataclasses.replace(datagen.SMALL, lookups_per_batch=8_000)


def run_py(code: str, timeout=480):
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=SUBPROC_ENV,
                          timeout=timeout, cwd=REPO)


def make_runtime(**kw):
    kw.setdefault("policies", ALL_POLICIES)
    kw.setdefault("pebs_period", 101)
    kw.setdefault("nb_scan_rate", 90)
    return EpochRuntime(400, 40, fused=True, **kw)


def make_epochs(n_epochs, n_blocks=400, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, n_blocks, (3, 2000)).astype(np.int32)
            for _ in range(n_epochs)]


def zipf_epochs(n_epochs, n_blocks=400, seed=3):
    rng = np.random.default_rng(seed)
    z = (rng.zipf(1.5, size=(n_epochs, 4, 4000)) % n_blocks).astype(np.int32)
    return [z[i] for i in range(n_epochs)]


# =====================================================  Counter64 exactness
def test_counter64_exact_across_2p24():
    """Satellite regression: float32 scalars silently stop incrementing at
    2^24; the hi/lo pair must march straight through."""
    c = counter_init()
    step = jnp.asarray(3_000_000, jnp.int32)
    for i in range(1, 8):                      # 21M > 2^24
        c = counter_add(c, step)
        assert float(c) == 3_000_000.0 * i
    assert int(c) == 21_000_000


def test_counter64_scaled_add_and_validation():
    a = counter_add(counter_init(), jnp.asarray(10_000_000, jnp.int32))
    b = counter_scaled_add(counter_init(), a, 3)
    assert float(b) == 30_000_000.0
    with pytest.raises(ValueError, match="scale"):
        counter_scaled_add(counter_init(), a, 64)
    with pytest.raises(ValueError, match="scale"):
        counter_scaled_add(counter_init(), a, -1)


def test_counter64_reads_like_the_old_float_scalar():
    """Every pre-existing caller reads event scalars via float(...) — the
    Counter64 must satisfy that protocol exactly."""
    st = tel.hmu_init(4, log_capacity=100)
    st = tel.hmu_observe(st, jnp.zeros((30,), jnp.int32))
    assert isinstance(st.log_used, Counter64)
    assert float(st.log_used) == 30.0
    assert int(float(st.log_dropped)) == 0


# ==========================================================  model validation
def test_fault_model_validation():
    with pytest.raises(ValueError, match="reset_p"):
        FaultModel.create(reset_p=np.zeros((2,), np.float32))
    with pytest.raises(ValueError, match="stale_epochs"):
        FaultModel.create(stale_epochs=-1)
    with pytest.raises(ValueError, match="pebs_drop_p"):
        FaultModel.create(pebs_drop_p=1.5)
    with pytest.raises(ValueError, match="entries"):
        FaultModel.create(pebs_drop_p=np.zeros((7,), np.float32), n_blocks=9)


def test_fault_model_for_segments_rejects_global_knobs_per_segment():
    with pytest.raises(ValueError, match="non-per-block"):
        FaultModel.for_segments((0, 5, 10), [{"reset_p": 1.0}, None])
    with pytest.raises(ValueError, match="offsets"):
        FaultModel.for_segments((0, 5), [{}, {}])


def test_fault_model_for_segments_builds_per_block_arrays():
    fm = FaultModel.for_segments(
        (0, 4, 10),
        [{"pebs_drop_p": 0.5, "hmu_counter_bits": 3}, None],
        nb_stall_p=0.25)
    drop = np.asarray(fm.pebs_drop_p)
    cap = np.asarray(fm.hmu_counter_max)
    np.testing.assert_allclose(drop[:4], 0.5)
    np.testing.assert_allclose(drop[4:], 0.0)
    assert (cap[:4] == 7).all() and (cap[4:] == np.iinfo(np.int32).max).all()
    assert float(fm.nb_stall_p) == 0.25


def test_hardening_validation():
    with pytest.raises(ValueError, match="hysteresis"):
        Hardening.make(demote_hysteresis=0)
    with pytest.raises(ValueError, match="unknown fallback lane"):
        Hardening.make(fallback={"nope": "hmu"})
    with pytest.raises(ValueError, match="compiler hints"):
        Hardening.make(fallback={"prefetch": "hmu"})
    with pytest.raises(ValueError, match="different collector"):
        Hardening.make(fallback={"hmu_oracle": "hmu"})
    with pytest.raises(ValueError, match="unknown fallback collector"):
        Hardening.make(fallback={"hmu_oracle": "tsc"})
    with pytest.raises(ValueError, match="quality_floor"):
        Hardening.make(quality_floor=1.5)


def test_faults_require_the_fused_path():
    with pytest.raises(ValueError, match="fused"):
        EpochRuntime(100, 10, fused=False, faults=FaultModel.create())
    with pytest.raises(ValueError, match="fused"):
        EpochRuntime(100, 10, fused=False, hardening=Hardening.make())


# ===========================================  neutral-model bit-identity
@pytest.mark.parametrize("sync_every", [1, 4])
def test_neutral_model_bit_identical_single_device(sync_every):
    """ISSUE acceptance: faults disabled => the fused path reproduces
    today's records and placements bit for bit, for K in {1, 4}."""
    epochs = make_epochs(6)
    base = make_runtime(sync_every=sync_every)
    tb = base.run(iter(epochs))
    neut = make_runtime(sync_every=sync_every,
                        faults=FaultModel.create(n_blocks=400))
    tn = neut.run(iter(epochs))
    for lane in ALL_POLICIES:
        for x, y in zip(tb.lane(lane), tn.lane(lane)):
            assert x.to_dict() == y.to_dict(), (lane, x.epoch)
        np.testing.assert_array_equal(base.lanes[lane].slot_to_block,
                                      neut.lanes[lane].slot_to_block)


def test_neutral_hardening_changes_nothing_but_reports_quality():
    """Hardening enabled on healthy telemetry: decisions (and every record
    field but the new quality estimate) match the unhardened run, and the
    estimate itself reads healthy (~1) for every collector-backed lane."""
    epochs = make_epochs(5)
    tb = make_runtime().run(iter(epochs))
    th = make_runtime(
        faults=FaultModel.create(n_blocks=400),
        hardening=Hardening.make(fallback={"hmu_oracle": "pebs"}),
    ).run(iter(epochs))
    for lane in ALL_POLICIES:
        for x, y in zip(tb.lane(lane), th.lane(lane)):
            dx, dy = x.to_dict(), y.to_dict()
            assert dx.pop("quality") == 1.0          # unhardened: constant
            q = dy.pop("quality")
            assert dx == dy, (lane, x.epoch)
            if LANE_COLLECTOR[lane] is None:
                assert q == 1.0                      # hint lanes never degrade
            else:
                assert q > 0.9, (lane, q)


def test_neutral_model_bit_identical_fleet():
    fl = FleetScenario([
        TenantSpec(DLRMScenario(spec=SMALL_SPEC, n_epochs=3,
                                batches_per_epoch=2)),
        TenantSpec(KVCacheScenario(batch=2, n_epochs=3, batches_per_epoch=2,
                                   accesses_per_batch=1024)),
    ])
    base = run_fleet(fl, hints=False, sync_every=2)
    neut = run_fleet(fl, hints=False, sync_every=2,
                     faults={"dlrm": {"pebs_drop_p": 0.0}})
    assert base["trajectory"] == neut["trajectory"]
    assert base["summary"] == neut["summary"]
    assert base["tenants"] == neut["tenants"]


@pytest.mark.slow
def test_neutral_model_bit_identical_sharded():
    """ISSUE acceptance: neutrality is sharding-transparent — an 8-device
    mesh run with a default FaultModel equals the meshless no-model run."""
    r = run_py("""
        import dataclasses, json
        from repro.dlrm import datagen
        from repro.faults import FaultModel
        from repro.launch.mesh import make_telemetry_mesh, use_mesh
        from repro.scenarios import DLRMScenario, run_scenario

        spec = dataclasses.replace(datagen.SMALL, lookups_per_batch=8_000)
        sc = DLRMScenario(spec=spec, n_epochs=4, batches_per_epoch=2,
                          shift_at=2)
        ref = run_scenario(sc, hints=True)
        mesh = make_telemetry_mesh(8)
        with use_mesh(mesh):
            shd = run_scenario(
                DLRMScenario(spec=spec, n_epochs=4, batches_per_epoch=2,
                             shift_at=2),
                hints=True, mesh=mesh, sync_every=2,
                faults=FaultModel.create(n_blocks=sc.n_blocks))
        assert json.dumps(ref["trajectory"], sort_keys=True) == \\
            json.dumps(shd["trajectory"], sort_keys=True)
        print("OK")
    """)
    assert "OK" in r.stdout, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


# ==================================================  dispatch / trace gates
def test_faulty_epoch_still_two_dispatches_one_trace():
    """ISSUE acceptance: the whole fault model rides inside the two existing
    dispatches — injection adds zero dispatches and zero retraces."""
    rt = make_runtime(
        faults=FaultModel.create(pebs_drop_p=0.5, reset_p=0.02,
                                 nb_stall_p=0.2, stale_epochs=2,
                                 hmu_counter_bits=10, seed=5, n_blocks=400),
        hardening=Hardening.make(fallback={"hmu_oracle": "pebs"},
                                 demote_hysteresis=3),
    )
    rt.step(make_epochs(1, seed=9)[0])           # warm the trace
    rt.flush()
    with rtmod.counting() as counts:
        rt.run(iter(make_epochs(8)))
        assert counts.dispatch == {"observe_all": 8, "epoch_step": 8,
                                   "reference": 0, "hint_refresh": 0,
                                   "record_sync": 8}
        assert counts.trace["epoch_step"] == 0


# ==================================================  per-fault degradation
def test_hmu_saturation_pins_counters_at_the_cap():
    fm = FaultModel.create(hmu_counter_bits=3, n_blocks=8)   # cap = 7
    bundle = tel.bundle_init(8, faults=fm)
    batches = jnp.zeros((1, 100), jnp.int32)                 # 100 hits, block 0
    bundle = tel.observe_all(bundle, batches)
    counts = np.asarray(bundle.hmu.counts)
    assert counts[0] == 7                                    # clamped, not wrapped
    assert int(tel.hmu_saturated(bundle.hmu,
                                 bundle.faults.hmu_counter_max)) == 1
    assert int(np.asarray(bundle.true_counts)[0]) == 100     # truth unaffected


def test_hmu_saturating_observe_without_a_model_clamps_at_int32():
    """Satellite: the bare collector saturates at int32 max instead of
    wrapping negative (poisoning top-k)."""
    st = tel.hmu_init(4)
    st = dataclasses.replace(
        st, counts=st.counts.at[0].set(np.iinfo(np.int32).max - 2))
    st = tel.hmu_observe(st, jnp.zeros((10,), jnp.int32))
    assert int(np.asarray(st.counts)[0]) == np.iinfo(np.int32).max
    assert int(tel.hmu_saturated(st)) == 1


def test_pebs_drops_starve_the_sampled_histogram():
    fm = FaultModel.create(pebs_drop_p=1.0, n_blocks=16, seed=2)
    bundle = tel.bundle_init(16, pebs_period=3, faults=fm)
    bundle = tel.observe_all(
        bundle, jnp.arange(48, dtype=jnp.int32).reshape(2, 24) % 16)
    assert int(np.asarray(bundle.pebs.sampled).sum()) == 0
    assert float(bundle.pebs.host_events) == 0.0      # dropped != serviced
    assert float(bundle.faults.pebs_dropped) == 16.0  # 48 accesses / period 3


def test_nb_stall_freezes_scanner_and_counts_stalls():
    fm = FaultModel.create(nb_stall_p=1.0, n_blocks=10, seed=4)
    bundle = tel.bundle_init(10, nb_scan_rate=4, faults=fm)
    for _ in range(3):
        bundle = tel.observe_all(bundle, jnp.zeros((2, 5), jnp.int32))
    assert int(bundle.nb.scan_ptr) == 0               # cursor never moved
    assert int(np.asarray(bundle.nb.faults).sum()) == 0   # nothing unmapped
    assert int(bundle.faults.nb_stalls) == 6          # every batch tick stalled


def test_collector_reset_wipes_counts_and_ticks_the_event_counter():
    fm = FaultModel.create(reset_p=np.array([1.0, 0.0, 0.0], np.float32),
                           n_blocks=8, seed=0)
    bundle = tel.bundle_init(8, faults=fm)
    bundle = tel.observe_all(bundle, jnp.zeros((2, 50), jnp.int32))
    bundle = tel.observe_all(bundle, jnp.zeros((2, 50), jnp.int32))
    # each epoch resets HMU counts before observing: only one epoch survives
    assert int(np.asarray(bundle.hmu.counts)[0]) == 100
    assert int(np.asarray(bundle.faults.resets)[COLLECTORS.index("hmu")]) == 2
    assert int(np.asarray(bundle.true_counts)[0]) == 200


def test_staleness_serves_estimates_d_epochs_late():
    """One hot block per epoch, moving: with stale_epochs=d the placement
    must track the block that was hot d epochs ago, and the served-estimate
    coverage collapses while the accounting (d_true) stays current."""
    n, d = 64, 2
    epochs = [np.full((1, 512), e, np.int32) for e in range(8)]
    rt = EpochRuntime(n, 1, fused=True, policies=("hmu_oracle",),
                      faults=FaultModel.create(stale_epochs=d, n_blocks=n))
    traj = rt.run(iter(epochs))
    assert int(np.asarray(rt.lanes["hmu_oracle"].slot_to_block)[0]) == 7 - d
    assert traj.lane("hmu_oracle")[-1].coverage == 0.0   # d epochs behind
    fresh = EpochRuntime(n, 1, fused=True, policies=("hmu_oracle",),
                         faults=FaultModel.create(n_blocks=n))
    fresh.run(iter(epochs))
    # without staleness the same stream tracks the *current* hot block
    assert int(np.asarray(fresh.lanes["hmu_oracle"].slot_to_block)[0]) == 7


# ============================================  hardening: fallback + hysteresis
def test_fallback_holds_coverage_where_naive_lane_collapses():
    """ISSUE headline: HMU resetting every epoch guts the oracle lane's
    deltas; the hardened run watches quality crater and swaps the lane's
    input to PEBS, holding coverage the naive lane loses."""
    eps = zipf_epochs(12)
    fm = lambda: FaultModel.create(
        reset_p=np.array([1.0, 0.0, 0.0], np.float32), seed=11, n_blocks=400)
    naive = EpochRuntime(400, 40, fused=True, policies=("hmu_oracle",),
                         pebs_period=101, faults=fm())
    tn = naive.run(iter(eps))
    hard = EpochRuntime(400, 40, fused=True, policies=("hmu_oracle",),
                        pebs_period=101, faults=fm(),
                        hardening=Hardening.make(
                            fallback={"hmu_oracle": "pebs"}))
    th = hard.run(iter(eps))
    cn = np.mean([r.coverage for r in tn.lane("hmu_oracle")[3:]])
    ch = np.mean([r.coverage for r in th.lane("hmu_oracle")[3:]])
    assert ch > cn + 0.05, (cn, ch)
    # the record stream shows the detection: smoothed quality craters
    assert th.lane("hmu_oracle")[-1].quality < 0.2
    assert tn.lane("hmu_oracle")[-1].quality == 1.0      # naive: no estimator


def test_hysteresis_one_matches_unhardened_demotions():
    """H=1 is the seed behaviour: the hardened reactive lane demotes on the
    first cold epoch exactly like the unhardened run (quality aside)."""
    epochs = make_epochs(6, seed=7)
    tb = make_runtime(policies=("reactive_watermark",)).run(iter(epochs))
    th = make_runtime(policies=("reactive_watermark",),
                      faults=FaultModel.create(n_blocks=400),
                      hardening=Hardening.make(demote_hysteresis=1),
                      ).run(iter(epochs))
    for x, y in zip(tb.lane("reactive_watermark"),
                    th.lane("reactive_watermark")):
        dx, dy = x.to_dict(), y.to_dict()
        dx.pop("quality"), dy.pop("quality")
        assert dx == dy


def test_hysteresis_defers_demotion_until_h_cold_epochs():
    """A block hot once then silent: H=1 demotes it after its first cold
    epoch, H=4 keeps it resident through 3 cold epochs."""
    n, k = 32, 4
    hot = np.full((1, 256), 5, np.int32)
    cold = np.full((1, 256), 9, np.int32)            # keeps traffic flowing
    epochs = [hot, cold, cold, cold]
    def demotions(h):
        rt = EpochRuntime(n, k, fused=True, policies=("reactive_watermark",),
                          faults=FaultModel.create(n_blocks=n),
                          hardening=Hardening.make(demote_hysteresis=h))
        rt.run(iter(e.copy() for e in epochs))
        return [r.demoted for r in rt.records["reactive_watermark"]]
    d1, d4 = demotions(1), demotions(4)
    assert sum(d1[1:]) > 0                           # demoted while cold
    assert sum(d4[1:3]) == 0                         # survived 2 cold epochs
    assert sum(d4) <= sum(d1)


# ==========================================================  fleet integration
def test_fleet_per_tenant_profile_degrades_only_that_tenant():
    """Tenant-segmented drop_p: the faulty tenant's PEBS-backed accuracy
    falls while the healthy tenant keeps its signal (the collectors are
    shared; the per-block drop array is not)."""
    def fleet():
        return FleetScenario([
            TenantSpec(DLRMScenario(spec=SMALL_SPEC, n_epochs=4,
                                    batches_per_epoch=2)),
            TenantSpec(KVCacheScenario(batch=2, n_epochs=4,
                                       batches_per_epoch=2,
                                       accesses_per_batch=1024)),
        ], pebs_period=11)
    fl = fleet()
    fm = fl.build_faults({"dlrm": {"pebs_drop_p": 1.0}}, seed=1)
    drop = np.asarray(fm.pebs_drop_p)
    dl = fl.tenant_index("dlrm")
    assert (drop[fl.offsets[dl]:fl.offsets[dl + 1]] == 1.0).all()
    assert (drop[fl.offsets[dl + 1]:] == 0.0).all()
    out = run_fleet(fleet(), policies=("hinted",), hints=True, faults=fm)
    assert set(out["tenants"]) == {"dlrm", "kv_cache"}
    assert "hinted" in out["tenants"]["dlrm"]["lanes"]
    with pytest.raises(KeyError, match="unknown tenant"):
        fl.build_faults({"nope": {}})


def test_fleet_faulty_run_keeps_two_dispatches():
    fl = FleetScenario([
        TenantSpec(DLRMScenario(spec=SMALL_SPEC, n_epochs=3,
                                batches_per_epoch=2)),
        TenantSpec(KVCacheScenario(batch=2, n_epochs=3, batches_per_epoch=2,
                                   accesses_per_batch=1024)),
    ])
    with rtmod.counting() as c:
        run_fleet(fl, hints=False, sync_every=3,
                  faults={"dlrm": {"pebs_drop_p": 0.7}},
                  hardening=Hardening.make(fallback={"hinted": "hmu"}))
        assert c.dispatch["observe_all"] == 3
        assert c.dispatch["epoch_step"] == 3
        assert c.dispatch["reference"] == 0
        assert c.dispatch["record_sync"] == 1
