"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle, with
shape/dtype sweeps, plus hypothesis property tests on telemetry invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.gather_count import gather_count, gather_count_ref
from repro.kernels.embedding_bag import embedding_bag, embedding_bag_ref
from repro.kernels.flash_attention import flash_attention, attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas


# --------------------------------------------------------------- gather_count
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,m,block_rows", [
    (256, 128, 128, 8),
    (512, 256, 384, 16),
    (128, 512, 100, 4),     # M not a tile multiple -> padding path
])
def test_gather_count_matches_ref(n, d, m, block_rows, dtype):
    rng = np.random.default_rng(0)
    storage = jnp.asarray(rng.normal(size=(n, d)), dtype)
    idx = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    counts = jnp.zeros((n // block_rows,), jnp.int32)
    out_p, c_p = gather_count(storage, idx, counts, block_rows=block_rows,
                              use_pallas=True, interpret=True, tile_m=128)
    out_r, c_r = gather_count_ref(storage, idx, counts, block_rows=block_rows)
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32))
    np.testing.assert_array_equal(np.asarray(c_p), np.asarray(c_r))


@pytest.mark.parametrize("m,tile_m", [
    (1, 128),       # a single lookup: the tile is almost all padding
    (129, 128),     # one element past a tile boundary
    (127, 128),     # one element short of a tile
])
def test_gather_count_ragged_tiles_pad_correction(m, tile_m):
    """The wrapper pads ragged index tails with row 0 and subtracts the
    phantom counts afterwards — block 0's counter must come out exact even
    when padding dominates the final tile."""
    rng = np.random.default_rng(7)
    storage = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 64, m), jnp.int32)
    counts = jnp.full((8,), 5, jnp.int32)        # non-zero carry-in
    out_p, c_p = gather_count(storage, idx, counts, block_rows=8,
                              use_pallas=True, interpret=True, tile_m=tile_m)
    out_r, c_r = gather_count_ref(storage, idx, counts, block_rows=8)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r))
    np.testing.assert_array_equal(np.asarray(c_p), np.asarray(c_r))
    assert out_p.shape == (m, 128)


def test_embedding_bag_ragged_bag_grid():
    """Bag grid that is no multiple of anything tile-ish (B=3, L=5) — the
    kernel's per-bag loop must not depend on round shapes."""
    rng = np.random.default_rng(8)
    storage = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 128, (3, 5)), jnp.int32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, (3, 5)), jnp.float32)
    counts = jnp.zeros((16,), jnp.int32)
    out_p, c_p = embedding_bag(storage, idx, counts, w, block_rows=8,
                               use_pallas=True, interpret=True)
    out_r, c_r = embedding_bag_ref(storage, idx, w, counts, block_rows=8)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(c_p), np.asarray(c_r))


def test_gather_count_accumulates_over_calls():
    storage = jnp.zeros((64, 128), jnp.float32)
    counts = jnp.zeros((8,), jnp.int32)
    idx = jnp.asarray([0, 8, 8, 63], jnp.int32)
    for _ in range(3):
        _, counts = gather_count(storage, idx, counts, block_rows=8,
                                 use_pallas=True, interpret=True, tile_m=128)
    expect = np.zeros(8, np.int32)
    expect[0] += 3; expect[1] += 6; expect[7] += 3
    np.testing.assert_array_equal(np.asarray(counts), expect)


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=255), min_size=1, max_size=64))
def test_property_counts_equal_exact_histogram(idx_list):
    """HMU telemetry invariant: kernel counters == exact per-block histogram."""
    storage = jnp.zeros((256, 128), jnp.bfloat16)
    idx = jnp.asarray(idx_list, jnp.int32)
    counts = jnp.zeros((32,), jnp.int32)
    _, c = gather_count(storage, idx, counts, block_rows=8,
                        use_pallas=True, interpret=True, tile_m=128)
    ref = np.bincount(np.asarray(idx_list) // 8, minlength=32)
    np.testing.assert_array_equal(np.asarray(c), ref)


# -------------------------------------------------------------- embedding_bag
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,l,n,d,block_rows", [
    (4, 8, 256, 128, 8),
    (8, 16, 512, 256, 16),
    (2, 4, 128, 512, 4),
])
def test_embedding_bag_matches_ref(b, l, n, d, block_rows, dtype):
    rng = np.random.default_rng(1)
    storage = jnp.asarray(rng.normal(size=(n, d)), dtype)
    idx = jnp.asarray(rng.integers(0, n, (b, l)), jnp.int32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, (b, l)), jnp.float32)
    counts = jnp.zeros((n // block_rows,), jnp.int32)
    out_p, c_p = embedding_bag(storage, idx, counts, w, block_rows=block_rows,
                               use_pallas=True, interpret=True)
    out_r, c_r = embedding_bag_ref(storage, idx, w, counts, block_rows=block_rows)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32), rtol=tol, atol=tol)
    np.testing.assert_array_equal(np.asarray(c_p), np.asarray(c_r))


def test_embedding_bag_unweighted_defaults_to_sum():
    storage = jnp.eye(16, 128, dtype=jnp.float32)
    idx = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    counts = jnp.zeros((4,), jnp.int32)
    out, _ = embedding_bag(storage, idx, counts, block_rows=4,
                           use_pallas=True, interpret=True)
    expect = np.zeros((1, 128), np.float32)
    expect[0, :4] = 1.0
    np.testing.assert_allclose(np.asarray(out), expect)


# ------------------------------------------------------------ flash_attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,kvh,s,d", [
    (4, 4, 256, 128),      # MHA
    (8, 2, 256, 128),      # GQA 4:1
    (2, 1, 512, 256),      # MQA
])
def test_flash_attention_causal_matches_ref(bh, kvh, s, d, dtype):
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(bh, s, d)) * 0.3, dtype)
    k = jnp.asarray(rng.normal(size=(kvh, s, d)) * 0.3, dtype)
    v = jnp.asarray(rng.normal(size=(kvh, s, d)) * 0.3, dtype)
    out_p = flash_attention_pallas(q, k, v, q_per_kv=bh // kvh, causal=True,
                                   interpret=True)
    out_r = attention_ref(q, k, v, q_per_kv=bh // kvh, causal=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32), rtol=tol, atol=tol)


def test_flash_attention_sliding_window():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 512, 128)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 512, 128)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 512, 128)) * 0.3, jnp.float32)
    out_p = flash_attention_pallas(q, k, v, q_per_kv=1, causal=True, window=128,
                                   interpret=True)
    out_r = attention_ref(q, k, v, q_per_kv=1, causal=True, window=128)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(2, 256, 128)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 256, 128)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 256, 128)) * 0.3, jnp.float32)
    out_p = flash_attention_pallas(q, k, v, q_per_kv=1, causal=False, interpret=True)
    out_r = attention_ref(q, k, v, q_per_kv=1, causal=False)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_wrapper_fallback_on_cpu():
    # wrapper should silently use the oracle on CPU (no TPU available here)
    q = jnp.ones((2, 128, 128), jnp.float32)
    out = flash_attention(q, q, q, q_per_kv=1)
    assert out.shape == (2, 128, 128)
    assert not np.any(np.isnan(np.asarray(out)))
