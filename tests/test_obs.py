"""repro.obs — span tracing, metrics registry, chrome-trace timelines.

Four layers under test, matching the observability PR's hard rule that
watching the runtime must cost the watched system nothing:

* **Registry** — counter/gauge/histogram families with labeled children,
  kind-mismatch and bad-bucket rejection, and the :class:`CounterDict`
  bridge that keeps ``core.runtime``'s ``DISPATCH_COUNTS``/``TRACE_COUNTS``
  dict API (including nested ``counting()`` scopes) while every increment
  lands in ``repro_dispatch_total{kind=...}``.
* **Tracer** — nestable spans over an injectable clock (exact durations
  with a fake clock), the NOOP_SPAN singleton identity, and a
  tracemalloc-verified zero-allocation disabled hot loop.
* **Timeline** — chrome trace-event conversion, the synthesized device
  track, and ``pipelining_visible``: structurally True for a
  ``sync_every=K>1`` span pattern, False for K=1.
* **Integration** — an enabled-tracer runtime run produces exactly the
  expected spans with zero added dispatches and bit-identical records vs
  disabled; runtime_span/runtime_metric wire records validate against the
  frozen schema; the Prometheus sink escapes hostile label values, emits
  HELP/TYPE for every family, and publishes the export client's own drop
  counters.
"""
import json
import tracemalloc

import numpy as np
import pytest

from repro.core import runtime as rtmod
from repro.core.runtime import EpochRuntime
from repro.export import (ExportClient, MemorySink, PrometheusTextSink,
                          SchemaError, runtime_metric_wire,
                          runtime_span_wire, validate_record)
from repro.obs import chrometrace
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.metrics import CounterDict, MetricsRegistry
from repro.obs.trace import (NOOP_SPAN, NULL_TRACER, Clock, Span, SpanTracer,
                             tracing)


class FakeClock(Clock):
    """Deterministic clock: each read returns the next scripted instant."""

    def __init__(self, start=0.0, step=1.0):
        self.t = start
        self.step = step
        super().__init__(self._tick)

    def _tick(self):
        t, self.t = self.t, self.t + self.step
        return t


# ---------------------------------------------------------------- registry
class TestRegistry:
    def test_counter_gauge_histogram_basics(self):
        reg = MetricsRegistry()
        c = reg.counter("repro_x_total", help="h").labels(kind="a")
        c.inc()
        c.inc(3)
        assert c.value == 4
        g = reg.gauge("repro_g").labels()
        g.set(2.5)
        assert g.value == 2.5
        h = reg.histogram("repro_d_s", buckets=(0.1, 1.0)).labels(span="s")
        for v in (0.05, 0.5, 5.0):
            h.observe(v)
        assert h.bucket_counts == [1, 1, 1]     # <=0.1, <=1.0, overflow
        assert h.count == 3 and h.sum == pytest.approx(5.55)

    def test_get_or_create_is_idempotent_but_kind_checked(self):
        reg = MetricsRegistry()
        fam = reg.counter("repro_x_total")
        assert reg.counter("repro_x_total") is fam
        with pytest.raises(ValueError, match="already registered as counter"):
            reg.gauge("repro_x_total")

    def test_bad_buckets_rejected(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError, match="strictly increasing"):
            reg.histogram("repro_bad_s", buckets=(1.0, 1.0, 2.0))
        with pytest.raises(ValueError, match="strictly increasing"):
            reg.histogram("repro_bad2_s", buckets=(2.0, 1.0))
        with pytest.raises(ValueError, match="only apply to histograms"):
            obs_metrics.MetricFamily("repro_c_total", "counter",
                                     buckets=(1.0,))

    def test_counter_rejects_negative_increment(self):
        c = MetricsRegistry().counter("repro_x_total").labels(kind="a")
        with pytest.raises(ValueError, match=">= 0"):
            c.inc(-1)

    def test_label_children_are_distinct_and_cached(self):
        fam = MetricsRegistry().counter("repro_x_total")
        a, b = fam.labels(kind="a"), fam.labels(kind="b")
        assert a is not b and fam.labels(kind="a") is a
        a.inc()
        assert (a.value, b.value) == (1, 0)
        assert len(fam.children()) == 2

    def test_counterdict_dict_api(self):
        fam = MetricsRegistry().counter("repro_x_total")
        view = CounterDict(fam, "kind", keys=("a", "b"))
        view["a"] += 2
        view["c"] = 7                        # new keys appear on assignment
        assert view["a"] == 2 and view["b"] == 0 and view["c"] == 7
        assert dict(view.items()) == {"a": 2, "b": 0, "c": 7}
        assert dict(view) == {"a": 2, "b": 0, "c": 7}
        assert view == {"a": 2, "b": 0, "c": 7}
        assert "a" in view and "z" not in view and len(view) == 3
        assert view.get("z", -1) == -1
        with pytest.raises(KeyError):
            view["z"]
        # increments are visible in the underlying registry family
        assert fam.labels(kind="a").value == 2

    def test_counterdict_requires_counter_family(self):
        with pytest.raises(ValueError, match="counter family"):
            CounterDict(MetricsRegistry().gauge("repro_g"), "kind")

    def test_runtime_counts_are_registry_views(self):
        assert isinstance(rtmod.DISPATCH_COUNTS, CounterDict)
        assert isinstance(rtmod.TRACE_COUNTS, CounterDict)
        fams = {f.name for f in obs_metrics.REGISTRY.families()}
        assert {"repro_dispatch_total", "repro_trace_total"} <= fams

    def test_counting_nests_over_registry_views(self):
        # the regression counting() guards: inner scopes must not blank
        # outer accrual, and inner activity accrues outward — now with the
        # module dicts backed by registry counters
        with rtmod.counting() as outer:
            rtmod.DISPATCH_COUNTS["observe_all"] += 1
            with rtmod.counting() as inner:
                rtmod.DISPATCH_COUNTS["observe_all"] += 2
                assert inner.dispatch["observe_all"] == 2
                assert outer.dispatch["observe_all"] == 3
            assert outer.dispatch["observe_all"] == 3
            assert dict(inner.dispatch)["observe_all"] == 2

    def test_publish_to_prometheus_sink(self):
        reg = MetricsRegistry()
        reg.counter("repro_x_total", help="things").labels(kind="a").inc(4)
        reg.gauge("repro_depth").labels(lane="l").set(3)
        reg.histogram("repro_d_s", help="dur",
                      buckets=(0.1, 1.0)).labels(span="s").observe(0.5)
        sink = PrometheusTextSink()
        reg.publish(sink)
        text = sink.render()
        assert '# HELP repro_x_total things' in text
        assert '# TYPE repro_x_total counter' in text
        assert 'repro_x_total{kind="a"} 4' in text
        assert 'repro_depth{lane="l"} 3' in text
        assert '# TYPE repro_d_s histogram' in text
        assert 'repro_d_s_bucket{span="s",le="0.1"} 0' in text
        assert 'repro_d_s_bucket{span="s",le="1"} 1' in text
        assert 'repro_d_s_bucket{span="s",le="+Inf"} 1' in text
        assert 'repro_d_s_sum{span="s"} 0.5' in text
        assert 'repro_d_s_count{span="s"} 1' in text


# ------------------------------------------------------------------ tracer
class TestTracer:
    def test_noop_span_is_a_singleton(self):
        assert NULL_TRACER.span("observe_all", epoch=3) is NOOP_SPAN
        assert NULL_TRACER.span("epoch_step") is NOOP_SPAN
        assert not NULL_TRACER.enabled and NULL_TRACER.spans == ()

    def test_disabled_hot_loop_allocates_nothing(self):
        tr = obs_trace.get_tracer()
        assert not tr.enabled

        def loop(tracer, iters):
            for step in range(iters):
                cm = (tracer.span("observe_all", epoch=step)
                      if tracer.enabled else NOOP_SPAN)
                with cm:
                    pass

        loop(tr, 256)                        # warm interning
        tracemalloc.start()
        try:
            base = tracemalloc.get_traced_memory()[0]
            loop(tr, 4096)
            grown = tracemalloc.get_traced_memory()[0] - base
        finally:
            tracemalloc.stop()
        assert grown == 0

    def test_fake_clock_gives_exact_durations(self):
        clock = FakeClock(start=10.0, step=1.0)
        tr = SpanTracer(clock=clock)
        with tr.span("observe_all", epoch=2):
            pass
        (s,) = tr.spans
        assert (s.name, s.epoch) == ("observe_all", 2)
        assert s.t0_s == 10.0 and s.dur_s == 1.0 and s.depth == 0

    def test_nesting_depth_and_args(self):
        tr = SpanTracer(clock=FakeClock())
        with tr.span("outer"):
            with tr.span("inner", epoch=1, arrays="a,b"):
                pass
        inner, outer = tr.spans            # inner closes first
        assert (inner.name, inner.depth, outer.depth) == ("inner", 1, 0)
        assert inner.args == {"arrays": "a,b"} and inner.epoch == 1
        assert outer.args is None

    def test_max_spans_drops_are_counted(self):
        tr = SpanTracer(clock=FakeClock(), max_spans=2)
        for _ in range(5):
            with tr.span("x"):
                pass
        assert len(tr.spans) == 2 and tr.dropped_spans == 3
        tr.clear()
        assert tr.spans == [] and tr.dropped_spans == 0

    def test_tracing_scope_installs_and_restores(self):
        before = obs_trace.get_tracer()
        with tracing(clock=FakeClock()) as tr:
            assert obs_trace.get_tracer() is tr and tr.enabled
            with tr.span("x"):
                pass
        assert obs_trace.get_tracer() is before
        assert [s.name for s in tr.spans] == ["x"]

    def test_metrics_mirror_records_span_durations(self):
        reg = MetricsRegistry()
        tr = SpanTracer(clock=FakeClock(), metrics=reg)
        with tr.span("observe_all"):
            pass
        (fam,) = [f for f in reg.families()
                  if f.name == "repro_span_duration_s"]
        (child,) = fam.children()
        assert dict(child.labels) == {"span": "observe_all"}
        assert child.count == 1 and child.sum == pytest.approx(1.0)

    def test_elapsed_s_uses_injected_clock(self):
        clock = FakeClock(start=5.0)
        assert obs_trace.elapsed_s(2.0, clock=clock) == 3.0


# ---------------------------------------------------------------- timeline
def span(name, t0, dur, *, tid="host", epoch=None, args=None, depth=0):
    return Span(name=name, t0_s=t0, dur_s=dur, tid=tid, depth=depth,
                epoch=epoch, args=args)


def pipelined_spans():
    """sync_every=2 shape: epoch 2's observe_all dispatches before the
    record_sync draining epochs [0, 2) begins."""
    return [
        span("observe_all", 0.0, 0.1, epoch=0),
        span("epoch_step", 0.1, 0.1, epoch=0),
        span("observe_all", 1.0, 0.1, epoch=1),
        span("epoch_step", 1.1, 0.1, epoch=1),
        span("observe_all", 2.0, 0.1, epoch=2),
        span("record_sync", 2.2, 0.5,
             args={"epoch_base": 0, "n_epochs": 2}),
        span("epoch_step", 2.8, 0.1, epoch=2),
    ]


class TestChromeTrace:
    def test_event_shape_and_normalisation(self):
        events = chrometrace.chrome_trace_events(
            [span("observe_all", 3.0, 0.25, epoch=7,
                  args={"arrays": "x"})])
        (e,) = events
        assert e["ph"] == "X" and e["cat"] == "runtime"
        assert e["ts"] == 0.0 and e["dur"] == pytest.approx(0.25e6)
        assert e["pid"] == 1 and e["tid"] == "host"
        assert e["args"] == {"epoch": 7, "arrays": "x"}

    def test_pipelining_visible_for_k_gt_1(self):
        assert chrometrace.pipelining_visible(pipelined_spans())

    def test_pipelining_not_visible_for_k_eq_1(self):
        serial = [
            span("observe_all", 0.0, 0.1, epoch=0),
            span("record_sync", 0.2, 0.1,
                 args={"epoch_base": 0, "n_epochs": 1}),
            span("observe_all", 1.0, 0.1, epoch=1),
            span("record_sync", 1.2, 0.1,
                 args={"epoch_base": 1, "n_epochs": 1}),
        ]
        assert not chrometrace.pipelining_visible(serial)

    def test_device_track_covers_sync_window(self):
        (e,) = chrometrace.device_track_events(pipelined_spans())
        assert e["tid"] == "device" and e["name"] == "device epochs [0,2)"
        # first drained epoch's dispatch (t=0.0) -> sync end (t=2.7)
        assert e["ts"] == 0.0 and e["dur"] == pytest.approx(2.7e6)

    def test_write_chrome_trace_round_trip(self, tmp_path):
        path = tmp_path / "trace.json"
        doc = chrometrace.write_chrome_trace(
            path, pipelined_spans(), metadata={"bench": "test"})
        on_disk = json.loads(path.read_text())
        assert on_disk == doc
        assert doc["displayTimeUnit"] == "ms"
        assert doc["otherData"] == {"bench": "test"}
        tids = {e["tid"] for e in doc["traceEvents"]}
        assert tids == {"host", "device"}
        ts = [e["ts"] for e in doc["traceEvents"]]
        assert ts == sorted(ts)


# -------------------------------------------------------------- wire forms
class TestWire:
    def test_runtime_span_wire_validates(self):
        rec = runtime_span_wire(
            span("record_sync", 1.5, 0.25, depth=1,
                 args={"epoch_base": 4, "n_epochs": 2}),
            scenario="kv_cache")
        assert validate_record(rec) is rec
        assert rec["t_start_us"] == pytest.approx(1.5e6)
        assert rec["duration_us"] == pytest.approx(0.25e6)
        assert (rec["epoch_base"], rec["n_epochs_count"]) == (4, 2)
        assert rec["track"] == "host" and rec["scenario"] == "kv_cache"

    def test_runtime_metric_wire_counter_and_histogram(self):
        c = runtime_metric_wire("repro_dispatch_total", "counter", 12,
                                labels={"kind": "observe_all"})
        validate_record(c)
        h = runtime_metric_wire(
            "repro_span_duration_s", "histogram",
            labels={"span": "observe_all"}, bucket_le=[0.1, 1.0],
            bucket_counts=[3, 1, 0], sum_value=0.6, observations=4)
        validate_record(h)
        assert h["bucket_counts"] == [3, 1, 0] and h["sum"] == 0.6

    def test_frozen_shapes_still_enforced(self):
        rec = runtime_span_wire(span("observe_all", 0.0, 0.1))
        rec["surprise"] = 1
        with pytest.raises(SchemaError, match="unknown fields"):
            validate_record(rec)
        bad = runtime_metric_wire("m", "counter", 1)
        bad["kind"] = "timer"
        with pytest.raises(SchemaError, match="not one of"):
            validate_record(bad)
        # label values are string-typed on the wire
        typed = runtime_metric_wire("m", "counter", 1, labels={"k": "v"})
        typed["labels"]["k"] = 3
        with pytest.raises(SchemaError, match="labels.k"):
            validate_record(typed)


# ----------------------------------------------------------- prometheus sink
class TestPrometheusSink:
    def test_hostile_label_values_round_trip(self):
        sink = PrometheusTextSink()
        hostile = 'a\\b"c\nd'
        sink.set_counter("repro_x_total", 1, help="h", kind=hostile)
        line = [ln for ln in sink.render().splitlines()
                if ln.startswith("repro_x_total{")][0]
        assert line == 'repro_x_total{kind="a\\\\b\\"c\\nd"} 1'
        # unescaping recovers the original value
        raw = line.split('kind="', 1)[1].rsplit('"}', 1)[0]
        unescaped = (raw.replace("\\n", "\n").replace('\\"', '"')
                     .replace("\\\\", "\\"))
        assert unescaped == hostile

    def test_every_family_gets_help_and_type(self):
        sink = PrometheusTextSink()
        sink.write([{"scenario": "s", "lane": "l", "coverage": 0.5}])
        sink.set_counter("repro_c_total", 1)
        sink.set_gauge("repro_g", 2)
        sink.set_histogram("repro_h_s", (0.1,), (1, 0), 0.05)
        text = sink.render()
        for name in ("repro_coverage_ratio", "repro_c_total", "repro_g",
                     "repro_h_s"):
            assert f"# HELP {name} " in text
            assert f"# TYPE {name} " in text
        assert "\n# HELP repro_h_s Latency histogram\n" in "\n" + text

    def test_histogram_rendering_is_cumulative(self):
        sink = PrometheusTextSink()
        sink.set_histogram("repro_h_s", (0.1, 1.0), (2, 3, 1), 2.5,
                           span="observe_all")
        text = sink.render()
        assert 'repro_h_s_bucket{span="observe_all",le="0.1"} 2' in text
        assert 'repro_h_s_bucket{span="observe_all",le="1"} 5' in text
        assert 'repro_h_s_bucket{span="observe_all",le="+Inf"} 6' in text
        assert 'repro_h_s_sum{span="observe_all"} 2.5' in text
        assert 'repro_h_s_count{span="observe_all"} 6' in text

    def test_histogram_bucket_count_mismatch_raises(self):
        with pytest.raises(ValueError, match="len\\(bounds\\)\\+1"):
            PrometheusTextSink().set_histogram("repro_h_s", (0.1,), (1,), 0.0)

    def test_newline_in_help_is_escaped(self):
        sink = PrometheusTextSink()
        sink.set_counter("repro_c_total", 1, help="line1\nline2")
        assert "# HELP repro_c_total line1\\nline2" in sink.render()


# ------------------------------------------------------- export integration
class TestExportIntegration:
    def test_spans_and_metrics_flow_through_client(self):
        sink = MemorySink()
        client = ExportClient(sink, flush_interval_s=0.01)
        try:
            assert client.export_runtime_span(
                span("observe_all", 0.0, 0.1, epoch=3))
            reg = MetricsRegistry()
            reg.counter("repro_x_total").labels(kind="a").inc(2)
            reg.histogram("repro_d_s",
                          buckets=(0.1,)).labels(span="s").observe(0.05)
            assert client.export_metrics(reg) == 2
            client.flush(timeout=10)
        finally:
            client.close()
        recs = sink.snapshot()
        kinds = sorted(r["record_type"] for r in recs)
        assert kinds == ["runtime_metric", "runtime_metric", "runtime_span"]
        for rec in recs:
            validate_record(rec)

    def test_drop_counters_published_to_prometheus_sink(self):
        sink = PrometheusTextSink()
        client = ExportClient(sink, flush_interval_s=0.01)
        try:
            # invalid records are accepted at the door (enqueue never
            # validates — that would put schema work on the epoch loop) and
            # dropped by the flusher, where the drop must become a counter
            assert client.emit({"record_type": "nonsense"})
            client.export_runtime_metric("repro_x_total", "counter", 1)
            client.flush(timeout=10)
            text = sink.render()
        finally:
            client.close()
        assert 'repro_export_dropped_total{reason="invalid"} 1' in text
        assert "repro_export_emitted_total 2" in text
        assert "repro_export_exported_total 1" in text

    def test_export_spans_are_not_recursive(self):
        # the client's own enqueue/flush spans must not emit records (that
        # would self-amplify); they are only host spans on the tracer
        sink = MemorySink()
        client = ExportClient(sink, flush_interval_s=0.01)
        try:
            with tracing(clock=FakeClock()) as tr:
                client.export_runtime_metric("repro_x_total", "counter", 1)
                client.flush(timeout=10)
            names = {s.name for s in tr.spans}
            assert "export.enqueue" in names
        finally:
            client.close()
        assert all(r["record_type"] == "runtime_metric"
                   for r in sink.snapshot())


# -------------------------------------------------------- runtime integration
def _run(n, k, eps, export=None):
    rt = EpochRuntime(n, k, policies=("hmu_oracle", "nb_two_touch"),
                      pebs_period=8, nb_scan_rate=n // 4, fused=True,
                      sync_every=2, export=export)
    with rtmod.counting() as c:
        rt.run(iter(eps))
        disp = dict(c.dispatch)
    return rt, disp


class TestRuntimeIntegration:
    N, K, EPOCHS = 512, 64, 4

    @pytest.fixture(scope="class")
    def runs(self):
        rng = np.random.default_rng(7)
        eps = [(rng.zipf(1.2, size=(2, 512)) % self.N).astype(np.int32)
               for _ in range(self.EPOCHS)]
        _run(self.N, self.K, eps)                      # warm the jit caches
        obs_trace.disable()
        off_rt, off_disp = _run(self.N, self.K, eps)
        with tracing() as tracer:
            on_rt, on_disp = _run(self.N, self.K, eps)
        return off_rt, off_disp, on_rt, on_disp, tracer

    def test_zero_added_dispatches(self, runs):
        _, off_disp, _, on_disp, _ = runs
        assert on_disp == off_disp
        per_epoch = (on_disp["observe_all"]
                     + on_disp["epoch_step"]) / self.EPOCHS
        assert per_epoch == 2

    def test_bit_identical_records_and_placements(self, runs):
        off_rt, _, on_rt, _, _ = runs
        for lane in ("hmu_oracle", "nb_two_touch"):
            assert ([r.to_dict() for r in off_rt.records[lane]]
                    == [r.to_dict() for r in on_rt.records[lane]])
            assert np.array_equal(off_rt.lanes[lane].slot_to_block,
                                  on_rt.lanes[lane].slot_to_block)

    def test_exact_span_accounting(self, runs):
        *_, tracer = runs
        by_name = {}
        for s in tracer.spans:
            by_name[s.name] = by_name.get(s.name, 0) + 1
        assert by_name["observe_all"] == self.EPOCHS
        assert by_name["epoch_step"] == self.EPOCHS
        assert by_name["record_sync"] == self.EPOCHS // 2   # sync_every=2
        assert tracer.dropped_spans == 0

    def test_pipelining_visible_in_real_run(self, runs):
        *_, tracer = runs
        assert chrometrace.pipelining_visible(tracer.spans)
        sync = [s for s in tracer.spans if s.name == "record_sync"][0]
        assert set(sync.args) == {"epoch_base", "n_epochs"}

    def test_spans_export_as_valid_wire_records(self, runs):
        *_, tracer = runs
        for s in tracer.spans:
            validate_record(runtime_span_wire(s, scenario="test"))
