"""Integration tests: the paper's technique wired into the LM stack
(TieredEmbedding in training, KV-page telemetry in serving, expert counters
in MoE)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core.tiered_embedding import TieredEmbedding
from repro.models.model import forward, init_params
from repro.serve import engine


def test_tiered_embedding_hit_rate_improves_with_rebalance():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(4096, 32)), jnp.float32)
    emb = TieredEmbedding.create(table, block_rows=8, fast_fraction=0.1)
    # skewed token stream: hot head = 5% of rows
    for _ in range(10):
        toks = np.where(rng.random(2048) < 0.9,
                        rng.integers(0, 200, 2048),
                        rng.integers(200, 4096, 2048))
        emb.observe_tokens(toks)
    rep_before = emb.modeled_lookup_time_s()
    assert rep_before["fast_hit_rate"] == 0.0      # nothing promoted yet
    moved = emb.rebalance()
    assert moved > 0
    rep = emb.modeled_lookup_time_s()
    assert rep["fast_hit_rate"] > 0.85
    assert rep["tiered_s"] < rep["all_slow_s"] * 0.5
    # reads unchanged by placement
    rows = jnp.asarray(rng.integers(0, 4096, 64))
    np.testing.assert_allclose(np.asarray(emb.store.gather(rows)),
                               np.asarray(table)[np.asarray(rows)])


def test_tiered_embedding_proactive_policy():
    rng = np.random.default_rng(1)
    table = jnp.zeros((1024, 16), jnp.float32)
    emb = TieredEmbedding.create(table, block_rows=8, fast_fraction=0.25,
                                 policy="proactive")
    emb.observe_tokens(rng.integers(0, 256, 4096))
    emb.rebalance()
    emb.observe_tokens(rng.integers(0, 256, 4096))
    assert emb.rebalance() >= 0                     # EWMA state exercised
    assert emb._pred is not None


def test_kv_page_mass_telemetry_shapes_and_conservation():
    cfg = get_smoke_config("internlm2-1.8b")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)))
    _, cache = engine.prefill(params, cfg, tokens=tokens, max_len=32)
    nxt = jnp.zeros((2,), jnp.int32)
    _, cache, aux = engine.decode_step(params, cfg, cache, nxt, page_size=8)
    mass = np.asarray(aux["kv_page_mass"], np.float64)
    assert mass.shape == (cfg.n_layers, 2, 32 // 8)
    # attention mass sums to ~n_heads per (layer, sequence)
    np.testing.assert_allclose(mass.sum(-1), cfg.n_heads, rtol=1e-3)


def test_kv_page_mass_matches_position_mass_histogram_ragged_final_page():
    """Ground truth for the page binning: page mass == the per-position
    attention-mass histogram (page_size=1 telemetry) summed over each page's
    positions — including the ragged final page when seq_len % page_size
    != 0 (max_len=13, page_size=8 -> pages of 8 and 5 positions)."""
    cfg = get_smoke_config("internlm2-1.8b")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(4)
    max_len, page = 13, 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 11)))
    _, cache = engine.prefill(params, cfg, tokens=tokens, max_len=max_len)
    nxt = jnp.zeros((2,), jnp.int32)
    _, _, aux_paged = engine.decode_step(params, cfg, cache, nxt,
                                         page_size=page)
    _, _, aux_pos = engine.decode_step(params, cfg, cache, nxt, page_size=1)
    paged = np.asarray(aux_paged["kv_page_mass"], np.float64)   # (L, B, 2)
    by_pos = np.asarray(aux_pos["kv_page_mass"], np.float64)    # (L, B, 13)
    assert paged.shape == (cfg.n_layers, 2, -(-max_len // page))
    np.testing.assert_allclose(paged[..., 0], by_pos[..., :page].sum(-1),
                               rtol=1e-6)
    np.testing.assert_allclose(paged[..., 1], by_pos[..., page:].sum(-1),
                               rtol=1e-6)
    # attention probability is conserved across the page grid: n_heads per
    # (layer, sequence), none of it lost to the ragged tail
    np.testing.assert_allclose(paged.sum(-1), cfg.n_heads, rtol=1e-3)
    # positions beyond the current length carry no mass
    assert np.all(by_pos[..., 12] == 0.0)        # pos==11 is the new token


def test_kv_page_mass_accumulates_over_decode_steps():
    """The scenario-layer feed: decode_telemetry's stacked per-step masses
    equal stepping the cache manually, and accumulated mass conserves
    n_heads per step on a ragged page grid."""
    cfg = get_smoke_config("internlm2-1.8b")
    params = init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(5)
    max_len, page, steps = 14, 4, 3
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 9)))
    _, cache = engine.prefill(params, cfg, tokens=tokens, max_len=max_len)
    step_toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (steps, 2)))
    _, mass = engine.decode_telemetry(params, cfg, cache, step_toks,
                                      page_size=page)
    assert mass.shape == (steps, cfg.n_layers, 2, -(-max_len // page))
    ref_cache = cache
    for t in range(steps):
        _, ref_cache, aux = engine.decode_step(params, cfg, ref_cache,
                                               step_toks[t], page_size=page)
        np.testing.assert_allclose(
            mass[t], np.asarray(aux["kv_page_mass"], np.float64),
            rtol=1e-5, atol=1e-7)    # jit'd loop vs eager steps (f32 math)
    np.testing.assert_allclose(mass.sum(-1), cfg.n_heads, rtol=1e-3)


def test_expert_counts_sum_to_topk_tokens():
    cfg = get_smoke_config("mixtral-8x22b")
    params = init_params(cfg, jax.random.key(3))
    toks = jnp.zeros((2, 16), jnp.int32)
    _, aux = forward(params, cfg, tokens=toks)
    counts = np.asarray(aux["expert_counts"])
    assert counts.shape == (cfg.n_layers, cfg.moe.n_experts)
    assert (counts.sum(-1) == 2 * 16 * cfg.moe.top_k).all()
