"""Integration tests: the paper's technique wired into the LM stack
(TieredEmbedding in training, KV-page telemetry in serving, expert counters
in MoE)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core.tiered_embedding import TieredEmbedding
from repro.models.model import forward, init_params
from repro.serve import engine


def test_tiered_embedding_hit_rate_improves_with_rebalance():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(4096, 32)), jnp.float32)
    emb = TieredEmbedding.create(table, block_rows=8, fast_fraction=0.1)
    # skewed token stream: hot head = 5% of rows
    for _ in range(10):
        toks = np.where(rng.random(2048) < 0.9,
                        rng.integers(0, 200, 2048),
                        rng.integers(200, 4096, 2048))
        emb.observe_tokens(toks)
    rep_before = emb.modeled_lookup_time_s()
    assert rep_before["fast_hit_rate"] == 0.0      # nothing promoted yet
    moved = emb.rebalance()
    assert moved > 0
    rep = emb.modeled_lookup_time_s()
    assert rep["fast_hit_rate"] > 0.85
    assert rep["tiered_s"] < rep["all_slow_s"] * 0.5
    # reads unchanged by placement
    rows = jnp.asarray(rng.integers(0, 4096, 64))
    np.testing.assert_allclose(np.asarray(emb.store.gather(rows)),
                               np.asarray(table)[np.asarray(rows)])


def test_tiered_embedding_proactive_policy():
    rng = np.random.default_rng(1)
    table = jnp.zeros((1024, 16), jnp.float32)
    emb = TieredEmbedding.create(table, block_rows=8, fast_fraction=0.25,
                                 policy="proactive")
    emb.observe_tokens(rng.integers(0, 256, 4096))
    emb.rebalance()
    emb.observe_tokens(rng.integers(0, 256, 4096))
    assert emb.rebalance() >= 0                     # EWMA state exercised
    assert emb._pred is not None


def test_kv_page_mass_telemetry_shapes_and_conservation():
    cfg = get_smoke_config("internlm2-1.8b")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)))
    _, cache = engine.prefill(params, cfg, tokens=tokens, max_len=32)
    nxt = jnp.zeros((2,), jnp.int32)
    _, cache, aux = engine.decode_step(params, cfg, cache, nxt, page_size=8)
    mass = np.asarray(aux["kv_page_mass"], np.float64)
    assert mass.shape == (cfg.n_layers, 2, 32 // 8)
    # attention mass sums to ~n_heads per (layer, sequence)
    np.testing.assert_allclose(mass.sum(-1), cfg.n_heads, rtol=1e-3)


def test_expert_counts_sum_to_topk_tokens():
    cfg = get_smoke_config("mixtral-8x22b")
    params = init_params(cfg, jax.random.key(3))
    toks = jnp.zeros((2, 16), jnp.int32)
    _, aux = forward(params, cfg, tokens=toks)
    counts = np.asarray(aux["expert_counts"])
    assert counts.shape == (cfg.n_layers, cfg.moe.n_experts)
    assert (counts.sum(-1) == 2 * 16 * cfg.moe.top_k).all()
