"""repro.export — frozen schema, non-blocking client, zero-overhead gates.

Three layers under test, matching the export plane's two hard guarantees:

* **Schema** — every wire record the plane emits validates against the
  checked-in ``telemetry.schema.json``; the frozen-ness is enforced (extra
  fields, missing fields, wrong types/units all rejected); the native
  subset validator agrees with the reference ``jsonschema`` package when
  that is installed; and the ``run_scenario``/``tenant_summary`` summary
  dicts are wire-conformant field-for-field.
* **Client** — bounded queue never blocks (queue-full drops are counted),
  invalid records are dropped not raised, the circuit breaker walks its
  trip/half-open/recover cycle, a permanently dead sink degrades the
  client to noop, atexit drains the queue on interpreter exit.
* **Non-interference** — export-on runs are bit-identical to export-off
  (trajectories, tenant rows, summaries), ``DISPATCH_COUNTS`` unchanged
  (epoch stays 2 dispatches, record syncs unchanged), a dead sink never
  stalls or raises into ``run()``, and the export path's peak host memory
  stays inside a ``tracemalloc`` budget.  Plus the PR's tail-flush bugfix:
  a run killed mid-stream still lands (and exports) every dispatched
  epoch.
"""
import json
import os
import subprocess
import sys
import textwrap
import threading
import time
import tracemalloc
from pathlib import Path

import numpy as np
import pytest

from repro.core import runtime as rtmod
from repro.core.runtime import ALL_POLICIES, EpochRuntime
from repro.export import (CircuitBreaker, ExportClient, JsonlSink,
                          MemorySink, NoopClient, PrometheusTextSink,
                          SchemaError, epoch_record_wire, lane_summary_wire,
                          load_schema, tenant_lane_summary_wire,
                          tenant_record_wire, validate_record)
from repro.faults.model import LANE_COLLECTOR, collector_for_lane
from repro.fleet import FleetScenario, TenantSpec, run_fleet
from repro.scenarios import KVCacheScenario, run_scenario

REPO = Path(__file__).resolve().parent.parent
SUBPROC_ENV = dict(os.environ, PYTHONPATH=str(REPO / "src"),
                   JAX_PLATFORMS="cpu")


def make_scenario(n_epochs=4, **kw):
    kw.setdefault("batch", 2)
    kw.setdefault("batches_per_epoch", 2)
    kw.setdefault("accesses_per_batch", 1_024)
    return KVCacheScenario(n_epochs=n_epochs, **kw)


def make_fleet(n_epochs=4):
    return FleetScenario([
        TenantSpec(make_scenario(n_epochs=n_epochs), name="kv_a"),
        TenantSpec(make_scenario(n_epochs=n_epochs, seed=7), name="kv_b"),
    ], capacity="weighted")


def make_runtime(sync_every=1, **kw):
    kw.setdefault("policies", ALL_POLICIES)
    kw.setdefault("pebs_period", 101)
    kw.setdefault("nb_scan_rate", 90)
    return EpochRuntime(400, 40, sync_every=sync_every, **kw)


def make_epochs(n_epochs, n_blocks=400, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, n_blocks, (3, 2000)).astype(np.int32)
            for _ in range(n_epochs)]


def sample_epoch_record():
    """A wire-valid epoch record via the real converter (duck-typed rec)."""
    class Rec:
        epoch = 3; lane = "hinted"; time_s = 1.5; access_s = 1.0
        host_tax_s = 0.25; migration_s = 0.25; hidden_s = 0.0
        accuracy = 0.9; coverage = 0.8; quality = 1.0
        resident = 64; promoted = 2; demoted = 1; host_events = 100.0
    return epoch_record_wire(Rec(), scenario="unit")


class SlowSink:
    """Sink that blocks in write() until released — forces queue pressure."""

    def __init__(self):
        self.release = threading.Event()
        self.records = []

    def write(self, records):
        self.release.wait(timeout=30)
        self.records.extend(records)


# =====================================================================
# schema + validator
# =====================================================================
class TestSchema:
    def test_schema_document_loads_and_is_frozen_shape(self):
        doc = load_schema()
        assert set(doc["$defs"]) >= {"epoch", "tenant", "lane_summary",
                                     "tenant_lane_summary"}
        for name in ("epoch", "tenant", "lane_summary",
                     "tenant_lane_summary"):
            node = doc["$defs"][name]
            assert node["additionalProperties"] is False
            assert node["properties"]["schema_version"]["const"] == 1

    def test_valid_epoch_record_passes(self):
        rec = sample_epoch_record()
        assert validate_record(rec) is rec

    def test_units_in_field_names(self):
        rec = sample_epoch_record()
        assert "time_s" in rec and "resident_blocks" in rec
        assert "host_events_count" in rec
        assert not any(k in rec for k in ("time", "resident", "host_events"))

    @pytest.mark.parametrize("mutate,", [
        lambda r: r.pop("coverage"),                       # missing required
        lambda r: r.__setitem__("surprise_field", 1),      # frozen: no extras
        lambda r: r.__setitem__("coverage", 1.5),          # ratio cap
        lambda r: r.__setitem__("coverage", -0.1),         # ratio floor
        lambda r: r.__setitem__("resident_blocks", 1.5),   # integer
        lambda r: r.__setitem__("resident_blocks", True),  # bool is not int
        lambda r: r.__setitem__("lane", "surprise_lane"),  # lane enum
        lambda r: r.__setitem__("collector", "ebpf"),      # collector enum
        lambda r: r.__setitem__("schema_version", 2),      # version const
        lambda r: r.__setitem__("epoch", -1),              # epoch floor
        lambda r: r.__setitem__("time_s", "fast"),         # number type
    ])
    def test_invalid_epoch_records_rejected(self, mutate):
        rec = sample_epoch_record()
        mutate(rec)
        with pytest.raises(SchemaError):
            validate_record(rec)

    def test_unknown_record_type_rejected(self):
        with pytest.raises(SchemaError, match="record_type"):
            validate_record({"record_type": "mystery"})
        with pytest.raises(SchemaError):
            validate_record({"schema_version": 1})
        # $defs that aren't record shapes (ratio, lane_name) don't dispatch
        with pytest.raises(SchemaError, match="record_type"):
            validate_record({"record_type": "ratio"})

    def test_non_dict_rejected(self):
        with pytest.raises(SchemaError):
            validate_record([sample_epoch_record()])

    def test_collector_field_tracks_lane(self):
        for lane, col in LANE_COLLECTOR.items():
            rec = sample_epoch_record()
            rec["lane"] = lane
            rec["collector"] = collector_for_lane(lane)
            assert rec["collector"] == col
            validate_record(rec)
            if col is not None:       # mismatched pair still type-checks,
                rec["collector"] = "bogus"        # bogus collector does not
                with pytest.raises(SchemaError):
                    validate_record(rec)

    def test_scenario_label_optional(self):
        rec = sample_epoch_record()
        del rec["scenario"]
        validate_record(rec)

    def test_native_validator_agrees_with_jsonschema(self):
        jsonschema = pytest.importorskip("jsonschema")
        doc = load_schema()
        good = sample_epoch_record()
        jsonschema.validate(good, doc)        # reference accepts
        validate_record(good)                 # ours accepts
        for mutate in (lambda r: r.pop("coverage"),
                       lambda r: r.__setitem__("extra", 1),
                       lambda r: r.__setitem__("coverage", 2.0)):
            bad = sample_epoch_record()
            mutate(bad)
            with pytest.raises(jsonschema.ValidationError):
                jsonschema.validate(bad, doc)
            with pytest.raises(SchemaError):
                validate_record(bad)


class TestSummaryConformance:
    """Satellite: the in-repo summary dicts ARE wire records minus the
    envelope — units in field names, schema-validated here."""

    def test_run_scenario_summary_is_schema_conformant(self):
        out = run_scenario(make_scenario(), hints=True)
        for lane in ALL_POLICIES:
            validate_record(lane_summary_wire(lane, out["summary"][lane],
                                              scenario="kv_cache"))
        assert "hidden_total_s" in out["summary"]["prefetch"]
        assert "pending_migration_us" in out["summary"]["prefetch"]

    def test_tenant_summary_is_schema_conformant(self):
        out = run_fleet(make_fleet(), hints=False)
        for tenant, block in out["tenants"].items():
            for lane, row in block["lanes"].items():
                validate_record(tenant_lane_summary_wire(tenant, lane, row))
                assert "promoted_total_blocks" in row
                assert "demoted_total_blocks" in row


# =====================================================================
# circuit breaker
# =====================================================================
class TestCircuitBreaker:
    def test_trip_half_open_recover_cycle(self):
        t = [0.0]
        b = CircuitBreaker(failure_threshold=2, cooldown_s=1.0,
                           clock=lambda: t[0])
        assert b.state == "closed" and b.allow()
        b.record_failure()
        assert b.state == "closed"            # below threshold
        b.record_failure()
        assert b.state == "open" and not b.allow() and b.trips == 1
        t[0] = 0.5
        assert not b.allow()                  # still cooling down
        t[0] = 1.0
        assert b.state == "half_open" and b.allow()
        b.record_failure()                    # probe fails -> re-open
        assert b.state == "open" and b.trips == 2
        t[0] = 2.5
        assert b.allow()                      # next probe
        b.record_success()
        assert b.state == "closed" and b.consecutive_trips == 0
        b.record_failure()                    # threshold counter was reset
        assert b.state == "closed"

    def test_success_resets_failure_streak(self):
        b = CircuitBreaker(failure_threshold=3)
        b.record_failure(); b.record_failure(); b.record_success()
        b.record_failure(); b.record_failure()
        assert b.state == "closed"


# =====================================================================
# client edge cases
# =====================================================================
class TestExportClient:
    def test_happy_path_batched_delivery(self):
        sink = MemorySink()
        client = ExportClient(sink, flush_interval_s=0.005)
        n = 100
        for _ in range(n):
            assert client.emit(sample_epoch_record())
        client.flush(timeout=10)
        st = client.stats()
        assert st["emitted"] == n and st["exported"] == n
        assert len(sink.snapshot()) == n
        assert sink.write_calls <= n          # batching actually batches
        client.close()

    def test_queue_full_drops_and_never_blocks(self):
        sink = SlowSink()
        client = ExportClient(sink, queue_size=8, flush_interval_s=0.005)
        t0 = time.monotonic()
        for _ in range(200):
            client.emit(sample_epoch_record())
        emit_elapsed = time.monotonic() - t0
        st = client.stats()
        assert st["dropped_queue_full"] > 0
        assert st["dropped_queue_full"] + st["emitted"] == 200
        # 200 emits against a wedged sink must not wait on it
        assert emit_elapsed < 5.0
        sink.release.set()
        client.flush(timeout=10)
        assert client.stats()["exported"] == client.stats()["emitted"]
        client.close()

    def test_invalid_record_dropped_counted_not_raised(self):
        sink = MemorySink()
        client = ExportClient(sink, flush_interval_s=0.005)
        client.emit({"record_type": "epoch", "schema_version": 1})
        client.emit(sample_epoch_record())
        client.flush(timeout=10)
        st = client.stats()
        assert st["dropped_invalid"] == 1 and st["exported"] == 1
        client.close()

    def test_breaker_trips_on_sink_failure_then_recovers(self):
        # sink fails its first 2 writes, then heals; threshold 2 trips the
        # breaker on exactly those failures; cooldown 0 => next batch is
        # the half-open probe and it recloses the breaker
        sink = MemorySink(fail_until=2)
        client = ExportClient(
            sink, batch_size=1, flush_interval_s=0.005,
            breaker=CircuitBreaker(failure_threshold=2, cooldown_s=0.0),
            degrade_after_trips=100)
        client.emit(sample_epoch_record())
        client.emit(sample_epoch_record())
        client.flush(timeout=10)
        st = client.stats()
        assert st["sink_failures"] == 2
        assert st["breaker_trips"] == 1
        assert st["dropped_sink_failure"] == 2
        client.emit(sample_epoch_record())    # half-open probe
        client.flush(timeout=10)
        st = client.stats()
        assert st["breaker_state"] == "closed" and st["exported"] == 1
        assert not st["degraded"]
        client.close()

    def test_open_breaker_sheds_at_emit(self):
        t = [0.0]
        sink = MemorySink()
        breaker = CircuitBreaker(failure_threshold=1, cooldown_s=100.0,
                                 clock=lambda: t[0])
        client = ExportClient(sink, flush_interval_s=0.005, breaker=breaker)
        breaker.record_failure()              # force open
        assert not client.emit(sample_epoch_record())
        st = client.stats()
        assert st["dropped_breaker_open"] == 1 and st["emitted"] == 0
        t[0] = 200.0                          # cooldown elapsed: accept again
        assert client.emit(sample_epoch_record())
        client.flush(timeout=10)
        assert client.stats()["exported"] == 1
        client.close()

    def test_dead_sink_degrades_to_noop(self):
        sink = MemorySink(fail_always=True)
        client = ExportClient(
            sink, batch_size=1, flush_interval_s=0.005,
            breaker=CircuitBreaker(failure_threshold=1, cooldown_s=0.0),
            degrade_after_trips=3)
        for _ in range(50):
            client.emit(sample_epoch_record())
        client.flush(timeout=10)
        st = client.stats()
        assert st["degraded"] is True
        assert st["breaker_trips"] >= 3 and st["exported"] == 0
        # noop behaviour from here on: emit refuses instantly
        assert client.emit(sample_epoch_record()) is False
        assert client.stats()["dropped_degraded"] >= 1
        client.close()

    def test_bind_labels_scenario_and_shares_counters(self):
        sink = MemorySink()
        client = ExportClient(sink, flush_interval_s=0.005)
        bound = client.bind(scenario="bound_name")
        bound.emit(sample_epoch_record())
        rec = sample_epoch_record()
        del rec["scenario"]

        class Rec:
            epoch = 0; lane = "prefetch"; time_s = 1.0; access_s = 1.0
            host_tax_s = 0.0; migration_s = 0.0; hidden_s = 0.0
            accuracy = 0.5; coverage = 0.5; quality = 1.0
            resident = 1; promoted = 0; demoted = 0; host_events = 0.0
        bound.export_epoch_record(Rec())
        client.flush(timeout=10)
        assert client.stats()["exported"] == 2
        assert sink.snapshot()[1]["scenario"] == "bound_name"
        with pytest.raises(TypeError):
            client.bind(region="us-east-1")
        client.close()

    def test_close_idempotent_and_noop_client_inert(self):
        client = ExportClient(MemorySink())
        client.close()
        client.close()
        noop = NoopClient()
        assert noop.emit(sample_epoch_record()) is False
        assert noop.bind(scenario="x") is noop
        noop.flush(); noop.close()
        assert noop.stats()["emitted"] == 0

    def test_interpreter_exit_drains_queue(self, tmp_path):
        """Satellite: atexit shutdown — a process that exits without
        close() still lands every emitted record in the JSONL sink."""
        out = tmp_path / "telemetry.jsonl"
        code = f"""
        import json
        from repro.export import ExportClient, JsonlSink

        rec = {json.dumps(sample_epoch_record())}
        client = ExportClient(JsonlSink({str(out)!r}),
                              flush_interval_s=0.01)
        for i in range(250):
            r = dict(rec); r["epoch"] = i
            assert client.emit(r)
        # no close(), no flush(): atexit must drain
        """
        res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, env=SUBPROC_ENV,
                             timeout=240, cwd=REPO)
        assert res.returncode == 0, res.stderr
        lines = out.read_text().splitlines()
        assert len(lines) == 250
        epochs = sorted(json.loads(l)["epoch"] for l in lines)
        assert epochs == list(range(250))
        for l in lines:
            validate_record(json.loads(l))


# =====================================================================
# sinks
# =====================================================================
class TestSinks:
    def test_jsonl_sink_round_trip(self, tmp_path):
        path = tmp_path / "t.jsonl"
        sink = JsonlSink(path)
        recs = [sample_epoch_record() for _ in range(3)]
        sink.write(recs[:2])
        sink.write(recs[2:])
        sink.close()
        lines = path.read_text().splitlines()
        assert [json.loads(l) for l in lines] == recs

    def test_prometheus_text_exposition(self):
        sink = PrometheusTextSink()
        rec = sample_epoch_record()
        sink.write([rec])
        tenant = tenant_record_wire(type("R", (), dict(
            epoch=0, lane="hinted", tenant="kv_a", time_s=1.0, access_s=1.0,
            host_tax_s=0.0, migration_s=0.0, accuracy=0.25, coverage=0.75,
            resident=8, promoted=0, demoted=0, n_fast=10, n_slow=2,
            hot_k=8))(), scenario="fleet")
        validate_record(tenant)
        sink.write([tenant])
        sink.set_counter("repro_dispatch_total", 12, kind="epoch_step")
        text = sink.render()
        assert "# TYPE repro_coverage_ratio gauge" in text
        assert ('repro_coverage_ratio{lane="hinted",scenario="unit",'
                'tenant=""} 0.8') in text
        assert ('repro_coverage_ratio{lane="hinted",scenario="fleet",'
                'tenant="kv_a"} 0.75') in text
        assert 'repro_dispatch_total{kind="epoch_step"} 12' in text
        # last write wins (gauge semantics)
        rec2 = dict(rec, coverage=0.5)
        sink.write([rec2])
        assert ('repro_coverage_ratio{lane="hinted",scenario="unit",'
                'tenant=""} 0.5') in sink.render()


# =====================================================================
# non-interference gates
# =====================================================================
class TestNonInterference:
    @pytest.mark.parametrize("sync_every", [1, 3])
    def test_bit_identical_and_zero_added_dispatches(self, sync_every):
        scenario = make_scenario(n_epochs=6)
        with rtmod.counting() as c_off:
            base = run_scenario(scenario, hints=True, sync_every=sync_every)
            off = dict(c_off.dispatch)    # views are live: snapshot now
        sink = MemorySink()
        client = ExportClient(sink, flush_interval_s=0.005)
        with rtmod.counting() as c_on:
            on = run_scenario(scenario, hints=True, sync_every=sync_every,
                              export=client)
            on_counts = dict(c_on.dispatch)
        client.flush(timeout=30)
        assert on_counts == off
        assert on_counts["observe_all"] == 6
        assert on_counts["epoch_step"] == 6           # 2 dispatches/epoch
        assert json.dumps(base, sort_keys=True) == json.dumps(
            on, sort_keys=True)
        st = client.stats()
        recs = sink.snapshot()
        assert st["dropped_queue_full"] == 0 and st["sink_failures"] == 0
        assert len(recs) == 6 * len(ALL_POLICIES) + len(ALL_POLICIES)
        for rec in recs:
            validate_record(rec)
            assert rec["scenario"] == scenario.name
        client.close()

    def test_fleet_bit_identical_with_tenant_rows(self):
        fleet = make_fleet(n_epochs=4)
        with rtmod.counting() as c_off:
            base = run_fleet(fleet, hints=False, sync_every=2)
            off = dict(c_off.dispatch)
        sink = MemorySink()
        client = ExportClient(sink, flush_interval_s=0.005)
        with rtmod.counting() as c_on:
            on = run_fleet(fleet, hints=False, sync_every=2, export=client)
            on_counts = dict(c_on.dispatch)
        client.flush(timeout=30)
        assert on_counts == off
        for key in ("trajectory", "summary", "tenants"):
            assert json.dumps(base[key], sort_keys=True) == json.dumps(
                on[key], sort_keys=True), key
        recs = sink.snapshot()
        by_type = {}
        for rec in recs:
            validate_record(rec)
            by_type.setdefault(rec["record_type"], []).append(rec)
        L = len(ALL_POLICIES)
        assert len(by_type["epoch"]) == 4 * L
        assert len(by_type["tenant"]) == 4 * L * 2
        assert len(by_type["lane_summary"]) == L
        assert len(by_type["tenant_lane_summary"]) == L * 2
        assert {r["tenant"] for r in by_type["tenant"]} == {"kv_a", "kv_b"}
        client.close()

    def test_reference_path_exports_too(self):
        rt = make_runtime(fused=False, policies=("hmu_oracle", "hinted"))
        sink = MemorySink()
        rt.export = ExportClient(sink, flush_interval_s=0.005)
        rt.run(make_epochs(3))
        rt.export.flush(timeout=30)
        recs = sink.snapshot()
        assert len(recs) == 3 * 2
        for rec in recs:
            validate_record(rec)
        rt.export.close()

    def test_dead_sink_never_stalls_or_corrupts_run(self):
        """The acceptance gate: a sink that fails every write trips the
        breaker to noop; run() neither stalls nor raises, and the
        trajectory is STILL bit-identical to the export-off run."""
        scenario = make_scenario(n_epochs=6)
        base = run_scenario(scenario, hints=False, sync_every=3)
        client = ExportClient(
            MemorySink(fail_always=True), batch_size=1,
            flush_interval_s=0.005,
            breaker=CircuitBreaker(failure_threshold=1, cooldown_s=0.0),
            degrade_after_trips=2)
        t0 = time.monotonic()
        on = run_scenario(scenario, hints=False, sync_every=3,
                          export=client)
        elapsed = time.monotonic() - t0
        client.flush(timeout=30)
        assert json.dumps(base, sort_keys=True) == json.dumps(
            on, sort_keys=True)
        st = client.stats()
        assert st["exported"] == 0
        assert st["degraded"] or st["breaker_trips"] >= 1
        assert elapsed < 120          # no stall (generous CI headroom)
        client.close()

    def test_midstream_exception_still_flushes_and_exports_tail(self):
        """Satellite bugfix: run() killed mid-stream flushes the pipelined
        partial-tail buffer (sync_every=K) — no dispatched epoch's record
        is lost, in-process or on the wire."""
        class Boom(RuntimeError):
            pass

        def dying_stream(epochs, die_after):
            for i, e in enumerate(epochs):
                if i == die_after:
                    raise Boom()
                yield e

        sink = MemorySink()
        client = ExportClient(sink, flush_interval_s=0.005)
        rt = make_runtime(sync_every=4, policies=("hmu_oracle", "hinted"),
                          export=client)
        with pytest.raises(Boom):
            rt.run(dying_stream(make_epochs(10), die_after=6))
        # 6 epochs dispatched: one full buffer of 4 + a partial tail of 2
        assert all(len(recs) == 6 for recs in rt.records.values())
        client.flush(timeout=30)
        recs = sink.snapshot()
        assert len(recs) == 6 * 2
        assert sorted({r["epoch"] for r in recs}) == list(range(6))
        # and the flushed records match an unkilled run bit for bit
        rt2 = make_runtime(sync_every=4, policies=("hmu_oracle", "hinted"))
        with pytest.raises(Boom):
            rt2.run(dying_stream(make_epochs(10), die_after=6))
        for lane in ("hmu_oracle", "hinted"):
            assert [r.to_dict() for r in rt.records[lane]] == \
                   [r.to_dict() for r in rt2.records[lane]]
        client.close()

    def test_tracemalloc_budget(self):
        """The export path's own peak host allocation stays bounded: the
        queue is the only buffer, so memory is O(queue_size), not
        O(records)."""
        class DiscardSink:
            def write(self, records):
                pass

        rec = sample_epoch_record()
        client = ExportClient(DiscardSink(), queue_size=1024,
                              flush_interval_s=0.002)
        tracemalloc.start()
        try:
            for i in range(20_000):
                r = dict(rec)
                r["epoch"] = i
                client.emit(r)
            client.flush(timeout=60)
            _, peak = tracemalloc.get_traced_memory()
        finally:
            tracemalloc.stop()
        st = client.stats()
        assert st["emitted"] + st["dropped_queue_full"] == 20_000
        # 20k records through a 1024-deep queue; budget is ~queue_size
        # records (<~1 KB each) plus converter overhead, far below the
        # O(records) ~20 MB an unbounded buffer would cost
        assert peak < 8 * 1024 * 1024, f"export path peaked at {peak} bytes"
        client.close()

    def test_export_on_vs_off_memory_overhead_bounded(self):
        """tracemalloc budget on the real epoch loop: export-on peak host
        memory stays within a fixed budget of export-off."""
        scenario = make_scenario(n_epochs=4)
        run_scenario(scenario, hints=False)     # warm jit caches

        tracemalloc.start()
        try:
            run_scenario(scenario, hints=False)
            _, peak_off = tracemalloc.get_traced_memory()
        finally:
            tracemalloc.stop()

        class DiscardSink:
            def write(self, records):
                pass

        client = ExportClient(DiscardSink(), flush_interval_s=0.005)
        tracemalloc.start()
        try:
            run_scenario(scenario, hints=False, export=client)
            client.flush(timeout=30)
            _, peak_on = tracemalloc.get_traced_memory()
        finally:
            tracemalloc.stop()
        client.close()
        budget = 4 * 1024 * 1024
        assert peak_on - peak_off < budget, (
            f"export added {peak_on - peak_off} bytes peak "
            f"(off={peak_off}, on={peak_on})")
