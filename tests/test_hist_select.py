"""hist_select: one-pass radix-histogram threshold select vs its oracles.

The kernel's contract is bit-identity with selectk's 32-round bitwise
threshold search — and therefore with the lax.top_k-equivalent selection
built on it, including lowest-index tie-breaks and the int32.min quota
sentinel.  Everything runs through the Pallas interpreter so CPU CI
executes the actual kernel body, not just the jnp reference."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import selectk
from repro.kernels.dispatch import PallasBackend
from repro.kernels.hist_select import MAX_N, kth_key_u, kth_key_u_ref

BACKEND = PallasBackend(interpret=True, select_tile_n=512)


def _keys(rng, n, b=1, ties=True):
    u = rng.integers(0, np.iinfo(np.uint32).max, size=(b, n), dtype=np.uint32)
    if ties and n >= 8:
        u[:, : n // 4] = u[:, 0:1]          # long duplicate run
    return jnp.asarray(u)


# ----------------------------------------------------- threshold bit-identity
@pytest.mark.parametrize("n", [50, 130, 997, 2048])
def test_kth_key_matches_ref_and_bitwise_search(n):
    rng = np.random.default_rng(0)
    u = _keys(rng, n, b=3)
    seg = jnp.zeros((n,), jnp.int32)
    for k in {0, 1, 7, n // 2, n}:
        t_pal = kth_key_u(u, seg, (k,), tile_n=BACKEND.select_tile_n,
                          use_pallas=True, interpret=True)
        t_ref = kth_key_u_ref(u, seg, (k,))
        t_bit = selectk._kth_largest(u, k)
        np.testing.assert_array_equal(np.asarray(t_pal),
                                      np.asarray(t_ref), err_msg=f"k={k}")
        np.testing.assert_array_equal(np.asarray(t_pal).reshape(-1),
                                      np.asarray(t_bit).reshape(-1),
                                      err_msg=f"k={k}")


def test_kth_key_rejects_oversized_input():
    n = MAX_N + 1
    u = jnp.zeros((1, n), jnp.uint32)
    seg = jnp.zeros((n,), jnp.int32)
    with pytest.raises(ValueError, match="MAX_N"):
        kth_key_u(u, seg, (1,), use_pallas=True, interpret=True)
    # selectk quietly takes the 32-round XLA search past the bound instead
    t = selectk._kth_dispatch(u, 1, BACKEND)
    np.testing.assert_array_equal(np.asarray(t), [0])


# -------------------------------------------- selection entry-point parity
@pytest.mark.parametrize("n,k", [(997, 97), (130, 13), (2048, 256)])
def test_select_top_k_backend_matches_lax_top_k(n, k):
    rng = np.random.default_rng(1)
    x = rng.integers(0, 5, n).astype(np.int32)      # tie-heavy
    # quota-masked rows carry int32.min sentinels; they must never select
    x[rng.choice(n, n // 10, replace=False)] = np.iinfo(np.int32).min
    xj = jnp.asarray(x)
    v_ref, i_ref = jax.lax.top_k(xj, k)
    v0, i0, m0 = selectk.select_top_k(xj, k, return_mask=True)
    v1, i1, m1 = selectk.select_top_k(xj, k, return_mask=True,
                                      backend=BACKEND)
    np.testing.assert_array_equal(np.asarray(v_ref), np.asarray(v1))
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))
    np.testing.assert_array_equal(
        np.asarray(selectk.top_k_mask(xj, k)),
        np.asarray(selectk.top_k_mask(xj, k, backend=BACKEND)))


def test_segment_top_k_mask_backend_matches_per_slice():
    """Per-tenant quota select: the vectorized kernel path must reproduce
    the per-slice XLA path bit for bit — zero-cap tenants (nothing
    protected) and over-sized caps (everything protected) included."""
    rng = np.random.default_rng(2)
    n = 997
    bounds = (0, 137, 400, n)
    caps = (10, 0, 900)
    x = jnp.asarray(rng.integers(0, 7, (2, n)).astype(np.int32))
    m0 = selectk.segment_top_k_mask(x, bounds, caps)
    m1 = selectk.segment_top_k_mask(x, bounds, caps, backend=BACKEND)
    np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))
    # cap semantics hold on the kernel path too
    got = np.asarray(m1)
    for s, (lo, hi) in enumerate(zip(bounds[:-1], bounds[1:])):
        counts = got[:, lo:hi].sum(axis=-1)
        assert (counts == min(caps[s], hi - lo)).all()


# --------------------------------------------------------------- satellites
def test_prefix_sum_prime_sizes_match_cumsum():
    """Regression: prefix_sum used to silently fall back to one jnp.cumsum
    whenever chunk didn't divide n — prime sizes now pad to the chunked
    scan and must still be exact."""
    rng = np.random.default_rng(3)
    for n in (1, 7, 97, 257, 1009):
        x = jnp.asarray((rng.random((2, n)) < 0.5))
        np.testing.assert_array_equal(
            np.asarray(jnp.cumsum(x.astype(jnp.int32), axis=-1)),
            np.asarray(selectk.prefix_sum(x)), err_msg=f"n={n}")


def test_sortable_key_contract_checked_and_sentinels_order_low():
    """sortable_key's precondition — non-negative scores, or all negatives
    equal to one shared sentinel — is debug-asserted eagerly; the two
    sentinels the runtime actually uses (float -1 demotion marker,
    int32.min quota mask) must order below every real score."""
    ok = selectk.sortable_key(jnp.asarray([3.0, 0.0, -1.0, -1.0]))
    u = np.asarray(selectk._to_u(ok))
    assert (u[2] == u[3]) and (u[2] < u[0]) and (u[2] < u[1])
    q = np.asarray(selectk._to_u(
        jnp.asarray([5, 0, np.iinfo(np.int32).min], jnp.int32)))
    assert q[2] < q[1] < q[0]
    with pytest.raises(ValueError, match="sentinel"):
        selectk.sortable_key(jnp.asarray([1.0, -1.0, -2.0]))
    # tracers can't be inspected eagerly — the check must not fire under jit
    jax.jit(selectk.sortable_key)(
        jnp.asarray([1.0, -1.0, -2.0])).block_until_ready()
