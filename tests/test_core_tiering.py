"""Unit + property tests for the core tiering library (blockstore, telemetry,
policy, metrics, cost model)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import TieredStore, policy, metrics, telemetry as tel
from repro.core.costmodel import CXL_SYSTEM, TPU_V5E_SYSTEM


# ------------------------------------------------------------------ TieredStore
def make_store(n_rows=64, dim=8, block_rows=4, n_slots=4, dtype=jnp.float32):
    data = jnp.arange(n_rows * dim, dtype=dtype).reshape(n_rows, dim)
    return data, TieredStore.create(data, block_rows=block_rows, n_slots=n_slots)


def test_gather_matches_source_initially():
    data, st_ = make_store()
    rows = jnp.array([0, 3, 17, 63, 5])
    np.testing.assert_allclose(st_.gather(rows), np.asarray(data)[np.asarray(rows)])


def test_promotion_preserves_gather_semantics():
    data, st_ = make_store()
    rows = jnp.arange(64)
    st2 = st_.promote(jnp.array([0, 7, 15]))
    np.testing.assert_allclose(st2.gather(rows), data)
    st3 = st2.demote(jnp.array([7]))
    np.testing.assert_allclose(st3.gather(rows), data)


def test_promote_then_evict_writes_back_dirty_blocks():
    data, st_ = make_store()
    st2 = st_.promote(jnp.array([2]))
    # write to a promoted row (hits the fast copy)
    newval = jnp.full((8,), 99.0)
    st2 = st2.scatter_update(jnp.array([8]), newval[None, :])  # row 8 in block 2
    # evict block 2 by filling all slots with other blocks
    st3 = st2.promote(jnp.array([4, 5, 6, 7]))
    got = st3.gather(jnp.array([8]))[0]
    np.testing.assert_allclose(got, newval, err_msg="writeback on eviction lost data")


def test_is_fast_and_occupancy():
    _, st_ = make_store()
    st2 = st_.promote(jnp.array([1, 9]))
    assert int(st2.fast_occupancy()) == 2
    assert bool(st2.is_fast(jnp.array([4]))[0])       # row 4 -> block 1
    assert not bool(st2.is_fast(jnp.array([0]))[0])


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.lists(st.integers(min_value=-1, max_value=15), min_size=1, max_size=12),
    rows=st.lists(st.integers(min_value=0, max_value=63), min_size=1, max_size=16),
)
def test_property_promotion_never_changes_reads(blocks, rows):
    data, st_ = make_store()
    st2 = st_.promote(jnp.array(blocks, dtype=jnp.int32))
    got = st2.gather(jnp.array(rows))
    np.testing.assert_allclose(got, np.asarray(data)[rows])
    # indirection invariants: slot<->block maps are mutually consistent
    b2s = np.asarray(st2.block_to_slot)
    s2b = np.asarray(st2.slot_to_block)
    for blk, slot in enumerate(b2s):
        if slot >= 0:
            assert s2b[slot] == blk
    for slot, blk in enumerate(s2b):
        if blk >= 0:
            assert b2s[blk] == slot
    assert (b2s >= 0).sum() == (s2b >= 0).sum() <= st2.n_slots


# ------------------------------------------------------------------ telemetry
def test_hmu_counts_are_exact():
    state = tel.hmu_init(100)
    rng = np.random.default_rng(0)
    ref = np.zeros(100, np.int64)
    for _ in range(5):
        ids = rng.integers(0, 100, 1000)
        state = tel.hmu_observe(state, jnp.asarray(ids))
        np.add.at(ref, ids, 1)
    np.testing.assert_array_equal(np.asarray(tel.hmu_estimate(state)), ref)


def test_hmu_log_overflow_accounting():
    state = tel.hmu_init(10, log_capacity=100)
    state = tel.hmu_observe(state, jnp.zeros((150,), jnp.int32))
    assert float(state.log_used) == 100.0
    assert float(state.log_dropped) == 50.0
    state = tel.hmu_drain_cost(state)
    assert float(state.log_used) == 0.0
    assert float(state.host_events) == 100.0


def test_pebs_sampling_rate_and_coverage_gap():
    period = 97
    state = tel.pebs_init(1000, period=period)
    rng = np.random.default_rng(1)
    n_total = 0
    for _ in range(10):
        ids = rng.integers(0, 1000, 5000)
        state = tel.pebs_observe(state, jnp.asarray(ids))
        n_total += ids.size
    n_samples = int(np.asarray(state.sampled).sum())
    assert n_samples == (n_total + period - 1) // period or abs(
        n_samples - n_total // period) <= 1
    # host pays exactly one event per sample
    assert int(float(state.host_events)) == n_samples


def test_pebs_estimate_scales_by_period():
    state = tel.pebs_init(4, period=10)
    state = tel.pebs_observe(state, jnp.zeros((100,), jnp.int32))
    est = np.asarray(tel.pebs_estimate(state))
    assert est[0] == 100 and est[1:].sum() == 0


def test_nb_sees_recency_not_frequency():
    """A block touched 1000x and a block touched once per scan window get the
    same fault count — the paper's NB accuracy failure."""
    state = tel.nb_init(4, scan_rate=4)  # full unmap every observe
    hot = np.zeros(1000, np.int64)                    # block 0, 1000 touches
    warm = np.array([1], np.int64)                    # block 1, 1 touch
    for _ in range(3):
        state = tel.nb_observe(state, jnp.asarray(np.concatenate([hot, warm])))
    faults = np.asarray(tel.nb_estimate(state))
    assert faults[0] == faults[1] == 3
    assert faults[2] == faults[3] == 0


def test_nb_fault_costs_host_events():
    state = tel.nb_init(8, scan_rate=8)
    state = tel.nb_observe(state, jnp.arange(8))
    assert float(state.host_events) == 8.0


# ------------------------------------------------------------------ policy
def test_oracle_top_k_requires_nonzero_counts():
    counts = jnp.array([5, 0, 3, 0, 9])
    plan = policy.oracle_top_k(counts, k=4)
    got = set(int(x) for x in np.asarray(plan.promote) if x >= 0)
    assert got == {0, 2, 4}


def test_nb_two_touch_gates_on_two_faults():
    faults = jnp.array([1, 2, 5, 0])
    plan = policy.nb_two_touch(faults, k=4)
    got = set(int(x) for x in np.asarray(plan.promote) if x >= 0)
    assert got == {1, 2}


def test_proactive_ewma_predicts_trend():
    prev = jnp.zeros(4)
    pred, plan = policy.proactive_ewma(prev, jnp.array([10, 0, 2, 0]), k=2, alpha=0.5)
    got = [int(x) for x in np.asarray(plan.promote) if x >= 0]
    assert got[0] == 0
    pred2, plan2 = policy.proactive_ewma(pred, jnp.array([0, 8, 2, 0]), k=2, alpha=0.5)
    got2 = [int(x) for x in np.asarray(plan2.promote) if x >= 0]
    assert 1 in got2  # rising block appears


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=1000), min_size=4, max_size=64),
       st.integers(min_value=1, max_value=16))
def test_property_oracle_topk_maximizes_captured_traffic(counts, k):
    counts_a = jnp.asarray(counts, jnp.int32)
    plan = policy.oracle_top_k(counts_a, k=k)
    ids = np.asarray(plan.promote)
    ids = ids[ids >= 0]
    captured = int(np.asarray(counts)[ids].sum()) if ids.size else 0
    best = int(np.sort(np.asarray(counts))[::-1][:k].sum())
    # oracle never captures less than any other k-set
    assert captured == min(best, int(np.asarray(counts).sum()))


# ------------------------------------------------------------------ metrics
def test_metrics_definitions():
    promoted = [0, 1, 2, 3]
    true_hot = [2, 3, 4, 5, 6, 7]
    assert metrics.accuracy(promoted, true_hot) == 0.5
    assert metrics.coverage(promoted, true_hot, k=6) == pytest.approx(2 / 6)
    assert metrics.overlap([0, 1], [1, 2], k=2) == 0.5


def test_hotness_cdf_shape():
    counts = np.r_[np.full(10, 1000), np.ones(990)]
    frac = metrics.pages_for_access_fraction(counts, 0.90)
    assert frac <= 0.02  # 1% of pages carry ~91% of accesses


# ------------------------------------------------------------------ cost model
def test_cost_model_tier_ordering():
    for sysm in (CXL_SYSTEM, TPU_V5E_SYSTEM):
        t_fast = sysm.access_time_s(1e6, 0, 256)
        t_slow = sysm.access_time_s(0, 1e6, 256)
        assert t_slow > t_fast > 0


def test_cost_model_monotone_in_slow_fraction():
    prev = -1.0
    for frac in np.linspace(0, 1, 11):
        t = CXL_SYSTEM.access_time_s((1 - frac) * 1e6, frac * 1e6, 256)
        assert t >= prev
        prev = t


def test_reactive_watermark_respects_capacity():
    counts = jnp.asarray([100, 90, 80, 5, 3, 1, 0, 0])
    plan = policy.reactive_watermark(counts, hot_threshold=10,
                                     free_slots=jnp.asarray(2), max_moves=8)
    got = [int(x) for x in np.asarray(plan.promote) if x >= 0]
    assert got == [0, 1]          # only 2 free slots, hottest first


def test_hinted_policy_blends_static_priority():
    counts = jnp.asarray([0, 0, 100, 100])
    hints = jnp.asarray([1.0, 0.0, 0.0, 1.0])   # block 0 pinned important
    plan = policy.hinted(counts, hints, k=2, hint_weight=0.9)
    got = set(int(x) for x in np.asarray(plan.promote) if x >= 0)
    assert 0 in got and 3 in got   # hint rescues cold block 0


def test_coldest_victims_orders_by_heat():
    est = jnp.asarray([100, 1, 50, 7])
    s2b = jnp.asarray([0, 1, 2, 3])   # all four blocks resident
    vic = policy.coldest_victims(est, s2b, n=2)
    assert [int(x) for x in np.asarray(vic)] == [1, 3]


# ----------------------------------------------- cost model: overlap semantics
def test_access_time_overlap_zero_is_serial_tier_sum():
    """overlap=0 (the default) is exactly fast-tier + slow-tier time."""
    for sysm in (CXL_SYSTEM, TPU_V5E_SYSTEM):
        nf, ns, bpa = 3e5, 7e5, 256.0
        tf = sysm.tier_time_s(nf, nf * bpa, sysm.fast)
        ts = sysm.tier_time_s(ns, ns * bpa, sysm.slow)
        assert sysm.access_time_s(nf, ns, bpa) == pytest.approx(tf + ts)
        assert sysm.access_time_s(nf, ns, bpa, overlap=0.0) == \
            pytest.approx(tf + ts)


def test_access_time_overlap_one_hides_all_slow_tier_time():
    nf, ns, bpa = 3e5, 7e5, 256.0
    tf = CXL_SYSTEM.tier_time_s(nf, nf * bpa, CXL_SYSTEM.fast)
    assert CXL_SYSTEM.access_time_s(nf, ns, bpa, overlap=1.0) == \
        pytest.approx(tf)


def test_access_time_monotone_decreasing_in_overlap():
    prev = float("inf")
    for ov in np.linspace(0.0, 1.0, 11):
        t = CXL_SYSTEM.access_time_s(1e5, 9e5, 256.0, overlap=float(ov))
        assert t <= prev
        prev = t


@pytest.mark.parametrize("bad", [-0.01, 1.01, 2.0, -1.0, float("nan")])
def test_access_time_rejects_out_of_range_overlap(bad):
    with pytest.raises(ValueError, match="overlap"):
        CXL_SYSTEM.access_time_s(1e5, 9e5, 256.0, overlap=bad)
    with pytest.raises(ValueError, match="overlap"):
        CXL_SYSTEM.migration_overlap_s(9e5, 256.0, 100, 4096.0, overlap=bad)
    with pytest.raises(ValueError, match="overlap"):
        CXL_SYSTEM.overlapped_epoch_time_s(1e5, 9e5, 256.0, 100, 4096.0,
                                           overlap=bad)


def test_overlapped_epoch_time_zero_overlap_is_stop_the_world():
    """overlap=0 charges migration serially: access_time_s + migration_time_s."""
    nf, ns, bpa, nb, bb = 2e5, 8e5, 256.0, 5_000, 4096.0
    serial = (CXL_SYSTEM.access_time_s(nf, ns, bpa)
              + CXL_SYSTEM.migration_time_s(nb, bb))
    assert CXL_SYSTEM.overlapped_epoch_time_s(nf, ns, bpa, nb, bb,
                                              overlap=0.0) == \
        pytest.approx(serial)


def test_overlapped_epoch_time_full_overlap_hides_shorter_leg():
    """overlap=1 hides min(slow-tier access time, migration DMA) — never more
    than the serial sum, never less than the unhidden legs."""
    nf, ns, bpa, bb = 2e5, 8e5, 256.0, 4096.0
    ts = CXL_SYSTEM.tier_time_s(ns, ns * bpa, CXL_SYSTEM.slow)
    for nb in (10, 5_000, 5_000_000):     # mig << ts, mig ~ ts, mig >> ts
        mig = CXL_SYSTEM.migration_time_s(nb, bb)
        access = CXL_SYSTEM.access_time_s(nf, ns, bpa)
        got = CXL_SYSTEM.overlapped_epoch_time_s(nf, ns, bpa, nb, bb,
                                                 overlap=1.0)
        assert got == pytest.approx(access + mig - min(ts, mig))
        assert got <= access + mig + 1e-12
        assert got >= max(access, mig) - 1e-12


def test_overlapped_epoch_time_monotone_in_overlap():
    prev = float("inf")
    for ov in np.linspace(0.0, 1.0, 11):
        t = CXL_SYSTEM.overlapped_epoch_time_s(2e5, 8e5, 256.0, 5_000, 4096.0,
                                               overlap=float(ov))
        assert t <= prev
        prev = t


def test_migration_overlap_zero_blocks_hides_nothing():
    assert CXL_SYSTEM.migration_overlap_s(8e5, 256.0, 0, 4096.0) == 0.0


def test_overlapped_epoch_time_matches_record_decomposition():
    """Parity contract with EpochRuntime._record's prefetch accounting: the
    runtime charges access_time_s + migration_time_s - migration_overlap_s
    component-wise (the record needs each field separately);
    overlapped_epoch_time_s folds the hidden share through the
    access_time_s(overlap=) hook.  The two derivations must stay equal for
    every (traffic mix, migration size, overlap) — an edit to either (the
    min(ts, mig) cap, the eff fold-out) breaks this, not just the docs."""
    for nf, ns in ((0.0, 9e5), (2e5, 8e5), (9e5, 0.0)):
        for nb in (0, 10, 5_000, 5_000_000):
            for ov in (0.0, 0.3, 1.0):
                decomposed = (
                    CXL_SYSTEM.access_time_s(nf, ns, 256.0)
                    + CXL_SYSTEM.migration_time_s(nb, 4096.0)
                    - CXL_SYSTEM.migration_overlap_s(ns, 256.0, nb, 4096.0,
                                                     overlap=ov))
                folded = CXL_SYSTEM.overlapped_epoch_time_s(
                    nf, ns, 256.0, nb, 4096.0, overlap=ov)
                assert folded == pytest.approx(decomposed, rel=1e-12), \
                    (nf, ns, nb, ov)
