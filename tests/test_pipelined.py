"""Pipelined async epoch loop: ``sync_every=K`` batches the runtime's
record syncs (device-resident ``(K,)`` accumulator, one ``device_get``
per K epochs, partial tail flushed on loop exit) and must stay
bit-identical to the synchronous per-epoch-sync loop for every K —
records, per-tenant rows, final placements, single-device and sharded —
while the epoch still costs exactly 2 dispatches, one trace, and one
``record_sync`` per K.  Plus the reuse/timing bugfixes that ride along:
``run()`` returns only its own stream's records, donation through
``_epoch_step`` keeps invalidating the previous epoch's buffers, and the
hint identity-skip cache still short-circuits under pipelining."""
import dataclasses
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.core import runtime as rtmod
from repro.core.runtime import ALL_POLICIES, EpochRuntime
from repro.dlrm import datagen
from repro.scenarios import (DLRMScenario, KVCacheScenario,
                             MmapBenchScenario, MoEExpertScenario,
                             run_scenario)

REPO = Path(__file__).resolve().parent.parent
SUBPROC_ENV = dict(os.environ, PYTHONPATH=str(REPO / "src"),
                   XLA_FLAGS="--xla_force_host_platform_device_count=8",
                   JAX_PLATFORMS="cpu")

SMALL_SPEC = dataclasses.replace(datagen.SMALL, lookups_per_batch=8_000)


def run_py(code: str, timeout=480):
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=SUBPROC_ENV,
                          timeout=timeout, cwd=REPO)


def make_runtime(sync_every=1, fused=True, **kw):
    kw.setdefault("policies", ALL_POLICIES)
    kw.setdefault("pebs_period", 101)
    kw.setdefault("nb_scan_rate", 90)
    return EpochRuntime(400, 40, fused=fused, sync_every=sync_every, **kw)


def make_epochs(n_epochs, n_blocks=400, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, n_blocks, (3, 2000)).astype(np.int32)
            for _ in range(n_epochs)]


SCENARIO_FACTORIES = {
    "dlrm": lambda: DLRMScenario(spec=SMALL_SPEC, n_epochs=4,
                                 batches_per_epoch=2, shift_at=2),
    "kv_cache": lambda: KVCacheScenario(batch=2, n_epochs=4,
                                        batches_per_epoch=2,
                                        accesses_per_batch=1_024),
    "moe_experts": lambda: MoEExpertScenario(n_epochs=4, batches_per_epoch=2,
                                             shift_at=2, batch=2),
    "mmap_bench": lambda: MmapBenchScenario(n_epochs=4, batches_per_epoch=2,
                                            accesses_per_batch=8_000),
}


# ------------------------------------------------------- raw-runtime parity
@pytest.mark.parametrize("sync_every", [1, 4, 7])
def test_sync_every_bit_identical_to_reference(sync_every):
    """ISSUE acceptance: K=1 (per-epoch sync), K=4 (7 epochs -> one full
    buffer + a 3-epoch partial tail), K=7 (tail-only flush) all reproduce
    the synchronous reference-path oracle bit for bit — every EpochRecord
    field, every lane, and the final placements."""
    epochs = make_epochs(7)
    ref = make_runtime(fused=False)
    t_ref = ref.run(iter(epochs))
    rt = make_runtime(sync_every=sync_every)
    t = rt.run(iter(epochs))
    for lane in ALL_POLICIES:
        assert len(t.lane(lane)) == 7
        for a, b in zip(t_ref.lane(lane), t.lane(lane)):
            assert a.to_dict() == b.to_dict(), (lane, a.epoch)
    lanes_ref, lanes_k = ref.lanes, rt.lanes
    for name in ALL_POLICIES:
        np.testing.assert_array_equal(lanes_ref[name].slot_to_block,
                                      lanes_k[name].slot_to_block)


def test_record_epochs_are_stamped_in_dispatch_order():
    rt = make_runtime(sync_every=3)
    rt.run(iter(make_epochs(5)))
    for recs in rt.records.values():
        assert [r.epoch for r in recs] == [0, 1, 2, 3, 4]


def test_sync_every_validation():
    with pytest.raises(ValueError, match="sync_every"):
        make_runtime(sync_every=0)
    with pytest.raises(ValueError, match="reference"):
        make_runtime(sync_every=2, fused=False)


# ----------------------------------------------- dispatch / trace accounting
def test_pipelined_epoch_still_two_dispatches_one_record_sync_per_k():
    """ISSUE acceptance: sync_every=K keeps the epoch at observe_all +
    epoch_step (2 dispatches), re-uses ONE trace across K boundaries (the
    row index is a traced scalar, the buffer a fixed (K,) shape), and pulls
    records exactly ceil(n_epochs / K) times."""
    rt = make_runtime(sync_every=4)
    rt.step(make_epochs(1, seed=9)[0])               # warm the trace
    rt.flush()
    with rtmod.counting() as counts:
        rt.run(iter(make_epochs(10)))
        assert counts.dispatch == {"observe_all": 10, "epoch_step": 10,
                                   "reference": 0, "hint_refresh": 0,
                                   "record_sync": 3}     # ceil(10 / 4)
        assert counts.trace["epoch_step"] == 0       # no per-K retrace


def test_manual_step_flush_semantics():
    """K=1 ``step`` keeps its historical per-epoch dict; K>1 returns the
    batches it flushed (empty until a buffer fills) and ``flush`` drains
    the partial tail on demand."""
    epochs = make_epochs(5)
    rt1 = make_runtime(sync_every=1)
    out = rt1.step(epochs[0])
    assert set(out) == set(ALL_POLICIES)
    assert all(hasattr(r, "time_s") for r in out.values())

    rt = make_runtime(sync_every=3)
    assert rt.step(epochs[0]) == {}
    assert rt.step(epochs[1]) == {}
    assert rt.step(epochs[2]) == {}                  # buffer full, not pulled
    flushed = rt.step(epochs[3])                     # pulled AFTER dispatching
    assert {len(v) for v in flushed.values()} == {3}
    assert [r.epoch for r in flushed["hmu_oracle"]] == [0, 1, 2]
    tail = rt.flush()
    assert {len(v) for v in tail.values()} == {1}
    assert rt.flush() == {}                          # idempotent when drained
    for recs in rt.records.values():
        assert len(recs) == 4
    # bit-identity with the per-epoch-sync loop holds for the manual path too
    rt1b = make_runtime(sync_every=1)
    for e in epochs[:4]:
        rt1b.step(e)
    for lane in ALL_POLICIES:
        for x, y in zip(rt1b.records[lane], rt.records[lane]):
            assert x.to_dict() == y.to_dict(), lane


# ------------------------------------------------------------ runtime reuse
def test_second_run_returns_only_its_own_records():
    """Bugfix regression: ``run`` snapshots the record index, so a reused
    runtime's second trajectory holds only the second stream's records;
    the full history stays on :meth:`trajectory`."""
    rt = make_runtime(sync_every=3)
    t1 = rt.run(iter(make_epochs(4, seed=0)))
    t2 = rt.run(iter(make_epochs(3, seed=1)))
    for lane in ALL_POLICIES:
        assert len(t1.lane(lane)) == 4
        assert len(t2.lane(lane)) == 3
        assert [r.epoch for r in t2.lane(lane)] == [4, 5, 6]
        full = rt.trajectory().lane(lane)
        assert len(full) == 7
        assert full[4:] == list(t2.lane(lane))
    # summaries built from t2 must not mix stream-1 epochs
    assert all(r.epoch >= 4 for lane in ALL_POLICIES for r in t2.lane(lane))


def test_run_after_manual_steps_excludes_them():
    rt = make_runtime(sync_every=2)
    rt.step(make_epochs(1, seed=5)[0])               # still buffered
    t = rt.run(iter(make_epochs(3, seed=6)))
    for lane in ALL_POLICIES:
        assert len(t.lane(lane)) == 3                # manual step not included
        assert len(rt.records[lane]) == 4            # ...but kept in history


# ----------------------------------------------------------------- donation
def test_epoch_step_donates_the_previous_state_buffers():
    """Donation regression: observe_all and _epoch_step both take the state
    via ``donate_argnums=0`` — after a step the previous epoch's collector,
    placement, and record-accumulator buffers must be invalidated, not
    copied.  (A silent donation regression would double peak memory at the
    5.24M-page paper scale.)"""
    rt = make_runtime(sync_every=2)
    rt.step(make_epochs(1, seed=0)[0])               # warm the trace
    prev = rt._state
    rt.step(make_epochs(1, seed=1)[0])
    assert prev.bundle.true_counts.is_deleted()      # donated by observe_all
    assert prev.placement.slot_to_block.is_deleted()  # donated by _epoch_step
    assert prev.out_buf["drained_lo"].is_deleted()      # accumulator rides along


# --------------------------------------------- hints under the batched sync
def test_hint_identity_skip_unchanged_under_pipelining():
    """The per-epoch hint refresh is a transfer, not a dispatch, and the
    identity-skip cache still short-circuits with sync_every>1: a static
    pipeline whose ranks never change uploads once, and hint_refresh counts
    the same for K=1 and K=4 over the same stream."""
    from repro.hints import HintPipeline, LookaheadWindow

    def counted(sync_every):
        rt = EpochRuntime(
            400, 40, policies=ALL_POLICIES, pebs_period=101, nb_scan_rate=90,
            sync_every=sync_every,
            hints=HintPipeline(400, lookahead=LookaheadWindow(400, depth=1)))
        epochs = make_epochs(6, seed=3)
        rt.step(epochs[0], lookahead=(epochs[1],))   # warm
        rt.flush()
        with rtmod.counting() as counts:
            traj = rt.run(iter(epochs))
            return dict(counts.dispatch), traj

    d1, t1 = counted(1)
    d4, t4 = counted(4)
    assert d1["hint_refresh"] == d4["hint_refresh"] > 0
    assert d4["record_sync"] == 2                    # ceil(6 / 4)
    assert d1["record_sync"] == 6
    for lane in ALL_POLICIES:
        for a, b in zip(t1.lane(lane), t4.lane(lane)):
            assert a.to_dict() == b.to_dict(), lane


# ----------------------------------------------------------- scenario parity
@pytest.mark.parametrize("name", sorted(SCENARIO_FACTORIES))
def test_scenario_sync_every_parity(name):
    """ISSUE acceptance: every workload scenario's trajectory and summary
    are identical under the batched sync (K=3 over 4 epochs — one full
    buffer plus a partial tail), hints enabled."""
    base = run_scenario(SCENARIO_FACTORIES[name](), hints=True)
    batched = run_scenario(SCENARIO_FACTORIES[name](), hints=True,
                           sync_every=3)
    assert batched["trajectory"] == base["trajectory"]
    assert batched["summary"] == base["summary"]


def test_fleet_sync_every_parity_including_tenant_rows():
    """ISSUE acceptance: the multi-tenant fleet's per-tenant (L, T)
    accounting rows ride the batched sync unchanged — global trajectory,
    summary, and every tenant record identical for K=3 vs K=1."""
    from repro.fleet import FleetScenario, TenantSpec, run_fleet

    def fleet():
        return FleetScenario(
            [TenantSpec(SCENARIO_FACTORIES["dlrm"](), weight=10.0,
                        name="dlrm"),
             TenantSpec(SCENARIO_FACTORIES["mmap_bench"](), weight=1.0,
                        name="scanner"),
             TenantSpec(SCENARIO_FACTORIES["moe_experts"](), weight=1.0,
                        name="moe")],
            k_hot=300, capacity="weighted")

    base = run_fleet(fleet(), hints=True)
    batched = run_fleet(fleet(), hints=True, sync_every=3)
    assert batched["trajectory"] == base["trajectory"]
    assert batched["summary"] == base["summary"]
    assert batched["tenants"] == base["tenants"]


@pytest.mark.slow
def test_sharded_sync_every_parity():
    """ISSUE acceptance: the batched sync is sharding-transparent — the
    (K, L)/(K, L, T) accumulator leaves replicate over the mesh and an
    8-device sync_every=3 run equals the single-device per-epoch-sync run
    exactly (subprocess: device count must be set before jax init)."""
    r = run_py("""
        import dataclasses, json
        from repro.dlrm import datagen
        from repro.launch.mesh import make_telemetry_mesh, use_mesh
        from repro.scenarios.dlrm import run_online

        spec = dataclasses.replace(datagen.SMALL, lookups_per_batch=8_000)
        kw = dict(spec=spec, n_epochs=4, batches_per_epoch=2, shift_at=2,
                  seed=0, hints=True)
        ref = run_online(**kw)
        mesh = make_telemetry_mesh(8)
        with use_mesh(mesh):
            shd = run_online(mesh=mesh, sync_every=3, **kw)
        assert json.dumps(ref["trajectory"], sort_keys=True) == \\
            json.dumps(shd["trajectory"], sort_keys=True)
        assert json.dumps(ref["summary"], sort_keys=True) == \\
            json.dumps(shd["summary"], sort_keys=True)
        print("OK")
    """)
    assert "OK" in r.stdout, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
