"""Epoch-driven runtime tests: the fused observe_all path is bit-identical to
the per-batch path and issues one jit dispatch per epoch; the fused
device-resident epoch_step is bit-identical to the per-lane reference path
and holds a whole epoch to two dispatches; sharded state matches
single-device; on the phase-shift workload proactive/EWMA over HMU counts
beats NB two-touch on modeled time in every post-shift epoch."""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import runtime as rtmod
from repro.core import telemetry as tel
from repro.core.manager import TieringManager
from repro.core.runtime import ALL_POLICIES, EpochRuntime
from repro.dlrm import datagen

REPO = Path(__file__).resolve().parent.parent
SUBPROC_ENV = dict(os.environ, PYTHONPATH=str(REPO / "src"),
                   XLA_FLAGS="--xla_force_host_platform_device_count=8",
                   JAX_PLATFORMS="cpu")


def run_py(code: str, timeout=480):
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=SUBPROC_ENV,
                          timeout=timeout, cwd=REPO)


# ------------------------------------------------------------- fused observe
def make_batches(n_blocks=400, n_batches=5, batch=3000, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, n_blocks, (n_batches, batch)).astype(np.int32)


def test_observe_all_bit_identical_to_per_batch_path():
    n = 400
    batches = make_batches(n)
    kw = dict(pebs_period=101, nb_scan_rate=90)
    ref = TieringManager(n, 40, **kw)
    for b in batches:
        ref.observe(b)
    fused = TieringManager(n, 40, **kw)
    fused.observe_epoch(batches)
    ref_leaves = jax.tree_util.tree_leaves(ref.bundle)
    fused_leaves = jax.tree_util.tree_leaves(fused.bundle)
    assert len(ref_leaves) == len(fused_leaves)
    for a, b in zip(ref_leaves, fused_leaves):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_observe_all_one_dispatch_per_epoch(monkeypatch):
    """The fused path must issue exactly one observe_all call per epoch, never
    fall back to the per-batch collector jits, and re-use one trace across
    equal-shaped epochs."""
    n = 256
    batches = make_batches(n, n_batches=4, batch=1000)
    mgr = TieringManager(n, 32, pebs_period=97, nb_scan_rate=64)

    dispatches = []
    real_observe_all = tel.observe_all
    monkeypatch.setattr(
        tel, "observe_all",
        lambda bundle, arr: (dispatches.append(arr.shape),
                             real_observe_all(bundle, arr))[1])

    def forbidden(*a, **k):  # pragma: no cover - failure path
        raise AssertionError("fused path must not use per-batch observe jits")

    monkeypatch.setattr(tel, "hmu_observe", forbidden)
    monkeypatch.setattr(tel, "pebs_observe", forbidden)
    monkeypatch.setattr(tel, "nb_observe", forbidden)
    monkeypatch.setattr(tel, "count_observe", forbidden)

    # warm the trace with an identically-shaped manager, then count re-traces
    tel.observe_all(tel.bundle_init(n, pebs_period=97, nb_scan_rate=64),
                    jnp.asarray(batches))
    dispatches.clear()
    with rtmod.counting() as counts:
        mgr.observe_epoch(batches)
        mgr.observe_epoch(make_batches(n, n_batches=4, batch=1000, seed=1))
        assert dispatches == [batches.shape, batches.shape]
        assert counts.observe_trace["observe_all"] == 0      # no re-trace


def test_observe_epoch_rejects_flat_stream():
    mgr = TieringManager(64, 8)
    with pytest.raises(ValueError):
        mgr.observe_epoch(np.zeros(100, np.int32))


# ----------------------------------------------------------- runtime basics
def test_runtime_rejects_unknown_policy():
    with pytest.raises(ValueError):
        EpochRuntime(64, 8, policies=("oracle_top_k_typo",))


def test_runtime_records_and_lane_invariants():
    n, k = 500, 50
    rt = EpochRuntime(n, k, policies=ALL_POLICIES, bytes_per_access=64.0,
                      block_bytes=1024.0, pebs_period=101, nb_scan_rate=125)
    rng = np.random.default_rng(0)
    for _ in range(3):
        rt.step(rng.integers(0, n, (2, 4000)).astype(np.int32))
    for name, lane in rt.lanes.items():
        recs = rt.records[name]
        assert [r.epoch for r in recs] == [0, 1, 2]
        # slot<->block maps stay mutually consistent and capacity-bounded
        s2b, b2s = lane.slot_to_block, lane.block_to_slot
        assert (s2b >= 0).sum() == (b2s >= 0).sum() <= k
        for slot, blk in enumerate(s2b):
            if blk >= 0:
                assert b2s[blk] == slot
        for r in recs:
            assert r.resident <= k
            assert r.time_s >= r.access_s >= 0
            assert 0.0 <= r.accuracy <= 1.0 and 0.0 <= r.coverage <= 1.0
    # epoch 0 serves everything from the slow tier (cold start)
    for name in rt.records:
        assert rt.records[name][0].resident == 0


def test_runtime_uniform_stream_converges_all_hmu_lanes():
    """On a stationary skewed stream every HMU-fed lane should reach high
    coverage of the true hot set after a couple of epochs."""
    spec = dataclasses.replace(datagen.SMALL, lookups_per_batch=20_000)
    n, k = spec.n_pages, 200
    rt = EpochRuntime(n, k, policies=("hmu_oracle", "proactive_ewma"),
                      bytes_per_access=spec.row_bytes,
                      block_bytes=spec.page_bytes, nb_scan_rate=n // 2)
    s = datagen.ZipfPageSampler(spec, seed=3)
    for _ in range(4):
        rt.step(np.stack([s.sample(spec.lookups_per_batch) for _ in range(2)]))
    for name in ("hmu_oracle", "proactive_ewma"):
        assert rt.records[name][-1].coverage > 0.7, name


def test_trajectory_json_roundtrip():
    import json

    rt = EpochRuntime(128, 16, policies=("hmu_oracle",), nb_scan_rate=32)
    rng = np.random.default_rng(1)
    rt.step(rng.integers(0, 128, (2, 500)).astype(np.int32))
    data = json.loads(rt.trajectory().to_json(shift_at=0))
    assert data["n_blocks"] == 128 and data["k_hot"] == 16
    rec = data["lanes"]["hmu_oracle"][0]
    assert {"epoch", "time_s", "accuracy", "coverage",
            "promoted", "demoted"} <= set(rec)


# ------------------------------------------------- fused multi-lane step
def _phase_shift_run(fused: bool, spec, n_epochs=6, batches_per_epoch=3,
                     shift_at=3, **kw):
    n = spec.n_pages
    rt = EpochRuntime(n, fused=fused, policies=ALL_POLICIES,
                      bytes_per_access=spec.row_bytes,
                      block_bytes=spec.page_bytes, **kw)
    traj = rt.run(datagen.phase_shift_epochs(
        spec, n_epochs=n_epochs, batches_per_epoch=batches_per_epoch,
        shift_at=shift_at, rotate_by=n // 2, seed=0))
    return rt, traj


def test_fused_step_bit_identical_to_reference_path():
    """Tentpole acceptance: every EpochRecord field of every lane and epoch
    from the device-resident fused step equals the per-lane reference path
    bit for bit on a phase-shift workload, including the final placements."""
    spec = dataclasses.replace(datagen.SMALL, lookups_per_batch=20_000)
    kw = dict(k_hot=250, pebs_period=401, nb_scan_rate=spec.n_pages // 4)
    rt_f, tf = _phase_shift_run(True, spec, **kw)
    rt_r, tr = _phase_shift_run(False, spec, **kw)
    for lane in ALL_POLICIES:
        ra, rb = tf.lane(lane), tr.lane(lane)
        assert len(ra) == len(rb) == 6
        for a, b in zip(ra, rb):
            assert a.to_dict() == b.to_dict(), (lane, a.epoch)
    lanes_f, lanes_r = rt_f.lanes, rt_r.lanes
    for name in ALL_POLICIES:
        np.testing.assert_array_equal(lanes_f[name].slot_to_block,
                                      lanes_r[name].slot_to_block)
        np.testing.assert_array_equal(lanes_f[name].block_to_slot,
                                      lanes_r[name].block_to_slot)


def test_fused_step_bit_identical_with_hints_and_rate_limit():
    """Same bit-identity under the non-default lane configs: static hints
    feeding the hinted lane and an NB promotion rate limit."""
    spec = dataclasses.replace(datagen.SMALL, lookups_per_batch=10_000)
    rng = np.random.default_rng(7)
    hints = (rng.random(spec.n_pages) * (rng.random(spec.n_pages) < 0.1)
             ).astype(np.float32)
    kw = dict(k_hot=200, pebs_period=211, nb_scan_rate=spec.n_pages // 3,
              hint_rank=hints, hint_weight=0.4, nb_rate_limit=37,
              ewma_alpha=0.3)
    _, tf = _phase_shift_run(True, spec, **kw)
    _, tr = _phase_shift_run(False, spec, **kw)
    for lane in ALL_POLICIES:
        for a, b in zip(tf.lane(lane), tr.lane(lane)):
            assert a.to_dict() == b.to_dict(), (lane, a.epoch)


def test_fused_epoch_is_two_dispatches_and_one_trace():
    """Acceptance: one epoch of all five lanes = observe_all + epoch_step
    (two dispatches), nothing from the per-lane reference machinery, and
    equal-shaped epochs re-use one epoch_step trace.  (Counted inside
    runtime.counting(), so activity from other tests can't leak in.)"""
    n = 512
    rt = EpochRuntime(n, 64, policies=ALL_POLICIES, pebs_period=97,
                      nb_scan_rate=128)
    rng = np.random.default_rng(0)
    rt.step(rng.integers(0, n, (3, 1000)).astype(np.int32))  # warm the trace
    with rtmod.counting() as counts:
        for _ in range(3):
            rt.step(rng.integers(0, n, (3, 1000)).astype(np.int32))
        assert counts.dispatch == {"observe_all": 3, "epoch_step": 3,
                                   "reference": 0, "hint_refresh": 0,
                                   "record_sync": 3}
        assert counts.trace["epoch_step"] == 0               # no re-trace


def test_fused_runtime_lane_views_keep_invariants():
    n, k = 600, 60
    rt = EpochRuntime(n, k, policies=ALL_POLICIES, pebs_period=101,
                      nb_scan_rate=150)
    rng = np.random.default_rng(1)
    for _ in range(3):
        rt.step(rng.integers(0, n, (2, 5000)).astype(np.int32))
    for name, lane in rt.lanes.items():
        s2b, b2s = lane.slot_to_block, lane.block_to_slot
        assert (s2b >= 0).sum() == (b2s >= 0).sum() <= k
        for slot, blk in enumerate(s2b):
            if blk >= 0:
                assert b2s[blk] == slot, name
    assert rt.lanes["proactive_ewma"].pred is not None
    assert rt.lanes["hmu_oracle"].pred is None


@pytest.mark.slow
def test_sharded_observe_all_and_epoch_step_parity():
    """Tentpole acceptance: trajectories with all per-block state sharded
    over an 8-device mesh equal the single-device run exactly (subprocess:
    device count must be set before jax initializes)."""
    r = run_py("""
        import dataclasses, json
        from repro.dlrm import datagen, tracesim
        from repro.launch.mesh import make_telemetry_mesh, use_mesh

        spec = dataclasses.replace(datagen.SMALL, lookups_per_batch=8_000)
        # hints=True also proves the sharded per-epoch hint refresh
        # (device_put with the mesh sharding) stays bit-identical
        kw = dict(spec=spec, n_epochs=4, batches_per_epoch=2, shift_at=2,
                  seed=0, hints=True)
        ref = tracesim.run_online(**kw)
        mesh = make_telemetry_mesh(8)
        with use_mesh(mesh):
            shd = tracesim.run_online(mesh=mesh, **kw)
        assert json.dumps(ref["trajectory"], sort_keys=True) == \\
            json.dumps(shd["trajectory"], sort_keys=True)
        print("OK")
    """)
    assert "OK" in r.stdout, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


@pytest.mark.slow
def test_paper_scale_sharded_online_run():
    """§VI at paper scale: a 5.24M-page phase-shift trajectory with sharded
    telemetry + lane state completes and produces sane records."""
    r = run_py("""
        import dataclasses
        from repro.dlrm import datagen, tracesim
        from repro.launch.mesh import make_telemetry_mesh, use_mesh

        spec = datagen.DLRMTraceSpec(n_params=5_368_709_120,
                                     lookups_per_batch=400_000)
        assert spec.n_pages == 5_242_880
        mesh = make_telemetry_mesh(8)
        with use_mesh(mesh):
            out = tracesim.run_online(
                spec=spec, mesh=mesh, n_epochs=3, batches_per_epoch=2,
                shift_at=2, k_hot=spec.n_pages // 64, seed=0)
        lanes = out["trajectory"]["lanes"]
        assert set(lanes) == set(%r)
        for recs in lanes.values():
            assert len(recs) == 3
            assert all(r["time_s"] > 0 for r in recs)
        # after one epoch the lanes lock on: the sparse stream leaves the
        # tail of the top-k tie-dominated (count-1 pages), so the threshold-
        # gated lanes show precision where the full-k oracle is diluted
        assert lanes["hmu_oracle"][1]["accuracy"] > 0.3
        assert lanes["reactive_watermark"][1]["accuracy"] > 0.6
        assert lanes["hinted"][1]["accuracy"] > 0.6
        print("OK")
    """ % (list(ALL_POLICIES),))
    assert "OK" in r.stdout, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


# ------------------------------------------------- hints + prefetch lane
def _hints_run(fused: bool, spec, n_epochs=6, batches_per_epoch=3,
               shift_at=3, prefetch_overlap=1.0, **kw):
    """Phase-shift run with a fresh default HintPipeline (pipelines are
    stateful, so every runtime gets its own)."""
    from repro.hints import HintPipeline

    n = spec.n_pages
    rt = EpochRuntime(n, fused=fused, policies=ALL_POLICIES,
                      bytes_per_access=spec.row_bytes,
                      block_bytes=spec.page_bytes,
                      hints=HintPipeline.for_dlrm(spec, seed=0),
                      prefetch_overlap=prefetch_overlap, **kw)
    traj = rt.run(datagen.phase_shift_epochs(
        spec, n_epochs=n_epochs, batches_per_epoch=batches_per_epoch,
        shift_at=shift_at, rotate_by=n // 2, seed=0))
    return rt, traj


def test_fused_step_bit_identical_with_hint_pipeline():
    """Tentpole acceptance: with the HintPipeline refreshing hint_rank /
    prefetch_rank every epoch, every EpochRecord field of all SIX lanes —
    including the prefetch lane's overlap-accounted time and hidden_s —
    matches the reference path bit for bit, as do the final placements."""
    spec = dataclasses.replace(datagen.SMALL, lookups_per_batch=20_000)
    kw = dict(k_hot=250, pebs_period=401, nb_scan_rate=spec.n_pages // 4)
    rt_f, tf = _hints_run(True, spec, **kw)
    rt_r, tr = _hints_run(False, spec, **kw)
    assert len(ALL_POLICIES) == 6 and "prefetch" in ALL_POLICIES
    for lane in ALL_POLICIES:
        for a, b in zip(tf.lane(lane), tr.lane(lane)):
            assert a.to_dict() == b.to_dict(), (lane, a.epoch)
    lanes_f, lanes_r = rt_f.lanes, rt_r.lanes
    for name in ALL_POLICIES:
        np.testing.assert_array_equal(lanes_f[name].slot_to_block,
                                      lanes_r[name].slot_to_block)


def test_hint_enabled_fused_epoch_is_still_two_dispatches():
    """ISSUE acceptance: the per-epoch hint refresh is a state-leaf transfer
    (DISPATCH_COUNTS['hint_refresh']), not a dispatch — a prefetch-enabled
    epoch stays at observe_all + epoch_step, on one re-used trace."""
    from repro.hints import HintPipeline, LookaheadWindow

    n = 512
    rng = np.random.default_rng(0)

    def epoch():
        return rng.integers(0, n, (3, 1000)).astype(np.int32)

    rt = EpochRuntime(n, 64, policies=ALL_POLICIES, pebs_period=97,
                      nb_scan_rate=128,
                      hints=HintPipeline(n, lookahead=LookaheadWindow(n)))
    rt.step(epoch(), lookahead=(epoch(),))        # warm the trace
    with rtmod.counting() as counts:
        for _ in range(3):
            rt.step(epoch(), lookahead=(epoch(),))
        assert counts.dispatch == {"observe_all": 3, "epoch_step": 3,
                                   "reference": 0, "hint_refresh": 3,
                                   "record_sync": 3}
        assert counts.trace["epoch_step"] == 0               # no re-trace


def test_prefetch_beats_static_hinted_on_post_shift_coverage():
    """ISSUE acceptance: on the phase-shift trajectory the lookahead-driven
    prefetch lane beats the static hinted lane on hot-set coverage — the
    lookahead covers the rotation in the very epoch it happens, while the
    static table prior goes stale (and gets down-weighted)."""
    spec = dataclasses.replace(datagen.SMALL, lookups_per_batch=20_000)
    shift_at = 3
    rt, traj = _hints_run(True, spec, shift_at=shift_at, k_hot=250,
                          pebs_period=401, nb_scan_rate=spec.n_pages // 4)
    pre_cov = np.array([r.coverage for r in traj.lane("prefetch")])
    hin_cov = np.array([r.coverage for r in traj.lane("hinted")])
    assert pre_cov[shift_at:].mean() > hin_cov[shift_at:].mean() + 0.2
    assert pre_cov[shift_at] > 0.9        # covered in the shift epoch itself
    assert rt.hints.detector.shifts_detected == 1


def test_prefetch_overlap_time_no_worse_than_stop_the_world():
    """ISSUE acceptance: the prefetch lane's overlap-accounted epoch time is
    no worse than non-overlapped migration in every epoch (and strictly
    better once it migrates), with everything else unchanged."""
    spec = dataclasses.replace(datagen.SMALL, lookups_per_batch=20_000)
    kw = dict(k_hot=250, pebs_period=401, nb_scan_rate=spec.n_pages // 4)
    _, t_ov = _hints_run(True, spec, prefetch_overlap=1.0, **kw)
    _, t_st = _hints_run(True, spec, prefetch_overlap=0.0, **kw)
    ov, st = t_ov.times("prefetch"), t_st.times("prefetch")
    assert (ov <= st).all(), (ov, st)
    assert ov.sum() < st.sum()
    hidden = np.array([r.hidden_s for r in t_ov.lane("prefetch")])
    np.testing.assert_allclose(st - ov, hidden, rtol=1e-9)
    assert all(r.hidden_s == 0.0 for r in t_st.lane("prefetch"))
    # the overlap knob touches nothing but the prefetch lane's accounting
    for lane in ALL_POLICIES[:-1]:
        for a, b in zip(t_ov.lane(lane), t_st.lane(lane)):
            assert a.to_dict() == b.to_dict(), (lane, a.epoch)


def test_counting_scopes_and_restores_the_counters():
    """runtime.counting() hands back scope-relative views of all three
    counter dicts (zero-based at entry) and never mutates the live dicts, so
    tests and benchmark runs stop leaking dispatch counts into each other
    while module-level totals stay monotonic."""
    rtmod.DISPATCH_COUNTS["observe_all"] += 1    # pre-existing activity
    outer_before = dict(rtmod.DISPATCH_COUNTS)
    rt = EpochRuntime(64, 8, policies=("hmu_oracle",), nb_scan_rate=16)
    rng = np.random.default_rng(0)
    with rtmod.counting() as counts:
        assert counts.dispatch["observe_all"] == 0           # zero at entry
        assert counts.trace["epoch_step"] == 0
        assert counts.observe_trace["observe_all"] == 0
        rt.step(rng.integers(0, 64, (2, 100)).astype(np.int32))
        assert counts.dispatch["observe_all"] == 1
        assert counts.dispatch["epoch_step"] == 1
    # live totals: what was there before, plus the block's activity
    assert rtmod.DISPATCH_COUNTS["observe_all"] == \
        outer_before["observe_all"] + 1
    assert rtmod.DISPATCH_COUNTS["epoch_step"] == \
        outer_before["epoch_step"] + 1


def test_counting_is_safely_nestable():
    """Regression (fleet satellite): re-entering counting() must not blank
    the outer scope's accrual — run_fleet composes counting() around its
    per-tenant solo sub-runs inside callers' own counting() scopes.  The
    outer view must read correctly before, DURING, and after inner scopes
    (the old zero-in-place implementation blanked the outer view while an
    inner scope was open), inner activity must accrue outward, and the
    exception path must not corrupt anything."""
    base = rtmod.DISPATCH_COUNTS["observe_all"]
    with rtmod.counting() as outer:
        rtmod.DISPATCH_COUNTS["observe_all"] += 1
        with rtmod.counting() as inner:
            rtmod.DISPATCH_COUNTS["observe_all"] += 2
            assert inner.dispatch["observe_all"] == 2
            assert outer.dispatch["observe_all"] == 3    # visible mid-inner
        assert outer.dispatch["observe_all"] == 3
        # full-dict comparison works on views (benchmark gate idiom)
        assert dict(inner.dispatch.items())["observe_all"] == 2
        try:
            with rtmod.counting():
                rtmod.DISPATCH_COUNTS["observe_all"] += 1
                raise RuntimeError("boom")
        except RuntimeError:
            pass
        assert outer.dispatch["observe_all"] == 4
    assert rtmod.DISPATCH_COUNTS["observe_all"] == base + 4


def test_pending_migration_resets_per_run():
    """Regression: pending_migration_s (the prefetch lane's boundary
    migration not yet charged to any record) must not carry into a reused
    runtime's next run() — the pending boundary belongs to the previous
    workload (where it is surfaced via the summary), so charging it against
    the new stream's first epoch would double-count it."""
    from repro.hints import HintPipeline, LookaheadWindow

    n = 400
    rng = np.random.default_rng(0)

    def epoch():
        return rng.integers(0, n, (2, 3000)).astype(np.int32)

    rt = EpochRuntime(n, 50, policies=("prefetch",), nb_scan_rate=100,
                      hints=HintPipeline(n, lookahead=LookaheadWindow(n)))
    # warm-up steps with live lookahead: the last boundary promotes, leaving
    # a pending migration that overlaps an epoch that never runs here
    rt.step(epoch(), lookahead=(epoch(),))
    rt.step(epoch(), lookahead=(epoch(),))
    assert rt.pending_migration_s > 0.0
    rt.run([epoch(), epoch()])
    first_rec_of_run = rt.records["prefetch"][2]
    assert first_rec_of_run.migration_s == 0.0   # previous pending not charged
    assert first_rec_of_run.hidden_s == 0.0


def test_prefetch_without_pipeline_stays_idle():
    """No hint pipeline -> empty lookahead window -> the prefetch lane never
    promotes (no churn from an absent compiler)."""
    n = 400
    rt = EpochRuntime(n, 50, policies=("prefetch",), nb_scan_rate=100)
    rng = np.random.default_rng(0)
    for _ in range(3):
        rt.step(rng.integers(0, n, (2, 2000)).astype(np.int32))
    recs = rt.records["prefetch"]
    assert all(r.promoted == 0 and r.resident == 0 for r in recs)
    assert all(r.host_events == 0.0 for r in recs)


# ------------------------------------------------- phase-shift acceptance
def test_proactive_beats_nb_every_post_shift_epoch():
    """ISSUE acceptance: on the phase-shift workload, proactive_ewma over HMU
    counts beats nb_two_touch on modeled time in EVERY post-shift epoch."""
    spec = dataclasses.replace(datagen.SMALL, lookups_per_batch=20_000)
    n = spec.n_pages
    k, shift_at, n_epochs = 250, 3, 7
    rt = EpochRuntime(
        n, k, policies=("proactive_ewma", "nb_two_touch"),
        bytes_per_access=spec.row_bytes, block_bytes=spec.page_bytes,
        pebs_period=401, nb_scan_rate=n // 4,
    )
    traj = rt.run(datagen.phase_shift_epochs(
        spec, n_epochs=n_epochs, batches_per_epoch=4, shift_at=shift_at,
        rotate_by=n // 2, seed=0))
    pro = traj.times("proactive_ewma")[shift_at:]
    nb = traj.times("nb_two_touch")[shift_at:]
    assert pro.shape == nb.shape == (n_epochs - shift_at,)
    assert (pro < nb).all(), (pro, nb)


def test_proactive_recovers_accuracy_after_shift_nb_does_not():
    spec = dataclasses.replace(datagen.SMALL, lookups_per_batch=20_000)
    n, k, shift_at = spec.n_pages, 250, 3
    rt = EpochRuntime(
        n, k, policies=("proactive_ewma", "nb_two_touch"),
        bytes_per_access=spec.row_bytes, block_bytes=spec.page_bytes,
        nb_scan_rate=n // 4,
    )
    traj = rt.run(datagen.phase_shift_epochs(
        spec, n_epochs=7, batches_per_epoch=4, shift_at=shift_at,
        rotate_by=n // 2, seed=0))
    pro_acc = [r.accuracy for r in traj.lane("proactive_ewma")]
    nb_acc = [r.accuracy for r in traj.lane("nb_two_touch")]
    # EWMA re-converges after the rotation; NB's cumulative two-touch doesn't
    assert pro_acc[-1] > 0.5
    assert pro_acc[-1] > nb_acc[-1] + 0.2


def test_phase_shift_generator_rotates_hot_set():
    spec = datagen.SMALL
    s = datagen.PhaseShiftSampler(spec, rotate_by=spec.n_pages // 2, seed=0)
    k = 100
    before = set(s.true_top_k_pages(k, phase=0).tolist())
    after = set(s.true_top_k_pages(k, phase=1).tolist())
    assert not before & after             # fully disjoint hot heads
    # samples actually concentrate on each phase's hot head
    for phase, hot in ((0, before), (1, after)):
        pages = s.sample(20_000, phase=phase)
        share = np.isin(pages, list(hot)).mean()
        assert share > 0.5, (phase, share)
