"""repro.hints unit tests: static table analysis, lookahead windows, the
EWMA phase-change detector, and the HintPipeline's per-epoch refresh."""
import numpy as np
import pytest

from repro.dlrm import datagen
from repro.hints import (HintPipeline, LookaheadWindow, PhaseChangeDetector,
                         StaticTableHints)

SPEC = datagen.SMALL


def layout(seed=0):
    return datagen.ZipfPageSampler(SPEC, seed).rank_to_page


# ------------------------------------------------------------ StaticTableHints
def test_static_hints_follow_the_layout_popularity_order():
    lay = layout()
    h = StaticTableHints(SPEC, lay)
    rank = h()
    assert rank.shape == (SPEC.n_pages,) and rank.dtype == np.float32
    assert rank[lay[0]] == pytest.approx(1.0)       # hottest page ranks 1.0
    by_popularity = rank[lay]                        # ranks in popularity order
    assert (np.diff(by_popularity) <= 0).all()       # monotone non-increasing
    assert (rank >= 0).all() and (rank <= 1.0).all()


def test_static_hints_aggregate_row_aliasing():
    """Page weight is the sum of the rows_per_page row priors aliased into
    the page — the rank-1 row dominates its page, so the aggregated head is
    *steeper* than a raw page-level Zipf and the #2/#1 ratio is exactly the
    row-sum ratio."""
    rpp = SPEC.rows_per_page
    assert rpp > 1
    lay = layout()
    rank = StaticTableHints(SPEC, lay)()
    row_w = np.arange(1, 2 * rpp + 1, dtype=np.float64) ** (-SPEC.alpha)
    expected = row_w[rpp:].sum() / row_w[:rpp].sum()
    assert rank[lay[1]] == pytest.approx(expected, rel=1e-5)
    assert rank[lay[1]] < 2.0 ** (-SPEC.alpha)


def test_static_hints_clip_zeroes_the_tail():
    lay = layout()
    rank = StaticTableHints(SPEC, lay, clip_rank=100)()
    assert (rank[lay[:100]] > 0).all()
    assert (rank[lay[100:]] == 0).all()


def test_static_hints_reject_bad_layout_shape():
    with pytest.raises(ValueError, match="rank_to_page"):
        StaticTableHints(SPEC, np.arange(10))


def test_static_hints_reject_clipping_every_hint():
    """clip_rank=0 would make the normalization 0/0 (an all-NaN rank)."""
    with pytest.raises(ValueError, match="clip_rank"):
        StaticTableHints(SPEC, layout(), clip_rank=0)


# ------------------------------------------------------------ LookaheadWindow
def test_lookahead_empty_queue_ranks_zero():
    w = LookaheadWindow(64, depth=2)
    assert (w.rank(()) == 0).all()
    assert w.rank(()).shape == (64,)


def test_lookahead_ranks_by_window_histogram():
    w = LookaheadWindow(8, depth=1)
    batches = np.array([[0, 0, 0, 1, 1, 2]])
    r = w.rank((batches,))
    assert r[0] == pytest.approx(1.0)
    assert r[1] == pytest.approx(2 / 3)
    assert r[2] == pytest.approx(1 / 3)
    assert (r[3:] == 0).all()


def test_lookahead_depth_bounds_the_window():
    w = LookaheadWindow(8, depth=1)
    near = np.array([[0, 0]])
    far = np.array([[5, 5, 5, 5]])
    r = w.rank((near, far))
    assert r[5] == 0.0                   # beyond depth: invisible
    assert r[0] == pytest.approx(1.0)


def test_lookahead_decay_discounts_farther_epochs():
    w = LookaheadWindow(8, depth=2, decay=0.5)
    r = w.rank((np.array([[0, 0]]), np.array([[1, 1]])))
    assert r[0] == pytest.approx(1.0)
    assert r[1] == pytest.approx(0.5)    # same count, one epoch farther out


def test_lookahead_rejects_nonpositive_depth():
    with pytest.raises(ValueError, match="depth"):
        LookaheadWindow(8, depth=0)


def test_epoch_histogram_memo_invalidates_on_in_place_refill():
    """Regression: a dataloader that refills ONE preallocated buffer per
    epoch (same object, new contents) must not be served the previous
    epoch's histogram — a stale memo here blinds the phase detector to a
    rotation and freezes the lookahead rank."""
    from repro.hints.providers import epoch_histogram

    buf = np.zeros((2, 100), np.int32)
    buf[:] = 1
    h1 = epoch_histogram(buf, 8).copy()
    assert h1[1] == 200
    buf[:] = 5                              # in-place refill: new epoch
    h2 = epoch_histogram(buf, 8)
    assert h2[5] == 200 and h2[1] == 0
    # unchanged buffer still hits the memo (same object returned)
    assert epoch_histogram(buf, 8) is h2


def test_detector_sees_rotation_through_a_reused_buffer():
    s = datagen.PhaseShiftSampler(SPEC, rotate_by=SPEC.n_pages // 2, seed=0)
    det = PhaseChangeDetector(SPEC.n_pages)
    buf = np.empty((3, 5_000), np.int64)
    for phase in (0, 0, 1):
        buf[:] = _epoch(s, phase=phase, batches=3, lookups=5_000)
        det.update(buf)
    assert det.shifts_detected == 1


# ------------------------------------------------------- PhaseChangeDetector
def _epoch(sampler, phase, batches=3, lookups=5_000):
    return np.stack([sampler.sample(lookups, phase=phase)
                     for _ in range(batches)])


def test_detector_stationary_stream_keeps_full_scale():
    s = datagen.PhaseShiftSampler(SPEC, rotate_by=SPEC.n_pages // 2, seed=0)
    det = PhaseChangeDetector(SPEC.n_pages)
    for _ in range(5):
        scale = det.update(_epoch(s, phase=0))
    assert scale == 1.0 and det.shifts_detected == 0


def test_detector_flags_rotation_once_and_downweights():
    s = datagen.PhaseShiftSampler(SPEC, rotate_by=SPEC.n_pages // 2, seed=0)
    det = PhaseChangeDetector(SPEC.n_pages, penalty=0.25)
    for _ in range(3):
        det.update(_epoch(s, phase=0))
    for _ in range(3):
        scale = det.update(_epoch(s, phase=1))
    assert det.shifts_detected == 1          # one rotation, detected once
    assert scale == pytest.approx(0.25)      # no recovery to the stale prior


def test_detector_counts_each_rotation():
    s = datagen.PhaseShiftSampler(SPEC, rotate_by=SPEC.n_pages // 3, seed=0)
    det = PhaseChangeDetector(SPEC.n_pages, penalty=0.5)
    for phase in (0, 0, 1, 1, 2, 2):
        det.update(_epoch(s, phase=phase))
    assert det.shifts_detected == 2
    assert det.scale == pytest.approx(0.25)


# --------------------------------------------------------------- HintPipeline
def test_pipeline_epoch_ranks_shapes_and_ranges():
    pipe = HintPipeline.for_dlrm(SPEC, seed=0)
    s = datagen.PhaseShiftSampler(SPEC, seed=0)
    hr, pr = pipe.epoch_ranks(_epoch(s, 0), (_epoch(s, 0),))
    for arr in (hr, pr):
        assert arr.shape == (SPEC.n_pages,) and arr.dtype == np.float32
        assert (arr >= 0).all() and (arr <= 1).all()
    assert pr.max() == pytest.approx(1.0)    # lookahead window non-empty
    assert pipe.lookahead_depth == 1


def test_pipeline_scales_static_hints_after_detected_shift():
    pipe = HintPipeline.for_dlrm(SPEC, seed=0)
    s = datagen.PhaseShiftSampler(SPEC, rotate_by=SPEC.n_pages // 2, seed=0)
    hr0, _ = pipe.epoch_ranks(_epoch(s, 0))
    pipe.epoch_ranks(_epoch(s, 0))
    hr_shift, _ = pipe.epoch_ranks(_epoch(s, 1))
    assert pipe.static_scale < 1.0
    nz = hr0 > 0
    np.testing.assert_allclose(hr_shift[nz] / hr0[nz], pipe.static_scale,
                               rtol=1e-5)


def test_pipeline_reuses_static_rank_object_until_scale_moves():
    """epoch_ranks returns the SAME hint_rank object while the detector
    scale is unchanged, so the runtime's identity check can skip re-uploading
    an n-block array every epoch."""
    pipe = HintPipeline.for_dlrm(SPEC, seed=0)
    s = datagen.PhaseShiftSampler(SPEC, rotate_by=SPEC.n_pages // 2, seed=0)
    hr1, _ = pipe.epoch_ranks(_epoch(s, 0))
    hr2, _ = pipe.epoch_ranks(_epoch(s, 0))
    assert hr1 is hr2
    hr3, _ = pipe.epoch_ranks(_epoch(s, 1))      # rotation -> new scale
    assert hr3 is not hr2
    hr4, _ = pipe.epoch_ranks(_epoch(s, 1))      # stationary again -> cached
    assert hr4 is hr3


def test_pipeline_without_providers_is_inert():
    pipe = HintPipeline(32)
    hr, pr = pipe.epoch_ranks(np.zeros((1, 4), np.int32))
    assert (hr == 0).all() and (pr == 0).all()
    assert pipe.lookahead_depth == 0 and pipe.static_scale == 1.0


def test_pipeline_rejects_wrong_static_shape():
    with pytest.raises(ValueError, match="static"):
        HintPipeline(32, static=np.zeros(8, np.float32))
