"""mmap-bench scenario — the paper's §III.A microbenchmark, online.

``workloads.mmap_bench`` reproduces the paper's synthetic region workload
(10 GiB mapped, 1 GiB hot for 90% of accesses) as a page-id access stream;
until now it only fed the offline fig3 profile->promote->replay path.  This
scenario packages that stream onto the :class:`~repro.scenarios.
AccessScenario` protocol, so the §III.A workload runs the same online
six-lane :class:`~repro.core.runtime.EpochRuntime` loop as DLRM / KV-cache /
MoE — and doubles as the fleet's antagonist tenant: a scanner that touches a
wide, internally-uniform region at high volume is exactly the noisy
neighbour that floods count-ranked selection in a shared fast tier
(``repro.fleet``).

The workload is stationary (no scripted rotation — ``shift_at`` defaults to
0 so summary slices cover the whole run).  Unlike the other workloads, the
hot region here IS compile-time knowledge: the program allocates the hot
arena, so the static hint layout is the identity rank map over the region
with a flat (``alpha=0``) within-region prior — the compiler annotates
"these pages are the arena", and the clip keeps the annotation to the hot
head.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from ..core.costmodel import CXL_SYSTEM, MemSystem
from ..hints import HintLayout
from ..workloads import mmap_bench

__all__ = ["MmapBenchScenario"]


@dataclasses.dataclass
class MmapBenchScenario:
    """§III.A mmap-bench as an online access scenario.

    Blocks are 4 KiB pages of the mapped region; the hot region occupies
    pages ``[0, spec.k_hot)`` and receives ``spec.hot_access_fraction`` of
    the accesses, uniform within each region.  ``accesses_per_batch`` sets
    the stream intensity — crank it to turn the benchmark into a
    noisy-neighbour scanner tenant.
    """

    spec: mmap_bench.MmapBenchSpec = mmap_bench.SMALL
    system: MemSystem = CXL_SYSTEM
    n_epochs: int = 6
    batches_per_epoch: int = 4
    accesses_per_batch: int = 20_000
    k_hot: Optional[int] = None          # fast-tier slots; default = hot pages
    shift_at: int = 0                    # stationary workload
    pebs_period: int = 1009
    seed: int = 0

    name = "mmap_bench"

    def __post_init__(self):
        n = self.spec.n_pages
        self.n_blocks = n
        self.k_hot = (self.spec.k_hot if self.k_hot is None
                      else min(int(self.k_hot), n))
        self.bytes_per_access = float(self.spec.access_bytes)
        self.block_bytes = float(self.spec.page_bytes)
        self.nb_scan_rate = max(n // self.batches_per_epoch, 1)

    def epochs(self) -> Iterator[np.ndarray]:
        """Deterministic per call: a fresh generator over the same seed."""
        total = self.n_epochs * self.batches_per_epoch * self.accesses_per_batch
        it = mmap_bench.access_stream(
            self.spec, total_accesses=total, batch=self.accesses_per_batch,
            seed=self.seed)
        for _ in range(self.n_epochs):
            yield np.stack([next(it) for _ in range(self.batches_per_epoch)])

    def hint_layout(self) -> HintLayout:
        # the program allocated the arena: identity layout, flat prior —
        # every annotated page ranks equally, the clip marks the hot head
        return HintLayout(
            self.n_blocks,
            rank_to_page=np.arange(self.n_blocks, dtype=np.int32),
            alpha=0.0,
            rows_per_page=max(self.spec.page_bytes
                              // self.spec.access_bytes, 1),
        )
