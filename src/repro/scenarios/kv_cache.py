"""LLM KV-cache scenario — tiered KV pages placed online from attention mass.

The serving engine's decode loop already emits the per-KV-page
attention-mass feed (``aux["kv_page_mass"]``, the serving-side HMU): every
decode step reports how much attention probability each ``(layer, sequence,
page)`` page of the KV cache absorbed.  This scenario turns that feed into
the EpochRuntime's page-index access batches, so a tiered KV cache is placed
online by the same six policy lanes as the DLRM table — nothing KV-specific
reaches the runtime.

Mechanics: a real model (smoke config by default) is prefilled once, then
decoded step by step via :func:`repro.serve.engine.decode_telemetry`.  Each
decode step's mass tensor is quantized into exactly ``accesses_per_batch``
page accesses (largest-remainder apportionment — deterministic, no
sampling), one batch row per step.  Pages the step never attends to get no
accesses; as ``pos`` advances past the prefill, freshly written pages start
absorbing mass, so the hot set drifts organically — the online regime's
re-convergence workload, with no synthetic rotation.  The final page is
ragged whenever ``max_len % page_size != 0`` (the default geometry makes it
so), exercising the ceil-divided page grid end to end.

There is no static hint layout: which pages a sequence attends to depends on
the decoded text, which no compiler knows ahead of time.  ``hint_layout()``
returns ``None`` — :func:`~repro.scenarios.run_scenario` then builds a
lookahead-only pipeline (the engine's own step queue), keeping the prefetch
lane live while the hinted lane falls back to pure PEBS telemetry.
"""
from __future__ import annotations

from typing import Iterator, List, Optional

import numpy as np

from ..core.costmodel import TPU_V5E_SYSTEM, MemSystem
from ..hints import HintLayout

__all__ = ["KVCacheScenario", "quantize_access_counts"]


def quantize_access_counts(weights: np.ndarray, total: int) -> np.ndarray:
    """Apportion ``total`` accesses over blocks proportionally to ``weights``
    (largest-remainder method): deterministic, exact total, zero weight ->
    zero accesses.  All-zero weights yield an all-zero count vector."""
    w = np.maximum(np.asarray(weights, np.float64).ravel(), 0.0)
    s = w.sum()
    counts = np.zeros(w.shape, np.int64)
    if s <= 0.0 or total <= 0:
        return counts
    exact = w * (float(total) / s)
    counts = np.floor(exact).astype(np.int64)
    short = int(total - counts.sum())
    if short > 0:
        top_up = np.argsort(-(exact - counts), kind="stable")[:short]
        counts[top_up] += 1
    return counts


class KVCacheScenario:
    """Tiered KV-cache placement driven by decode-time attention mass.

    Blocks are ``(layer, sequence, page)`` KV pages, indexed
    ``(layer * batch + seq) * pages_per_seq + page`` — the flattening of the
    engine's ``(L, B, P)`` mass tensor.  One epoch is ``batches_per_epoch``
    decode steps; one batch row is one step's mass quantized to
    ``accesses_per_batch`` page accesses.

    The decode loop runs once (deterministic: fixed init key and token
    stream) and the resulting epochs are cached, so repeated ``epochs()``
    calls — e.g. a fused run and its reference bit-identity check — replay
    the identical stream without re-running the model.
    """

    name = "kv_cache"

    def __init__(
        self,
        arch: str = "internlm2-1.8b",
        batch: int = 4,
        page_size: int = 4,
        prefill_len: int = 19,
        n_epochs: int = 6,
        batches_per_epoch: int = 4,
        accesses_per_batch: int = 4096,
        k_hot: Optional[int] = None,
        shift_at: Optional[int] = None,
        system: MemSystem = TPU_V5E_SYSTEM,
        pebs_period: int = 101,
        seed: int = 0,
    ):
        from ..configs import get_smoke_config
        from ..serve.engine import kv_page_geometry

        self.arch = arch
        self.cfg = get_smoke_config(arch)
        self.batch = int(batch)
        self.page_size = int(page_size)
        self.prefill_len = int(prefill_len)
        self.n_epochs = int(n_epochs)
        self.batches_per_epoch = int(batches_per_epoch)
        self.accesses_per_batch = int(accesses_per_batch)
        self.n_steps = self.n_epochs * self.batches_per_epoch
        # every decode step appends one token per sequence, so the cache must
        # hold the prefill plus the whole decode run
        self.max_len = self.prefill_len + self.n_steps
        geom = kv_page_geometry(self.cfg, self.batch, self.max_len,
                                self.page_size)
        self.pages_per_seq = geom["pages_per_seq"]
        self.n_blocks = geom["n_blocks"]
        self.bytes_per_access = float(geom["bytes_per_access"])
        self.block_bytes = float(geom["block_bytes"])
        self.k_hot = (max(self.n_blocks // 4, 1) if k_hot is None
                      else min(int(k_hot), self.n_blocks))
        # no scripted rotation: the drift is the decode frontier advancing;
        # slice the summary at mid-run by default
        self.shift_at = (self.n_epochs // 2 if shift_at is None
                         else int(shift_at))
        self.system = system
        self.pebs_period = int(pebs_period)
        self.nb_scan_rate = max(self.n_blocks // self.batches_per_epoch, 1)
        self.seed = int(seed)
        self._epochs: Optional[List[np.ndarray]] = None

    # ------------------------------------------------------------- generation
    def _generate(self) -> List[np.ndarray]:
        import jax
        import jax.numpy as jnp
        from ..models.model import init_params
        from ..serve import engine

        rng = np.random.default_rng(self.seed)
        params = init_params(self.cfg, jax.random.key(self.seed))
        prompt = rng.integers(0, self.cfg.vocab_size,
                              (self.batch, self.prefill_len))
        _, cache = engine.prefill(params, self.cfg,
                                  tokens=jnp.asarray(prompt, jnp.int32),
                                  max_len=self.max_len)
        step_tokens = rng.integers(0, self.cfg.vocab_size,
                                   (self.n_steps, self.batch))
        _, mass = engine.decode_telemetry(
            params, self.cfg, cache, jnp.asarray(step_tokens, jnp.int32),
            page_size=self.page_size)           # (T, L, B, P)
        rows = [self.access_batch(m) for m in mass]
        bpe = self.batches_per_epoch
        return [np.stack(rows[e * bpe:(e + 1) * bpe])
                for e in range(self.n_epochs)]

    def access_batch(self, step_mass: np.ndarray) -> np.ndarray:
        """One decode step's ``(L, B, P)`` mass -> one equal-length batch row
        of page-block indices (the flattened mass order IS the block id)."""
        counts = quantize_access_counts(step_mass, self.accesses_per_batch)
        return np.repeat(np.arange(self.n_blocks, dtype=np.int32), counts)

    # --------------------------------------------------------------- protocol
    def epochs(self) -> Iterator[np.ndarray]:
        if self._epochs is None:
            self._epochs = self._generate()
        return iter(self._epochs)

    def hint_layout(self) -> Optional[HintLayout]:
        return None          # attention hotness is runtime-only
