"""DLRM embedding-table scenario — the paper's §III.B workload, online.

The phase-shifting Zipf page trace (:mod:`repro.dlrm.datagen`) packaged as
an :class:`~repro.scenarios.AccessScenario`: blocks are embedding-table
pages, the hot set rotates once at ``shift_at``, and the compiler's static
knowledge is the table layout (popularity rank -> page id) plus the row-level
Zipf prior — the :class:`~repro.hints.HintLayout` the hinted lane's static
provider analyses.

:func:`run_online` keeps the historical ``dlrm.tracesim.run_online``
signature (re-exported from there) as a thin wrapper over
:func:`~repro.scenarios.run_scenario`.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from ..core.costmodel import CXL_SYSTEM, MemSystem
from ..core.runtime import ALL_POLICIES
from ..dlrm import datagen
from ..hints import HintLayout
from .base import run_scenario

__all__ = ["DLRMScenario", "run_online"]


@dataclasses.dataclass
class DLRMScenario:
    """Phase-shifting DLRM embedding-page trace.

    Geometry comes from the trace spec (page = block, row = access); the
    collector rates are the §VI defaults (``nb_scan_rate`` = one NB scan
    pass per epoch's batches).  ``rotate_by`` is the hot-head rotation at
    ``shift_at`` (default a third of the table, see
    :class:`~repro.dlrm.datagen.PhaseShiftSampler`).
    """

    spec: datagen.DLRMTraceSpec = datagen.SMALL
    system: MemSystem = CXL_SYSTEM
    n_epochs: int = 8
    batches_per_epoch: int = 4
    shift_at: int = 4
    k_hot: Optional[int] = None
    pebs_period: int = 401
    rotate_by: Optional[int] = None
    seed: int = 0

    name = "dlrm"

    def __post_init__(self):
        n = self.spec.n_pages
        self.n_blocks = n
        self.k_hot = min(self.k_hot if self.k_hot is not None
                         else max(n // 20, 1), n)
        self.bytes_per_access = float(self.spec.row_bytes)
        self.block_bytes = float(self.spec.page_bytes)
        self.nb_scan_rate = max(n // self.batches_per_epoch, 1)

    def epochs(self) -> Iterator[np.ndarray]:
        return datagen.phase_shift_epochs(
            self.spec, n_epochs=self.n_epochs,
            batches_per_epoch=self.batches_per_epoch, shift_at=self.shift_at,
            rotate_by=self.rotate_by, seed=self.seed)

    def hint_layout(self) -> HintLayout:
        # layout from the same sampler the trace uses, so the static hints
        # point at the actual table layout by construction
        sampler = datagen.PhaseShiftSampler(
            self.spec, rotate_by=self.rotate_by, seed=self.seed)
        return HintLayout(self.n_blocks, rank_to_page=sampler.rank_to_page,
                          alpha=self.spec.alpha,
                          rows_per_page=self.spec.rows_per_page)


def run_online(
    spec: datagen.DLRMTraceSpec = datagen.SMALL,
    system: MemSystem = CXL_SYSTEM,
    n_epochs: int = 8,
    batches_per_epoch: int = 4,
    shift_at: int = 4,
    k_hot: Optional[int] = None,
    policies: tuple = ALL_POLICIES,
    pebs_period: int = 401,
    rotate_by: Optional[int] = None,
    seed: int = 0,
    hints=False,
    lookahead_depth: int = 1,
    prefetch_overlap: float = 1.0,
    fused: bool = True,
    mesh=None,
    sync_every: int = 1,
    export=None,
) -> dict:
    """§VI online regime: multi-epoch phase-shifting DLRM trace through the
    EpochRuntime.  The hot set rotates at ``shift_at``; the trajectory shows
    which telemetry/policy pairs re-converge and which collapse (NB).

    ``hints=True`` attaches the scenario's default
    :class:`repro.hints.HintPipeline` (static table analysis +
    ``lookahead_depth`` epochs of lookahead + phase-change re-weighting) so
    the hinted lane runs on compiler-derived ranks and the prefetch lane is
    live; a pre-built pipeline may be passed instead.  ``prefetch_overlap``
    is how much of the prefetch lane's migration streams under the epoch it
    serves.

    ``fused`` selects the device-resident two-dispatch epoch loop (default)
    or the per-lane reference path; ``mesh`` (see
    ``launch.mesh.make_telemetry_mesh``) shards all per-page state across
    devices for paper-scale (5.24 M page) trajectories; ``sync_every=K``
    batches the fused loop's record syncs (bit-identical for every K);
    ``export=`` streams records through a :class:`repro.export.ExportClient`
    (observability-only: trajectories are bit-identical either way).

    Returns ``{"trajectory": per-epoch dict, "summary": headline numbers}``.
    """
    scenario = DLRMScenario(
        spec=spec, system=system, n_epochs=n_epochs,
        batches_per_epoch=batches_per_epoch, shift_at=shift_at, k_hot=k_hot,
        pebs_period=pebs_period, rotate_by=rotate_by, seed=seed)
    return run_scenario(
        scenario, policies=policies, hints=hints,
        lookahead_depth=lookahead_depth, prefetch_overlap=prefetch_overlap,
        fused=fused, mesh=mesh, sync_every=sync_every, export=export)
