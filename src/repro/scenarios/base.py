"""AccessScenario protocol + the workload-agnostic online driver.

A scenario is everything the :class:`~repro.core.runtime.EpochRuntime` needs
to place one workload online, and nothing about *how* the runtime does it:

* **epoch stream** — ``epochs()`` yields ``(n_batches, batch_size)`` int32
  block-index arrays, deterministic per call (so a fused run and its
  reference-path bit-identity check replay the same stream);
* **page geometry** — ``n_blocks`` blocks, ``k_hot`` fast slots,
  ``bytes_per_access`` / ``block_bytes`` sizes;
* **cost-model params** — the :class:`~repro.core.costmodel.MemSystem` plus
  collector rates (``pebs_period``, ``nb_scan_rate``);
* **optional hint layout** — ``hint_layout()`` returns what a compiler knows
  statically (:class:`~repro.hints.HintLayout`), or ``None`` when hotness is
  runtime-only.

:func:`run_scenario` is the one packaging of the six-lane runtime: build via
:meth:`EpochRuntime.for_scenario`, drive the stream, summarize the
trajectory.  Every scenario inherits the runtime's invariants — fused vs
reference bit-identity, exactly 2 jit dispatches per epoch (hint refreshes
are transfers), sharded parity under ``mesh=`` — because the runtime never
learns which workload it is placing.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from ..core.costmodel import MemSystem
from ..core.runtime import ALL_POLICIES, EpochRuntime, Trajectory
from ..hints import HintLayout, HintPipeline

__all__ = ["AccessScenario", "build_hints", "run_scenario",
           "scenario_summary"]


@runtime_checkable
class AccessScenario(Protocol):
    """Structural contract every workload packaging satisfies (duck-typed —
    scenarios don't inherit anything)."""

    name: str                   # row key in benchmarks / trajectory meta
    n_blocks: int               # blocks the placement ranges over
    k_hot: int                  # fast-tier capacity in blocks
    shift_at: int               # epoch the workload shifts (summary slicing)
    system: MemSystem           # two-tier cost model
    bytes_per_access: float     # bytes one access stream element touches
    block_bytes: float          # bytes one migration moves
    pebs_period: int            # PEBS collector sampling period
    nb_scan_rate: int           # NB scanner unmap rate (blocks/batch)

    def epochs(self) -> Iterable[np.ndarray]:
        """Fresh, deterministic epoch stream of (n_batches, batch) arrays."""
        ...

    def hint_layout(self) -> Optional[HintLayout]:
        """Static structure a compiler would know, or None if runtime-only."""
        ...


def build_hints(scenario: AccessScenario, depth: int = 1,
                clip_rank: Optional[int] = None,
                detector: bool = True) -> HintPipeline:
    """The scenario's default :class:`HintPipeline` — fresh per call, since
    pipelines are stateful (phase-detector EWMA, cached scaled ranks).

    A scenario may provide its own ``build_pipeline(depth=, clip_rank=,
    detector=)`` factory, which then wins over the single-layout default:
    ``repro.fleet.FleetScenario`` uses this to compose per-tenant static
    hints (one :class:`HintLayout` per tenant, scattered into the global
    block space) — something a single flat layout cannot express."""
    build = getattr(scenario, "build_pipeline", None)
    if build is not None:
        return build(depth=depth, clip_rank=clip_rank, detector=detector)
    layout = scenario.hint_layout()
    if layout is None:
        layout = HintLayout(scenario.n_blocks)
    return HintPipeline.for_scenario(layout, depth=depth,
                                     clip_rank=clip_rank, detector=detector)


def scenario_summary(rt: EpochRuntime, traj: Trajectory,
                     policies: Sequence[str], shift_at: int) -> dict:
    """Headline per-lane numbers from a trajectory (the same columns for
    every workload, so scenarios are comparable row-for-row).

    Per-lane dicts are wire-conformant ``lane_summary`` records minus the
    envelope (units in field names, validated against
    ``repro.export.telemetry.schema.json`` in tests); the cross-lane
    aggregates (``proactive_vs_nb_post_shift``, ...) sit beside them at the
    top level and are not export records."""
    summary: Dict[str, object] = {}
    for name in policies:
        ts = traj.times(name)
        recs = traj.lane(name)
        accs = np.array([r.accuracy for r in recs])
        covs = np.array([r.coverage for r in recs])
        post = slice(shift_at, None)
        summary[name] = {
            "mean_time_us": float(ts.mean() * 1e6),
            "post_shift_mean_time_us": float(ts[post].mean() * 1e6),
            "final_accuracy": float(accs[-1]),
            "final_coverage": float(covs[-1]),
            "post_shift_mean_coverage": float(covs[post].mean()),
            "post_shift_recovery_epochs": int(np.argmax(
                accs[post] >= 0.5)) if (accs[post] >= 0.5).any() else -1,
            "hidden_total_s": float(sum(r.hidden_s for r in recs)),
        }
        if name == "prefetch":
            # the final boundary's migration overlaps an epoch that never
            # runs; report it so lane-total comparisons stay honest
            summary[name]["pending_migration_us"] = float(
                rt.pending_migration_s * 1e6)
    if "proactive_ewma" in policies and "nb_two_touch" in policies:
        summary["proactive_vs_nb_post_shift"] = float(
            summary["nb_two_touch"]["post_shift_mean_time_us"]
            / summary["proactive_ewma"]["post_shift_mean_time_us"])
    if "prefetch" in policies and "hinted" in policies:
        summary["prefetch_vs_hinted_post_shift_coverage"] = (
            summary["prefetch"]["post_shift_mean_coverage"]
            - summary["hinted"]["post_shift_mean_coverage"])
    return summary


def run_scenario(
    scenario: AccessScenario,
    policies: Sequence[str] = ALL_POLICIES,
    hints=False,
    lookahead_depth: int = 1,
    prefetch_overlap: float = 1.0,
    fused: bool = True,
    mesh=None,
    sync_every: int = 1,
    epochs: Optional[Iterable[np.ndarray]] = None,
    faults=None,
    hardening=None,
    export=None,
    **runtime_overrides,
) -> dict:
    """Place one scenario online: all ``policies`` lanes over the scenario's
    epoch stream, through one :class:`EpochRuntime` built from its geometry.

    ``hints=True`` attaches the scenario's default pipeline
    (:func:`build_hints` — static layout if the scenario has one,
    ``lookahead_depth`` epochs of lookahead, phase detector) so the hinted
    lane runs on compiler-derived ranks and the prefetch lane is live; a
    pre-built pipeline may be passed instead (it is stateful — never share
    one across runs that must match).  ``fused`` selects the device-resident
    two-dispatch epoch loop (default) or the per-lane reference path;
    ``mesh`` shards all per-block state across devices.  ``epochs`` replaces
    the scenario's own stream — pass a pre-materialized list when timing the
    run, so data generation stays outside the measurement (the stream must
    still be the scenario's: geometry and accounting assume it).  Extra
    keyword arguments override runtime constructor kwargs (``ewma_alpha=``).

    ``sync_every=K`` batches the runtime's record syncs: the fused loop
    accumulates K epochs of record fields on device and pulls them in one
    transfer, so the host never serializes against the device mid-stream.
    Trajectories are bit-identical for every K (the partial tail is flushed
    on loop exit); K > 1 requires ``fused=True``.

    ``faults=`` injects a :class:`repro.faults.FaultModel` into the fused
    observe path (saturation / drops / resets / stalls / staleness);
    ``hardening=`` enables the degradation-aware machinery (quality-gated
    fallback, demotion hysteresis).  Both require ``fused=True``; a
    default-constructed model reproduces the fault-free run bit for bit.

    ``export=`` attaches a :class:`repro.export.ExportClient`: per-epoch
    records stream out at the runtime's record-sync boundary and each
    lane's summary is emitted as a ``lane_summary`` record on completion,
    all tagged with the scenario's name.  Export is observability-only —
    trajectories are bit-identical export-on vs export-off and the epoch
    dispatch count is unchanged.

    Returns ``{"trajectory": per-epoch dict, "summary": headline numbers}``.
    """
    if hints is True:
        hints = build_hints(scenario, depth=lookahead_depth)
    exp = export.bind(scenario=scenario.name) if export is not None else None
    rt = EpochRuntime.for_scenario(
        scenario, policies=tuple(policies), hints=hints or None,
        prefetch_overlap=prefetch_overlap, fused=fused, mesh=mesh,
        sync_every=sync_every, faults=faults, hardening=hardening,
        export=exp, **runtime_overrides)
    traj = rt.run(scenario.epochs() if epochs is None else epochs)
    summary = scenario_summary(rt, traj, policies, scenario.shift_at)
    if exp is not None:
        for name in policies:
            exp.export_lane_summary(name, summary[name])
    return {
        "trajectory": json.loads(traj.to_json(scenario=scenario.name,
                                              shift_at=scenario.shift_at)),
        "summary": summary,
    }
