"""repro.scenarios — one EpochRuntime, many workloads.

The paper's HMU argument is that *device-level* telemetry generalizes across
workloads: the collector sees physical accesses, so the same
observe -> decide -> migrate -> account loop should place a DLRM embedding
table, an LLM KV cache, or a bank of MoE experts without knowing which it is
(TPP and NeoMem make exactly this workload-generality the test of a tiering
design).  This package is that claim made structural: the
:class:`AccessScenario` protocol is everything a workload must provide — an
epoch stream of block-index batches, the page geometry, the cost-model
parameters, and optionally what a compiler knows statically
(:class:`~repro.hints.HintLayout`) — and :func:`run_scenario` is the one
packaging of the six-lane :class:`~repro.core.runtime.EpochRuntime` over any
of them.

Scenarios:

* :class:`DLRMScenario` (``scenarios/dlrm.py``) — the phase-shifting Zipf
  page trace; ``run_online`` (still re-exported from ``dlrm.tracesim``) is
  its thin wrapper.
* :class:`KVCacheScenario` (``scenarios/kv_cache.py``) — KV pages placed
  from the serving engine's per-page attention-mass feed; the decode loop's
  ``kv_page_mass`` telemetry becomes the access stream.
* :class:`MoEExpertScenario` (``scenarios/moe_experts.py``) — expert banks
  placed from router activation counters, replacing the old offline
  ``TieringManager`` flow with online epoch placement.
* :class:`MmapBenchScenario` (``scenarios/mmap_bench.py``) — the paper's
  §III.A microbenchmark stream on the online loop; also the noisy-neighbour
  scanner tenant of the multi-tenant fleet (``repro.fleet``).

The runtime's invariants — fused vs reference bit-identity, exactly 2 jit
dispatches per epoch (hint refreshes are state-leaf transfers), sharded
parity — hold per scenario because the runtime is workload-blind; the
benchmark harness records per-scenario coverage/accuracy rows
(``results/BENCH_epoch_runtime.json``) and CI smoke-gates a non-DLRM
scenario on the same 2-dispatch count.

The model-backed scenarios import the model stack lazily (PEP 562), so
trace-only users of ``run_online`` never pay for it.
"""
from .base import AccessScenario, build_hints, run_scenario, scenario_summary
from .dlrm import DLRMScenario, run_online
from .mmap_bench import MmapBenchScenario

__all__ = [
    "AccessScenario", "DLRMScenario", "KVCacheScenario", "MmapBenchScenario",
    "MoEExpertScenario",
    "build_hints", "run_online", "run_scenario", "scenario_summary",
]

_LAZY = {
    "KVCacheScenario": "kv_cache",
    "MoEExpertScenario": "moe_experts",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f".{_LAZY[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
