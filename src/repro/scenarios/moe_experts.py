"""MoE expert-bank scenario — online placement of expert weights.

The paper's DLRM sparsity argument applied to expert weights: with top-k
routing only a sliver of expert bytes is live per token, and the router's
expert-activation counters ARE memory-side telemetry (full coverage, zero
extra cost).  The old flow profiled offline with a ``TieringManager`` and
batch-promoted once; this scenario replaces it with *online* epoch placement:
the router counters from a real MoE forward pass become the EpochRuntime's
access batches (via :func:`repro.models.moe.expert_access_batch`), and the
six lanes place the expert banks epoch by epoch while the routing mix shifts
mid-run (new traffic rotates token popularity, so different experts become
hot — the regime where per-epoch frequency tracking re-converges and NB-style
cumulative recency collapses).

Blocks are expert ids; one block spans the expert's gate/up/down weights in
every layer (``block_bytes = bytes_per_expert * n_layers``), matching how an
inference server would pin an expert across its layer instances.  No static
hint layout: which experts run hot depends on the serving traffic, not the
compile-time graph.
"""
from __future__ import annotations

from typing import Iterator, List, Optional

import numpy as np

from ..core.costmodel import TPU_V5E_SYSTEM, MemSystem
from ..hints import HintLayout

__all__ = ["MoEExpertScenario"]


class MoEExpertScenario:
    """Online expert-bank tiering from router telemetry.

    The model (smoke config by default) runs one forward pass per batch of
    Zipf-popular tokens; at epoch ``shift_at`` token popularity rotates by
    half the vocabulary, re-routing traffic to different experts.  Each batch
    row is the layer-summed expert access stream — constant length
    ``batch * seq * top_k * n_layers`` by construction, so epochs stack.

    The forward passes run once (fixed init key and token stream) and the
    epochs are cached, so fused and reference runs replay identical streams.
    """

    name = "moe_experts"

    def __init__(
        self,
        arch: str = "kimi-k2-1t-a32b",
        n_epochs: int = 6,
        batches_per_epoch: int = 4,
        shift_at: int = 3,
        batch: int = 4,
        seq: int = 64,
        zipf_a: float = 1.3,
        k_hot: Optional[int] = None,
        system: MemSystem = TPU_V5E_SYSTEM,
        pebs_period: int = 101,
        seed: int = 0,
    ):
        from ..configs import get_smoke_config

        self.arch = arch
        self.cfg = get_smoke_config(arch)
        if self.cfg.family != "moe":
            raise ValueError(f"expert tiering needs a MoE family arch, "
                             f"got {arch!r} ({self.cfg.family})")
        self.n_epochs = int(n_epochs)
        self.batches_per_epoch = int(batches_per_epoch)
        self.shift_at = int(shift_at)
        self.batch = int(batch)
        self.seq = int(seq)
        self.zipf_a = float(zipf_a)
        e = self.cfg.moe.n_experts
        self.n_blocks = e
        self.k_hot = (max(e // 4, 1) if k_hot is None
                      else min(int(k_hot), e))       # HBM: 25% of experts
        # gate/up/down bf16 per layer; a block is the expert across layers
        bytes_per_expert = 3 * self.cfg.d_model * self.cfg.moe.d_expert * 2
        self.bytes_per_access = float(bytes_per_expert)
        self.block_bytes = float(bytes_per_expert * self.cfg.n_layers)
        self.system = system
        self.pebs_period = int(pebs_period)
        self.nb_scan_rate = max(e // 2, 1)
        self.seed = int(seed)
        self._epochs: Optional[List[np.ndarray]] = None

    @property
    def batch_len(self) -> int:
        """Every batch row's length: tokens * top_k * layers."""
        return (self.batch * self.seq * self.cfg.moe.top_k
                * self.cfg.n_layers)

    # ------------------------------------------------------------- generation
    def _token_batch(self, rng: np.random.Generator,
                     shifted: bool) -> np.ndarray:
        """Zipf-popular token ids; ``shifted`` rotates popularity so a
        different expert subset becomes hot."""
        v = self.cfg.vocab_size
        toks = np.minimum(rng.zipf(self.zipf_a, size=(self.batch, self.seq))
                          - 1, v - 1)
        if shifted:
            toks = (toks + v // 2) % v
        return toks.astype(np.int32)

    def _generate(self) -> List[np.ndarray]:
        import jax
        import jax.numpy as jnp
        from ..models.model import forward, init_params
        from ..models.moe import expert_access_batch

        cfg = self.cfg
        rng = np.random.default_rng(self.seed)
        params = init_params(cfg, jax.random.key(self.seed))
        counts_fn = jax.jit(
            lambda p, t: forward(p, cfg, tokens=t)[1]["expert_counts"])
        epochs = []
        for ep in range(self.n_epochs):
            rows = []
            for _ in range(self.batches_per_epoch):
                toks = self._token_batch(rng, shifted=ep >= self.shift_at)
                counts = np.asarray(counts_fn(params, jnp.asarray(toks)))
                rows.append(expert_access_batch(counts))      # (L,E) -> ids
            epochs.append(np.stack(rows))
        return epochs

    # --------------------------------------------------------------- protocol
    def epochs(self) -> Iterator[np.ndarray]:
        if self._epochs is None:
            self._epochs = self._generate()
        return iter(self._epochs)

    def hint_layout(self) -> Optional[HintLayout]:
        return None          # routing hotness is runtime-only
