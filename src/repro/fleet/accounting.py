"""Per-tenant accounting — slicing the fleet's epoch records by tenant.

The runtime's per-tenant raw counters (``EpochRuntime.tenant_records``: one
``(n_lanes, n_tenants)`` int row set per epoch, produced by tenant-segment
reductions inside the fused epoch step and pulled on the runtime's batched
record sync — with ``sync_every=K`` the rows ride the same every-K
transfer as the global records) become
:class:`TenantRecord` rows here, re-priced with each tenant's OWN cost-model
geometry: a tenant's access time uses its own ``bytes_per_access``, its
migration time its own ``block_bytes``, so a KV page tenant and an expert
bank tenant read in their native units even though the device ran them as
one undifferentiated block space.

Definitions (per tenant t, lane l, epoch e):

* ``coverage``  = |fast ∩ hot_t| / hot_k[t] where ``hot_t`` is the tenant's
  own top-``hot_k[t]`` blocks by epoch count *within its id range* — the
  same denominator the tenant's solo run uses, so fleet-vs-solo coverage
  deltas are meaningful (the interference headline).
* ``accuracy``  = |fast ∩ hot_t| / resident_t.
* ``host_tax_s`` = the lane's global host tax apportioned by the tenant's
  share of the epoch's accesses (collectors are device-global; events do
  not carry tenant ids).
* ``time_s`` = access + tax + migration, stop-the-world migration charging
  for every lane: the prefetch lane's overlap accounting needs the global
  epoch's concurrency structure and stays on the global record
  (``EpochRecord.hidden_s``).

Conservation: ``n_fast``/``n_slow``/``resident``/``promoted``/``demoted``
sum across tenants to the global :class:`~repro.core.runtime.EpochRecord`
exactly (tested); ``coverage`` does not, by construction — per-tenant hot
sets are per-tenant truths, not a partition of the global top-K.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from ..core.runtime import EpochRuntime

__all__ = ["TenantRecord", "tenant_trajectories", "tenant_summary"]


@dataclasses.dataclass
class TenantRecord:
    """One tenant's slice of one lane's accounting for one epoch."""
    epoch: int
    lane: str
    tenant: str
    time_s: float
    access_s: float
    host_tax_s: float
    migration_s: float
    accuracy: float
    coverage: float
    resident: int
    promoted: int
    demoted: int
    n_fast: int
    n_slow: int
    hot_k: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def tenant_trajectories(rt: EpochRuntime, fleet, export=None,
                        ) -> Dict[str, Dict[str, List[TenantRecord]]]:
    """``{tenant: {lane: [TenantRecord per epoch]}}`` from a fleet run.

    Flushes the runtime's batched record sync first, so a caller that
    manually ``step``-ped with ``sync_every > 1`` never reads a partial
    ``tenant_records`` history.  ``export=`` emits every row as a
    ``tenant`` wire record tagged by tenant name (the rows rode the same
    batched record sync as the global records — exporting them here adds
    no device transfer)."""
    if rt.fused:
        rt.flush()                  # sync_every=K partial tail, if any
    if rt.tenancy is None or not rt.tenant_records:
        raise ValueError("runtime has no tenant accounting; build it via "
                         "EpochRuntime.for_scenario on a FleetScenario")
    lanes = list(rt.records)
    hot_k = rt.tenancy.hot_k
    out: Dict[str, Dict[str, List[TenantRecord]]] = {
        t.name: {lane: [] for lane in lanes} for t in fleet.tenants}
    for e, raw in enumerate(rt.tenant_records):
        for i, lane in enumerate(lanes):
            g = rt.records[lane][e]
            total = float(raw["n_fast"][i].sum() + raw["n_slow"][i].sum())
            for t_idx, spec in enumerate(fleet.tenants):
                n_fast = int(raw["n_fast"][i][t_idx])
                n_slow = int(raw["n_slow"][i][t_idx])
                inter = int(raw["inter"][i][t_idx])
                resident = int(raw["resident"][i][t_idx])
                promoted = int(raw["promoted"][i][t_idx])
                demoted = int(raw["demoted"][i][t_idx])
                access_s = rt.system.access_time_s(
                    n_fast, n_slow, spec.scenario.bytes_per_access)
                migration_s = rt.system.migration_time_s(
                    promoted + demoted, spec.scenario.block_bytes)
                share = (n_fast + n_slow) / total if total else 0.0
                host_tax_s = g.host_tax_s * share
                rec = TenantRecord(
                    epoch=e, lane=lane, tenant=spec.name,
                    time_s=access_s + host_tax_s + migration_s,
                    access_s=access_s, host_tax_s=host_tax_s,
                    migration_s=migration_s,
                    accuracy=(inter / resident) if resident else 0.0,
                    coverage=inter / hot_k[t_idx],
                    resident=resident, promoted=promoted, demoted=demoted,
                    n_fast=n_fast, n_slow=n_slow, hot_k=hot_k[t_idx],
                )
                out[spec.name][lane].append(rec)
                if export is not None:
                    export.export_tenant_record(rec)
    return out


def tenant_summary(rt: EpochRuntime, fleet,
                   policies: Sequence[str], export=None) -> dict:
    """Headline per-tenant numbers: quota, hot-set size, and per-lane
    mean/final coverage + accuracy, mean epoch time, move totals — plus the
    full per-epoch rows (the machine-readable trajectory).

    The per-lane dicts are wire-conformant ``tenant_lane_summary`` records
    minus the envelope (units in field names, validated against
    ``repro.export.telemetry.schema.json`` in tests)."""
    trajs = tenant_trajectories(rt, fleet, export=export)
    caps = rt.tenancy.caps
    summary: Dict[str, dict] = {}
    for t_idx, spec in enumerate(fleet.tenants):
        lanes = {}
        for lane in policies:
            recs = trajs[spec.name][lane]
            covs = np.array([r.coverage for r in recs])
            accs = np.array([r.accuracy for r in recs])
            lanes[lane] = {
                "mean_coverage": float(covs.mean()),
                "final_coverage": float(covs[-1]),
                "mean_accuracy": float(accs.mean()),
                "final_accuracy": float(accs[-1]),
                "mean_time_us": float(np.mean(
                    [r.time_s for r in recs]) * 1e6),
                "promoted_total_blocks": int(sum(r.promoted for r in recs)),
                "demoted_total_blocks": int(sum(r.demoted for r in recs)),
            }
            if export is not None:
                export.export_tenant_lane_summary(spec.name, lane,
                                                  lanes[lane])
        summary[spec.name] = {
            "n_blocks": spec.n_blocks,
            "hot_k": rt.tenancy.hot_k[t_idx],
            "cap": None if caps is None else caps[t_idx],
            "weight": spec.weight,
            "lanes": lanes,
            "records": {lane: [r.to_dict() for r in trajs[spec.name][lane]]
                        for lane in policies},
        }
    return summary
