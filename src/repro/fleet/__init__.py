"""repro.fleet — multi-tenant telemetry: many workloads, one fast tier.

The paper's HMU argument is ultimately a datacenter argument: device-level
telemetry pays off when *many* workloads contend for one bounded fast tier —
the regime TPP (Meta's CXL page placement) and Telescope (terabyte-scale
telemetry) target.  This package co-locates several
:class:`~repro.scenarios.AccessScenario`\\ s in one block space and drives
the six-lane :class:`~repro.core.runtime.EpochRuntime` over the mix:

* :class:`TenantSpec` / :class:`FleetScenario` (``fleet/scenario.py``) —
  the global<->local id-space mapping, the deterministic per-epoch stream
  interleave, merged cost-model geometry, composed per-tenant hint layouts.
  The fleet is itself an ``AccessScenario``: the runtime never learns it is
  placing four workloads instead of one.
* :mod:`~repro.fleet.capacity` — shared pool / static partition /
  weighted-fair quotas, compiled into the :class:`~repro.core.runtime.
  Tenancy` the fused epoch step enforces on device (segment-capped
  selection; the epoch stays at exactly 2 dispatches).
* :mod:`~repro.fleet.accounting` — per-tenant coverage / accuracy /
  epoch-time rows sliced from the runtime's tenant-segment reductions
  (scalar-only host sync), re-priced in each tenant's own byte geometry.
* :func:`run_fleet` — the packaging; ``examples/fleet_mix.py`` shows the
  headline: under a shared pool a scanning noisy neighbour craters a DLRM
  tenant's coverage, while weighted-fair quotas hold it near its solo run —
  the paper's coverage/accuracy limits study, lifted to fleet scale.
"""
from .accounting import TenantRecord, tenant_summary, tenant_trajectories
from .capacity import CAPACITY_POLICIES, fair_quotas, make_tenancy
from .scenario import FleetScenario, TenantSpec, run_fleet

__all__ = [
    "CAPACITY_POLICIES", "FleetScenario", "TenantRecord", "TenantSpec",
    "fair_quotas", "make_tenancy", "run_fleet", "tenant_summary",
    "tenant_trajectories",
]
