"""Capacity policies — how one bounded fast tier is split among tenants.

Three policies, all compiled into a :class:`~repro.core.runtime.Tenancy`
whose quotas the fused epoch step enforces on device (segment-capped
selection, see ``runtime._epoch_step``):

* ``"shared"``    — one pool, no quotas: every lane's top-k selection is
  global, tenants compete on raw counter magnitude.  This is TPP's default
  regime and the fleet's interference baseline: a scanning tenant with loud
  counters simply out-ranks a quieter tenant's hot set.
* ``"partition"`` — static partition proportional to each tenant's declared
  demand (its solo ``k_hot``): the capacity split an operator would
  provision from solo profiles, with no cross-tenant priorities.
* ``"weighted"``  — weighted-fair quotas from explicit per-tenant weights:
  the operator's SLO knob.  A protected tenant gets a quota covering its
  solo hot set regardless of how loud its neighbours are.

Quota arithmetic is largest-remainder apportionment (exact total, zero
weight -> zero quota) reusing the scenario layer's
:func:`~repro.scenarios.kv_cache.quantize_access_counts`, with a
``min_quota`` floor so no positive-weight tenant is starved to zero slots.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from ..core.runtime import Tenancy
from ..scenarios.kv_cache import quantize_access_counts

__all__ = ["CAPACITY_POLICIES", "fair_quotas", "make_tenancy"]

CAPACITY_POLICIES = ("shared", "partition", "weighted")


def fair_quotas(weights: Sequence[float], k_hot: int,
                min_quota: int = 1) -> np.ndarray:
    """Apportion ``k_hot`` fast-tier slots proportionally to ``weights``
    (largest-remainder, exact total), then raise every positive-weight
    tenant to at least ``min_quota`` slots, taking the shortfall from the
    largest quotas — a floor, not a fairness change."""
    w = np.asarray(weights, np.float64)
    if (w < 0).any() or w.sum() <= 0:
        raise ValueError(f"weights must be non-negative with a positive "
                         f"sum, got {list(weights)}")
    if k_hot < min_quota * int((w > 0).sum()):
        raise ValueError(f"k_hot={k_hot} cannot give {int((w > 0).sum())} "
                         f"tenants min_quota={min_quota} slots each")
    q = quantize_access_counts(w, int(k_hot))
    while True:
        short = (w > 0) & (q < min_quota)
        if not short.any():
            return q
        q[np.argmax(short)] += 1
        q[np.argmax(np.where(short, -1, q))] -= 1


def make_tenancy(
    offsets: Sequence[int],
    hot_k: Sequence[int],
    k_hot: int,
    capacity: str = "shared",
    weights: Optional[Sequence[float]] = None,
) -> Tenancy:
    """Compile a capacity policy into the runtime's :class:`Tenancy`.

    ``offsets``/``hot_k`` are the fleet's id-space layout (cumulative block
    offsets, per-tenant solo hot-set sizes); ``k_hot`` the shared fast
    tier's capacity.  ``"partition"`` derives quota weights from ``hot_k``
    (demand-proportional); ``"weighted"`` uses ``weights`` (required);
    ``"shared"`` sets no quotas."""
    if capacity not in CAPACITY_POLICIES:
        raise ValueError(f"unknown capacity policy {capacity!r}; choose "
                         f"from {CAPACITY_POLICIES}")
    caps: Optional[Tuple[int, ...]] = None
    if capacity == "partition":
        caps = tuple(int(c) for c in fair_quotas(hot_k, k_hot))
    elif capacity == "weighted":
        if weights is None:
            raise ValueError("capacity='weighted' needs per-tenant weights")
        if len(weights) != len(hot_k):
            raise ValueError(f"need one weight per tenant, got "
                             f"{len(weights)} for {len(hot_k)} tenants")
        caps = tuple(int(c) for c in fair_quotas(weights, k_hot))
    return Tenancy(offsets=tuple(int(o) for o in offsets),
                   hot_k=tuple(int(h) for h in hot_k), caps=caps)
