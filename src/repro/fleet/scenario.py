"""FleetScenario — many workloads co-located in one block space.

``TenantSpec`` wraps any :class:`~repro.scenarios.AccessScenario` with a
fleet identity (name, quota weight); ``FleetScenario`` concatenates N
tenants' block spaces into one global id space and is *itself* an
``AccessScenario``, so the whole fleet runs through the unmodified
:func:`~repro.scenarios.run_scenario` packaging — the runtime stays
workload-blind even about how many workloads it is placing.

The fleet owns exactly the plumbing the runtime must never learn:

* **id space** — tenant ``t``'s local block ``b`` is global block
  ``offsets[t] + b`` (:meth:`FleetScenario.to_global` /
  :meth:`~FleetScenario.to_local` round-trip);
* **stream interleave** — per epoch, every tenant's epoch batches are
  flattened, offset, concatenated and shuffled by a per-epoch seeded
  permutation (requests from co-located workloads arrive interleaved at
  the memory device), then cut into fixed-length batch rows;
* **merged geometry** — access/block byte sizes are averaged weighted by
  each tenant's traffic/block share (the runtime models one device); the
  per-tenant accounting (``fleet.accounting``) re-prices each tenant's
  rows with its OWN byte sizes;
* **hint composition** — each tenant's static :class:`~repro.hints.
  HintLayout` is analysed with its own prior and scattered into the global
  rank space (:meth:`~repro.hints.HintPipeline.for_fleet`);
* **capacity** — the chosen policy (shared / partition / weighted) compiles
  into the :class:`~repro.core.runtime.Tenancy` the fused epoch step
  enforces on device.

:func:`run_fleet` is the packaging: one six-lane run over the mix, global
summary plus per-tenant coverage/accuracy/epoch-time rows, and optional
per-tenant solo baselines for interference headlines.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..core import runtime as rtmod
from ..core.costmodel import MemSystem
from ..core.runtime import ALL_POLICIES, EpochRuntime, Tenancy
from ..hints import HintPipeline
from ..scenarios.base import run_scenario, scenario_summary
from . import accounting
from .capacity import make_tenancy

__all__ = ["TenantSpec", "FleetScenario", "run_fleet"]


@dataclasses.dataclass
class TenantSpec:
    """One workload's seat in the fleet: its scenario, its quota weight
    (the ``"weighted"`` capacity policy's knob), and its row name."""
    scenario: object                    # an AccessScenario
    weight: float = 1.0
    name: Optional[str] = None
    offset: int = dataclasses.field(default=0, init=False)

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"tenant weight must be positive, "
                             f"got {self.weight}")
        if self.name is None:
            self.name = self.scenario.name

    @property
    def n_blocks(self) -> int:
        return self.scenario.n_blocks

    @property
    def k_hot(self) -> int:
        """The tenant's solo fast-tier target — its coverage denominator
        and its demand under the ``"partition"`` policy."""
        return min(self.scenario.k_hot, self.scenario.n_blocks)


class FleetScenario:
    """N tenants, one block space, one bounded fast tier.

    ``k_hot`` defaults to the sum of the tenants' solo targets (no scarcity);
    pass something smaller to study contention.  ``capacity`` selects the
    quota policy (see :mod:`repro.fleet.capacity`); ``"weighted"`` reads the
    tenant specs' ``weight``.  The fleet runs ``min(tenant n_epochs)``
    epochs of ``max(tenant batches_per_epoch)`` interleaved batch rows.
    """

    name = "fleet"

    def __init__(
        self,
        tenants: Sequence[TenantSpec],
        k_hot: Optional[int] = None,
        capacity: str = "shared",
        system: Optional[MemSystem] = None,
        pebs_period: Optional[int] = None,
        seed: int = 0,
    ):
        if len(tenants) < 2:
            raise ValueError("a fleet needs at least two tenants")
        # shallow-copy the specs (scenario objects stay shared so cached
        # model-backed streams replay): the fleet assigns offsets, and two
        # fleets over the same spec objects must not fight over them
        self.tenants: List[TenantSpec] = [dataclasses.replace(t)
                                          for t in tenants]
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"tenant names must be unique, got {names}")
        off = 0
        for t in self.tenants:
            t.offset = off
            off += t.n_blocks
        self.n_blocks = off
        self.offsets: Tuple[int, ...] = tuple(
            [t.offset for t in self.tenants] + [off])
        self.k_hot = (sum(t.k_hot for t in self.tenants) if k_hot is None
                      else min(int(k_hot), self.n_blocks))
        self.capacity = capacity
        self.tenancy: Tenancy = make_tenancy(
            self.offsets, [t.k_hot for t in self.tenants], self.k_hot,
            capacity=capacity, weights=[t.weight for t in self.tenants])
        self.seed = int(seed)
        self.n_epochs = min(t.scenario.n_epochs for t in self.tenants)
        self.batches_per_epoch = max(t.scenario.batches_per_epoch
                                     for t in self.tenants)
        self.shift_at = min(max(t.scenario.shift_at for t in self.tenants),
                            max(self.n_epochs - 1, 0))
        # merged cost-model geometry: the runtime models ONE memory device,
        # so scalar byte sizes are traffic/block-share weighted means; the
        # per-tenant accounting re-prices each tenant with its own sizes
        self.system = system if system is not None \
            else self.tenants[0].scenario.system
        traffic = np.array([self._epoch_accesses(t) for t in self.tenants],
                           np.float64)
        blocks = np.array([t.n_blocks for t in self.tenants], np.float64)
        self.bytes_per_access = float(np.average(
            [t.scenario.bytes_per_access for t in self.tenants],
            weights=traffic))
        self.block_bytes = float(np.average(
            [t.scenario.block_bytes for t in self.tenants], weights=blocks))
        self.pebs_period = (min(t.scenario.pebs_period for t in self.tenants)
                            if pebs_period is None else int(pebs_period))
        self.nb_scan_rate = max(self.n_blocks // self.batches_per_epoch, 1)

    @staticmethod
    def _epoch_accesses(t: TenantSpec) -> float:
        """Per-epoch access volume a tenant contributes (weighting only)."""
        s = t.scenario
        for attr in ("accesses_per_batch", "batch_len"):
            if hasattr(s, attr):
                return s.batches_per_epoch * float(getattr(s, attr))
        if hasattr(s, "spec") and hasattr(s.spec, "lookups_per_batch"):
            return s.batches_per_epoch * float(s.spec.lookups_per_batch)
        return float(s.n_blocks)

    # ------------------------------------------------------------- id space
    def tenant_index(self, name: str) -> int:
        for i, t in enumerate(self.tenants):
            if t.name == name:
                return i
        raise KeyError(name)

    def to_global(self, tenant: int, local_ids) -> np.ndarray:
        """Tenant-local block ids -> global fleet ids."""
        local = np.asarray(local_ids)
        n_t = self.tenants[tenant].n_blocks
        if local.size and (local.min() < 0 or local.max() >= n_t):
            raise ValueError(f"local ids out of range [0, {n_t}) for "
                             f"tenant {tenant}")
        return (local + self.offsets[tenant]).astype(np.int64)

    def to_local(self, global_ids) -> Tuple[np.ndarray, np.ndarray]:
        """Global fleet ids -> (tenant index, tenant-local id) pairs."""
        g = np.asarray(global_ids)
        if g.size and (g.min() < 0 or g.max() >= self.n_blocks):
            raise ValueError(f"global ids out of range [0, {self.n_blocks})")
        tenant = np.searchsorted(np.asarray(self.offsets), g,
                                 side="right") - 1
        return (tenant.astype(np.int64),
                (g - np.asarray(self.offsets)[tenant]).astype(np.int64))

    # ------------------------------------------------------------- protocol
    def epochs(self) -> Iterator[np.ndarray]:
        """Interleaved fleet stream, deterministic per call: epoch e of every
        tenant, offset into global id space, concatenated, shuffled by the
        per-epoch seed, and cut into ``batches_per_epoch`` equal rows (the
        tail shorter than one row — at most batches_per_epoch-1 accesses —
        is dropped deterministically)."""
        streams = [iter(t.scenario.epochs()) for t in self.tenants]
        for e in range(self.n_epochs):
            parts = [np.asarray(next(it)).ravel().astype(np.int64) + t.offset
                     for t, it in zip(self.tenants, streams)]
            flat = np.concatenate(parts).astype(np.int32)
            rng = np.random.default_rng([self.seed, e])
            rng.shuffle(flat)
            rows = self.batches_per_epoch
            batch = flat.size // rows
            yield flat[: batch * rows].reshape(rows, batch)

    def hint_layout(self):
        """No single flat layout exists for a fleet (each tenant has its own
        prior); hint composition happens in :meth:`build_pipeline`."""
        return None

    def build_pipeline(self, depth: int = 1, clip_rank: Optional[int] = None,
                       detector: bool = True) -> HintPipeline:
        """Composed fleet pipeline (what ``run_scenario(..., hints=True)``
        and :func:`run_fleet` attach): every tenant's static layout analysed
        with its own prior, scattered at its offset —
        :meth:`HintPipeline.for_fleet`."""
        return HintPipeline.for_fleet(
            self.n_blocks,
            [(t.offset, t.scenario.hint_layout()) for t in self.tenants],
            depth=depth, clip_rank=clip_rank, detector=detector)

    def build_faults(self, profiles: Dict[str, dict], **global_kwargs):
        """Per-tenant fault profiles -> one fleet-wide
        :class:`~repro.faults.FaultModel`, keyed by tenant name.  Each
        profile sets the per-block-resolvable knobs (``pebs_drop_p``,
        ``hmu_counter_bits`` / ``hmu_counter_max``) on that tenant's block
        segment; collector-global knobs (``reset_p``, ``nb_stall_p``,
        ``stale_epochs``, ``seed``) go in ``global_kwargs`` — a reset drains
        the shared collector, it cannot hit one tenant's blocks alone."""
        from ..faults import FaultModel
        unknown = set(profiles) - {t.name for t in self.tenants}
        if unknown:
            raise KeyError(f"unknown tenant names {sorted(unknown)}; "
                           f"tenants are {[t.name for t in self.tenants]}")
        segs = [profiles.get(t.name) for t in self.tenants]
        return FaultModel.for_segments(self.offsets, segs, **global_kwargs)


def run_fleet(
    fleet: FleetScenario,
    policies: Sequence[str] = ALL_POLICIES,
    hints=True,
    lookahead_depth: int = 1,
    prefetch_overlap: float = 1.0,
    fused: bool = True,
    mesh=None,
    sync_every: int = 1,
    epochs=None,
    solo: bool = False,
    faults=None,
    hardening=None,
    export=None,
    **runtime_overrides,
) -> dict:
    """Place the whole fleet online and slice the result per tenant.

    Mirrors :func:`~repro.scenarios.run_scenario` (the fleet IS a scenario;
    the runtime inherits its :class:`Tenancy` through
    ``EpochRuntime.for_scenario``) but keeps the runtime in hand so the
    per-tenant accounting (``fleet.accounting``) can be sliced from
    ``EpochRuntime.tenant_records``.  Returns ``{"trajectory", "summary",
    "tenants"}`` — the tenants section holds one coverage/accuracy/time row
    per tenant per lane per epoch plus headline summaries.

    ``sync_every=K`` batches the runtime's record syncs — the per-tenant
    ``(n_lanes, n_tenants)`` rows ride the same every-K transfer as the
    global records, bit-identical for every K.

    ``faults=`` takes a fleet-wide :class:`~repro.faults.FaultModel` or a
    ``{tenant_name: profile}`` dict handed to :meth:`FleetScenario.
    build_faults` (per-tenant degradation; collector-global knobs then ride
    ``runtime_overrides``-style through ``build_faults`` yourself).
    ``hardening=`` passes through to the runtime unchanged.  Solo baselines
    always run fault-free — the comparison is *this tenant under the fleet's
    faults* vs *this tenant alone on healthy telemetry*.

    ``export=`` attaches a :class:`repro.export.ExportClient`: global
    per-epoch records stream out at the record-sync boundary, per-tenant
    rows are emitted as ``tenant`` wire records tagged by tenant name, and
    the global ``lane_summary`` / per-tenant ``tenant_lane_summary``
    headline rows land on completion.  Solo baseline runs are NOT exported
    (they are comparison scaffolding, not fleet telemetry).

    ``solo=True`` additionally runs every tenant's scenario alone (fresh
    pipelines, same policies) for interference-vs-isolation comparisons,
    each under a nested :func:`~repro.core.runtime.counting` scope whose
    view stamps the solo row's own ``dispatches_per_epoch`` (nesting is
    safe: counting() hands out scope-relative views, never mutating the
    live dicts).  Solo dispatches still accrue to enclosing scopes, so
    gate callers that assert fleet dispatch counts should leave it off.
    """
    if hints is True:
        hints = fleet.build_pipeline(depth=lookahead_depth)
    if isinstance(faults, dict):
        faults = fleet.build_faults(faults)
    exp = export.bind(scenario=fleet.name) if export is not None else None
    rt = EpochRuntime.for_scenario(
        fleet, policies=tuple(policies), hints=hints or None,
        prefetch_overlap=prefetch_overlap, fused=fused, mesh=mesh,
        sync_every=sync_every, faults=faults, hardening=hardening,
        export=exp, **runtime_overrides)
    traj = rt.run(fleet.epochs() if epochs is None else epochs)
    summary = scenario_summary(rt, traj, policies, fleet.shift_at)
    if exp is not None:
        for name in policies:
            exp.export_lane_summary(name, summary[name])
    out = {
        "trajectory": json.loads(traj.to_json(
            scenario=fleet.name, shift_at=fleet.shift_at,
            capacity=fleet.capacity)),
        "summary": summary,
        "tenants": accounting.tenant_summary(rt, fleet, policies,
                                             export=exp),
    }
    if solo:
        solos: Dict[str, dict] = {}
        for t in fleet.tenants:
            with rtmod.counting() as c:
                solos[t.name] = run_scenario(
                    t.scenario, policies=policies, hints=bool(hints),
                    lookahead_depth=lookahead_depth,
                    prefetch_overlap=prefetch_overlap, fused=fused)
            solos[t.name]["dispatches_per_epoch"] = (
                c.dispatch["observe_all"] + c.dispatch["epoch_step"]
                + c.dispatch["reference"]) / t.scenario.n_epochs
        out["solo"] = solos
    return out
