"""Non-blocking telemetry export client.

The one invariant everything here serves: **export must never cost the
observed runtime anything**.  The epoch loop's side of the client is a
single ``queue.put_nowait`` on records it already materialised for its own
bookkeeping — no added dispatch, no blocking, no exception escapes.  All
real work (schema validation, batching, sink I/O) happens on a daemon
flusher thread, and every way that work can go wrong is absorbed:

* queue full -> the record is dropped and counted (``dropped_queue_full``);
  the producer never waits.
* record invalid against the frozen schema -> dropped and counted
  (``dropped_invalid``); validation runs in the flusher, off the hot path.
* sink raises -> the :class:`CircuitBreaker` counts consecutive failures
  and trips open; while open, records are dropped at ``emit`` time
  (``dropped_breaker_open``) without touching the queue.  After a cooldown
  the breaker goes half-open and lets one probe batch through: success
  closes it, failure re-opens it.  ``degrade_after_trips`` consecutive
  trips with no intervening success declares the sink dead and the client
  permanently degrades to noop behaviour (:class:`NoopClient` semantics) —
  the run finishes at full speed with export silently off.

``stats()`` surfaces every counter so nothing is dropped silently, and
``close()`` (idempotent, also registered via ``atexit``) drains the queue
and joins the flusher so short-lived processes don't lose the tail.
"""
from __future__ import annotations

import atexit
import queue
import threading
import time
from typing import Dict, List, Optional

from ..obs import trace as obs_trace
from .schema import (SchemaError, epoch_record_wire, lane_summary_wire,
                     runtime_metric_wire, runtime_span_wire,
                     tenant_lane_summary_wire, tenant_record_wire,
                     validate_record)

__all__ = ["CircuitBreaker", "ExportClient", "NoopClient"]

_SENTINEL = object()


class CircuitBreaker:
    """Consecutive-failure circuit breaker (closed -> open -> half-open).

    Closed: everything flows.  ``failure_threshold`` consecutive sink
    failures trip it open; while open, ``allow()`` is False until
    ``cooldown_s`` has elapsed, at which point the breaker goes half-open
    and ``allow()`` admits a probe.  ``record_success()`` closes it again;
    ``record_failure()`` in half-open re-opens immediately.  ``clock`` is
    injectable so tests drive the cooldown without sleeping.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, failure_threshold: int = 3, cooldown_s: float = 0.25,
                 clock=time.monotonic) -> None:
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self._clock = clock
        self._lock = threading.Lock()
        self._state = self.CLOSED
        self._failures = 0
        self._opened_at = 0.0
        self.trips = 0                 # total times tripped open
        self.consecutive_trips = 0     # trips since the last success

    @property
    def state(self) -> str:
        with self._lock:
            self._maybe_half_open()
            return self._state

    def _maybe_half_open(self) -> None:
        if (self._state == self.OPEN
                and self._clock() - self._opened_at >= self.cooldown_s):
            self._state = self.HALF_OPEN

    def allow(self) -> bool:
        """May a write proceed right now?  (Open + cooldown elapsed counts
        as yes — that IS the half-open probe.)"""
        with self._lock:
            self._maybe_half_open()
            return self._state != self.OPEN

    def record_success(self) -> None:
        with self._lock:
            self._state = self.CLOSED
            self._failures = 0
            self.consecutive_trips = 0

    def record_failure(self) -> None:
        with self._lock:
            self._maybe_half_open()
            self._failures += 1
            if (self._state == self.HALF_OPEN
                    or self._failures >= self.failure_threshold):
                self._state = self.OPEN
                self._opened_at = self._clock()
                self._failures = 0
                self.trips += 1
                self.consecutive_trips += 1


class NoopClient:
    """The do-nothing client: same surface as :class:`ExportClient`, zero
    state, zero threads.  Also the behaviour a degraded ExportClient
    converges to once its breaker declares the sink dead."""

    degraded = False

    def emit(self, record: dict) -> bool:
        return False

    def export_epoch_record(self, rec) -> bool:
        return False

    def export_tenant_record(self, rec) -> bool:
        return False

    def export_lane_summary(self, lane: str, summary: dict) -> bool:
        return False

    def export_tenant_lane_summary(self, tenant: str, lane: str,
                                   summary: dict) -> bool:
        return False

    def export_runtime_span(self, span) -> bool:
        return False

    def export_runtime_metric(self, metric: str, kind: str, value=None,
                              **kw) -> bool:
        return False

    def export_metrics(self, registry) -> int:
        return 0

    def bind(self, **labels: str) -> "NoopClient":
        return self

    def flush(self, timeout: Optional[float] = None) -> None:
        pass

    def close(self, timeout: Optional[float] = None) -> None:
        pass

    def stats(self) -> Dict[str, object]:
        return {"emitted": 0, "exported": 0, "dropped_queue_full": 0,
                "dropped_invalid": 0, "dropped_breaker_open": 0,
                "dropped_sink_failure": 0, "dropped_degraded": 0,
                "sink_failures": 0, "breaker_state": "closed",
                "breaker_trips": 0, "degraded": False}


class ExportClient:
    """Bounded-queue, background-flushed, breaker-guarded export client.

    Parameters
    ----------
    sink : object with ``write(List[dict])`` (see ``repro.export.sinks``)
    queue_size : producer-side bound; overflow drops (never blocks)
    batch_size : max records per ``sink.write`` call
    flush_interval_s : flusher wakeup period when the queue is idle
    validate : check every record against the frozen schema in the
        flusher thread (invalid records are dropped + counted, not raised)
    breaker : injectable :class:`CircuitBreaker` (tests pass a fake clock)
    degrade_after_trips : consecutive breaker trips with no successful
        write before the client permanently degrades to noop
    scenario : default scenario label stamped on every wire record
    """

    def __init__(self, sink, *, queue_size: int = 2048, batch_size: int = 256,
                 flush_interval_s: float = 0.05, validate: bool = True,
                 breaker: Optional[CircuitBreaker] = None,
                 degrade_after_trips: int = 3,
                 scenario: Optional[str] = None) -> None:
        self.sink = sink
        self.batch_size = int(batch_size)
        self.validate = bool(validate)
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        self.degrade_after_trips = int(degrade_after_trips)
        self.scenario = scenario
        self._queue: "queue.Queue" = queue.Queue(maxsize=int(queue_size))
        self._flush_interval_s = float(flush_interval_s)
        self._lock = threading.Lock()          # guards the counters below
        self._emitted = 0
        self._exported = 0
        self._dropped_queue_full = 0
        self._dropped_invalid = 0
        self._dropped_breaker_open = 0
        self._dropped_sink_failure = 0
        self._dropped_degraded = 0
        self._sink_failures = 0
        self._degraded = False
        self._closed = False
        self._idle = threading.Event()         # queue drained & written
        self._idle.set()
        self._thread = threading.Thread(target=self._flusher_loop,
                                        name="repro-export-flusher",
                                        daemon=True)
        self._thread.start()
        self._atexit = atexit.register(self.close)

    # ------------------------------------------------------------ producers
    @property
    def degraded(self) -> bool:
        return self._degraded

    def emit(self, record: dict) -> bool:
        """Enqueue one wire record.  Never blocks, never raises; returns
        whether the record was accepted."""
        _tr = obs_trace.get_tracer()
        if not _tr.enabled:
            return self._emit(record)
        with _tr.span("export.enqueue"):
            return self._emit(record)

    def _emit(self, record: dict) -> bool:
        if self._degraded or self._closed:
            with self._lock:
                self._dropped_degraded += 1
            return False
        if not self.breaker.allow():
            # breaker open and cooling down: shed load at the door instead
            # of queueing records the flusher would only throw away
            with self._lock:
                self._dropped_breaker_open += 1
            return False
        try:
            self._queue.put_nowait(record)
        except queue.Full:
            with self._lock:
                self._dropped_queue_full += 1
            return False
        self._idle.clear()
        with self._lock:
            self._emitted += 1
        return True

    def export_epoch_record(self, rec) -> bool:
        return self.emit(epoch_record_wire(rec, self.scenario))

    def export_tenant_record(self, rec) -> bool:
        return self.emit(tenant_record_wire(rec, self.scenario))

    def export_lane_summary(self, lane: str, summary: dict) -> bool:
        return self.emit(lane_summary_wire(lane, summary, self.scenario))

    def export_tenant_lane_summary(self, tenant: str, lane: str,
                                   summary: dict) -> bool:
        return self.emit(
            tenant_lane_summary_wire(tenant, lane, summary, self.scenario))

    def export_runtime_span(self, span) -> bool:
        """One closed :class:`repro.obs.trace.Span` -> wire record."""
        return self.emit(runtime_span_wire(span, self.scenario))

    def export_runtime_metric(self, metric: str, kind: str, value=None,
                              **kw) -> bool:
        """One metric sample -> wire record (see ``runtime_metric_wire``)."""
        return self.emit(runtime_metric_wire(metric, kind, value,
                                             scenario=self.scenario, **kw))

    def export_metrics(self, registry) -> int:
        """Emit one ``runtime_metric`` record per labeled child of every
        family in a :class:`repro.obs.metrics.MetricsRegistry`; returns how
        many records were accepted.  Call at run boundaries — a registry
        dump is a snapshot, not a stream."""
        accepted = 0
        for fam in registry.families():
            for child in fam.children():
                labels = dict(child.labels) or None
                if fam.kind == "histogram":
                    ok = self.export_runtime_metric(
                        fam.name, "histogram", labels=labels,
                        bucket_le=fam.buckets,
                        bucket_counts=child.bucket_counts,
                        sum_value=child.sum, observations=child.count)
                else:
                    ok = self.export_runtime_metric(
                        fam.name, fam.kind, child.value, labels=labels)
                accepted += bool(ok)
        return accepted

    def bind(self, **labels: str) -> "_BoundClient":
        """A lightweight view of this client with a different scenario
        label — lets ``run_scenario`` tag records without mutating a
        caller-owned client."""
        unknown = set(labels) - {"scenario"}
        if unknown:
            raise TypeError(f"unknown bind labels {sorted(unknown)}; the "
                            f"frozen schema only carries 'scenario'")
        return _BoundClient(self, labels.get("scenario", self.scenario))

    # -------------------------------------------------------------- flusher
    def _flusher_loop(self) -> None:
        while True:
            try:
                item = self._queue.get(timeout=self._flush_interval_s)
            except queue.Empty:
                self._idle.set()
                if self._closed:
                    break
                continue
            closing = item is _SENTINEL
            batch: List[dict] = [] if closing else [item]
            while len(batch) < self.batch_size:
                try:
                    nxt = self._queue.get_nowait()
                except queue.Empty:
                    break
                if nxt is _SENTINEL:
                    closing = True
                    continue
                batch.append(nxt)
            if batch:
                self._write_batch(batch)
            if closing and self._queue.empty():
                break
        # final drain: whatever raced in after the sentinel
        tail: List[dict] = []
        while True:
            try:
                nxt = self._queue.get_nowait()
            except queue.Empty:
                break
            if nxt is not _SENTINEL:
                tail.append(nxt)
        if tail:
            self._write_batch(tail)
        try:
            if hasattr(self.sink, "flush"):
                self.sink.flush()
        except Exception:
            pass
        self._idle.set()

    def _write_batch(self, batch: List[dict]) -> None:
        # runs on the flusher thread -> its own track in the chrome trace;
        # stats are (re)published after every attempt (even all-dropped
        # ones) so a dropping exporter is visible from a scrape.
        _tr = obs_trace.get_tracer()
        cm = (_tr.span("export.write_batch", batch=len(batch))
              if _tr.enabled else obs_trace.NOOP_SPAN)
        try:
            with cm:
                self._write_batch_inner(batch)
        finally:
            self._publish_stats()

    _PUBLISHED_STAT_KEYS = ("emitted", "exported", "sink_failures")
    _PUBLISHED_DROP_KEYS = ("dropped_queue_full", "dropped_invalid",
                            "dropped_breaker_open", "dropped_sink_failure",
                            "dropped_degraded")

    def _publish_stats(self) -> None:
        """Mirror the client's own counters into the sink's ``set_counter``
        path (when it has one): ``repro_export_{emitted,exported,
        sink_failures}_total`` plus ``repro_export_dropped_total`` labelled
        by reason.  Best-effort — a sink that throws here must not take the
        flusher down with it."""
        set_counter = getattr(self.sink, "set_counter", None)
        if set_counter is None:
            return
        st = self.stats()
        try:
            for key in self._PUBLISHED_STAT_KEYS:
                set_counter(f"repro_export_{key}_total", st[key],
                            help=f"Export client {key.replace('_', ' ')}")
            for key in self._PUBLISHED_DROP_KEYS:
                set_counter("repro_export_dropped_total", st[key],
                            help="Records dropped by the export client, "
                                 "by reason",
                            reason=key[len("dropped_"):])
        except Exception:
            pass

    def _write_batch_inner(self, batch: List[dict]) -> None:
        if self.validate:
            good: List[dict] = []
            bad = 0
            for rec in batch:
                try:
                    good.append(validate_record(rec))
                except SchemaError:
                    bad += 1
            if bad:
                with self._lock:
                    self._dropped_invalid += bad
        else:
            good = batch
        if not good:
            return
        if self._degraded or not self.breaker.allow():
            with self._lock:
                self._dropped_breaker_open += len(good)
            return
        try:
            self.sink.write(good)
        except Exception:
            self.breaker.record_failure()
            with self._lock:
                self._sink_failures += 1
                self._dropped_sink_failure += len(good)
                if self.breaker.consecutive_trips >= self.degrade_after_trips:
                    self._degraded = True
        else:
            self.breaker.record_success()
            with self._lock:
                self._exported += len(good)

    # ------------------------------------------------------------ lifecycle
    def flush(self, timeout: Optional[float] = None) -> None:
        """Block (the CALLER, never the epoch loop — call between runs)
        until everything enqueued so far has been offered to the sink."""
        _tr = obs_trace.get_tracer()
        cm = (_tr.span("export.flush") if _tr.enabled else obs_trace.NOOP_SPAN)
        with cm:
            deadline = None if timeout is None else time.monotonic() + timeout
            while not (self._queue.empty() and self._idle.is_set()):
                if not self._thread.is_alive():
                    break
                if deadline is not None and time.monotonic() >= deadline:
                    break
                time.sleep(0.005)
        # emit-time drops (queue_full / breaker_open / degraded) may never
        # reach _write_batch; a flush is the natural scrape boundary
        self._publish_stats()

    def close(self, timeout: Optional[float] = 5.0) -> None:
        """Stop accepting records, drain the queue, join the flusher, and
        close the sink.  Idempotent; also runs at interpreter exit."""
        if self._closed:
            return
        self._closed = True
        try:
            atexit.unregister(self.close)
        except Exception:
            pass
        try:
            self._queue.put_nowait(_SENTINEL)
        except queue.Full:
            pass  # flusher sees _closed on its next idle wakeup
        self._thread.join(timeout=timeout)
        try:
            if hasattr(self.sink, "close"):
                self.sink.close()
        except Exception:
            pass

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {
                "emitted": self._emitted,
                "exported": self._exported,
                "dropped_queue_full": self._dropped_queue_full,
                "dropped_invalid": self._dropped_invalid,
                "dropped_breaker_open": self._dropped_breaker_open,
                "dropped_sink_failure": self._dropped_sink_failure,
                "dropped_degraded": self._dropped_degraded,
                "sink_failures": self._sink_failures,
                "breaker_state": self.breaker.state,
                "breaker_trips": self.breaker.trips,
                "degraded": self._degraded,
            }


class _BoundClient:
    """A scenario-labelled view over an :class:`ExportClient`.  Shares the
    parent's queue, flusher, breaker, and counters; only the label differs.
    """

    def __init__(self, parent: ExportClient, scenario: Optional[str]) -> None:
        self._parent = parent
        self.scenario = scenario

    @property
    def degraded(self) -> bool:
        return self._parent.degraded

    def emit(self, record: dict) -> bool:
        return self._parent.emit(record)

    def export_epoch_record(self, rec) -> bool:
        return self.emit(epoch_record_wire(rec, self.scenario))

    def export_tenant_record(self, rec) -> bool:
        return self.emit(tenant_record_wire(rec, self.scenario))

    def export_lane_summary(self, lane: str, summary: dict) -> bool:
        return self.emit(lane_summary_wire(lane, summary, self.scenario))

    def export_tenant_lane_summary(self, tenant: str, lane: str,
                                   summary: dict) -> bool:
        return self.emit(
            tenant_lane_summary_wire(tenant, lane, summary, self.scenario))

    def export_runtime_span(self, span) -> bool:
        return self.emit(runtime_span_wire(span, self.scenario))

    def export_runtime_metric(self, metric: str, kind: str, value=None,
                              **kw) -> bool:
        return self.emit(runtime_metric_wire(metric, kind, value,
                                             scenario=self.scenario, **kw))

    def export_metrics(self, registry) -> int:
        return ExportClient.export_metrics(self, registry)

    def bind(self, **labels: str):
        return self._parent.bind(**labels)

    def flush(self, timeout: Optional[float] = None) -> None:
        self._parent.flush(timeout)

    def close(self, timeout: Optional[float] = 5.0) -> None:
        self._parent.close(timeout)

    def stats(self) -> Dict[str, object]:
        return self._parent.stats()
