"""Pluggable export sinks — where validated wire records actually land.

A sink is anything with ``write(records)`` taking a batch (list) of wire
dicts, plus optional ``flush()`` / ``close()``.  Sinks are called ONLY from
the :class:`~repro.export.client.ExportClient` flusher thread, never from
the epoch loop, so a slow or dead sink costs the observed runtime nothing:
the client's circuit breaker absorbs every exception a sink raises.

Three sinks cover the repo's needs:

* :class:`JsonlSink` — newline-delimited JSON to a file; the durable
  cross-run format (``results/telemetry.jsonl`` style).
* :class:`MemorySink` — collects records in a list; the test double, with a
  ``fail_every``/``fail_until`` knob to script sink failures for circuit-
  breaker tests.
* :class:`PrometheusTextSink` — maintains last-value gauges keyed by
  (scenario, lane, tenant) from incoming records and renders Prometheus
  text exposition format v0.0.4 on demand (``render()``); for scrape-style
  ops integration of coverage/accuracy/quality/epoch-time and the
  runtime's dispatch counters.
"""
from __future__ import annotations

import io
import json
import threading
from typing import Dict, List, Optional, Tuple

__all__ = ["JsonlSink", "MemorySink", "PrometheusTextSink", "SinkError"]


class SinkError(RuntimeError):
    """A sink refused a batch (used by MemorySink's scripted failures)."""


class JsonlSink:
    """Appends one JSON object per line to ``path``.

    The file handle opens lazily on first write so constructing a client
    with a JSONL sink costs nothing until telemetry actually flows, and a
    sink pointed at an unwritable path fails in the flusher thread (where
    the breaker catches it), not in user code.
    """

    def __init__(self, path) -> None:
        self.path = str(path)
        self._fh: Optional[io.TextIOBase] = None

    def write(self, records: List[dict]) -> None:
        if self._fh is None:
            self._fh = open(self.path, "a", encoding="utf-8")
        self._fh.write("".join(
            json.dumps(rec, separators=(",", ":"), sort_keys=True) + "\n"
            for rec in records))

    def flush(self) -> None:
        if self._fh is not None:
            self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class MemorySink:
    """In-memory sink for tests; thread-safe.

    ``fail_until`` makes the first N ``write`` calls raise (then recover) —
    the shape circuit-breaker recovery tests need.  ``fail_always`` models
    a permanently dead sink.
    """

    def __init__(self, fail_until: int = 0, fail_always: bool = False) -> None:
        self.records: List[dict] = []
        self.write_calls = 0
        self.failed_calls = 0
        self.fail_until = fail_until
        self.fail_always = fail_always
        self._lock = threading.Lock()

    def write(self, records: List[dict]) -> None:
        with self._lock:
            self.write_calls += 1
            if self.fail_always or self.write_calls <= self.fail_until:
                self.failed_calls += 1
                raise SinkError(f"scripted failure #{self.failed_calls}")
            self.records.extend(records)

    def snapshot(self) -> List[dict]:
        with self._lock:
            return list(self.records)


# Prometheus metric name -> (wire field, help text).  Only gauge-shaped
# fields; monotone totals come in via set_counter().
_GAUGE_FIELDS = (
    ("repro_coverage_ratio", "coverage",
     "Fraction of true-hot blocks resident in the fast tier"),
    ("repro_accuracy_ratio", "accuracy",
     "Fraction of fast-tier accesses that hit resident blocks"),
    ("repro_quality_ratio", "quality",
     "Collector telemetry quality (observed access mass fraction)"),
    ("repro_epoch_time_seconds", "time_s",
     "Modelled epoch execution time"),
)


def _escape_label_value(value: str) -> str:
    """Label-value escaping per the text exposition format v0.0.4:
    backslash, double-quote, and line feed."""
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _escape_help(text: str) -> str:
    """HELP-line escaping: backslash and line feed (quotes are legal)."""
    return str(text).replace("\\", "\\\\").replace("\n", "\\n")


class PrometheusTextSink:
    """Last-value gauges rendered as Prometheus text exposition.

    ``write`` folds each record's ratio/time fields into gauges labelled
    ``{scenario, lane, tenant}`` (absent labels rendered as empty strings
    so series stay distinct); ``set_counter`` / ``set_gauge`` publish
    externally-owned samples (the runtime's ``DISPATCH_COUNTS``, the
    export client's own drop counters, registry gauges); ``set_histogram``
    publishes a bounded-bucket histogram rendered cumulatively with the
    standard ``_bucket{le=...}`` / ``_sum`` / ``_count`` triplet;
    ``render`` produces the scrape body — every family gets ``# HELP`` and
    ``# TYPE`` metadata, and label values are escaped (backslash, double
    quote, newline) per format v0.0.4.  Thread-safe: ``write`` runs on the
    flusher thread while ``render`` is called from a scrape/test thread.
    """

    def __init__(self) -> None:
        # metric -> label-tuple -> value
        self._gauges: Dict[str, Dict[Tuple[str, str, str], float]] = {
            name: {} for name, _, _ in _GAUGE_FIELDS}
        self._counters: Dict[str, Dict[Tuple[Tuple[str, str], ...], float]] = {}
        self._ext_gauges: Dict[str, Dict[Tuple[Tuple[str, str], ...], float]] = {}
        # name -> label-tuple -> (bounds, bucket_counts, sum, count)
        self._hists: Dict[str, Dict[Tuple[Tuple[str, str], ...], tuple]] = {}
        self._help: Dict[str, str] = {}
        self._lock = threading.Lock()

    def write(self, records: List[dict]) -> None:
        with self._lock:
            for rec in records:
                labels = (rec.get("scenario", ""), rec.get("lane", ""),
                          rec.get("tenant", ""))
                for name, field, _ in _GAUGE_FIELDS:
                    if field in rec:
                        self._gauges[name][labels] = float(rec[field])

    def _remember_help(self, name: str, help: Optional[str]) -> None:
        if help:
            self._help[name] = str(help)

    def set_counter(self, name: str, value: float, help: Optional[str] = None,
                    **labels: str) -> None:
        """Publish a monotone counter sample (e.g. ``repro_dispatch_total``
        from ``DISPATCH_COUNTS``, labelled by kind)."""
        with self._lock:
            self._remember_help(name, help)
            self._counters.setdefault(name, {})[
                tuple(sorted(labels.items()))] = float(value)

    def set_gauge(self, name: str, value: float, help: Optional[str] = None,
                  **labels: str) -> None:
        """Publish an externally-owned last-value gauge sample."""
        with self._lock:
            self._remember_help(name, help)
            self._ext_gauges.setdefault(name, {})[
                tuple(sorted(labels.items()))] = float(value)

    def set_histogram(self, name: str, bounds, bucket_counts, sum_value,
                      count=None, help: Optional[str] = None,
                      **labels: str) -> None:
        """Publish one bounded-bucket histogram: ``bounds`` are the finite
        ``le`` upper bounds, ``bucket_counts`` the per-bucket (NOT
        cumulative) counts with one trailing overflow bucket."""
        bounds = tuple(float(b) for b in bounds)
        bucket_counts = tuple(int(c) for c in bucket_counts)
        if len(bucket_counts) != len(bounds) + 1:
            raise ValueError(
                f"{name}: need len(bounds)+1 bucket counts, got "
                f"{len(bucket_counts)} for {len(bounds)} bounds")
        if count is None:
            count = sum(bucket_counts)
        with self._lock:
            self._remember_help(name, help)
            self._hists.setdefault(name, {})[
                tuple(sorted(labels.items()))] = (
                    bounds, bucket_counts, float(sum_value), int(count))

    @staticmethod
    def _fmt_labels(pairs) -> str:
        if not pairs:
            return ""
        body = ",".join(f'{k}="{_escape_label_value(v)}"' for k, v in pairs)
        return "{" + body + "}"

    def _meta(self, out: List[str], name: str, kind: str,
              default_help: str) -> None:
        out.append(f"# HELP {name} "
                   f"{_escape_help(self._help.get(name, default_help))}")
        out.append(f"# TYPE {name} {kind}")

    def render(self) -> str:
        """Prometheus text exposition format v0.0.4."""
        out: List[str] = []
        with self._lock:
            for name, field, help_text in _GAUGE_FIELDS:
                series = self._gauges[name]
                if not series:
                    continue
                out.append(f"# HELP {name} {_escape_help(help_text)}")
                out.append(f"# TYPE {name} gauge")
                for (scenario, lane, tenant), val in sorted(series.items()):
                    pairs = [("lane", lane), ("scenario", scenario),
                             ("tenant", tenant)]
                    out.append(f"{name}{self._fmt_labels(pairs)} {val:g}")
            for name in sorted(self._ext_gauges):
                self._meta(out, name, "gauge", "Last-value gauge")
                for pairs, val in sorted(self._ext_gauges[name].items()):
                    out.append(f"{name}{self._fmt_labels(pairs)} {val:g}")
            for name in sorted(self._counters):
                self._meta(out, name, "counter", "Monotone counter")
                for pairs, val in sorted(self._counters[name].items()):
                    out.append(f"{name}{self._fmt_labels(pairs)} {val:g}")
            for name in sorted(self._hists):
                self._meta(out, name, "histogram", "Latency histogram")
                for pairs, (bounds, counts, sum_v, count) in sorted(
                        self._hists[name].items()):
                    cum = 0
                    for bound, c in zip(bounds, counts):
                        cum += c
                        bpairs = list(pairs) + [("le", f"{bound:g}")]
                        out.append(f"{name}_bucket"
                                   f"{self._fmt_labels(bpairs)} {cum}")
                    bpairs = list(pairs) + [("le", "+Inf")]
                    out.append(f"{name}_bucket{self._fmt_labels(bpairs)} "
                               f"{cum + counts[-1]}")
                    out.append(f"{name}_sum{self._fmt_labels(pairs)} "
                               f"{sum_v:g}")
                    out.append(f"{name}_count{self._fmt_labels(pairs)} "
                               f"{count}")
        return "\n".join(out) + ("\n" if out else "")
