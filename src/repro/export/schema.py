"""Frozen telemetry wire schema — the contract downstream tooling parses.

The in-process record types (:class:`~repro.core.runtime.EpochRecord`,
:class:`~repro.fleet.accounting.TenantRecord`, the ``run_scenario`` summary
dicts) are free to evolve with the runtime; what crosses the process
boundary is not.  This module freezes the **wire form**: field names with
units encoded in them (``_s`` seconds, ``_us`` microseconds, ``_blocks``
block counts, ``_count`` event counts; ratios unitless in [0, 1]),
documented field-for-field in ``docs/telemetry_schema.md`` and encoded as
JSON Schema in the checked-in ``telemetry.schema.json`` next to this file.

* :func:`validate_record` checks one wire record against the schema and
  raises :class:`SchemaError` with the offending path.  The validator is
  self-contained (it interprets the subset of JSON Schema the document
  uses — ``$ref`` into ``$defs``, ``const``/``enum``/``type``,
  ``properties``/``required``/``additionalProperties``, ``minimum``/
  ``maximum``, top-level ``oneOf`` dispatched on ``record_type``) so the
  export plane validates everywhere the repo runs; when the ``jsonschema``
  package is importable the test suite cross-checks both validators agree.
* ``epoch_record_wire`` / ``tenant_record_wire`` / ``lane_summary_wire`` /
  ``tenant_lane_summary_wire`` convert the in-process objects to wire
  records.  Conversion is the ONLY place internal and wire names may
  differ (``resident`` -> ``resident_blocks``), which is what lets the
  schema stay frozen while the runtime refactors freely.

Schema evolution is additive only, along two paths.  Adding a whole new
``record_type`` (as the repro.obs PR did with ``runtime_span`` /
``runtime_metric``) leaves every existing shape byte-identical and is
version-neutral: consumers switch on ``record_type`` and ignore types they
do not know, while old validators reject the new types loudly rather than
mis-parse them.  Adding a field to an *existing* shape must be optional,
bumps that shape's ``schema_version``, and existing fields never change
name, type, or units — so consumers can gate on the version.
"""
from __future__ import annotations

import json
from functools import lru_cache
from pathlib import Path
from typing import Dict, Optional

from ..faults.model import collector_for_lane

__all__ = [
    "SCHEMA_PATH", "SCHEMA_VERSION", "SchemaError", "load_schema",
    "validate_record", "epoch_record_wire", "tenant_record_wire",
    "lane_summary_wire", "tenant_lane_summary_wire",
    "runtime_span_wire", "runtime_metric_wire",
]

SCHEMA_VERSION = 1
SCHEMA_PATH = Path(__file__).with_name("telemetry.schema.json")

# run_scenario/tenant_summary cross-lane aggregate keys that live in the
# summary dict next to the per-lane rows; never part of a wire record
_SUMMARY_AGGREGATES = ("proactive_vs_nb_post_shift",
                       "prefetch_vs_hinted_post_shift_coverage")


class SchemaError(ValueError):
    """A wire record does not conform to the frozen telemetry schema."""


@lru_cache(maxsize=1)
def load_schema() -> dict:
    """The checked-in JSON-Schema document (parsed once per process)."""
    return json.loads(SCHEMA_PATH.read_text())


# ------------------------------------------------------------ the validator
_TYPES = {
    "object": dict, "string": str, "boolean": bool,
    "array": list, "null": type(None),
}


def _deref(node: dict, schema: dict) -> dict:
    ref = node.get("$ref")
    if ref is None:
        return node
    if not ref.startswith("#/"):              # pragma: no cover - frozen doc
        raise SchemaError(f"unsupported $ref {ref!r}")
    out = schema
    for part in ref[2:].split("/"):
        out = out[part]
    return out


def _check(value, node: dict, schema: dict, path: str) -> None:
    node = _deref(node, schema)
    if "const" in node:
        if value != node["const"]:
            raise SchemaError(f"{path}: expected {node['const']!r}, "
                              f"got {value!r}")
        return
    if "enum" in node:
        if value not in node["enum"]:
            raise SchemaError(f"{path}: {value!r} not one of {node['enum']}")
        return
    typ = node.get("type")
    if typ == "integer":
        # bool is an int subclass; the schema means a real integer
        if isinstance(value, bool) or not isinstance(value, int):
            raise SchemaError(f"{path}: expected integer, got {value!r}")
    elif typ == "number":
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            raise SchemaError(f"{path}: expected number, got {value!r}")
    elif typ is not None:
        if not isinstance(value, _TYPES[typ]):
            raise SchemaError(f"{path}: expected {typ}, got {value!r}")
    if "minimum" in node and value < node["minimum"]:
        raise SchemaError(f"{path}: {value!r} < minimum {node['minimum']}")
    if "maximum" in node and value > node["maximum"]:
        raise SchemaError(f"{path}: {value!r} > maximum {node['maximum']}")
    if typ == "array":
        items = node.get("items")
        if items is not None:
            for i, element in enumerate(value):
                _check(element, items, schema, f"{path}[{i}]")
    if typ == "object":
        props = node.get("properties", {})
        addl = node.get("additionalProperties")
        for req in node.get("required", ()):
            if req not in value:
                raise SchemaError(f"{path}: missing required field {req!r}")
        if addl is False:
            extra = set(value) - set(props)
            if extra:
                raise SchemaError(f"{path}: unknown fields "
                                  f"{sorted(extra)} (the schema is frozen; "
                                  f"additive changes need a version bump)")
        elif isinstance(addl, dict):    # schema-valued: free keys, typed values
            for key in set(value) - set(props):
                _check(value[key], addl, schema, f"{path}.{key}")
        for key, sub in props.items():
            if key in value:
                _check(value[key], sub, schema, f"{path}.{key}")


def validate_record(record: dict) -> dict:
    """Check one wire record against the frozen schema; returns the record
    unchanged so emit paths can validate inline.  Raises
    :class:`SchemaError` naming the offending field path."""
    if not isinstance(record, dict):
        raise SchemaError(f"record must be a dict, got {type(record).__name__}")
    schema = load_schema()
    rtype = record.get("record_type")
    defs = schema["$defs"]
    if rtype not in defs or "record_type" not in defs[rtype].get(
            "properties", {}):
        known = sorted(d for d in defs
                       if "record_type" in defs[d].get("properties", {}))
        raise SchemaError(f"record_type: {rtype!r} not one of {known}")
    _check(record, defs[rtype], schema, f"${rtype}")
    return record


# ------------------------------------------------------- wire conversions
def _with_scenario(rec: dict, scenario: Optional[str]) -> dict:
    if scenario is not None:
        rec["scenario"] = scenario
    return rec


def epoch_record_wire(rec, scenario: Optional[str] = None) -> dict:
    """:class:`~repro.core.runtime.EpochRecord` -> frozen wire record.
    ``rec`` is duck-typed (attribute access only) so this package never
    imports ``repro.core``."""
    return _with_scenario({
        "record_type": "epoch",
        "schema_version": SCHEMA_VERSION,
        "epoch": int(rec.epoch),
        "lane": rec.lane,
        "collector": collector_for_lane(rec.lane),
        "time_s": float(rec.time_s),
        "access_s": float(rec.access_s),
        "host_tax_s": float(rec.host_tax_s),
        "migration_s": float(rec.migration_s),
        "hidden_s": float(rec.hidden_s),
        "accuracy": float(rec.accuracy),
        "coverage": float(rec.coverage),
        "quality": float(rec.quality),
        "resident_blocks": int(rec.resident),
        "promoted_blocks": int(rec.promoted),
        "demoted_blocks": int(rec.demoted),
        "host_events_count": float(rec.host_events),
    }, scenario)


def tenant_record_wire(rec, scenario: Optional[str] = None) -> dict:
    """:class:`~repro.fleet.accounting.TenantRecord` -> wire record."""
    return _with_scenario({
        "record_type": "tenant",
        "schema_version": SCHEMA_VERSION,
        "epoch": int(rec.epoch),
        "lane": rec.lane,
        "tenant": rec.tenant,
        "time_s": float(rec.time_s),
        "access_s": float(rec.access_s),
        "host_tax_s": float(rec.host_tax_s),
        "migration_s": float(rec.migration_s),
        "accuracy": float(rec.accuracy),
        "coverage": float(rec.coverage),
        "resident_blocks": int(rec.resident),
        "promoted_blocks": int(rec.promoted),
        "demoted_blocks": int(rec.demoted),
        "n_fast_accesses_count": float(rec.n_fast),
        "n_slow_accesses_count": float(rec.n_slow),
        "hot_k_blocks": int(rec.hot_k),
    }, scenario)


def lane_summary_wire(lane: str, summary: Dict[str, object],
                      scenario: Optional[str] = None) -> dict:
    """One lane's ``run_scenario``/``run_online`` summary dict -> wire
    record.  The summary dict is already schema-conformant field-for-field
    (units in names), so this only stamps the envelope."""
    rec = {"record_type": "lane_summary", "schema_version": SCHEMA_VERSION,
           "lane": lane}
    rec.update(summary)
    return _with_scenario(rec, scenario)


def tenant_lane_summary_wire(tenant: str, lane: str,
                             summary: Dict[str, object],
                             scenario: Optional[str] = None) -> dict:
    """One tenant x lane row of ``fleet.accounting.tenant_summary`` ->
    wire record."""
    rec = {"record_type": "tenant_lane_summary",
           "schema_version": SCHEMA_VERSION, "tenant": tenant, "lane": lane}
    rec.update(summary)
    return _with_scenario(rec, scenario)


def runtime_span_wire(span, scenario: Optional[str] = None) -> dict:
    """:class:`repro.obs.trace.Span` -> wire record.  ``span`` is
    duck-typed (``name``/``t0_s``/``dur_s``/``tid``/``depth``/``epoch``/
    ``args`` attributes) so this package never imports ``repro.obs``.
    Seconds become the wire's ``_us`` fields; a ``record_sync`` span's
    drained window (``epoch_base``/``n_epochs`` args) rides along so
    timeline consumers can rebuild the device track."""
    rec = {
        "record_type": "runtime_span",
        "schema_version": SCHEMA_VERSION,
        "span": str(span.name),
        "track": str(span.tid),
        "t_start_us": float(span.t0_s) * 1e6,
        "duration_us": max(float(span.dur_s), 0.0) * 1e6,
        "depth": int(span.depth),
    }
    if span.epoch is not None:
        rec["epoch"] = int(span.epoch)
    args = span.args or {}
    if "epoch_base" in args:
        rec["epoch_base"] = int(args["epoch_base"])
    if "n_epochs" in args:
        rec["n_epochs_count"] = int(args["n_epochs"])
    return _with_scenario(rec, scenario)


def runtime_metric_wire(metric: str, kind: str, value=None, *,
                        labels: Optional[Dict[str, str]] = None,
                        bucket_le=None, bucket_counts=None,
                        sum_value=None, observations=None,
                        scenario: Optional[str] = None) -> dict:
    """One registry metric sample -> wire record.  Counters/gauges carry
    ``value``; histograms carry the full bounded-bucket state
    (``bucket_le`` upper bounds, ``bucket_counts`` with the trailing
    overflow bucket, ``sum``/``observations_count``).  Label values are
    coerced to strings — the wire's ``labels`` map is string-to-string."""
    rec: Dict[str, object] = {
        "record_type": "runtime_metric",
        "schema_version": SCHEMA_VERSION,
        "metric": str(metric),
        "kind": str(kind),
    }
    if labels:
        rec["labels"] = {str(k): str(v) for k, v in labels.items()}
    if value is not None:
        rec["value"] = float(value)
    if bucket_le is not None:
        rec["bucket_le"] = [float(b) for b in bucket_le]
    if bucket_counts is not None:
        rec["bucket_counts"] = [int(c) for c in bucket_counts]
    if sum_value is not None:
        rec["sum"] = float(sum_value)
    if observations is not None:
        rec["observations_count"] = int(observations)
    return _with_scenario(rec, scenario)
