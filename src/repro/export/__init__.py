"""repro.export — non-blocking telemetry export plane.

Everything the runtime already measures (per-epoch lane records, per-tenant
rows, collector quality, run summaries) leaves the process through this
package, under two hard guarantees:

1. **Frozen wire schema** (``schema.py`` + ``telemetry.schema.json``):
   units encoded in field names, JSON-Schema checked in, every emitted
   record validated.  Internal dataclasses may refactor; the wire form may
   only grow optional fields with a version bump.
2. **Zero cost to the observed system** (``client.py`` + ``sinks.py``):
   the epoch loop's contribution is one non-blocking enqueue at the
   existing ``sync_every=K`` record-sync boundary — no extra device
   dispatch, bit-identical trajectories export-on vs export-off, and a
   circuit breaker that degrades a failing sink to noop instead of ever
   blocking or raising into ``run()``.

Typical use::

    from repro.export import ExportClient, JsonlSink
    client = ExportClient(JsonlSink("results/telemetry.jsonl"))
    out = run_scenario(scenario, export=client)
    client.close()

See ``docs/telemetry_schema.md`` for the frozen field/type/units table.
"""
from .client import CircuitBreaker, ExportClient, NoopClient
from .schema import (SCHEMA_PATH, SCHEMA_VERSION, SchemaError, load_schema,
                     validate_record, epoch_record_wire, tenant_record_wire,
                     lane_summary_wire, tenant_lane_summary_wire,
                     runtime_span_wire, runtime_metric_wire)
from .sinks import JsonlSink, MemorySink, PrometheusTextSink, SinkError

__all__ = [
    "CircuitBreaker", "ExportClient", "NoopClient",
    "SCHEMA_PATH", "SCHEMA_VERSION", "SchemaError", "load_schema",
    "validate_record", "epoch_record_wire", "tenant_record_wire",
    "lane_summary_wire", "tenant_lane_summary_wire",
    "runtime_span_wire", "runtime_metric_wire",
    "JsonlSink", "MemorySink", "PrometheusTextSink", "SinkError",
]
