"""InternLM2-1.8B [dense]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544 [arXiv:2403.17297; hf]."""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b", family="attn",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=92544, rope="rope", rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b-smoke", family="attn",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128, rope="rope", rope_theta=1e6,
    )
