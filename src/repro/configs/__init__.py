"""Architecture registry: --arch <id> resolves here.

Each module defines ``config()`` (the exact published configuration) and
``smoke_config()`` (same family/features, reduced dims, for CPU smoke tests).
Optional per-arch attributes: ``SHARDING_OVERRIDES`` (logical->mesh axis
remaps), ``OPTIMIZER`` ("adamw" | "adafactor").
"""
from __future__ import annotations

import importlib
from typing import Dict

ARCH_IDS = [
    "musicgen-medium",
    "rwkv6-3b",
    "llama3.2-3b",
    "qwen2-0.5b",
    "internlm2-1.8b",
    "yi-9b",
    "qwen2-vl-72b",
    "mixtral-8x22b",
    "kimi-k2-1t-a32b",
    "zamba2-2.7b",
]

_MODULES: Dict[str, str] = {
    "musicgen-medium": "musicgen_medium",
    "rwkv6-3b": "rwkv6_3b",
    "llama3.2-3b": "llama3_2_3b",
    "qwen2-0.5b": "qwen2_0_5b",
    "internlm2-1.8b": "internlm2_1_8b",
    "yi-9b": "yi_9b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "mixtral-8x22b": "mixtral_8x22b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "zamba2-2.7b": "zamba2_2_7b",
}


def arch_module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str):
    return arch_module(arch_id).config()


def get_smoke_config(arch_id: str):
    return arch_module(arch_id).smoke_config()


def get_optimizer_name(arch_id: str) -> str:
    return getattr(arch_module(arch_id), "OPTIMIZER", "adamw")


def get_sharding_overrides(arch_id: str) -> dict:
    return getattr(arch_module(arch_id), "SHARDING_OVERRIDES", {})
