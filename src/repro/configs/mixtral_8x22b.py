"""Mixtral-8x22B [moe]: 8 experts top-2, SWA. 56L d_model=6144 48H (kv=8)
expert d_ff=16384 vocab=32768 [arXiv:2401.04088; hf].

Sharding: 8 experts do not divide the 16-way model axis, so experts stay
replicated across "model" and the expert d_ff is tensor-parallel instead
(SHARDING_OVERRIDES below)."""
from repro.models.model import ModelConfig, MoECfg

SHARDING_OVERRIDES = {"experts": None, "expert_mlp": "model"}


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16384, vocab_size=32768, window=4096,
        moe=MoECfg(n_experts=8, top_k=2, d_expert=16384),
        rope="rope", rope_theta=1e6, sub_quadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128, window=32,
        moe=MoECfg(n_experts=4, top_k=2, d_expert=64),
        rope="rope", rope_theta=1e6, sub_quadratic=True,
    )
