"""Zamba2-2.7B [hybrid]: Mamba2 backbone + shared attention block (with
per-invocation LoRA) every 6 layers. 54L d_model=2560 32H (kv=32, MHA)
d_ff=10240 ssm_state=64 [arXiv:2411.15242; hf]."""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="zamba2",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
        d_ff=10240, vocab_size=32000, ssm_state=64, zamba_attn_every=6,
        rope="rope", sub_quadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke", family="zamba2",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=128, ssm_state=16, zamba_attn_every=2,
        rope="rope", sub_quadratic=True,
    )
