"""Yi-9B [dense]: llama-arch GQA. 48L d_model=4096 32H (kv=4) d_ff=11008
vocab=64000 [arXiv:2403.04652; hf]."""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b", family="attn",
        n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=11008, vocab_size=64000, rope="rope", rope_theta=5e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b-smoke", family="attn",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128, rope="rope", rope_theta=5e6,
    )
