"""RWKV6-3B "Finch" [ssm]: attention-free, data-dependent decay.
32L d_model=2560 d_ff=8960 vocab=65536 [arXiv:2404.05892; hf]."""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="rwkv6",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
        d_ff=8960, vocab_size=65536, rope="none", sub_quadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-smoke", family="rwkv6",
        n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, head_dim=64,
        d_ff=256, vocab_size=128, rope="none", sub_quadratic=True,
    )
