"""Qwen2-0.5B [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936, QKV bias [arXiv:2407.10671; hf]."""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b", family="attn",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
        d_ff=4864, vocab_size=151936, qkv_bias=True, rope="rope",
        rope_theta=1e6, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b-smoke", family="attn",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128, qkv_bias=True, rope="rope",
        rope_theta=1e6, tie_embeddings=True,
    )
