"""MusicGen-medium [audio]: decoder-only over EnCodec tokens.
48L d_model=1536 24H (kv=24, MHA) d_ff=6144 vocab=2048 [arXiv:2306.05284; hf].
The EnCodec audio frontend is a STUB: input_specs provide token ids (the
frontend's output); generation decodes EnCodec codes."""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="attn",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
        d_ff=6144, vocab_size=2048, rope="rope", frontend="tokens",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke", family="attn",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128, rope="rope", frontend="tokens",
    )
