"""Kimi-K2 1T-A32B [moe]: 61L d_model=7168 64H (GQA kv=8, head_dim=112)
expert d_ff=2048, MoE 384 experts top-8 + 1 shared, vocab=163840
[arXiv:2501.kimi2; paper-table, unverified].

~1.03T params; the flagship arch for the paper's technique: top-8 of 384
experts => ~2% of expert bytes hot per token (expert tiering telemetry).
bf16 params + Adafactor: 1T fp32 AdamW state cannot fit 256 chips; see
DESIGN.md and the dry-run memory table."""
import jax.numpy as jnp
from repro.models.model import ModelConfig, MoECfg

OPTIMIZER = "adafactor"


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
        d_ff=2048, vocab_size=163840,
        moe=MoECfg(n_experts=384, top_k=8, d_expert=2048, n_shared=1),
        rope="rope", rope_theta=5e4, param_dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=128,
        moe=MoECfg(n_experts=8, top_k=2, d_expert=64, n_shared=1),
        rope="rope", rope_theta=5e4, param_dtype=jnp.bfloat16,
    )
