"""Llama-3.2-3B [dense]: 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256 [hf:meta-llama/Llama-3.2-3B; unverified]."""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b", family="attn",
        n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=128256, rope="rope", rope_theta=500000.0,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b-smoke", family="attn",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128, rope="rope", rope_theta=500000.0,
    )
