"""Qwen2-VL-72B [vlm]: M-RoPE, dynamic resolution. 80L d_model=8192 64H
(GQA kv=8) d_ff=29568 vocab=152064 [arXiv:2409.12191; hf].
The vision tower is a STUB: input_specs provide precomputed patch
embeddings (B, S, d_model) plus 3D (t,h,w) M-RoPE position ids."""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", family="attn",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=29568, vocab_size=152064, qkv_bias=True,
        rope="mrope", rope_theta=1e6, frontend="embeddings",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b-smoke", family="attn",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128, qkv_bias=True,
        rope="mrope", rope_theta=1e6, frontend="embeddings",
    )
