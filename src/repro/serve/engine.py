"""Prefill + single-token decode for every architecture family.

Caches (all functional pytrees; leading L dim scanned):
  attn/moe : {"k","v": (L,B,KVH,S,hd), "pos": (B,)}
  rwkv6    : {"wkv": (L,B,H,hd,hd) f32, "sh_mix","sh_ffn": (L,B,D), "pos"}
  zamba2   : {"ssm": (L,B,H,P,N) f32, "conv": (L,B,3,convC),
              "k","v": (ninv,B,KVH,S,hd), "pos"}

The decode path optionally emits per-KV-page attention-mass telemetry
(``page_size``>0) — the serving-side HMU feed for the tiered KV cache.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import attention as attn_lib
from ..models.layers import AttnParams, apply_rope, rms_norm, swiglu
from ..models.model import ModelConfig, constrain_batch, transformer_block, \
    rwkv6_block, zamba2_mamba_block, zamba2_shared_attention, logits_fn
from ..models.moe import MoEParams, moe_block
from ..models.rwkv6 import (RWKV6FFNParams, RWKV6Params, rwkv6_channel_mix_step,
                            rwkv6_mix, rwkv6_mix_step)
from ..models.mamba2 import Mamba2Params, mamba2_mix, mamba2_mix_step

Cache = Dict[str, Any]


# ================================================================ cache init
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Cache:
    dtype = dtype or cfg.activ_dtype
    L, kvh, hd, d = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    pos = jnp.zeros((batch,), jnp.int32)
    if cfg.family in ("attn", "moe"):
        return {
            "k": jnp.zeros((L, batch, kvh, max_len, hd), dtype),
            "v": jnp.zeros((L, batch, kvh, max_len, hd), dtype),
            "pos": pos,
        }
    if cfg.family == "rwkv6":
        h = d // 64
        return {
            "wkv": jnp.zeros((L, batch, h, 64, 64), jnp.float32),
            "sh_mix": jnp.zeros((L, batch, d), dtype),
            "sh_ffn": jnp.zeros((L, batch, d), dtype),
            "pos": pos,
        }
    if cfg.family == "zamba2":
        convc = cfg.d_inner + 2 * cfg.ssm_state
        ninv = cfg.n_shared_attn
        return {
            "ssm": jnp.zeros((L, batch, cfg.mamba_heads,
                              cfg.d_inner // cfg.mamba_heads, cfg.ssm_state),
                             jnp.float32),
            "conv": jnp.zeros((L, batch, 3, convc), dtype),
            "k": jnp.zeros((ninv, batch, kvh, max_len, hd), dtype),
            "v": jnp.zeros((ninv, batch, kvh, max_len, hd), dtype),
            "pos": pos,
        }
    raise ValueError(cfg.family)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> Cache:
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


# ================================================================== prefill
def prefill(params: dict, cfg: ModelConfig, tokens=None, embeds=None,
            positions=None, max_len: Optional[int] = None
            ) -> Tuple[jax.Array, Cache]:
    """Full-sequence pass that also builds the cache.
    Returns (last-token logits (B, V), cache)."""
    # Per-path attention schedule: the triangular unrolled schedule wins at
    # training (-17% FLOPs) but its 64 unrolled Q-blocks interact with the
    # seq-sharded cache stacking to emit thousands of collective-permutes at
    # prefill (17x collective bytes, §Perf B5) — prefill uses the masked
    # online-softmax scan instead.
    import dataclasses as _dc
    if cfg.causal_schedule == "triangular":
        cfg = _dc.replace(cfg, causal_schedule="masked")
    if embeds is not None:
        x = embeds.astype(cfg.activ_dtype)
    else:
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activ_dtype)
    b, s, _ = x.shape
    max_len = max_len or s
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if cfg.rope == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, b, s))
    cache = init_cache(cfg, b, max_len)
    cache["pos"] = jnp.full((b,), s, jnp.int32)

    if cfg.family in ("attn", "moe"):
        def body(x, bp):
            x = constrain_batch(x, cfg)
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            from ..models.layers import attention_block
            h, (k, v) = attention_block(
                h, AttnParams(bp["wq"], bp["wk"], bp["wv"], bp["wo"],
                              bp.get("bq"), bp.get("bk"), bp.get("bv")),
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim, positions=positions,
                rope_mode=cfg.rope, rope_theta=cfg.rope_theta,
                window=cfg.window, causal_schedule=cfg.causal_schedule,
                block_k=cfg.attn_block_k, return_kv=True)
            x = x + h
            h = rms_norm(x, bp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                mp = MoEParams(bp["router"], bp["e_gate"], bp["e_up"],
                               bp["e_down"], bp.get("s_gate"), bp.get("s_up"),
                               bp.get("s_down"))
                bax = None
                if cfg.act_batch_axes:
                    bax = (tuple(cfg.act_batch_axes)
                           if len(cfg.act_batch_axes) > 1
                           else cfg.act_batch_axes[0])
                h, _ = moe_block(
                    h, mp, top_k=cfg.moe.top_k,
                    capacity_factor=cfg.moe.capacity_factor,
                    groups=cfg.moe_groups or (1, 1), batch_axes=bax,
                    expert_sharded=cfg.moe_expert_sharded)
            else:
                h = swiglu(h, bp["w_gate"], bp["w_up"], bp["w_down"])
            return x + h, (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
        pad = max_len - s
        cache["k"] = jnp.pad(ks, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))) \
            .astype(cache["k"].dtype)
        cache["v"] = jnp.pad(vs, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))) \
            .astype(cache["v"].dtype)

    elif cfg.family == "rwkv6":
        def body(x, bp):
            xn = rms_norm(x, bp["ln1"], cfg.norm_eps)
            x2, st = rwkv6_block(x, bp, cfg)
            xn2 = rms_norm(x2, bp["ln2"], cfg.norm_eps)
            return x2, (st, xn[:, -1], xn2[:, -1])
        x, (sts, shm, shf) = jax.lax.scan(body, x, params["blocks"])
        cache["wkv"], cache["sh_mix"], cache["sh_ffn"] = sts, shm, shf

    elif cfg.family == "zamba2":
        every, ninv = cfg.zamba_attn_every, cfg.n_shared_attn
        grouped = jax.tree.map(
            lambda t: t.reshape((ninv, every) + t.shape[1:]), params["blocks"])
        ssms, convs, kss, vss = [], [], [], []
        # python loop over invocations (ninv is small) keeps shared-attn KV
        # capture simple; mamba layers inside still scan
        for inv in range(ninv):
            gp = jax.tree.map(lambda t: t[inv], grouped)

            def inner(x, bp):
                xn = rms_norm(x, bp["ln1"], cfg.norm_eps)
                x2, st = zamba2_mamba_block(x, bp, cfg)
                # conv state: last 3 pre-conv inputs
                dt_ = x.dtype
                zxbcdt = jnp.einsum("bsd,de->bse", xn, bp["in_proj"].astype(dt_))
                di, n = cfg.d_inner, cfg.ssm_state
                xin = zxbcdt[..., di:2 * di + 2 * n]
                conv_tail = xin[:, -3:]
                return x2, (st, conv_tail)

            x, (st_g, conv_g) = jax.lax.scan(inner, x, gp)
            ssms.append(st_g)
            convs.append(conv_g)
            # shared attention with KV capture
            sp = params["shared_attn"]
            x, (k, v) = _zamba_shared_attn_kv(x, sp, cfg, inv, positions)
            kss.append(k)
            vss.append(v)
        cache["ssm"] = jnp.concatenate(ssms, axis=0)
        conv = jnp.concatenate(convs, axis=0)
        cache["conv"] = conv.astype(cache["conv"].dtype)
        pad = max_len - s
        cache["k"] = jnp.pad(jnp.stack(kss), ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))) \
            .astype(cache["k"].dtype)
        cache["v"] = jnp.pad(jnp.stack(vss), ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))) \
            .astype(cache["v"].dtype)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, cfg, x[:, -1:])[:, 0]
    return logits, cache


def _zamba_shared_attn_kv(x, sp, cfg, inv, positions):
    h = rms_norm(x, sp["ln"], cfg.norm_eps)
    b, s, d = h.shape
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads

    def lora(nm):
        a = sp[f"lora_{nm}_a"][inv]
        b_ = sp[f"lora_{nm}_b"][inv]
        return jnp.einsum("bsd,dr,re->bse", h, a.astype(h.dtype), b_.astype(h.dtype))

    def proj(w, delta, n):
        y = jnp.einsum("bsd,dh->bsh", h, w.astype(h.dtype)) + delta[..., : n * hd]
        return y.reshape(b, s, n, hd).transpose(0, 2, 1, 3)

    q = proj(sp["wq"], lora("q"), nh)
    k = proj(sp["wk"], lora("k"), nkv)
    v = proj(sp["wv"], lora("v"), nkv)
    q = apply_rope(q, positions[:, None], cfg.rope_theta)
    k = apply_rope(k, positions[:, None], cfg.rope_theta)
    o = attn_lib.flash_train(q, k, v, causal=True, window=cfg.window,
                             causal_schedule=cfg.causal_schedule,
                             block_k=cfg.attn_block_k)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
    x = x + jnp.einsum("bsh,hd->bsd", o, sp["wo"].astype(h.dtype))
    hm = rms_norm(x, sp["ln_mlp"], cfg.norm_eps)
    x = x + swiglu(hm, sp["w_gate"], sp["w_up"], sp["w_down"])
    return x, (k, v)


# ================================================================ decode
def decode_step(params: dict, cfg: ModelConfig, cache: Cache,
                tokens: jax.Array, page_size: int = 0
                ) -> Tuple[jax.Array, Cache, Dict[str, Any]]:
    """One token for every sequence in the batch.
    tokens: (B,) int32. Returns (logits (B,V), cache, telemetry aux)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activ_dtype)  # (B,D)
    pos = cache["pos"]
    b = x.shape[0]
    aux: Dict[str, Any] = {}

    if cfg.family in ("attn", "moe"):
        def body(carry, xs):
            x = constrain_batch(carry, cfg)
            bp, kc, vc = xs
            h = rms_norm(x[:, None], bp["ln1"], cfg.norm_eps)[:, 0]
            hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads

            def proj(w, bias, n):
                y = jnp.einsum("bd,dh->bh", h, w.astype(h.dtype))
                if bias is not None:
                    y = y + bias.astype(h.dtype)
                return y.reshape(b, n, hd)

            q = proj(bp["wq"], bp.get("bq"), nh)
            k = proj(bp["wk"], bp.get("bk"), nkv)
            v = proj(bp["wv"], bp.get("bv"), nkv)
            if cfg.rope in ("rope", "mrope"):
                # mrope degenerates to 1-D rope at decode (text position)
                q = apply_rope(q[:, :, None, :], pos[:, None, None],
                               cfg.rope_theta)[:, :, 0]
                k = apply_rope(k[:, :, None, :], pos[:, None, None],
                               cfg.rope_theta)[:, :, 0]
            kc, vc = attn_lib.update_kv_cache(kc, vc, k, v, pos)
            if page_size:
                o, mass = attn_lib.decode_step(q, kc, vc, pos, window=cfg.window,
                                               page_size=page_size)
            else:
                o = attn_lib.decode_step(q, kc, vc, pos, window=cfg.window)
                mass = jnp.zeros((b, 1), jnp.float32)
            o = o.reshape(b, nh * hd)
            x = x + jnp.einsum("bh,hd->bd", o, bp["wo"].astype(h.dtype))
            h2 = rms_norm(x[:, None], bp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                mp = MoEParams(bp["router"], bp["e_gate"], bp["e_up"],
                               bp["e_down"], bp.get("s_gate"), bp.get("s_up"),
                               bp.get("s_down"))
                h2, moe_aux = moe_block(h2, mp, top_k=cfg.moe.top_k,
                                        capacity_factor=4.0)
                counts = moe_aux["counts"]
            else:
                h2 = swiglu(h2, bp["w_gate"], bp["w_up"], bp["w_down"])
                counts = jnp.zeros((1,), jnp.int32)
            x = x + h2[:, 0]
            return x, (kc, vc, mass, counts)

        x, (ks, vs, mass, counts) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"]))
        cache = dict(cache, k=ks, v=vs, pos=pos + 1)
        aux["kv_page_mass"] = mass          # (L, B, npages)
        if cfg.family == "moe":
            aux["expert_counts"] = counts   # (L, E)

    elif cfg.family == "rwkv6":
        def body(carry, xs):
            x = carry
            bp, st, shm, shf = xs
            p = RWKV6Params(bp["tm_mu"], bp["tm_lora_a"], bp["tm_lora_b"],
                            bp["w0"], bp["w_lora_a"], bp["w_lora_b"], bp["u"],
                            bp["wr"], bp["wk"], bp["wv"], bp["wg"], bp["wo"],
                            bp["ln_x"])
            xn = rms_norm(x[:, None], bp["ln1"], cfg.norm_eps)[:, 0]
            h, st = rwkv6_mix_step(xn, shm, st, p, n_heads=cfg.d_model // 64)
            x = x + h
            xn2 = rms_norm(x[:, None], bp["ln2"], cfg.norm_eps)[:, 0]
            fp = RWKV6FFNParams(bp["f_mu_k"], bp["f_mu_r"], bp["f_wk"],
                                bp["f_wv"], bp["f_wr"])
            x = x + rwkv6_channel_mix_step(xn2, shf, fp)
            return x, (st, xn, xn2)

        x, (sts, shm, shf) = jax.lax.scan(
            body, x, (params["blocks"], cache["wkv"], cache["sh_mix"],
                      cache["sh_ffn"]))
        cache = dict(cache, wkv=sts, sh_mix=shm, sh_ffn=shf, pos=pos + 1)

    elif cfg.family == "zamba2":
        every, ninv = cfg.zamba_attn_every, cfg.n_shared_attn
        grouped = jax.tree.map(
            lambda t: t.reshape((ninv, every) + t.shape[1:]), params["blocks"])
        ssm = cache["ssm"].reshape((ninv, every) + cache["ssm"].shape[1:])
        conv = cache["conv"].reshape((ninv, every) + cache["conv"].shape[1:])
        new_ssm, new_conv, new_k, new_v = [], [], [], []
        for inv in range(ninv):
            gp = jax.tree.map(lambda t: t[inv], grouped)

            def inner(carry, xs):
                x = carry
                bp, st, cv = xs
                p = Mamba2Params(bp["in_proj"], bp["conv_w"], bp["conv_b"],
                                 bp["a_log"], bp["d_skip"], bp["dt_bias"],
                                 bp["norm"], bp["out_proj"])
                xn = rms_norm(x[:, None], bp["ln1"], cfg.norm_eps)[:, 0]
                h, cv, st = mamba2_mix_step(
                    xn, cv, st, p, d_inner=cfg.d_inner,
                    n_heads=cfg.mamba_heads, d_state=cfg.ssm_state)
                return x + h, (st, cv)

            x, (st_g, cv_g) = jax.lax.scan(
                inner, x, (gp, ssm[inv], conv[inv]))
            new_ssm.append(st_g)
            new_conv.append(cv_g)
            x, k, v = _zamba_shared_attn_decode(
                x, params["shared_attn"], cfg, inv, cache["k"][inv],
                cache["v"][inv], pos)
            new_k.append(k)
            new_v.append(v)
        cache = dict(
            cache,
            ssm=jnp.concatenate(new_ssm, 0), conv=jnp.concatenate(new_conv, 0),
            k=jnp.stack(new_k), v=jnp.stack(new_v), pos=pos + 1,
        )

    x = rms_norm(x[:, None], params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, cfg, x)[:, 0]
    return logits, cache, aux


def kv_page_geometry(cfg: ModelConfig, batch: int, max_len: int,
                     page_size: int) -> Dict[str, int]:
    """Page-space geometry of a tiered KV cache: how the decode loop's
    ``kv_page_mass`` telemetry maps onto tiering blocks.

    Each ``(layer, sequence, page)`` triple is one block — the unit the
    serving engine can independently place in HBM or host memory.  Pages are
    ceil-divided (``pages_per_seq``), so a ``max_len`` that is not a page
    multiple gets a ragged final page.  ``bytes_per_access`` is one attended
    position's K+V read; ``block_bytes`` one full page of K+V."""
    if cfg.family not in ("attn", "moe"):
        raise ValueError(f"kv_page_mass telemetry needs a KV cache; "
                         f"family {cfg.family!r} has none")
    pages_per_seq = -(-max_len // page_size)
    kv_item = jnp.dtype(cfg.activ_dtype).itemsize
    pos_bytes = 2 * cfg.n_kv_heads * cfg.head_dim * kv_item    # K + V
    return {
        "n_blocks": cfg.n_layers * batch * pages_per_seq,
        "pages_per_seq": pages_per_seq,
        "bytes_per_access": pos_bytes,
        "block_bytes": pos_bytes * page_size,
    }


def decode_telemetry(params: dict, cfg: ModelConfig, cache: Cache,
                     tokens: jax.Array, page_size: int
                     ) -> Tuple[Cache, "np.ndarray"]:
    """Drive a multi-step decode loop and collect its KV telemetry feed.

    ``tokens`` is ``(T, B)`` — one token per sequence per step.  Each step is
    one jit'd :func:`decode_step` with ``page_size`` telemetry on; the
    per-step ``kv_page_mass`` arrays are stacked into ``(T, L, B,
    pages_per_seq)`` host floats — the access-mass stream a
    :class:`repro.scenarios.kv_cache.KVCacheScenario` quantizes into the
    EpochRuntime's page-index batches.  Returns ``(final cache, mass)``."""
    import numpy as np

    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t,
                                               page_size=page_size))
    masses = []
    for t in tokens:
        _, cache, aux = step(params, cache, t)
        masses.append(aux["kv_page_mass"])
    return cache, np.asarray(jnp.stack(masses), np.float64)


def _zamba_shared_attn_decode(x, sp, cfg, inv, kc, vc, pos):
    b, d = x.shape
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    h = rms_norm(x[:, None], sp["ln"], cfg.norm_eps)[:, 0]

    def lora(nm):
        a, b_ = sp[f"lora_{nm}_a"][inv], sp[f"lora_{nm}_b"][inv]
        return jnp.einsum("bd,dr,re->be", h, a.astype(h.dtype), b_.astype(h.dtype))

    def proj(w, delta, n):
        y = jnp.einsum("bd,dh->bh", h, w.astype(h.dtype)) + delta[..., : n * hd]
        return y.reshape(b, n, hd)

    q = proj(sp["wq"], lora("q"), nh)
    k = proj(sp["wk"], lora("k"), nkv)
    v = proj(sp["wv"], lora("v"), nkv)
    q = apply_rope(q[:, :, None, :], pos[:, None, None], cfg.rope_theta)[:, :, 0]
    k = apply_rope(k[:, :, None, :], pos[:, None, None], cfg.rope_theta)[:, :, 0]
    kc, vc = attn_lib.update_kv_cache(kc, vc, k, v, pos)
    o = attn_lib.decode_step(q, kc, vc, pos, window=cfg.window)
    o = o.reshape(b, nh * hd)
    x = x + jnp.einsum("bh,hd->bd", o, sp["wo"].astype(h.dtype))
    hm = rms_norm(x[:, None], sp["ln_mlp"], cfg.norm_eps)
    x = x + swiglu(hm, sp["w_gate"], sp["w_up"], sp["w_down"])[:, 0]
    return x, kc, vc
