"""Serving runtime: prefill/decode engines with per-family caches and the
tiered-KV telemetry hooks."""
