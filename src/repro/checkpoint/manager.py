"""Sharded, async, mesh-agnostic checkpointing.

Layout (one directory per step):
    ckpt_dir/
      step_000123.tmp/            # written first
        manifest.json              # tree structure, shapes, dtypes, step,
                                   # data-pipeline state, mesh shape at save
        arr_<idx>.npy              # one file per leaf (per-host shard in a
                                   # real multi-host run; full array here)
      step_000123/                 # atomic rename on completion -> publish

Design points for 1000+-node runs:
  * **Atomic publish**: readers only ever see complete checkpoints (tmp dir
    renamed after fsync of every file + manifest) — a preempted save never
    corrupts the latest-good pointer.
  * **Async**: `save()` snapshots to host memory synchronously (cheap) and
    writes in a background thread, overlapping the next training steps;
    `wait()` joins before the next save or exit.
  * **Elastic restore**: arrays are stored unsharded-logical (per-leaf
    global layout) with the saving mesh recorded; `restore(..., mesh=)`
    re-shards to any new mesh via jax.device_put — restart on a different
    pod count re-shards FSDP state transparently.
  * **Retention**: keep_last N checkpoints, garbage-collect older.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str | Path, keep_last: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             block: bool = False) -> None:
        """Snapshot ``tree`` (pytree of arrays) and write asynchronously."""
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        # synchronous host snapshot: training can mutate buffers afterwards
        host_leaves = [np.asarray(x) for x in leaves]
        meta = {
            "step": int(step),
            "treedef": jax.tree.unflatten(
                treedef, list(range(len(host_leaves)))),
            "extra": extra or {},
            "time": time.time(),
        }

        def _write():
            try:
                tmp = self.dir / f"step_{step:08d}.tmp"
                final = self.dir / f"step_{step:08d}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                for i, arr in enumerate(host_leaves):
                    np.save(tmp / f"arr_{i}.npy", arr)
                (tmp / "manifest.json").write_text(json.dumps({
                    "step": meta["step"],
                    "tree": _encode_tree(meta["treedef"]),
                    "n_arrays": len(host_leaves),
                    "extra": meta["extra"],
                    "time": meta["time"],
                }))
                if final.exists():
                    shutil.rmtree(final)
                tmp.rename(final)                  # atomic publish
                self._gc()
            except BaseException as e:             # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.iterdir()
                       if p.is_dir() and p.name.startswith("step_")
                       and not p.name.endswith(".tmp"))
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, shardings: Any = None
                ) -> tuple[Any, dict]:
        """Returns (tree, extra).  ``shardings``: optional pytree of
        NamedSharding to re-shard onto (elastic restore on a new mesh)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        arrays = [np.load(d / f"arr_{i}.npy")
                  for i in range(manifest["n_arrays"])]
        tree = _decode_tree(manifest["tree"], arrays)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, manifest["extra"]

    # ------------------------------------------------------------------- gc
    def _gc(self):
        steps = sorted(p for p in self.dir.iterdir()
                       if p.is_dir() and p.name.startswith("step_")
                       and not p.name.endswith(".tmp"))
        for p in steps[: -self.keep_last]:
            shutil.rmtree(p, ignore_errors=True)


def _encode_tree(t):
    if isinstance(t, dict):
        return {"__d": {k: _encode_tree(v) for k, v in t.items()}}
    if hasattr(t, "_fields"):   # namedtuple (check before tuple!)
        return {"__n": type(t).__name__,
                "__f": {k: _encode_tree(v) for k, v in t._asdict().items()}}
    if isinstance(t, (list, tuple)):
        tag = "__l" if isinstance(t, list) else "__t"
        return {tag: [_encode_tree(v) for v in t]}
    return int(t)


def _decode_tree(t, arrays):
    if isinstance(t, dict):
        if "__d" in t:
            return {k: _decode_tree(v, arrays) for k, v in t["__d"].items()}
        if "__l" in t:
            return [_decode_tree(v, arrays) for v in t["__l"]]
        if "__t" in t:
            return tuple(_decode_tree(v, arrays) for v in t["__t"])
        if "__n" in t:
            # namedtuples restore as plain dicts keyed by field (callers that
            # need the concrete type re-wrap; OptState handled in train.py)
            return {k: _decode_tree(v, arrays) for k, v in t["__f"].items()}
    return arrays[int(t)]
