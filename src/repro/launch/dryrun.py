import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  * jit-lowers the train/prefill/decode step with the schema-derived
    shardings against ShapeDtypeStruct inputs (no allocation),
  * compiles, prints memory_analysis() (proves fit) and cost_analysis()
    (FLOPs/bytes for §Roofline),
  * parses the optimized HLO for collective bytes (all-gather, all-reduce,
    reduce-scatter, all-to-all, collective-permute) -> roofline collective
    term,
  * writes one JSON record per cell to --out (results/dryrun/).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
      --shape train_4k [--multi-pod] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import gzip
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, get_config, get_optimizer_name,
                           get_sharding_overrides)
from repro.launch import sharding as sh
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.launch.shapes import SHAPES, applicable, input_specs
from repro.models.model import abstract_params, ModelConfig
from repro.optim import get_optimizer, cosine_schedule
from repro.serve import engine
from repro.train.steps import make_train_step
from repro.launch import hloanalysis

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3": 1,
                "f8e5m2": 1, "s16": 2, "u16": 2}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(hlo_type: str) -> int:
    """bytes of an HLO shape string like 'bf16[256,4096,3072]{2,1,0}'."""
    m = re.match(r"([a-z0-9]+)\[([\d,]*)\]", hlo_type)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the optimized HLO.
    Tuple shapes contribute each element."""
    out = {c: 0 for c in _COLLECTIVES}
    count = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # matches:  %name = TYPE all-gather(...)  /  ... = (T1, T2) all-reduce(
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s+([a-z\-]+)\(", stripped)
        if not m:
            continue
        op = m.group(2)
        if op.rstrip("-start") in _COLLECTIVES or op in [c + "-start" for c in _COLLECTIVES] or op in _COLLECTIVES:
            base = op[:-6] if op.endswith("-start") else op
            if base not in _COLLECTIVES:
                continue
            types = re.findall(r"[a-z0-9]+\[[\d,]*\]", m.group(1))
            total = sum(_shape_bytes(t) for t in types)
            out[base] += total
            count[base] += 1
    return {"bytes": out, "count": count,
            "total_bytes": int(sum(out.values()))}


def build_step(cfg: ModelConfig, shape, mesh, overrides):
    """Returns (jitted_fn, example_args_abstract) for the cell's step kind."""
    import dataclasses as _dc
    bax = sh.batch_axes(mesh, shape.global_batch)
    if bax is not None and not isinstance(bax, tuple):
        bax = (bax,)
    updates = dict(act_batch_axes=bax)
    if cfg.moe is not None and bax is not None:
        rules = sh.apply_overrides(sh.default_rules(mesh, cfg), overrides)
        gd = 1
        for a in bax:
            gd *= mesh.shape[a]
        gm = mesh.shape.get("model", 1)
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        if tokens % (gd * gm) == 0 and tokens // (gd * gm) >= cfg.moe.top_k:
            updates["moe_groups"] = (gd, gm)
            updates["moe_expert_sharded"] = rules.get("experts") == "model"
    cfg = _dc.replace(cfg, **updates)
    pspecs = sh.model_pspecs(mesh, cfg, overrides)
    params_abs = abstract_params(cfg)

    if shape.kind == "train":
        opt = get_optimizer(get_optimizer_name_from_cfg(cfg))
        step_fn = make_train_step(cfg, opt, cosine_schedule(3e-4, 100, 10000))
        opt_state_abs = jax.eval_shape(opt.init, params_abs)
        opt_specs = sh.opt_pspecs(pspecs, opt_state_abs)
        batch_abs = input_specs(cfg, shape)
        bspecs = sh.batch_specs(mesh, cfg, batch_abs)
        jitted = jax.jit(
            step_fn,
            # explicit NamedShardings: older jax (< 0.6) rejects raw
            # PartitionSpecs in in_shardings even under an ambient mesh
            in_shardings=sh.named(mesh, (pspecs, opt_specs, bspecs)),
            out_shardings=(*sh.named(mesh, (pspecs, opt_specs)), None),
            donate_argnums=(0, 1),
        )
        return jitted, (params_abs, opt_state_abs, batch_abs)

    if shape.kind == "prefill":
        batch_abs = input_specs(cfg, shape)
        bspecs = sh.batch_specs(mesh, cfg, batch_abs)
        cache_specs = sh.cache_pspecs(mesh, cfg, shape.global_batch,
                                      shape.seq_len)

        def fn(params, batch):
            return engine.prefill(params, cfg, tokens=batch.get("tokens"),
                                  embeds=batch.get("embeds"),
                                  positions=batch.get("positions"))

        jitted = jax.jit(fn, in_shardings=sh.named(mesh, (pspecs, bspecs)),
                         out_shardings=sh.named(
                             mesh, (sh.batch_pspec(mesh, shape.global_batch),
                                    cache_specs)))
        return jitted, (params_abs, batch_abs)

    # decode
    cache_abs = engine.abstract_cache(cfg, shape.global_batch, shape.seq_len)
    cache_specs = sh.cache_pspecs(mesh, cfg, shape.global_batch, shape.seq_len)
    tok_abs = input_specs(cfg, shape)["tokens"]
    bspec = P(sh.batch_axes(mesh, shape.global_batch))

    def fn(params, cache, tokens):
        logits, cache, _ = engine.decode_step(params, cfg, cache, tokens)
        return logits, cache

    jitted = jax.jit(fn, in_shardings=sh.named(mesh, (pspecs, cache_specs, bspec)),
                     out_shardings=sh.named(mesh, (bspec, cache_specs)),
                     donate_argnums=(1,))
    return jitted, (params_abs, cache_abs, tok_abs)


def get_optimizer_name_from_cfg(cfg) -> str:
    # adafactor for the 1T cell (see configs/kimi_k2_1t_a32b.py)
    return "adafactor" if cfg.name.startswith("kimi") else "adamw"


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             cfg_override=None, save_hlo: bool = False,
             cfg_updates: dict | None = None) -> dict:
    cfg = cfg_override or get_config(arch)
    if cfg_updates:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **cfg_updates)
    shape = SHAPES[shape_name]
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "skipped", "reason": None,
    }
    if not applicable(cfg, shape):
        rec["reason"] = "long_500k skipped: pure full-attention arch (DESIGN.md §5)"
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    overrides = get_sharding_overrides(arch)
    with use_mesh(mesh):
        jitted, args = build_step(cfg, shape, mesh, overrides)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)          # raw (body-once) counts
        executed = hloanalysis.analyze(hlo)   # trip-count-aware totals

    n_dev = mesh.devices.size
    rec.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        devices=n_dev,
        # raw cost_analysis (NOTE: while bodies counted once — see
        # hloanalysis; the "executed" block is the trip-count-aware truth)
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        executed=executed,
        collectives=coll,
        memory={
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0),
        },
    )
    out_dir.mkdir(parents=True, exist_ok=True)
    fname = f"{arch.replace('.', '_')}__{shape_name}__{rec['mesh']}.json"
    (out_dir / fname).write_text(json.dumps(rec, indent=1))
    if save_hlo:
        with gzip.open(out_dir / (fname[:-5] + ".hlo.gz"), "wt") as f:
            f.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (e.g. remat=dots)")
    args = ap.parse_args()
    cfg_updates = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        cfg_updates[k] = int(v) if v.isdigit() else v

    out_dir = Path(args.out)
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
            try:
                rec = run_cell(arch, shape, mp, out_dir,
                               save_hlo=args.save_hlo,
                               cfg_updates=cfg_updates or None)
                if rec["status"] == "ok":
                    m = rec["memory"]
                    ex = rec["executed"]
                    print(f"[ok]   {tag}: compile={rec['compile_s']}s "
                          f"exflops={ex['flops']:.3e} "
                          f"excoll={ex['collective_total_bytes']:.3e}B "
                          f"args={m['argument_bytes']/1e9:.2f}GB "
                          f"temp={m['temp_bytes']/1e9:.2f}GB", flush=True)
                else:
                    print(f"[skip] {tag}: {rec['reason']}", flush=True)
            except Exception as e:
                failures += 1
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
