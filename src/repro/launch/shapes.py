"""Assigned input shapes and per-arch applicability (the 40-cell grid).

  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> serve prefill
  decode_32k   seq 32,768  global_batch 128   -> serve decode (1 new token)
  long_500k    seq 524,288 global_batch 1     -> serve decode, sub-quadratic
                                                 archs only (see DESIGN.md §5)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..models.model import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    """long_500k only for sub-quadratic archs (SSM / hybrid / SWA) — the
    7 pure full-attention archs skip it (documented in DESIGN.md §5)."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
    shardable, no device allocation)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    if shape.kind == "train":
        specs = {"labels": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.frontend == "embeddings":
            specs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
            if cfg.rope == "mrope":
                specs["positions"] = jax.ShapeDtypeStruct((3, b, s), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        return specs

    if shape.kind == "prefill":
        if cfg.frontend == "embeddings":
            specs = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)}
            if cfg.rope == "mrope":
                specs["positions"] = jax.ShapeDtypeStruct((3, b, s), i32)
            return specs
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}

    if shape.kind == "decode":
        # one new token against a seq_len-deep cache (cache specs built by
        # serve.engine.abstract_cache)
        return {"tokens": jax.ShapeDtypeStruct((b,), i32)}

    raise ValueError(shape.kind)
