"""Logical-axis -> mesh-axis rules and input/cache/opt-state shardings.

The model schema labels every parameter dim with a *logical* axis
("embed", "heads", "mlp", "vocab", "experts", ...).  One rules table maps
those to physical mesh axes; per-arch overrides (e.g. Mixtral's experts)
come from the config module.  Batch/cache shardings are derived here too,
so dryrun/train/serve all agree.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.model import ModelConfig, param_pspecs


def default_rules(mesh: Mesh, cfg: ModelConfig) -> Dict[Optional[str], object]:
    """FSDP over "data", tensor parallel over "model", DP over "pod"+"data".

    kv_heads shard over "model" only when divisible; experts shard over
    "model" when divisible (EP), else expert-TP via the d_expert axis.
    """
    model_size = mesh.shape.get("model", 1)
    rules: Dict[Optional[str], object] = {
        None: None,
        "layers": None,
        "embed": "data",          # FSDP / ZeRO-3: gather at use
        "heads": "model",
        "kv_heads": "model" if cfg.n_kv_heads % model_size == 0 else None,
        "mlp": "model",
        "vocab": "model",
        "experts": None,
        "expert_mlp": "model",
    }
    if cfg.moe is not None and cfg.moe.n_experts % model_size == 0:
        rules["experts"] = "model"     # expert parallelism
        rules["expert_mlp"] = None
    # heads not divisible by model axis (e.g. qwen2 14H, musicgen 24H on 16):
    # fall back to FSDP-only sharding for head-dims
    if (cfg.n_heads * cfg.head_dim) % model_size != 0:
        rules["heads"] = None
    if cfg.n_heads % model_size != 0 and (cfg.n_heads * cfg.head_dim) % model_size == 0:
        # shard the fused head*dim axis anyway (it is a single matrix dim)
        rules["heads"] = "model"
    return rules


def apply_overrides(rules: dict, overrides: dict) -> dict:
    out = dict(rules)
    out.update(overrides)
    return out


def batch_axes(mesh: Mesh, batch_size: int | None = None):
    """Mesh axes the batch dim shards over: the largest prefix of
    ("pod","data") whose size divides the batch (None if nothing fits —
    e.g. long_500k's global_batch=1)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if batch_size is not None:
        while axes:
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            if batch_size % prod == 0:
                break
            axes = axes[1:]
        if not axes:
            return None
    return axes if len(axes) > 1 else axes[0]


def _maybe(axis, dim_size, mesh):
    """axis if it divides dim_size else None."""
    if axis is None:
        return None
    sz = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        sz *= mesh.shape[a]
    return axis if dim_size % sz == 0 else None


def batch_pspec(mesh: Mesh, batch_size: int | None = None) -> P:
    return P(batch_axes(mesh, batch_size))


def batch_specs(mesh: Mesh, cfg: ModelConfig, batch_shapes: dict) -> dict:
    """PartitionSpecs for a training batch dict (leading dim = batch)."""
    specs = {}
    for k, v in batch_shapes.items():
        nd = len(v.shape)
        if k == "positions" and nd == 3:      # mrope (3, B, S): batch is dim 1
            b = batch_axes(mesh, v.shape[1])
            specs[k] = P(None, b, None)
        else:
            b = batch_axes(mesh, v.shape[0])
            specs[k] = P(b, *((None,) * (nd - 1)))
    return specs


def cache_pspecs(mesh: Mesh, cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Shardings for the serving cache.

    KV caches: batch over ("pod","data") when divisible; KV heads over
    "model" when divisible, else the sequence dim takes "model".  (§Perf C4
    tried head-dim sharding to keep the positional update a local DUS —
    refuted: SPMD still select-rewrites, and the hd-contracted score dots
    add 56x collective bytes.  The remaining seq-sharded-update rewrite is
    a known SPMD lowering gap; the production fix is a paged cache, noted
    in DESIGN.md.)  When the batch cannot shard (long_500k, B=1) the
    sequence dim also absorbs the data axes."""
    b = batch_axes(mesh, batch)
    kvh_ax = _maybe("model", cfg.n_kv_heads, mesh)
    hd_ax = None
    seq_candidates = []
    if kvh_ax is None:
        seq_candidates.append("model")
    if b is None:
        seq_candidates.extend(a for a in ("pod", "data") if a in mesh.shape)
    seq_ax = _maybe(tuple(seq_candidates) if len(seq_candidates) > 1
                    else (seq_candidates[0] if seq_candidates else None),
                    max_len, mesh)
    pos = P(b)

    if cfg.family in ("attn", "moe"):
        kv = P(None, b, kvh_ax, seq_ax, hd_ax)
        return {"k": kv, "v": kv, "pos": pos}
    if cfg.family == "rwkv6":
        h_ax = _maybe("model", cfg.d_model // 64, mesh)
        return {
            "wkv": P(None, b, h_ax, None, None),
            "sh_mix": P(None, b, None),
            "sh_ffn": P(None, b, None),
            "pos": pos,
        }
    if cfg.family == "zamba2":
        kv = P(None, b, kvh_ax, seq_ax, hd_ax)
        return {
            "ssm": P(None, b, _maybe("model", cfg.mamba_heads, mesh), None, None),
            "conv": P(None, b, None, _maybe("model", cfg.d_inner + 2 * cfg.ssm_state, mesh)),
            "k": kv, "v": kv, "pos": pos,
        }
    raise ValueError(cfg.family)


def named(mesh: Mesh, tree_pspecs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_pspecs,
        is_leaf=lambda x: isinstance(x, P))


def model_pspecs(mesh: Mesh, cfg: ModelConfig, overrides: Optional[dict] = None):
    rules = apply_overrides(default_rules(mesh, cfg), overrides or {})
    return param_pspecs(cfg, rules)


def opt_pspecs(param_specs, opt_state):
    """Optimizer state mirrors parameter sharding (m/v same shape; adafactor
    factored stats drop the last/second-to-last dim)."""
    from jax.sharding import PartitionSpec as P

    def one(path_spec, leaf):
        return path_spec

    # adamw: {"m": tree, "v": tree} same structure as params
    def map_like(tree):
        if isinstance(tree, dict) and set(tree) == {"m", "v"}:
            return {"m": param_specs, "v": param_specs}
        return None

    mapped = map_like(opt_state.inner)
    if mapped is not None:
        return type(opt_state)(P(), mapped)

    # adafactor: per-leaf dict {"vr","vc"} or {"v"}
    def factored(spec, state_leaf):
        if "v" in state_leaf:
            return {"v": spec}
        vr = P(*spec[:-1]) if len(spec) else P()
        vc = P(*(spec[:-2] + spec[-1:])) if len(spec) >= 2 else P()
        return {"vr": vr, "vc": vc}

    inner = jax.tree.map(
        factored, param_specs, opt_state.inner,
        is_leaf=lambda x: isinstance(x, P) or (
            isinstance(x, dict) and ("v" in x or "vr" in x)),
    )
    return type(opt_state)(P(), inner)
