"""End-to-end training driver.

Wires: config -> params -> sharded train step -> deterministic data pipeline
-> checkpoint/restore -> preemption guard -> straggler detector -> HMU
embedding telemetry + tiering report.

Examples (CPU-runnable):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 20 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
      --steps 50 --resume --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import (ARCH_IDS, get_config, get_smoke_config,
                           get_optimizer_name)
from repro.core.tiered_embedding import TieredEmbedding
from repro.data import DataConfig, TokenPipeline
from repro.models.model import init_params
from repro.optim import cosine_schedule, get_optimizer
from repro.optim.optimizers import OptState
from repro.runtime import PreemptionGuard, StragglerDetector
from repro.train.steps import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--tiering", action="store_true", default=True,
                    help="HMU embedding telemetry + tiering report")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.frontend == "embeddings":
        print(f"note: {args.arch} uses an embedding frontend; training driver "
              "feeds token batches through the (stub-bypassed) embed table")
        cfg = type(cfg)(**{**cfg.__dict__, "frontend": "tokens"})

    opt = get_optimizer(get_optimizer_name(args.arch))
    lr = cosine_schedule(args.lr, max(args.steps // 10, 1), args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt, lr, grad_accum=args.grad_accum))

    params = init_params(cfg, jax.random.key(args.seed))
    opt_state = opt.init(params)

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, seed=args.seed)
    pipeline = TokenPipeline(data_cfg)
    start_step = 0

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and args.resume and ckpt.latest_step() is not None:
        state, extra = ckpt.restore()
        params = state["params"]
        inner = state["opt"]["inner"]
        opt_state = OptState(jnp.asarray(state["opt"]["step"]), inner)
        pipeline, start_step = TokenPipeline.resume(data_cfg, extra["data"])
        print(f"resumed from step {start_step}")

    guard = PreemptionGuard()
    straggler = StragglerDetector()
    emb = TieredEmbedding.create(params["embed"], fast_fraction=0.1) \
        if args.tiering else None

    t_start = time.time()
    for step in range(start_step, args.steps):
        batch_np = pipeline.batch(step)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        action = straggler.observe(step, dt)
        if action:
            print(f"[straggler] step {step}: {action}")
        if emb is not None:
            emb.observe_tokens(batch_np["tokens"])
            if (step + 1) % 10 == 0:
                moved = emb.rebalance()
                rep = emb.modeled_lookup_time_s()
                print(f"[tiering] step {step}: promoted {moved} blocks, "
                      f"hit={rep['fast_hit_rate']:.2%} "
                      f"tiered={rep['tiered_s']*1e6:.0f}us "
                      f"all_fast={rep['all_fast_s']*1e6:.0f}us "
                      f"all_slow={rep['all_slow_s']*1e6:.0f}us")
        print(f"step {step}: loss={loss:.4f} lr={float(metrics['lr']):.2e} "
              f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms",
              flush=True)
        if ckpt and ((step + 1) % args.ckpt_every == 0 or guard.preempted):
            ckpt.save(step + 1, {"params": params, "opt": opt_state},
                      extra={"data": pipeline.state(step + 1)},
                      block=guard.preempted)
            if guard.preempted:
                print(f"preempted: checkpointed at step {step + 1}, exiting")
                return 0
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt_state},
                  extra={"data": pipeline.state(args.steps)}, block=True)
    print(f"done: {args.steps - start_step} steps in "
          f"{time.time() - t_start:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
