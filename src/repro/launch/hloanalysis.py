"""Trip-count-aware analysis of optimized HLO.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
program built around ``lax.scan`` (i.e. every layer-stacked model here)
underreports FLOPs, bytes and collective traffic by ~n_layers.  This module
re-derives the executed totals from ``compiled.as_text()``:

  * parses computations, builds a per-computation symbol table (op types),
  * extracts while-loop trip counts from their condition computations,
  * walks the call graph (ENTRY -> while bodies x trip, fusions, calls),
  * accounts:
      - ``flops``:        2 * prod(output dims) * prod(contraction dims)
                          for every dot (recursing into fusions),
      - ``hbm_bytes``:    operands + outputs of top-level ops (NOT fusion
                          internals — fused intermediates never touch HBM),
      - ``collectives``:  per-type wire bytes with ring-cost factors and
                          participant-group sizes from replica_groups.

This is the dry-run "profile" that §Roofline and §Perf iterate on.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "token": 0,
                "u2": 1, "s2": 1, "u4": 1, "s4": 1}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# "%name = TYPE op-name(operands), attrs"  (post-optimization HLO)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\((.*)$")
# greedy params group: signatures contain nested parens (tuple params)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")


def _shape_elems_bytes(stype: str) -> Tuple[int, int]:
    m = _SHAPE_RE.match(stype)
    if not m:
        return 0, 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n, n * _DTYPE_BYTES.get(m.group(1), 4)


def _tuple_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(m.group(1), 4)
    return total


@dataclasses.dataclass
class Op:
    name: str
    out_type: str
    opcode: str
    rest: str           # operands + attributes (raw text)
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]          # param name -> type
    ops: List[Op]
    symbols: Dict[str, str]         # op name -> output type


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        mc = _COMP_RE.match(line)
        if mc and line.endswith("{"):
            params = {}
            for pm in re.finditer(r"%?([\w.\-]+):\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?))", mc.group(2)):
                params[pm.group(1)] = pm.group(2)
            cur = Computation(mc.group(1), params, [], dict(params))
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if not mo:
            continue
        name, otype, opcode, rest = mo.groups()
        operands = re.findall(r"%([\w.\-]+)", rest.split("),")[0])
        op = Op(name, otype, opcode, rest, operands)
        cur.ops.append(op)
        cur.symbols[name] = otype
    return comps


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = []
    for op in cond.ops:
        if op.opcode == "constant":
            # _OP_RE leaves rest = "<value>), attrs" after "constant("
            m = re.match(r"(\-?\d+)\)", op.rest.strip())
            if m:
                consts.append(int(m.group(1)))
    pos = [c for c in consts if c > 0]
    return max(pos) if pos else 1


def _dot_flops(comp: Computation, op: Op) -> float:
    out_elems, _ = _shape_elems_bytes(op.out_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if not m or not op.operands:
        return 0.0
    lhs_type = comp.symbols.get(op.operands[0], "")
    sm = _SHAPE_RE.match(lhs_type)
    if not sm:
        return 0.0
    dims = [int(d) for d in sm.group(2).split(",")] if sm.group(2) else []
    contract = 1
    for i in (int(x) for x in m.group(1).split(",") if x):
        if i < len(dims):
            contract *= dims[i]
    return 2.0 * out_elems * contract


def _group_size(rest: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class Account:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_wire_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLL_OPS})
    coll_count: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {c: 0 for c in _COLL_OPS})

    def add(self, other: "Account", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for c in _COLL_OPS:
            self.coll_wire_bytes[c] += other.coll_wire_bytes[c] * mult
            self.coll_count[c] += int(other.coll_count[c] * mult)


_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "copy", "partition-id", "replica-id",
               "after-all", "iota", "while", "conditional"}


def _op_hbm_bytes(comp: Computation, op: Op) -> float:
    if op.opcode in _SKIP_BYTES:
        return 0.0
    # In-place-aliasable updates: XLA aliases the target buffer (donated /
    # loop-carried), so real HBM traffic is the UPDATE bytes, not the whole
    # buffer.  Charge update operands (+ the written region ~ update size),
    # skip the pass-through target and the full-size output.
    if op.opcode in ("dynamic-update-slice", "scatter"):
        total = 0.0
        for o in op.operands[1:]:
            t = comp.symbols.get(o)
            if t:
                total += _tuple_bytes(t)
        return 2.0 * float(total)      # read update + write region
    # slicing reads only the slice, not the whole operand
    if op.opcode in ("dynamic-slice", "slice", "gather"):
        return 2.0 * _tuple_bytes(op.out_type)   # read region + write out
    total = _tuple_bytes(op.out_type)
    for o in op.operands:
        t = comp.symbols.get(o)
        if t:
            total += _tuple_bytes(t)
    return float(total)


def _fusion_hbm_bytes(comps: Dict[str, Computation], comp: Computation,
                      op: Op) -> float:
    """HBM traffic of a fusion = what crosses its boundary, with slice
    awareness: an operand consumed only by slice/gather ops inside the fused
    computation contributes the *sliced* bytes; a root dynamic-update-slice
    writes only the update region (XLA aliases the target)."""
    mb = re.search(r"calls=%?([\w.\-]+)", op.rest)
    called = comps.get(mb.group(1)) if mb else None
    if called is None:
        return _op_hbm_bytes(comp, op)

    # ---- output side
    root = called.ops[-1] if called.ops else None
    if root is not None and root.opcode == "dynamic-update-slice":
        upd = 0.0
        for o in root.operands[1:]:
            t = called.symbols.get(o)
            if t:
                upd += _tuple_bytes(t)
        out_bytes = 2.0 * upd
    elif root is not None and root.opcode == "scatter":
        upd = 0.0
        for o in root.operands[1:]:        # indices + updates
            t = called.symbols.get(o)
            if t:
                upd += _tuple_bytes(t)
        out_bytes = 2.0 * upd
    else:
        out_bytes = float(_tuple_bytes(op.out_type))

    # ---- operand side: param index -> name
    param_name = {}
    for o in called.ops:
        if o.opcode == "parameter":
            m = re.match(r"(\d+)\)", o.rest.strip())
            if m:
                param_name[int(m.group(1))] = o.name
    total = out_bytes
    for i, operand in enumerate(op.operands):
        t = comp.symbols.get(operand)
        if not t:
            continue
        full = float(_tuple_bytes(t))
        pname = param_name.get(i)
        if pname is None:
            total += full
            continue
        consumers = [o for o in called.ops if pname in o.operands]
        if consumers and all(
            o.opcode in ("dynamic-slice", "slice", "gather")
            or (o.opcode == "dynamic-update-slice" and o.operands
                and o.operands[0] == pname)
            for o in consumers
        ):
            sliced = 0.0
            for o in consumers:
                if o.opcode == "dynamic-update-slice":
                    continue            # aliased target: counted on output
                sliced += _tuple_bytes(o.out_type)
            total += min(sliced, full)
        else:
            total += full
    return total


def analyze(text: str, n_devices_per_group: int = 16) -> dict:
    """Walk ENTRY with trip-count multipliers; returns executed totals
    (per-device, since post-SPMD HLO is the per-device program)."""
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None:       # fall back: biggest computation
        entry = max(comps, key=lambda c: len(comps[c].ops))

    memo: Dict[str, Account] = {}

    def eval_comp(name: str, depth=0) -> Account:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        acc = Account()
        if comp is None or depth > 50:
            return acc
        memo[name] = acc    # pre-insert (cycle guard)
        for op in comp.ops:
            base = op.opcode.replace("-start", "") if op.opcode.endswith("-start") else op.opcode
            if op.opcode == "dot":
                acc.flops += _dot_flops(comp, op)
                acc.hbm_bytes += _op_hbm_bytes(comp, op)
            elif base in _COLL_OPS and not op.opcode.endswith("-done"):
                out_b = _tuple_bytes(op.out_type)
                g = _group_size(op.rest, n_devices_per_group)
                ring = (g - 1) / max(g, 1)
                factor = {"all-gather": ring, "reduce-scatter": ring,
                          "all-reduce": 2 * ring, "all-to-all": ring,
                          "collective-permute": 1.0}[base]
                acc.coll_wire_bytes[base] += out_b * factor
                acc.coll_count[base] += 1
                acc.hbm_bytes += _op_hbm_bytes(comp, op)
            elif op.opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", op.rest)
                trips = _trip_count(comps, mc.group(1)) if mc else 1
                if mb:
                    acc.add(eval_comp(mb.group(1), depth + 1), trips)
            elif op.opcode == "fusion":
                mb = re.search(r"calls=%?([\w.\-]+)", op.rest)
                if mb:
                    inner = eval_comp(mb.group(1), depth + 1)
                    # flops + collectives recurse; bytes = fusion boundary only
                    acc.flops += inner.flops
                    for c in _COLL_OPS:
                        acc.coll_wire_bytes[c] += inner.coll_wire_bytes[c]
                        acc.coll_count[c] += inner.coll_count[c]
                acc.hbm_bytes += _fusion_hbm_bytes(comps, comp, op)
            elif op.opcode in ("call", "async-start", "custom-call"):
                mb = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", op.rest)
                if mb:
                    acc.add(eval_comp(mb.group(1), depth + 1), 1.0)
                # A resolvable plain call's traffic is whatever its callee's
                # ops do — charging the call boundary too double-counts every
                # operand at full size (e.g. the CPU backend wraps gather
                # fusions in %parallel_* calls, turning a 4 KB sliced read
                # into the whole table).  Opaque targets (custom-call,
                # async-start without a parsed callee) still pay boundary
                # bytes since we cannot see inside them.
                if not (op.opcode in ("call", "async-start")
                        and mb and mb.group(1) in comps):
                    acc.hbm_bytes += _op_hbm_bytes(comp, op)
            elif op.opcode == "conditional":
                for mb in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                      r"(?:true|false)_computation=%?([\w.\-]+))",
                                      op.rest):
                    names = (mb.group(1) or mb.group(2) or "")
                    for nm in re.findall(r"%?([\w.\-]+)", names):
                        acc.add(eval_comp(nm, depth + 1), 1.0)
            else:
                acc.hbm_bytes += _op_hbm_bytes(comp, op)
        return acc

    acc = eval_comp(entry)
    return {
        "flops": acc.flops,
        "hbm_bytes": acc.hbm_bytes,
        "collective_wire_bytes": dict(acc.coll_wire_bytes),
        "collective_count": dict(acc.coll_count),
        "collective_total_bytes": float(sum(acc.coll_wire_bytes.values())),
    }
