"""Batched serving driver: prefill a prompt batch, decode N tokens, with
tiered-KV-cache telemetry (per-page attention mass -> hot-page promotion
report, the serving analogue of Table 1).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import TPU_V5E_SYSTEM
from repro.core.metrics import pages_for_access_fraction
from repro.models.model import init_params
from repro.serve import engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page granularity for tiering telemetry")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.frontend == "embeddings":
        cfg = type(cfg)(**{**cfg.__dict__, "frontend": "tokens"})
    params = init_params(cfg, jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (args.batch, args.prompt_len)))
    max_len = args.prompt_len + args.gen

    t0 = time.time()
    prefill_jit = jax.jit(lambda p, t: engine.prefill(p, cfg, tokens=t,
                                                      max_len=max_len))
    logits, cache = prefill_jit(params, prompts)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.0f}ms "
          f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")

    page_mass = None
    has_kv = cfg.family in ("attn", "moe")
    decode_jit = jax.jit(lambda p, c, t: engine.decode_step(
        p, cfg, c, t, page_size=args.page_size if has_kv else 0))

    tokens = jnp.argmax(logits, -1).astype(jnp.int32)
    out_tokens = [tokens]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache, aux = decode_jit(params, cache, tokens)
        tokens = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(tokens)
        if has_kv and "kv_page_mass" in aux:
            m = np.asarray(aux["kv_page_mass"], np.float64).sum((0, 1))
            page_mass = m if page_mass is None else page_mass + m
    jax.block_until_ready(tokens)
    t_dec = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out_tokens], 1)
    print(f"decode: {args.gen - 1} steps in {t_dec*1e3:.0f}ms "
          f"({args.batch * (args.gen - 1) / max(t_dec, 1e-9):.0f} tok/s)")
    print(f"sample generation (row 0): {gen[0][:16].tolist()}")

    if page_mass is not None:
        frac = pages_for_access_fraction(page_mass, 0.90)
        k = max(int(len(page_mass) * 0.25), 1)
        hot = np.argsort(-page_mass)[:k]
        covered = page_mass[hot].sum() / max(page_mass.sum(), 1e-9)
        print(f"[kv-tiering] {len(page_mass)} pages/seq: top {frac:.0%} of "
              f"pages carry 90% of attention mass; keeping 25% of pages "
              f"fast-tier covers {covered:.0%} of mass")
        sysm = TPU_V5E_SYSTEM
        bpa = cfg.n_kv_heads * cfg.head_dim * 2 * 2  # k+v bf16 per token read
        n = page_mass.sum()
        t_tier = sysm.access_time_s(covered * n, (1 - covered) * n, bpa)
        t_fast = sysm.access_time_s(n, 0, bpa)
        print(f"[kv-tiering] modeled cache-read time: tiered(25% fast)="
              f"{t_tier*1e6:.1f}us vs all-HBM={t_fast*1e6:.1f}us "
              f"(footprint 4x smaller)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
