"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 single pod (16x16) or 2 pods (2x16x16).

    Axes: "pod" is the outer data-parallel axis (gradient all-reduce crosses
    pods once per step over DCN); "data" is FSDP + batch; "model" is tensor/
    expert parallel (stays inside a pod's ICI torus).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests (e.g. (2,4) on 8 host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_telemetry_mesh(n_devices: int | None = None, axis: str = "blocks"):
    """1-D mesh for memory-side telemetry: per-block state (collector
    histograms, lane placements) shards over ``axis`` so paper-scale
    (5.24 M page) epoch runs keep the decision loop next to the counters.
    Defaults to all visible devices."""
    n = len(jax.devices()) if n_devices is None else int(n_devices)
    return jax.make_mesh((n,), (axis,))


def use_mesh(mesh):
    """Ambient-mesh context, portable across jax versions: ``jax.set_mesh``
    where it exists (>= 0.6), else the Mesh object itself (a context manager
    with the same ambient-mesh effect on older releases)."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh
