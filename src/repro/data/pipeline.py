"""Deterministic, resumable, shardable synthetic token pipeline.

Production properties the trainer depends on:
  * **Determinism**: batch(i) is a pure function of (seed, step) — restart at
    step k replays exactly the remaining stream, no data loss or dup.
  * **Sharding**: each data-parallel rank materializes only its slice
    (host-side; the per-rank slice feeds jax.make_array_from_process_data in
    a real multi-host launch).
  * **Skew**: token ids are Zipf-distributed (configurable) so embedding-row
    hotness is realistic — this is what the TieredEmbedding telemetry sees.

The "dataset" is synthetic (procedural) because the paper's LM-side workload
only needs realistic *access statistics*; swap `_tokens_for` with a real
tokenized shard reader for production.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1      # token popularity skew
    n_ranks: int = 1
    rank: int = 0


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.n_ranks:
            raise ValueError("global_batch must divide across ranks")
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_ranks
        n = cfg.vocab_size
        ranks = np.arange(1, n + 1, dtype=np.float64)
        w = ranks ** (-cfg.zipf_alpha)
        self._cdf = np.cumsum(w) / w.sum()
        # stable rank->token shuffle so hot tokens are spread over the table
        self._rank_to_tok = np.random.default_rng(cfg.seed).permutation(n) \
            .astype(np.int32)

    def batch(self, step: int) -> dict:
        """Deterministic batch for ``step`` (this rank's slice)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.rank))            # counter-based determinism
        u = rng.random((self.local_batch, cfg.seq_len + 1))
        toks = self._rank_to_tok[np.searchsorted(self._cdf, u)]
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def state(self, step: int) -> dict:
        """Checkpointable pipeline state (tiny: it is all recomputable)."""
        return {"seed": self.cfg.seed, "step": step, "rank": self.cfg.rank}

    @staticmethod
    def resume(cfg: DataConfig, state: dict) -> tuple["TokenPipeline", int]:
        assert state["seed"] == cfg.seed, "seed mismatch on resume"
        return TokenPipeline(cfg), int(state["step"])
