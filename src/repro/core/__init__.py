"""Memory-side tiering telemetry — the paper's contribution as a JAX library.

Public surface:
  TieredStore           two-tier block store + indirection (blockstore.py)
  Placement             the one bounded-fast-tier slot<->block substrate,
                        lane-stackable and device-resident (placement.py)
  HMU / PEBS / NB       telemetry emulators over one access stream (telemetry.py)
  policies              oracle top-k, NB two-touch, reactive, proactive, hinted
  selectk               O(n) exact top-k / rank kernels (no full sorts)
  MemSystem             two-tier analytic cost model (costmodel.py)
  TieringManager        Fig.2 "Tiering Agent" glue (manager.py)
  EpochRuntime          online observe->decide->migrate->account loop running
                        all five policies in two jit dispatches per epoch
                        (runtime.py; fused=False keeps the per-lane reference)
  metrics               accuracy / coverage / overlap / hotness CDF
"""
from .blockstore import TieredStore
from .costmodel import CXL_SYSTEM, TPU_V5E_SYSTEM, MemSystem, TierSpec
from .manager import StrategyResult, TieringManager
from .placement import Placement
from .runtime import ALL_POLICIES, EpochRecord, EpochRuntime, Trajectory
from . import metrics, placement, policy, selectk, telemetry

__all__ = [
    "TieredStore", "TieringManager", "StrategyResult", "Placement",
    "EpochRuntime", "EpochRecord", "Trajectory", "ALL_POLICIES",
    "MemSystem", "TierSpec", "CXL_SYSTEM", "TPU_V5E_SYSTEM",
    "metrics", "placement", "policy", "selectk", "telemetry",
]
