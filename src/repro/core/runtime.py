"""Epoch-driven tiering runtime — observe -> decide -> migrate -> account.

The paper's headline numbers come from a one-shot profile->promote->replay
methodology; its §VI vision (reactive placement, proactive movement, compiler
hints from a programmable HMU) is inherently *online*.  This module is that
online regime: a loop over epochs in which

  1. **observe**  — the whole epoch's access stream is fed to all three
     collectors (HMU / PEBS / NB) and the ground-truth counter in ONE jit
     dispatch (``telemetry.observe_all``'s ``lax.scan``),
  2. **decide**   — every policy lane (five of them, one per §VI strategy)
     turns its collector's *epoch-local* estimate into a migration plan,
  3. **migrate**  — promotions are applied against a bounded fast tier;
     when slots run out the lane demotes ``policy.coldest_victims`` first,
  4. **account**  — the epoch is charged: modeled access time under the
     placement that actually *served* it (decided from data up to the
     previous epoch — no time travel), plus the collector's host tax and the
     epoch's migration traffic; accuracy/coverage are scored against the
     epoch's own true top-K.

Per-epoch records form a trajectory (a time series, not a single end-state
number) — the NeoMem / HybridTier evaluation regime, and what exposes the
phase-shift behaviour: proactive/EWMA re-ranks within one epoch of a hot-set
rotation while NB's cumulative two-touch signal keeps serving the stale set.

Policy lanes and their telemetry sources:

=================  =========================  ===============================
lane               estimate                   host tax per epoch
=================  =========================  ===============================
hmu_oracle         HMU epoch-delta counts     log drain (~ns/record)
nb_two_touch       NB cumulative faults       hint faults (~2 us each)
reactive_watermark HMU epoch-delta counts     log drain
proactive_ewma     EWMA of HMU epoch deltas   log drain
hinted             PEBS epoch-delta estimate  PEBS samples (~1.5 us each)
                   blended with static hints
=================  =========================  ===============================
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from . import metrics, policy
from . import telemetry as tel
from .costmodel import CXL_SYSTEM, MemSystem, split_accesses_by_tier

__all__ = [
    "ALL_POLICIES", "EpochRecord", "EpochRuntime", "Trajectory",
]

ALL_POLICIES = (
    "hmu_oracle", "nb_two_touch", "reactive_watermark", "proactive_ewma",
    "hinted",
)

# Host-side cost per telemetry event (see dlrm.tracesim for the NB/PEBS
# calibration; HMU pays only bulk log processing — the paper's 'process the
# trace immediately', which NMC would shrink further).
NB_FAULT_COST_S = 2e-6
PEBS_SAMPLE_COST_S = 1.5e-6
HMU_DRAIN_COST_S = 2e-9


@dataclasses.dataclass
class EpochRecord:
    """One lane's accounting for one epoch."""
    epoch: int
    lane: str
    time_s: float            # access + host tax + migration
    access_s: float
    host_tax_s: float
    migration_s: float
    accuracy: float          # placement that served the epoch vs epoch top-K
    coverage: float
    resident: int            # fast blocks during the epoch
    promoted: int            # migrations applied at epoch end
    demoted: int
    host_events: float       # telemetry events charged this epoch

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Trajectory:
    """Per-epoch time series for every lane (the runtime's output)."""
    n_blocks: int
    k_hot: int
    records: Dict[str, List[EpochRecord]]

    def lane(self, name: str) -> List[EpochRecord]:
        return self.records[name]

    def times(self, name: str) -> np.ndarray:
        return np.array([r.time_s for r in self.records[name]])

    def to_json(self, **meta) -> str:
        return json.dumps({
            "n_blocks": self.n_blocks,
            "k_hot": self.k_hot,
            **meta,
            "lanes": {name: [r.to_dict() for r in recs]
                      for name, recs in self.records.items()},
        }, indent=1)


@dataclasses.dataclass
class _Lane:
    """Per-policy placement state: a bounded fast tier's indirection maps
    (same invariants as TieredStore's, without carrying the payload rows)."""
    name: str
    slot_to_block: np.ndarray            # (k,) int32, -1 = free
    block_to_slot: np.ndarray            # (n_blocks,) int32, -1 = slow-only
    pred: Optional[np.ndarray] = None    # EWMA state (proactive lane)

    @property
    def fast_mask(self) -> np.ndarray:
        return self.block_to_slot >= 0

    def resident_ids(self) -> np.ndarray:
        s = self.slot_to_block
        return s[s >= 0]


def _unique_in_order(ids: np.ndarray, k: int) -> np.ndarray:
    """Valid plan ids, de-duplicated preserving priority order, capped at k."""
    ids = np.asarray(ids).reshape(-1)
    ids = ids[ids >= 0]
    _, first = np.unique(ids, return_index=True)
    return ids[np.sort(first)][:k]


class EpochRuntime:
    """Runs all policy lanes over one shared telemetry stream, epoch by epoch.

    One collector set observes the stream once per epoch (fused); each lane
    owns only its placement.  ``step`` consumes one epoch of equal-size
    batches ``(n_batches, batch_size)`` and returns that epoch's records;
    ``run`` drives a whole workload and returns the :class:`Trajectory`.
    """

    def __init__(
        self,
        n_blocks: int,
        k_hot: int,
        policies: Sequence[str] = ALL_POLICIES,
        system: MemSystem = CXL_SYSTEM,
        bytes_per_access: float = 256.0,
        block_bytes: float = 4096.0,
        pebs_period: int = 10007,
        nb_scan_rate: Optional[int] = None,
        hmu_log_capacity: int = 1 << 33,
        ewma_alpha: float = 0.5,
        hint_rank: Optional[np.ndarray] = None,
        hint_weight: float = 0.25,
        reactive_hot_threshold: Optional[int] = None,
        nb_rate_limit: Optional[int] = None,
    ):
        unknown = set(policies) - set(ALL_POLICIES)
        if unknown:
            raise ValueError(f"unknown policies {sorted(unknown)}; "
                             f"choose from {ALL_POLICIES}")
        self.n_blocks = int(n_blocks)
        self.k_hot = min(int(k_hot), self.n_blocks)
        self.system = system
        self.bytes_per_access = float(bytes_per_access)
        self.block_bytes = float(block_bytes)
        self.ewma_alpha = float(ewma_alpha)
        self.hint_rank = (np.zeros((n_blocks,), np.float32)
                          if hint_rank is None
                          else np.asarray(hint_rank, np.float32))
        self.hint_weight = float(hint_weight)
        self.reactive_hot_threshold = reactive_hot_threshold
        self.nb_rate_limit = nb_rate_limit
        scan = nb_scan_rate if nb_scan_rate is not None else max(n_blocks // 16, 1)
        self.bundle = tel.bundle_init(
            n_blocks, pebs_period=pebs_period, nb_scan_rate=scan,
            hmu_log_capacity=hmu_log_capacity,
        )
        self.lanes = {
            name: _Lane(
                name=name,
                slot_to_block=np.full((self.k_hot,), -1, np.int32),
                block_to_slot=np.full((self.n_blocks,), -1, np.int32),
                pred=(np.zeros((self.n_blocks,), np.float32)
                      if name == "proactive_ewma" else None),
            )
            for name in policies
        }
        self.epoch = 0
        self.records: Dict[str, List[EpochRecord]] = {n: [] for n in self.lanes}
        # epoch-delta baselines
        self._prev_true = np.zeros((n_blocks,), np.int64)
        self._prev_hmu = np.zeros((n_blocks,), np.int64)
        self._prev_pebs = np.zeros((n_blocks,), np.int64)
        self._prev_pebs_host = 0.0
        self._prev_nb_host = 0.0

    # ------------------------------------------------------------- migrate
    def _apply_plan(self, lane: _Lane, plan: policy.MigrationPlan,
                    est: np.ndarray) -> Tuple[int, int]:
        """Promote the plan into the lane's bounded fast tier; evict
        ``coldest_victims`` when no slots are free.  Returns (promoted,
        demoted) block counts — each is one block copy of migration traffic."""
        want = _unique_in_order(np.asarray(plan.promote), self.k_hot)
        if want.size == 0:
            return 0, 0
        new = want[lane.block_to_slot[want] < 0]
        if new.size == 0:
            return 0, 0
        free = np.nonzero(lane.slot_to_block < 0)[0]
        demoted = 0
        need = new.size - free.size
        if need > 0:
            vic = np.asarray(policy.plan_eviction(
                jnp.asarray(est, jnp.float32), jnp.asarray(want),
                jnp.asarray(lane.slot_to_block), int(need)))
            vic = vic[vic >= 0]
            if vic.size:
                slots = lane.block_to_slot[vic]
                lane.slot_to_block[slots] = -1
                lane.block_to_slot[vic] = -1
                demoted = int(vic.size)
            free = np.nonzero(lane.slot_to_block < 0)[0]
        take = int(min(new.size, free.size))
        if take:
            sel, slots = new[:take], free[:take]
            lane.slot_to_block[slots] = sel
            lane.block_to_slot[sel] = slots
        return take, demoted

    def _demote_untouched(self, lane: _Lane, est: np.ndarray) -> int:
        """Watermark demotion: free every resident block the epoch never
        touched (est == 0) so reactive promotion has slots."""
        resident = lane.resident_ids()
        idle = resident[est[resident] == 0]
        if idle.size:
            slots = lane.block_to_slot[idle]
            lane.slot_to_block[slots] = -1
            lane.block_to_slot[idle] = -1
        return int(idle.size)

    # -------------------------------------------------------------- decide
    def _plan(self, lane: _Lane, d_hmu: np.ndarray, d_pebs: np.ndarray,
              nb_faults: np.ndarray, epoch_accesses: int,
              ) -> Tuple[policy.MigrationPlan, np.ndarray, int]:
        """One lane's decide step -> (plan, estimate, pre-demotions)."""
        k = self.k_hot
        pre_demoted = 0
        if lane.name == "hmu_oracle":
            est = d_hmu
            plan = policy.oracle_top_k(jnp.asarray(est, jnp.int32), k)
        elif lane.name == "nb_two_touch":
            est = nb_faults
            plan = policy.nb_two_touch(jnp.asarray(est, jnp.int32), k,
                                       self.nb_rate_limit)
        elif lane.name == "reactive_watermark":
            est = d_hmu
            pre_demoted = self._demote_untouched(lane, est)
            free = int(np.sum(lane.slot_to_block < 0))
            thr = (self.reactive_hot_threshold
                   if self.reactive_hot_threshold is not None
                   else max(2, epoch_accesses // (8 * max(k, 1))))
            plan = policy.reactive_watermark(
                jnp.asarray(est, jnp.int32), int(thr),
                jnp.asarray(free), max_moves=k)
        elif lane.name == "proactive_ewma":
            pred, plan = policy.proactive_ewma(
                jnp.asarray(lane.pred), jnp.asarray(d_hmu, jnp.float32), k,
                alpha=self.ewma_alpha)
            lane.pred = np.asarray(pred)
            est = lane.pred
        elif lane.name == "hinted":
            est = d_pebs
            plan = policy.hinted(jnp.asarray(est, jnp.int32),
                                 jnp.asarray(self.hint_rank), k,
                                 hint_weight=self.hint_weight)
        else:  # pragma: no cover - guarded in __init__
            raise ValueError(lane.name)
        return plan, np.asarray(est), pre_demoted

    # ---------------------------------------------------------------- step
    def step(self, batches) -> Dict[str, EpochRecord]:
        """Consume one epoch ``(n_batches, batch_size)``: fused observe, then
        decide/migrate/account every lane.  Returns this epoch's records."""
        batches = np.ascontiguousarray(np.asarray(batches, np.int32))
        if batches.ndim != 2:
            raise ValueError(f"epoch batches must be 2-D, got {batches.shape}")
        epoch_accesses = int(batches.size)

        # -- observe (one dispatch) + drain the HMU log
        self.bundle = tel.observe_all(self.bundle, jnp.asarray(batches))
        drained = float(self.bundle.hmu.log_used)
        self.bundle = dataclasses.replace(
            self.bundle, hmu=tel.hmu_drain_cost(self.bundle.hmu))

        # -- epoch-local estimates
        true_now = np.asarray(self.bundle.true_counts, np.int64)
        hmu_now = np.asarray(tel.hmu_estimate(self.bundle.hmu), np.int64)
        pebs_now = np.asarray(tel.pebs_estimate(self.bundle.pebs), np.int64)
        d_true = true_now - self._prev_true
        d_hmu = hmu_now - self._prev_hmu
        d_pebs = pebs_now - self._prev_pebs
        nb_faults = np.asarray(tel.nb_estimate(self.bundle.nb), np.int64)
        pebs_host = float(self.bundle.pebs.host_events)
        nb_host = float(self.bundle.nb.host_events)
        d_pebs_host = pebs_host - self._prev_pebs_host
        d_nb_host = nb_host - self._prev_nb_host
        self._prev_true, self._prev_hmu, self._prev_pebs = true_now, hmu_now, pebs_now
        self._prev_pebs_host, self._prev_nb_host = pebs_host, nb_host

        epoch_hot = metrics.true_top_k(d_true, self.k_hot)
        out: Dict[str, EpochRecord] = {}
        for lane in self.lanes.values():
            # -- account the epoch under the placement that served it
            served = lane.resident_ids().copy()
            n_fast, n_slow = split_accesses_by_tier(d_true, lane.fast_mask)
            access_s = self.system.access_time_s(
                n_fast, n_slow, self.bytes_per_access)
            if lane.name == "nb_two_touch":
                host_events, per_event = d_nb_host, NB_FAULT_COST_S
            elif lane.name == "hinted":
                host_events, per_event = d_pebs_host, PEBS_SAMPLE_COST_S
            else:
                host_events, per_event = drained, HMU_DRAIN_COST_S
            host_tax_s = host_events * per_event

            # -- decide + migrate for the NEXT epoch
            plan, est, pre_demoted = self._plan(
                lane, d_hmu, d_pebs, nb_faults, epoch_accesses)
            promoted, demoted = self._apply_plan(lane, plan, est)
            demoted += pre_demoted
            migration_s = self.system.migration_time_s(
                promoted + demoted, self.block_bytes)

            rec = EpochRecord(
                epoch=self.epoch, lane=lane.name,
                time_s=access_s + host_tax_s + migration_s,
                access_s=access_s, host_tax_s=host_tax_s,
                migration_s=migration_s,
                accuracy=metrics.accuracy(served, epoch_hot),
                coverage=metrics.coverage(served, epoch_hot, self.k_hot),
                resident=int(served.size), promoted=promoted, demoted=demoted,
                host_events=host_events,
            )
            self.records[lane.name].append(rec)
            out[lane.name] = rec
        self.epoch += 1
        return out

    # ----------------------------------------------------------------- run
    def run(self, epochs: Iterable) -> Trajectory:
        for batches in epochs:
            self.step(batches)
        return self.trajectory()

    def trajectory(self) -> Trajectory:
        return Trajectory(n_blocks=self.n_blocks, k_hot=self.k_hot,
                          records=self.records)
