"""Epoch-driven tiering runtime — observe -> decide -> migrate -> account.

The paper's headline numbers come from a one-shot profile->promote->replay
methodology; its §VI vision (reactive placement, proactive movement, compiler
hints from a programmable HMU) is inherently *online*.  This module is that
online regime: a loop over epochs in which

  1. **observe**  — the whole epoch's access stream is fed to all three
     collectors (HMU / PEBS / NB) and the ground-truth counter in ONE jit
     dispatch (``telemetry.observe_all``'s ``lax.scan``),
  2. **decide**   — every policy lane (five of them, one per §VI strategy)
     turns its collector's *epoch-local* estimate into a migration plan,
  3. **migrate**  — promotions are applied against a bounded fast tier;
     when slots run out the lane demotes plan-guarded coldest victims first,
  4. **account**  — the epoch is charged: modeled access time under the
     placement that actually *served* it (decided from data up to the
     previous epoch — no time travel), plus the collector's host tax and the
     epoch's migration traffic; accuracy/coverage are scored against the
     epoch's own true top-K.

**Dispatch accounting.**  Steps 2-4 are one jit'd ``_epoch_step`` that keeps
every lane's placement state — a lane-stacked :class:`~repro.core.placement.
Placement` plus the EWMA predictor — resident on device and ``vmap``s the
policy/migration kernels over the lane axis, so a whole epoch is exactly
**two dispatches** (``observe_all`` + ``epoch_step``; counted in
:data:`DISPATCH_COUNTS`, traced-once proven via :data:`TRACE_COUNTS`) and
only the scalar :class:`EpochRecord` fields cross the device boundary.
Per-lane branching is a lane-config tuple (estimate source, selection
threshold, move cap, hint weight) baked into the trace; top-k selection uses
:mod:`~repro.core.selectk`'s O(n) kernels instead of full-length sorts.  The
pre-refactor per-lane host loop (five policy lanes x several small jits +
four full-array pulls per epoch) is preserved as ``fused=False`` — the
bit-identity reference and the benchmark baseline.

Policy lanes and their telemetry sources:

=================  =========================  ===============================
lane               estimate                   host tax per epoch
=================  =========================  ===============================
hmu_oracle         HMU epoch-delta counts     log drain (~ns/record)
nb_two_touch       NB cumulative faults       hint faults (~2 us each)
reactive_watermark HMU epoch-delta counts     log drain
proactive_ewma     EWMA of HMU epoch deltas   log drain
hinted             PEBS epoch-delta estimate  PEBS samples (~1.5 us each)
                   blended with static hints
prefetch           lookahead window over the  none (compiler hints are free
                   queued next-epoch batches  at run time)
=================  =========================  ===============================

**Hints.**  The ``hinted`` and ``prefetch`` lanes' rank arrays come from a
:class:`~repro.hints.HintPipeline` (``hints=`` at construction): per epoch
the pipeline's providers (static table analysis, bounded lookahead over the
batch queue, EWMA phase-change re-weighting) produce fresh ``hint_rank`` /
``prefetch_rank`` arrays which replace state leaves before the epoch step —
a host-to-device transfer counted in ``DISPATCH_COUNTS["hint_refresh"]``,
*not* a third dispatch.  The ``prefetch`` lane promotes blocks the lookahead
says the next epoch will touch, before the accesses land; its boundary
migration therefore streams concurrently with the epoch it serves, charged
component-wise in ``_record`` (access + migration - hidden overlap) —
equivalent to ``MemSystem.overlapped_epoch_time_s``, parity-tested in
``test_core_tiering`` — with the migration issued at the *previous* boundary
charged against the epoch it overlapped and its hidden share recorded in
``EpochRecord.hidden_s``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import json
from collections import deque
from functools import partial
from typing import Dict, Iterable, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from . import metrics, policy, selectk
from . import telemetry as tel
from .costmodel import CXL_SYSTEM, MemSystem, split_accesses_by_tier
from .placement import Placement, apply_plan, demote_idle

__all__ = [
    "ALL_POLICIES", "DISPATCH_COUNTS", "TRACE_COUNTS",
    "Counters", "counting",
    "EpochRecord", "EpochRuntime", "Trajectory",
]

ALL_POLICIES = (
    "hmu_oracle", "nb_two_touch", "reactive_watermark", "proactive_ewma",
    "hinted", "prefetch",
)

# Host-side cost per telemetry event (see dlrm.tracesim for the NB/PEBS
# calibration; HMU pays only bulk log processing — the paper's 'process the
# trace immediately', which NMC would shrink further).
NB_FAULT_COST_S = 2e-6
PEBS_SAMPLE_COST_S = 1.5e-6
HMU_DRAIN_COST_S = 2e-9

# Python-side counters.  TRACE_COUNTS ticks once per (shape, config) trace of
# the fused step — tests prove the epoch loop compiles once.  DISPATCH_COUNTS
# ticks per *call*: a fused epoch is exactly observe_all + epoch_step; the
# reference path's count grows with every policy-lane jit/eager op and
# full-array pull it issues.  "hint_refresh" counts HintPipeline refreshes —
# host-to-device transfers of the rank arrays, not dispatches — so the
# 2-dispatch/epoch claim stays auditable with hints enabled.
TRACE_COUNTS = {"epoch_step": 0}
DISPATCH_COUNTS = {"observe_all": 0, "epoch_step": 0, "reference": 0,
                   "hint_refresh": 0}


class Counters(NamedTuple):
    """The live counter dicts a :func:`counting` block observes (zeroed at
    entry): per-call dispatches, epoch_step traces, and the telemetry
    module's observe_all traces."""
    dispatch: Dict[str, int]
    trace: Dict[str, int]
    observe_trace: Dict[str, int]


@contextlib.contextmanager
def counting():
    """Scoped view of the dispatch/trace counters.

    ``DISPATCH_COUNTS``, ``TRACE_COUNTS`` and ``telemetry.TRACE_COUNTS`` are
    module-level mutable dicts, so raw reads leak activity across tests and
    benchmark runs.  Inside a ``with counting() as c:`` block all three are
    zeroed in place (every runtime keeps ticking the same dict objects, so
    ``c.dispatch`` etc. show exactly the block's activity); on exit the
    pre-entry totals are added back, so outer accounting stays monotonic and
    nested/concurrent readers outside the block never see counts vanish.
    """
    managed = (DISPATCH_COUNTS, TRACE_COUNTS, tel.TRACE_COUNTS)
    saved = [dict(d) for d in managed]
    for d in managed:
        for key in d:
            d[key] = 0
    try:
        yield Counters(*managed)
    finally:
        for d, before in zip(managed, saved):
            for key, val in before.items():
                d[key] = d.get(key, 0) + val


@dataclasses.dataclass
class EpochRecord:
    """One lane's accounting for one epoch."""
    epoch: int
    lane: str
    time_s: float            # access + host tax + migration
    access_s: float
    host_tax_s: float
    migration_s: float
    accuracy: float          # placement that served the epoch vs epoch top-K
    coverage: float
    resident: int            # fast blocks during the epoch
    promoted: int            # migrations applied at epoch end
    demoted: int
    host_events: float       # telemetry events charged this epoch
    hidden_s: float = 0.0    # migration time overlapped away (prefetch lane)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Trajectory:
    """Per-epoch time series for every lane (the runtime's output)."""
    n_blocks: int
    k_hot: int
    records: Dict[str, List[EpochRecord]]

    def lane(self, name: str) -> List[EpochRecord]:
        return self.records[name]

    def times(self, name: str) -> np.ndarray:
        return np.array([r.time_s for r in self.records[name]])

    def to_json(self, **meta) -> str:
        return json.dumps({
            "n_blocks": self.n_blocks,
            "k_hot": self.k_hot,
            **meta,
            "lanes": {name: [r.to_dict() for r in recs]
                      for name, recs in self.records.items()},
        }, indent=1)


@dataclasses.dataclass
class _Lane:
    """Per-policy placement state of the *reference* path (host numpy maps;
    the fused path holds the same state lane-stacked in a Placement)."""
    name: str
    slot_to_block: np.ndarray            # (k,) int32, -1 = free
    block_to_slot: np.ndarray            # (n_blocks,) int32, -1 = slow-only
    pred: Optional[np.ndarray] = None    # EWMA state (proactive lane)

    @property
    def fast_mask(self) -> np.ndarray:
        return self.block_to_slot >= 0

    def resident_ids(self) -> np.ndarray:
        s = self.slot_to_block
        return s[s >= 0]


def _unique_in_order(ids: np.ndarray, k: int) -> np.ndarray:
    """Valid plan ids, de-duplicated preserving priority order, capped at k."""
    ids = np.asarray(ids).reshape(-1)
    ids = ids[ids >= 0]
    _, first = np.unique(ids, return_index=True)
    return ids[np.sort(first)][:k]


# ======================================================  fused device step
class _FusedCfg(NamedTuple):
    """Hashable static config baked into the epoch_step trace."""
    lanes: Tuple[str, ...]
    n_blocks: int
    k_hot: int
    ewma_alpha: float
    hint_weight: float
    nb_rate_limit: Optional[int]
    reactive_hot_threshold: Optional[int]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class _FusedState:
    """Everything the epoch loop mutates, resident on device between epochs."""
    bundle: tel.TelemetryBundle
    placement: Placement         # lane-stacked: (L, k_hot) / (L, n_blocks)
    pred: jax.Array              # (n_blocks,) f32 EWMA (the proactive lane's)
    hint_rank: jax.Array         # (n_blocks,) f32 static priorities
    prefetch_rank: jax.Array     # (n_blocks,) f32 lookahead priorities
    prev_hmu: jax.Array          # (n_blocks,) i32 epoch-delta baselines
    prev_pebs: jax.Array


@partial(jax.jit, static_argnames=("cfg", "s_max"), donate_argnums=0)
def _epoch_step(state: _FusedState, epoch_accesses: jax.Array, *,
                cfg: _FusedCfg, s_max: int):
    """decide + migrate + account for every lane in ONE dispatch.

    ``epoch_accesses`` is traced and ``s_max`` (the static PEBS-positives
    bound) is quantized by the caller, so ragged epoch sizes share traces
    instead of recompiling the five-lane program per unique size.  Returns
    the next state plus the per-lane integer/scalar outputs the host needs
    to assemble :class:`EpochRecord`s — nothing (n_blocks,)-sized ever
    leaves the device.
    """
    TRACE_COUNTS["epoch_step"] += 1
    lanes, n, k = cfg.lanes, cfg.n_blocks, cfg.k_hot
    b = state.bundle

    # -- drain the HMU log (host tax charged below from the drained count)
    drained = b.hmu.log_used
    bundle = dataclasses.replace(b, hmu=tel.hmu_drain_cost(b.hmu))

    # -- epoch-local estimates (deltas against the previous epoch's totals).
    #    The HMU counter is exact, so d_hmu *is* the epoch's ground truth
    #    (bit-identical to d_true) — the oracle lane's selection doubles as
    #    the epoch-hot set and true_counts never needs its own ranking.
    true_now = b.true_counts
    hmu_now = b.hmu.counts
    pebs_now = b.pebs.sampled * b.pebs.period
    d_hmu = hmu_now - state.prev_hmu
    d_pebs = pebs_now - state.prev_pebs
    nb_faults = b.nb.faults
    d_hmu_f = d_hmu.astype(jnp.float32)

    thr = (cfg.reactive_hot_threshold
           if cfg.reactive_hot_threshold is not None
           else jnp.maximum(2, epoch_accesses // (8 * max(k, 1))))

    # -- per-lane selection keys (int32; floats via order-isomorphic bitcast),
    #    eviction estimates, and selection gates: the lane-config arrays that
    #    replace the per-lane Python branching.  Lanes that rank the same
    #    signal (oracle + reactive + the epoch-hot set all rank d_hmu) share
    #    one selection row.
    rows: Dict[str, Tuple[jax.Array, jax.Array]] = {}

    def row(rkey: str, key: jax.Array, est: jax.Array) -> int:
        if rkey not in rows:
            rows[rkey] = (key, est)
        return list(rows).index(rkey)

    hmu_row = row("hmu", d_hmu, d_hmu_f)
    pred_new = state.pred
    lane_row, min_keys, caps, is_reactive = [], [], [], []
    for name in lanes:
        if name == "hmu_oracle":
            r, min_key, cap = hmu_row, 1, k
        elif name == "nb_two_touch":
            cap = k if cfg.nb_rate_limit is None else min(k, cfg.nb_rate_limit)
            r, min_key = row("nb", nb_faults, nb_faults.astype(jnp.float32)), 2
        elif name == "reactive_watermark":
            r, min_key, cap = hmu_row, 0, k      # 0 = thr placeholder (traced)
        elif name == "proactive_ewma":
            pred_new = (cfg.ewma_alpha * d_hmu_f
                        + (1.0 - cfg.ewma_alpha) * state.pred)
            r = row("pred", selectk.sortable_key(pred_new), pred_new)
            min_key, cap = 1, k
        elif name == "hinted":
            # exact argsort(argsort(d_pebs)): positives are bounded by this
            # epoch's PEBS samples, so rank the sparse support only
            t_rank = selectk.stable_rank_sparse(d_pebs, s_max)
            score = policy.hinted_score(d_pebs, t_rank, state.hint_rank,
                                        cfg.hint_weight)
            r = row("score", selectk.sortable_key(score),
                    d_pebs.astype(jnp.float32))
            min_key, cap = 0, k
        elif name == "prefetch":
            # lookahead rank in [0,1]; min_key 1 gates rank > 0 (int32 bits of
            # any positive float are >= 1), matching policy.prefetch's gate
            r = row("la", selectk.sortable_key(state.prefetch_rank),
                    state.prefetch_rank)
            min_key, cap = 1, k
        else:  # pragma: no cover - guarded in __init__
            raise ValueError(name)
        lane_row.append(r)
        min_keys.append(min_key)
        caps.append(cap)
        is_reactive.append(name == "reactive_watermark")

    key_rows = jnp.stack([kv[0] for kv in rows.values()])   # (U, n) int32
    est_rows = jnp.stack([kv[1] for kv in rows.values()])   # (U, n) f32
    lane_row = np.asarray(lane_row)
    est_lanes = est_rows[lane_row]                          # (L, n) f32
    reactive_arr = jnp.asarray(is_reactive)
    min_key_arr = jnp.where(reactive_arr, thr,
                            jnp.asarray(min_keys, jnp.int32))[:, None]
    cap_arr = jnp.asarray(caps, jnp.int32)

    # -- one O(n) selection per unique signal, fanned out to lanes
    vals_u, ids_u, sel_u = selectk.select_top_k(key_rows, k, return_mask=True)
    vals, ids = vals_u[lane_row], ids_u[lane_row]           # (L, k)

    # -- account the epoch under the placement that served it (pre-migration)
    hot = sel_u[hmu_row]                           # epoch's true top-K set
    fast0 = state.placement.fast_mask              # (L, n)
    n_fast = jnp.sum(jnp.where(fast0, d_hmu, 0), axis=-1)
    n_slow = jnp.sum(d_hmu) - n_fast
    inter = jnp.sum((fast0 & hot).astype(jnp.int32), axis=-1)
    resident0 = state.placement.resident()

    # -- decide: ordered top-k ids per lane, gated per lane config
    pl, pre_demoted = demote_idle(state.placement, est_lanes,
                                  reactive_arr[:, None])
    free_slots = jnp.sum((pl.slot_to_block < 0).astype(jnp.int32), axis=-1)
    cap_eff = jnp.where(reactive_arr, jnp.minimum(cap_arr, free_slots),
                        cap_arr)
    ok = (vals >= min_key_arr) & (jnp.arange(k, dtype=jnp.int32)[None, :]
                                  < cap_eff[:, None])
    want = jnp.where(ok, ids, -1)

    # -- migrate: bounded promotion with plan-guarded coldest-victim eviction
    pl, promoted, demoted = apply_plan(pl, want, est_lanes)

    del true_now  # true_counts stays in the bundle; d_hmu already equals it
    out = {
        "drained": drained,
        "pebs_host": bundle.pebs.host_events,
        "nb_host": bundle.nb.host_events,
        "n_fast": n_fast, "n_slow": n_slow,
        "inter": inter, "resident": resident0,
        "promoted": promoted, "demoted": demoted + pre_demoted,
    }
    state = _FusedState(
        bundle=bundle, placement=pl, pred=pred_new,
        hint_rank=state.hint_rank, prefetch_rank=state.prefetch_rank,
        prev_hmu=hmu_now, prev_pebs=pebs_now,
    )
    return state, out


class EpochRuntime:
    """Runs all policy lanes over one shared telemetry stream, epoch by epoch.

    One collector set observes the stream once per epoch (fused); each lane
    owns only its placement.  ``step`` consumes one epoch of equal-size
    batches ``(n_batches, batch_size)`` and returns that epoch's records;
    ``run`` drives a whole workload and returns the :class:`Trajectory`.

    ``fused=True`` (default) keeps all lane state on device and executes
    decide+migrate+account as the single ``_epoch_step`` dispatch;
    ``fused=False`` is the pre-refactor per-lane host loop kept as the
    bit-identity reference and benchmark baseline.  ``mesh`` (with a
    ``NamedSharding`` axis named ``axis``) shards every (n_blocks,)-sized
    array — collector histograms and lane placements — across devices for
    paper-scale (5.24 M page) runs; see ``launch.mesh.make_telemetry_mesh``.

    ``hints`` (a :class:`repro.hints.HintPipeline`) refreshes the hinted
    lane's ``hint_rank`` and the prefetch lane's ``prefetch_rank`` every
    epoch from the pipeline's providers; ``run`` buffers the epoch stream by
    the pipeline's lookahead depth so ``step`` sees the queued next epochs.
    ``prefetch_overlap`` in [0,1] is how much of the prefetch lane's boundary
    migration streams concurrently with the epoch it serves (0 = the same
    stop-the-world charging every other lane pays).
    """

    def __init__(
        self,
        n_blocks: int,
        k_hot: int,
        policies: Sequence[str] = ALL_POLICIES,
        system: MemSystem = CXL_SYSTEM,
        bytes_per_access: float = 256.0,
        block_bytes: float = 4096.0,
        pebs_period: int = 10007,
        nb_scan_rate: Optional[int] = None,
        hmu_log_capacity: int = 1 << 33,
        ewma_alpha: float = 0.5,
        hint_rank: Optional[np.ndarray] = None,
        hint_weight: float = 0.25,
        reactive_hot_threshold: Optional[int] = None,
        nb_rate_limit: Optional[int] = None,
        hints=None,
        prefetch_overlap: float = 1.0,
        fused: bool = True,
        mesh=None,
        mesh_axis: str = "blocks",
    ):
        unknown = set(policies) - set(ALL_POLICIES)
        if unknown:
            raise ValueError(f"unknown policies {sorted(unknown)}; "
                             f"choose from {ALL_POLICIES}")
        if mesh is not None and not fused:
            raise ValueError("mesh sharding requires the fused epoch step "
                             "(the reference path keeps lane state on the "
                             "host); pass fused=True or drop mesh")
        self.n_blocks = int(n_blocks)
        self.k_hot = min(int(k_hot), self.n_blocks)
        self.system = system
        self.bytes_per_access = float(bytes_per_access)
        self.block_bytes = float(block_bytes)
        self.ewma_alpha = float(ewma_alpha)
        self.hint_rank = (np.zeros((n_blocks,), np.float32)
                          if hint_rank is None
                          else np.asarray(hint_rank, np.float32))
        self.prefetch_rank = np.zeros((n_blocks,), np.float32)
        self.hint_weight = float(hint_weight)
        self.reactive_hot_threshold = reactive_hot_threshold
        self.nb_rate_limit = nb_rate_limit
        self.hints = hints                  # Optional[repro.hints.HintPipeline]
        self.prefetch_overlap = float(prefetch_overlap)
        if not 0.0 <= self.prefetch_overlap <= 1.0:
            raise ValueError(f"prefetch_overlap must be in [0, 1], "
                             f"got {prefetch_overlap!r}")
        self._prefetch_pending = 0          # blocks moved at the last boundary
        self._mesh, self._mesh_axis = mesh, mesh_axis
        self.fused = bool(fused)
        scan = nb_scan_rate if nb_scan_rate is not None else max(n_blocks // 16, 1)
        bundle = tel.bundle_init(
            n_blocks, pebs_period=pebs_period, nb_scan_rate=scan,
            hmu_log_capacity=hmu_log_capacity,
        )
        self._lane_names = tuple(policies)
        self.epoch = 0
        self.records: Dict[str, List[EpochRecord]] = {n: [] for n in policies}
        self._prev_pebs_host = 0.0
        self._prev_nb_host = 0.0
        if self.fused:
            L = len(self._lane_names)
            self._cfg = _FusedCfg(
                lanes=self._lane_names, n_blocks=self.n_blocks,
                k_hot=self.k_hot, ewma_alpha=self.ewma_alpha,
                hint_weight=self.hint_weight,
                nb_rate_limit=self.nb_rate_limit,
                reactive_hot_threshold=self.reactive_hot_threshold,
            )
            def zeros_n():
                # distinct buffers (not one shared array) so donation works
                return jnp.zeros((self.n_blocks,), jnp.int32)

            self._state = _FusedState(
                bundle=bundle,
                placement=Placement.create(self.n_blocks, self.k_hot, lanes=L),
                pred=jnp.zeros((self.n_blocks,), jnp.float32),
                hint_rank=jnp.asarray(self.hint_rank),
                prefetch_rank=jnp.asarray(self.prefetch_rank),
                prev_hmu=zeros_n(), prev_pebs=zeros_n(),
            )
            if mesh is not None:
                self._state = _shard_state(self._state, mesh, mesh_axis)
        else:
            self.bundle = bundle
            self._ref_lanes = {
                name: _Lane(
                    name=name,
                    slot_to_block=np.full((self.k_hot,), -1, np.int32),
                    block_to_slot=np.full((self.n_blocks,), -1, np.int32),
                    pred=(np.zeros((self.n_blocks,), np.float32)
                          if name == "proactive_ewma" else None),
                )
                for name in policies
            }
            # epoch-delta baselines (host copies, like the PR-1 loop)
            self._prev_true = np.zeros((n_blocks,), np.int64)
            self._prev_hmu = np.zeros((n_blocks,), np.int64)
            self._prev_pebs = np.zeros((n_blocks,), np.int64)

    # ---------------------------------------------------------- constructors
    @classmethod
    def for_scenario(cls, scenario, *, policies: Sequence[str] = ALL_POLICIES,
                     hints=None, prefetch_overlap: float = 1.0,
                     fused: bool = True, mesh=None, mesh_axis: str = "blocks",
                     **overrides) -> "EpochRuntime":
        """Build a runtime from an :class:`repro.scenarios.AccessScenario`'s
        geometry and cost-model parameters — the scenario supplies what the
        DLRM-shaped callers used to hand-wire (block count, hot-set size,
        per-access and per-block byte sizes, collector rates, memory system).
        ``overrides`` replace any constructor kwarg (e.g. ``ewma_alpha=``)."""
        kw = dict(
            policies=policies,
            system=scenario.system,
            bytes_per_access=scenario.bytes_per_access,
            block_bytes=scenario.block_bytes,
            pebs_period=scenario.pebs_period,
            nb_scan_rate=scenario.nb_scan_rate,
            hints=hints, prefetch_overlap=prefetch_overlap,
            fused=fused, mesh=mesh, mesh_axis=mesh_axis,
        )
        kw.update(overrides)
        return cls(scenario.n_blocks, scenario.k_hot, **kw)

    # ------------------------------------------------------- state accessors
    @property
    def lanes(self) -> Dict[str, _Lane]:
        """Per-lane placement view (host copies in fused mode)."""
        if not self.fused:
            return self._ref_lanes
        s2b = np.asarray(self._state.placement.slot_to_block)
        b2s = np.asarray(self._state.placement.block_to_slot)
        pred = np.asarray(self._state.pred)
        return {
            name: _Lane(
                name=name, slot_to_block=s2b[i], block_to_slot=b2s[i],
                pred=pred if name == "proactive_ewma" else None)
            for i, name in enumerate(self._lane_names)
        }

    @property
    def pending_migration_s(self) -> float:
        """Migration time of the prefetch lane's last boundary, not yet
        charged to any record: pending migration overlaps the NEXT epoch's
        stream, so at the end of a finite run the final boundary's cost has
        no epoch to land in.  Surfaced here (and in ``run_online``'s summary)
        so lane-total comparisons can account for it instead of it being
        silently dropped — every other lane charges its final boundary into
        its last record even though that migration serves no epoch either."""
        return self.system.migration_time_s(self._prefetch_pending,
                                            self.block_bytes)

    # ----------------------------------------------------------- hint refresh
    def set_hint_ranks(self, hint_rank: Optional[np.ndarray] = None,
                       prefetch_rank: Optional[np.ndarray] = None) -> None:
        """Replace the hint arrays the next epoch step reads.  On the fused
        path this swaps state leaves — a host-to-device transfer (sharded
        like the rest of the state under ``mesh``), not a dispatch, so the
        epoch stays at two; counted in ``DISPATCH_COUNTS['hint_refresh']``.
        An array that is the SAME object as the current one is skipped (the
        HintPipeline returns its cached static rank until the phase detector
        moves the scale), so an unchanged n-block hint_rank is not
        re-uploaded every epoch — the counter only ticks when something
        actually changed."""
        updates = {}
        if hint_rank is not None and hint_rank is not self.hint_rank:
            self.hint_rank = np.asarray(hint_rank, np.float32)
            updates["hint_rank"] = self.hint_rank
        if prefetch_rank is not None and prefetch_rank is not self.prefetch_rank:
            self.prefetch_rank = np.asarray(prefetch_rank, np.float32)
            updates["prefetch_rank"] = self.prefetch_rank
        if updates:
            DISPATCH_COUNTS["hint_refresh"] += 1
        if self.fused and updates:
            def put(x: np.ndarray) -> jax.Array:
                if self._mesh is None:
                    return jnp.asarray(x)
                from jax.sharding import NamedSharding, PartitionSpec as P
                return jax.device_put(
                    x, NamedSharding(self._mesh, P(self._mesh_axis)))

            self._state = dataclasses.replace(
                self._state, **{k: put(v) for k, v in updates.items()})

    # ------------------------------------------------------------- migrate
    def _apply_plan(self, lane: _Lane, plan: policy.MigrationPlan,
                    est: np.ndarray) -> Tuple[int, int]:
        """Reference path: promote the plan into the lane's bounded fast
        tier; evict plan-guarded coldest victims when no slots are free.
        Returns (promoted, demoted) block counts — each is one block copy of
        migration traffic."""
        want = _unique_in_order(np.asarray(plan.promote), self.k_hot)
        if want.size == 0:
            return 0, 0
        new = want[lane.block_to_slot[want] < 0]
        if new.size == 0:
            return 0, 0
        free = np.nonzero(lane.slot_to_block < 0)[0]
        demoted = 0
        need = new.size - free.size
        if need > 0:
            DISPATCH_COUNTS["reference"] += 1
            vic = np.asarray(policy.plan_eviction(
                jnp.asarray(est, jnp.float32), jnp.asarray(want),
                jnp.asarray(lane.slot_to_block), int(need)))
            vic = vic[vic >= 0]
            if vic.size:
                slots = lane.block_to_slot[vic]
                lane.slot_to_block[slots] = -1
                lane.block_to_slot[vic] = -1
                demoted = int(vic.size)
            free = np.nonzero(lane.slot_to_block < 0)[0]
        take = int(min(new.size, free.size))
        if take:
            sel, slots = new[:take], free[:take]
            lane.slot_to_block[slots] = sel
            lane.block_to_slot[sel] = slots
        return take, demoted

    def _demote_untouched(self, lane: _Lane, est: np.ndarray) -> int:
        """Watermark demotion: free every resident block the epoch never
        touched (est == 0) so reactive promotion has slots."""
        resident = lane.resident_ids()
        idle = resident[est[resident] == 0]
        if idle.size:
            slots = lane.block_to_slot[idle]
            lane.slot_to_block[slots] = -1
            lane.block_to_slot[idle] = -1
        return int(idle.size)

    # -------------------------------------------------------------- decide
    def _plan(self, lane: _Lane, d_hmu: np.ndarray, d_pebs: np.ndarray,
              nb_faults: np.ndarray, epoch_accesses: int,
              ) -> Tuple[policy.MigrationPlan, np.ndarray, int]:
        """Reference path: one lane's decide step -> (plan, estimate,
        pre-demotions)."""
        k = self.k_hot
        pre_demoted = 0
        DISPATCH_COUNTS["reference"] += 1
        if lane.name == "hmu_oracle":
            est = d_hmu
            plan = policy.oracle_top_k(jnp.asarray(est, jnp.int32), k)
        elif lane.name == "nb_two_touch":
            est = nb_faults
            plan = policy.nb_two_touch(jnp.asarray(est, jnp.int32), k,
                                       self.nb_rate_limit)
        elif lane.name == "reactive_watermark":
            est = d_hmu
            pre_demoted = self._demote_untouched(lane, est)
            free = int(np.sum(lane.slot_to_block < 0))
            thr = (self.reactive_hot_threshold
                   if self.reactive_hot_threshold is not None
                   else max(2, epoch_accesses // (8 * max(k, 1))))
            plan = policy.reactive_watermark(
                jnp.asarray(est, jnp.int32), int(thr),
                jnp.asarray(free), max_moves=k)
        elif lane.name == "proactive_ewma":
            pred, plan = policy.proactive_ewma(
                jnp.asarray(lane.pred), jnp.asarray(d_hmu, jnp.float32), k,
                alpha=self.ewma_alpha)
            lane.pred = np.asarray(pred)
            est = lane.pred
        elif lane.name == "hinted":
            est = d_pebs
            plan = policy.hinted(jnp.asarray(est, jnp.int32),
                                 jnp.asarray(self.hint_rank), k,
                                 hint_weight=self.hint_weight)
        elif lane.name == "prefetch":
            est = self.prefetch_rank
            plan = policy.prefetch(jnp.asarray(est), k)
        else:  # pragma: no cover - guarded in __init__
            raise ValueError(lane.name)
        return plan, np.asarray(est), pre_demoted

    # ---------------------------------------------------------------- step
    def step(self, batches, lookahead: Sequence = ()) -> Dict[str, EpochRecord]:
        """Consume one epoch ``(n_batches, batch_size)``: fused observe, then
        decide/migrate/account every lane.  ``lookahead`` is the queued
        upcoming epochs (the dataloader's prefetch queue) handed to the hint
        pipeline, if any.  Returns this epoch's records."""
        batches = np.ascontiguousarray(np.asarray(batches, np.int32))
        if batches.ndim != 2:
            raise ValueError(f"epoch batches must be 2-D, got {batches.shape}")
        if self.hints is not None:
            self.set_hint_ranks(*self.hints.epoch_ranks(batches, lookahead))
        if self.fused:
            return self._step_fused(batches)
        return self._step_reference(batches)

    def _record(self, name: str, n_fast: float, n_slow: float,
                host_events: float, promoted: int, demoted: int,
                resident: int, inter: int) -> EpochRecord:
        """Shared epoch accounting (host float64 scalar math, both paths)."""
        access_s = self.system.access_time_s(
            n_fast, n_slow, self.bytes_per_access)
        per_event = (NB_FAULT_COST_S if name == "nb_two_touch" else
                     PEBS_SAMPLE_COST_S if name == "hinted" else
                     0.0 if name == "prefetch" else
                     HMU_DRAIN_COST_S)
        host_tax_s = host_events * per_event
        hidden_s = 0.0
        if name == "prefetch":
            # Lookahead lets the prefetch lane issue its boundary migration
            # ahead of the epoch it serves, so the migration charged here is
            # the one issued at the PREVIOUS boundary — it streamed under
            # THIS epoch's accesses, and the overlapped share is hidden
            # (MemSystem.overlapped_epoch_time_s).  Every other lane pays its
            # boundary migration stop-the-world, same as before.
            moved = self._prefetch_pending
            self._prefetch_pending = promoted + demoted
            migration_s = self.system.migration_time_s(moved, self.block_bytes)
            hidden_s = self.system.migration_overlap_s(
                n_slow, self.bytes_per_access, moved, self.block_bytes,
                self.prefetch_overlap)
        else:
            migration_s = self.system.migration_time_s(
                promoted + demoted, self.block_bytes)
        return EpochRecord(
            epoch=self.epoch, lane=name,
            time_s=access_s + host_tax_s + migration_s - hidden_s,
            access_s=access_s, host_tax_s=host_tax_s, migration_s=migration_s,
            accuracy=(inter / resident) if resident else 0.0,
            coverage=(inter / self.k_hot) if self.k_hot else 0.0,
            resident=resident, promoted=promoted, demoted=demoted,
            host_events=host_events, hidden_s=hidden_s,
        )

    def _step_fused(self, batches: np.ndarray) -> Dict[str, EpochRecord]:
        state = self._state
        DISPATCH_COUNTS["observe_all"] += 1
        bundle = tel.observe_all(state.bundle, jnp.asarray(batches))
        state = dataclasses.replace(state, bundle=bundle)
        # static PEBS-positives bound, quantized to the next power of two so
        # ragged epoch sizes don't retrace the epoch program
        bound = int(batches.size) // state.bundle.pebs.period + 2
        s_max = min(self.n_blocks, 1 << (bound - 1).bit_length())
        DISPATCH_COUNTS["epoch_step"] += 1
        self._state, dev = _epoch_step(
            state, jnp.asarray(batches.size, jnp.int32),
            cfg=self._cfg, s_max=s_max)
        out_host = jax.device_get(dev)           # the only per-epoch sync
        pebs_host = float(out_host["pebs_host"])
        nb_host = float(out_host["nb_host"])
        d_pebs_host = pebs_host - self._prev_pebs_host
        d_nb_host = nb_host - self._prev_nb_host
        self._prev_pebs_host, self._prev_nb_host = pebs_host, nb_host
        drained = float(out_host["drained"])

        out: Dict[str, EpochRecord] = {}
        for i, name in enumerate(self._lane_names):
            host_events = (d_nb_host if name == "nb_two_touch" else
                           d_pebs_host if name == "hinted" else
                           0.0 if name == "prefetch" else drained)
            rec = self._record(
                name,
                n_fast=float(out_host["n_fast"][i]),
                n_slow=float(out_host["n_slow"][i]),
                host_events=host_events,
                promoted=int(out_host["promoted"][i]),
                demoted=int(out_host["demoted"][i]),
                resident=int(out_host["resident"][i]),
                inter=int(out_host["inter"][i]),
            )
            self.records[name].append(rec)
            out[name] = rec
        self.epoch += 1
        return out

    def _step_reference(self, batches: np.ndarray) -> Dict[str, EpochRecord]:
        epoch_accesses = int(batches.size)

        # -- observe (one dispatch) + drain the HMU log
        DISPATCH_COUNTS["observe_all"] += 1
        self.bundle = tel.observe_all(self.bundle, jnp.asarray(batches))
        drained = float(self.bundle.hmu.log_used)
        self.bundle = dataclasses.replace(
            self.bundle, hmu=tel.hmu_drain_cost(self.bundle.hmu))

        # -- epoch-local estimates (four full-array pulls per epoch)
        DISPATCH_COUNTS["reference"] += 4
        true_now = np.asarray(self.bundle.true_counts, np.int64)
        hmu_now = np.asarray(tel.hmu_estimate(self.bundle.hmu), np.int64)
        pebs_now = np.asarray(tel.pebs_estimate(self.bundle.pebs), np.int64)
        d_true = true_now - self._prev_true
        d_hmu = hmu_now - self._prev_hmu
        d_pebs = pebs_now - self._prev_pebs
        nb_faults = np.asarray(tel.nb_estimate(self.bundle.nb), np.int64)
        pebs_host = float(self.bundle.pebs.host_events)
        nb_host = float(self.bundle.nb.host_events)
        d_pebs_host = pebs_host - self._prev_pebs_host
        d_nb_host = nb_host - self._prev_nb_host
        self._prev_true, self._prev_hmu, self._prev_pebs = true_now, hmu_now, pebs_now
        self._prev_pebs_host, self._prev_nb_host = pebs_host, nb_host

        epoch_hot = metrics.true_top_k(d_true, self.k_hot)
        out: Dict[str, EpochRecord] = {}
        for lane in self._ref_lanes.values():
            # -- account the epoch under the placement that served it
            served = lane.resident_ids().copy()
            n_fast, n_slow = split_accesses_by_tier(d_true, lane.fast_mask)
            host_events = (d_nb_host if lane.name == "nb_two_touch" else
                           d_pebs_host if lane.name == "hinted" else
                           0.0 if lane.name == "prefetch" else drained)

            # -- decide + migrate for the NEXT epoch
            plan, est, pre_demoted = self._plan(
                lane, d_hmu, d_pebs, nb_faults, epoch_accesses)
            promoted, demoted = self._apply_plan(lane, plan, est)
            inter = int(np.intersect1d(served, epoch_hot).size)
            rec = self._record(
                lane.name, n_fast=n_fast, n_slow=n_slow,
                host_events=host_events, promoted=promoted,
                demoted=demoted + pre_demoted,
                resident=int(served.size), inter=inter,
            )
            self.records[lane.name].append(rec)
            out[lane.name] = rec
        self.epoch += 1
        return out

    # ----------------------------------------------------------------- run
    def run(self, epochs: Iterable) -> Trajectory:
        """Drive a whole epoch stream.  With a hint pipeline attached, the
        stream is buffered by the pipeline's lookahead depth so each ``step``
        sees the queued next epochs — the dataloader's prefetch queue, which
        is what the lookahead provider models.

        Each ``run`` is one workload: the prefetch lane's pending boundary
        migration is cleared on entry, so a runtime reused for a second
        ``run`` does not charge the previous stream's final boundary (already
        surfaced via :attr:`pending_migration_s`) against the new stream's
        first epoch."""
        self._prefetch_pending = 0
        depth = self.hints.lookahead_depth if self.hints is not None else 0
        it = iter(epochs)
        buf: deque = deque()                # current epoch + queued lookahead
        while True:
            if not buf:
                buf.extend(itertools.islice(it, 1))
                if not buf:
                    break
            batches = buf.popleft()
            buf.extend(itertools.islice(it, depth - len(buf)))
            self.step(batches, lookahead=tuple(buf))
        return self.trajectory()

    def trajectory(self) -> Trajectory:
        return Trajectory(n_blocks=self.n_blocks, k_hot=self.k_hot,
                          records=self.records)


def _shard_state(state: _FusedState, mesh, axis: str) -> _FusedState:
    """Distribute every (n_blocks,)-sized leaf (collector histograms, lane
    placements, EWMA state) over ``mesh``'s ``axis``; scalars and slot maps
    are replicated.  jit then partitions observe_all and epoch_step via
    GSPMD — the decision loop runs where the counters live."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    n_blocks = state.bundle.true_counts.shape[0]

    def put(x):
        if getattr(x, "ndim", 0) >= 1 and x.shape[-1] == n_blocks:
            spec = P(*([None] * (x.ndim - 1) + [axis]))
        else:
            spec = P()
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, state)
