"""Epoch-driven tiering runtime — observe -> decide -> migrate -> account.

The paper's headline numbers come from a one-shot profile->promote->replay
methodology; its §VI vision (reactive placement, proactive movement, compiler
hints from a programmable HMU) is inherently *online*.  This module is that
online regime: a loop over epochs in which

  1. **observe**  — the whole epoch's access stream is fed to all three
     collectors (HMU / PEBS / NB) and the ground-truth counter in ONE jit
     dispatch (``telemetry.observe_all``'s ``lax.scan``),
  2. **decide**   — every policy lane (five of them, one per §VI strategy)
     turns its collector's *epoch-local* estimate into a migration plan,
  3. **migrate**  — promotions are applied against a bounded fast tier;
     when slots run out the lane demotes plan-guarded coldest victims first,
  4. **account**  — the epoch is charged: modeled access time under the
     placement that actually *served* it (decided from data up to the
     previous epoch — no time travel), plus the collector's host tax and the
     epoch's migration traffic; accuracy/coverage are scored against the
     epoch's own true top-K.

**Dispatch accounting.**  Steps 2-4 are one jit'd ``_epoch_step`` that keeps
every lane's placement state — a lane-stacked :class:`~repro.core.placement.
Placement` plus the EWMA predictor — resident on device and ``vmap``s the
policy/migration kernels over the lane axis, so a whole epoch is exactly
**two dispatches** (``observe_all`` + ``epoch_step``; counted in
:data:`DISPATCH_COUNTS`, traced-once proven via :data:`TRACE_COUNTS`) and
only the :class:`EpochRecord` fields cross the device boundary.
Per-lane branching is a lane-config tuple (estimate source, selection
threshold, move cap, hint weight) baked into the trace; top-k selection uses
:mod:`~repro.core.selectk`'s O(n) kernels instead of full-length sorts.  The
pre-refactor per-lane host loop (five policy lanes x several small jits +
four full-array pulls per epoch) is preserved as ``fused=False`` — the
bit-identity reference and the benchmark baseline.

**Pipelined record sync.**  The record fields themselves are accumulated on
device: ``_epoch_step`` writes each epoch's scalars, per-lane counters, and
per-tenant rows into row ``out_row`` of a stacked ``(sync_every,)`` buffer
pytree (``_FusedState.out_buf`` — ``out_row`` is a traced scalar, so K
boundaries never retrace), and the host pulls the whole buffer in ONE
``jax.device_get`` every ``sync_every`` epochs (counted in
``DISPATCH_COUNTS["record_sync"]``; partial tail flushed on loop exit).
With ``sync_every=1`` (default) the loop is the historical synchronous one;
with K>1 the flush happens *after* the next epoch's ``observe_all`` is
dispatched, so the host assembles :class:`EpochRecord`\\ s — cumulative
host-tax deltas and the prefetch lane's pending-migration chain replayed in
dispatch order, hence bit-identical for every K — while the device streams
ahead.  Both jits donate their state operand (``donate_argnums=0``), so the
loop also never copies the collector/placement buffers; the telemetry that
"observes without interfering" finally stops interfering with itself.
Donation bounds the pipeline depth: a donated operand must be *ready*
before its dispatch returns, so the host runs at most one epoch ahead of
the device — enough to overlap all its per-epoch work (hint refresh,
record assembly) with the in-flight step.  That overlap is real freed time
wherever host and device are separate resources (accelerator backends, a
multi-core host); on a single-core CPU host the two share the core and the
loop is throughput-neutral — which is why the benchmark gates below are
*structural* (sync count, dispatch count, bit-identity), not a wall-clock
ratio.

Policy lanes and their telemetry sources:

=================  =========================  ===============================
lane               estimate                   host tax per epoch
=================  =========================  ===============================
hmu_oracle         HMU epoch-delta counts     log drain (~ns/record)
nb_two_touch       NB cumulative faults       hint faults (~2 us each)
reactive_watermark HMU epoch-delta counts     log drain
proactive_ewma     EWMA of HMU epoch deltas   log drain
hinted             PEBS epoch-delta estimate  PEBS samples (~1.5 us each)
                   blended with static hints
prefetch           lookahead window over the  none (compiler hints are free
                   queued next-epoch batches  at run time)
=================  =========================  ===============================

**Hints.**  The ``hinted`` and ``prefetch`` lanes' rank arrays come from a
:class:`~repro.hints.HintPipeline` (``hints=`` at construction): per epoch
the pipeline's providers (static table analysis, bounded lookahead over the
batch queue, EWMA phase-change re-weighting) produce fresh ``hint_rank`` /
``prefetch_rank`` arrays which replace state leaves before the epoch step —
a host-to-device transfer counted in ``DISPATCH_COUNTS["hint_refresh"]``,
*not* a third dispatch.  The ``prefetch`` lane promotes blocks the lookahead
says the next epoch will touch, before the accesses land; its boundary
migration therefore streams concurrently with the epoch it serves, charged
component-wise in ``_record`` (access + migration - hidden overlap) —
equivalent to ``MemSystem.overlapped_epoch_time_s``, parity-tested in
``test_core_tiering`` — with the migration issued at the *previous* boundary
charged against the epoch it overlapped and its hidden share recorded in
``EpochRecord.hidden_s``.

**Multi-tenancy.**  A :class:`Tenancy` (built by ``repro.fleet``) declares
how one shared block space splits into per-tenant id ranges, each tenant's
true-hot-set size, and optional per-tenant quotas.  With quotas, every
lane's top-k select becomes *segment-capped* (``selectk.segment_top_k_mask``
masks each key row to each tenant's own top-``caps[t]`` before the global
select), so a noisy tenant cannot crowd a quiet one out of any lane's
candidate list — and because ``apply_plan`` never evicts a still-wanted
resident while ``sum(caps) <= k_hot``, a tenant's capped want is *admitted*
unconditionally: quotas are isolation guarantees.  Per-tenant accounting
(tenant-segment reductions over the per-block ``tenant_id`` state leaf plus
each tenant's own top-``hot_k[t]`` hot set) rides in the same single
device->host sync as the scalar record fields, one (L, T) row set per
epoch in ``EpochRuntime.tenant_records``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import json
from collections import deque
from functools import partial
from typing import Dict, Iterable, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from . import metrics, policy, selectk
from . import telemetry as tel
from ..faults.model import (CARRY_BASE, COLLECTORS, LANE_COLLECTOR,
                            FaultModel, Hardening)
from ..kernels.dispatch import PallasBackend, resolve_backend
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .costmodel import CXL_SYSTEM, MemSystem, split_accesses_by_tier
from .placement import Placement, apply_plan, demote_idle

__all__ = [
    "ALL_POLICIES", "DISPATCH_COUNTS", "TRACE_COUNTS",
    "Counters", "counting",
    "EpochRecord", "EpochRuntime", "Tenancy", "Trajectory",
]

ALL_POLICIES = (
    "hmu_oracle", "nb_two_touch", "reactive_watermark", "proactive_ewma",
    "hinted", "prefetch",
)

# Host-side cost per telemetry event (see dlrm.tracesim for the NB/PEBS
# calibration; HMU pays only bulk log processing — the paper's 'process the
# trace immediately', which NMC would shrink further).
NB_FAULT_COST_S = 2e-6
PEBS_SAMPLE_COST_S = 1.5e-6
HMU_DRAIN_COST_S = 2e-9

# Python-side counters.  TRACE_COUNTS ticks once per (shape, config) trace of
# the fused step — tests prove the epoch loop compiles once.  DISPATCH_COUNTS
# ticks per *call*: a fused epoch is exactly observe_all + epoch_step; the
# reference path's count grows with every policy-lane jit/eager op and
# full-array pull it issues.  "hint_refresh" counts HintPipeline refreshes —
# host-to-device transfers of the rank arrays, not dispatches — so the
# 2-dispatch/epoch claim stays auditable with hints enabled.  "record_sync"
# counts device->host record pulls (one batched ``jax.device_get`` of the
# stacked ``(sync_every,)`` record buffer): the synchronous loop pays one
# per epoch, ``sync_every=K`` exactly ceil(n_epochs / K) — the benchmark
# gate that keeps a reintroduced per-epoch host sync from landing.
#
# Since the repro.obs PR both dicts are CounterDict views over the process
# metrics registry (repro_trace_total / repro_dispatch_total, labelled by
# kind) so the same counts are scrapeable; the dict API and the never-zeroed
# reentrancy contract below are unchanged.
TRACE_COUNTS = obs_metrics.CounterDict(
    obs_metrics.REGISTRY.counter(
        "repro_trace_total",
        help="XLA (re)traces of the fused epoch step / observe_all"),
    "kind", keys=("epoch_step",))
DISPATCH_COUNTS = obs_metrics.CounterDict(
    obs_metrics.REGISTRY.counter(
        "repro_dispatch_total",
        help="Host->device dispatches and transfers by kind"),
    "kind", keys=("observe_all", "epoch_step", "reference",
                  "hint_refresh", "record_sync"))


class _CounterView:
    """Read-only scope-relative view of one live counter dict: each key reads
    as (current total) - (total at scope entry).  The live dict is never
    mutated, so any number of views — nested, overlapping, or read while an
    inner scope is open — stay correct simultaneously."""

    def __init__(self, live: Dict[str, int]):
        self._live = live
        self._base = dict(live)

    def __getitem__(self, key: str) -> int:
        if key not in self._live:       # fail fast like the dicts it wraps:
            raise KeyError(key)         # a typo'd gate must not read as 0
        return self._live[key] - self._base.get(key, 0)

    def get(self, key: str, default: int = 0) -> int:
        return self[key] if key in self._live else default

    def __contains__(self, key: str) -> bool:
        return key in self._live

    def __iter__(self):
        return iter(self._live)

    def keys(self):
        return self._live.keys()

    def items(self):
        return [(k, self[k]) for k in self._live]

    def __eq__(self, other) -> bool:
        if isinstance(other, _CounterView):
            other = dict(other.items())
        return dict(self.items()) == other

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"_CounterView({dict(self.items())!r})"


class Counters(NamedTuple):
    """The scope-relative counter views a :func:`counting` block observes
    (zero-based at entry): per-call dispatches, epoch_step traces, and the
    telemetry module's observe_all traces."""
    dispatch: _CounterView
    trace: _CounterView
    observe_trace: _CounterView


@contextlib.contextmanager
def counting():
    """Scoped view of the dispatch/trace counters.

    ``DISPATCH_COUNTS``, ``TRACE_COUNTS`` and ``telemetry.TRACE_COUNTS`` are
    module-level mutable dicts, so raw reads leak activity across tests and
    benchmark runs.  ``with counting() as c:`` snapshots all three at entry
    and hands back views that read each counter relative to that snapshot —
    ``c.dispatch`` etc. show exactly the activity since the block started.

    The live dicts are never zeroed or restored, which makes the scope
    safely **nestable**: an earlier implementation zeroed the dicts in
    place, so re-entering ``counting()`` (as :func:`repro.fleet.run_fleet`
    does around its per-tenant solo sub-runs) blanked the outer scope's
    accrual while the inner scope was open.  Now an outer view keeps
    reading correctly at any point — before, during, and after any number
    of inner scopes — and inner activity accrues outward, so enclosing
    accounting stays monotonic.
    """
    yield Counters(_CounterView(DISPATCH_COUNTS), _CounterView(TRACE_COUNTS),
                   _CounterView(tel.TRACE_COUNTS))


@dataclasses.dataclass
class EpochRecord:
    """One lane's accounting for one epoch."""
    epoch: int
    lane: str
    time_s: float            # access + host tax + migration
    access_s: float
    host_tax_s: float
    migration_s: float
    accuracy: float          # placement that served the epoch vs epoch top-K
    coverage: float
    resident: int            # fast blocks during the epoch
    promoted: int            # migrations applied at epoch end
    demoted: int
    host_events: float       # telemetry events charged this epoch
    hidden_s: float = 0.0    # migration time overlapped away (prefetch lane)
    quality: float = 1.0     # smoothed quality of the lane's primary
                             # collector (1.0 without hardening / for the
                             # collector-free prefetch lane)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Trajectory:
    """Per-epoch time series for every lane (the runtime's output)."""
    n_blocks: int
    k_hot: int
    records: Dict[str, List[EpochRecord]]

    def lane(self, name: str) -> List[EpochRecord]:
        return self.records[name]

    def times(self, name: str) -> np.ndarray:
        return np.array([r.time_s for r in self.records[name]])

    def to_json(self, **meta) -> str:
        return json.dumps({
            "n_blocks": self.n_blocks,
            "k_hot": self.k_hot,
            **meta,
            "lanes": {name: [r.to_dict() for r in recs]
                      for name, recs in self.records.items()},
        }, indent=1)


@dataclasses.dataclass
class _Lane:
    """Per-policy placement state of the *reference* path (host numpy maps;
    the fused path holds the same state lane-stacked in a Placement)."""
    name: str
    slot_to_block: np.ndarray            # (k,) int32, -1 = free
    block_to_slot: np.ndarray            # (n_blocks,) int32, -1 = slow-only
    pred: Optional[np.ndarray] = None    # EWMA state (proactive lane)

    @property
    def fast_mask(self) -> np.ndarray:
        return self.block_to_slot >= 0

    def resident_ids(self) -> np.ndarray:
        s = self.slot_to_block
        return s[s >= 0]


def _unique_in_order(ids: np.ndarray, k: int) -> np.ndarray:
    """Valid plan ids, de-duplicated preserving priority order, capped at k."""
    ids = np.asarray(ids).reshape(-1)
    ids = ids[ids >= 0]
    _, first = np.unique(ids, return_index=True)
    return ids[np.sort(first)][:k]


class Tenancy(NamedTuple):
    """Static multi-tenant layout of one shared block space (``repro.fleet``).

    ``offsets`` are the cumulative block offsets of the per-tenant id ranges
    (length T+1, ``offsets[0] == 0``, ``offsets[-1] == n_blocks``); tenant
    ``t`` owns global ids ``[offsets[t], offsets[t+1])``.  ``hot_k`` is each
    tenant's true-hot-set size — the denominator of its per-tenant coverage,
    i.e. the fast-tier target the tenant would run solo — and ``caps`` are
    per-tenant admission quotas applied to every lane's migration plan each
    epoch (``None`` = shared pool, no quota enforcement).  A tenant whose
    plan is quota-capped still gets its first ``caps[t]`` wanted blocks
    admitted *unconditionally* whenever ``sum(caps) <= k_hot``, because
    ``placement.apply_plan`` never evicts a still-wanted resident ahead of a
    free slot — admission quotas are therefore isolation guarantees, not
    just rate limits.  Hashable: baked into the fused trace like the rest
    of ``_FusedCfg``."""
    offsets: Tuple[int, ...]
    hot_k: Tuple[int, ...]
    caps: Optional[Tuple[int, ...]] = None

    @property
    def n_tenants(self) -> int:
        return len(self.offsets) - 1

    @property
    def sizes(self) -> Tuple[int, ...]:
        return tuple(b - a for a, b in zip(self.offsets, self.offsets[1:]))

    def block_tenants(self) -> np.ndarray:
        """Per-block tenant ids, (n_blocks,) int32 — the fused state leaf."""
        return np.repeat(np.arange(self.n_tenants, dtype=np.int32),
                         self.sizes)

    def validate(self, n_blocks: int, k_hot: int) -> None:
        offs = self.offsets
        if len(offs) < 2 or offs[0] != 0 or offs[-1] != n_blocks or any(
                b <= a for a, b in zip(offs, offs[1:])):
            raise ValueError(f"tenancy offsets must be strictly increasing "
                             f"from 0 to n_blocks={n_blocks}, got {offs}")
        if len(self.hot_k) != self.n_tenants or any(
                not 0 < h <= s for h, s in zip(self.hot_k, self.sizes)):
            raise ValueError(f"hot_k must give every tenant a size in "
                             f"(0, n_tenant_blocks], got {self.hot_k}")
        if self.caps is not None:
            if len(self.caps) != self.n_tenants or any(
                    c < 0 for c in self.caps):
                raise ValueError(f"caps must be one non-negative quota per "
                                 f"tenant, got {self.caps}")
            if sum(self.caps) > k_hot:
                raise ValueError(f"tenant caps sum to {sum(self.caps)} > "
                                 f"k_hot={k_hot}; quotas must fit the fast "
                                 f"tier for admission to be guaranteed")


# ======================================================  fused device step
class _FusedCfg(NamedTuple):
    """Hashable static config baked into the epoch_step trace."""
    lanes: Tuple[str, ...]
    n_blocks: int
    k_hot: int
    ewma_alpha: float
    hint_weight: float
    nb_rate_limit: Optional[int]
    reactive_hot_threshold: Optional[int]
    tenancy: Optional[Tenancy] = None
    hardening: Optional[Hardening] = None
    pallas: Optional[PallasBackend] = None


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class _FusedState:
    """Everything the epoch loop mutates, resident on device between epochs."""
    bundle: tel.TelemetryBundle
    placement: Placement         # lane-stacked: (L, k_hot) / (L, n_blocks)
    pred: jax.Array              # (n_blocks,) f32 EWMA (the proactive lane's)
    hint_rank: jax.Array         # (n_blocks,) f32 static priorities
    prefetch_rank: jax.Array     # (n_blocks,) f32 lookahead priorities
    prev_hmu: jax.Array          # (n_blocks,) i32 epoch-delta baselines
    prev_pebs: jax.Array
    tenant_id: jax.Array         # (n_blocks,) i32 tenant of each block
                                 # (all-zero without a Tenancy)
    out_buf: Dict[str, jax.Array]
                                 # stacked (sync_every,)-leading record
                                 # fields: scalars (K,), per-lane (K, L),
                                 # per-tenant (K, L, T) — the batched-sync
                                 # accumulator, donated like everything else
    # --- robustness leaves (None = subsystem off; presence keys the trace,
    #     so a fault-free runtime compiles exactly the seed program) -------
    prev_true: Optional[jax.Array] = None
                                 # (n_blocks,) i32 ground-truth baseline;
                                 # only with faults (d_hmu is no longer the
                                 # truth, so accounting keeps its own delta)
    stale: Optional[jax.Array] = None
                                 # (stale_epochs+1, 3, n_blocks) i32 delay
                                 # ring of [d_hmu, d_pebs, nb] estimates
    stale_ptr: Optional[jax.Array] = None      # () i32 ring write position
    quality: Optional[jax.Array] = None
                                 # (3,) f32 smoothed per-collector quality
                                 # (COLLECTORS order; hardening only)
    prev_nb: Optional[jax.Array] = None
                                 # (n_blocks,) i32 last served NB faults —
                                 # the NB quality signal's epoch baseline
    nb_ewma: Optional[jax.Array] = None
                                 # () f32 EWMA of NB epoch fault mass (the
                                 # "expected" NB signal quality divides by)
    cold_streak: Optional[jax.Array] = None
                                 # (L, n_blocks) i32 consecutive-cold-epoch
                                 # counters (demote hysteresis H > 1 only)


def _out_buf_init(sync_every: int, n_lanes: int,
                  tenancy: Optional[Tenancy],
                  hardening: Optional[Hardening] = None):
    """Zeroed device accumulator for ``sync_every`` epochs of record fields.
    Dtypes mirror what ``_epoch_step`` computes (hi/lo i32 collector
    scalars, i32 lane counts) so the buffered write is a pure row store —
    pulling row j yields bit-identical values to the per-epoch sync it
    replaces."""
    K, L = int(sync_every), int(n_lanes)

    def scal():
        return jnp.zeros((K,), jnp.int32)

    def lane():
        return jnp.zeros((K, L), jnp.int32)

    buf = {
        # collector event scalars ride as exact hi/lo int32 pairs (the
        # device carries them as faults.Counter64; the host recombines in
        # float64, exact to 2**53)
        "drained_hi": scal(), "drained_lo": scal(),
        "pebs_host_hi": scal(), "pebs_host_lo": scal(),
        "nb_host_hi": scal(), "nb_host_lo": scal(),
        "n_fast": lane(), "n_slow": lane(),
        "inter": lane(), "resident": lane(),
        "promoted": lane(), "demoted": lane(),
    }
    if hardening is not None:
        buf["quality"] = jnp.zeros((K, 3), jnp.float32)
    if tenancy is not None:
        T = tenancy.n_tenants
        buf["tenant"] = {
            key: jnp.zeros((K, L, T), jnp.int32)
            for key in ("n_fast", "n_slow", "inter", "resident",
                        "promoted", "demoted")
        }
    return buf


@partial(jax.jit, static_argnames=("cfg", "s_max"), donate_argnums=0)
def _epoch_step(state: _FusedState, epoch_accesses: jax.Array,
                out_row: jax.Array, *, cfg: _FusedCfg, s_max: int):
    """decide + migrate + account for every lane in ONE dispatch.

    ``epoch_accesses`` is traced and ``s_max`` (the static PEBS-positives
    bound) is quantized by the caller, so ragged epoch sizes share traces
    instead of recompiling the five-lane program per unique size.  The
    per-lane integer/scalar outputs the host needs to assemble
    :class:`EpochRecord`s are written into row ``row`` (traced, so neither
    the row position nor a ``sync_every`` boundary retraces) of the
    donated ``state.out_buf`` accumulator and ride back inside the state —
    nothing leaves the device until the runtime's batched record sync
    pulls the stacked buffer, and nothing (n_blocks,)-sized ever does.
    """
    TRACE_COUNTS["epoch_step"] += 1
    lanes, n, k = cfg.lanes, cfg.n_blocks, cfg.k_hot
    har = cfg.hardening
    b = state.bundle
    faulty = b.faults is not None

    # -- drain the HMU log (host tax charged below from the drained count)
    drained = b.hmu.log_used
    bundle = dataclasses.replace(b, hmu=tel.hmu_drain_cost(b.hmu))

    # -- epoch-local estimates (deltas against the previous epoch's totals).
    #    Without faults the HMU counter is exact, so d_hmu *is* the epoch's
    #    ground truth (bit-identical to d_true) — the oracle lane's selection
    #    doubles as the epoch-hot set and true_counts never needs its own
    #    ranking.  With faults the runtime carries its own prev_true
    #    baseline: accounting stays ground-truth while the lanes see only
    #    what their (degraded) collectors deliver.
    true_now = b.true_counts
    hmu_now = b.hmu.counts
    pebs_now = b.pebs.sampled * b.pebs.period
    d_hmu = hmu_now - state.prev_hmu
    d_pebs = pebs_now - state.prev_pebs
    nb_faults = b.nb.faults
    d_true = (true_now - state.prev_true if state.prev_true is not None
              else d_hmu)

    # -- staleness: the policies read estimates from a delay ring this
    #    epoch's deltas are only written into — served values are
    #    stale_epochs old (zeros while the ring warms up).  Accounting
    #    (d_true) is never delayed: the workload really happened now.
    if state.stale is not None:
        depth = state.stale.shape[0]
        stale_new = state.stale.at[state.stale_ptr].set(
            jnp.stack([d_hmu, d_pebs, nb_faults]))
        serve_at = (state.stale_ptr + 1) % depth
        served = stale_new[serve_at]
        d_hmu, d_pebs, nb_faults = served[0], served[1], served[2]
        stale_ptr_new = serve_at
    else:
        stale_new = stale_ptr_new = None
    if faulty:
        # reset events shrink cumulative collector state, so a delta can go
        # negative — "no information this epoch", never negative hotness
        d_hmu = jnp.maximum(d_hmu, 0)
        d_pebs = jnp.maximum(d_pebs, 0)
    d_hmu_f = d_hmu.astype(jnp.float32)

    # -- per-collector quality: observed epoch mass vs expected (hardening).
    #    HMU and period-scaled PEBS should both report ~the epoch's access
    #    mass; NB's expectation is its own smoothed fault-mass history.
    #    Saturation, drops, resets and stalls all shrink observed mass, so
    #    one EWMA-smoothed scalar per collector covers every fault lane.
    if har is not None:
        exp_mass = jnp.maximum(epoch_accesses.astype(jnp.float32), 1.0)
        obs_hmu = jnp.sum(d_hmu).astype(jnp.float32)
        obs_pebs = jnp.sum(d_pebs).astype(jnp.float32)
        d_nb = jnp.maximum(nb_faults - state.prev_nb, 0)
        obs_nb = jnp.sum(d_nb).astype(jnp.float32)
        q_raw = jnp.stack([
            policy.quality_estimate(obs_hmu, exp_mass),
            policy.quality_estimate(obs_pebs, exp_mass),
            jnp.where(state.nb_ewma > 0.0,
                      policy.quality_estimate(obs_nb, state.nb_ewma), 1.0),
        ])
        quality_new = policy.quality_smooth(state.quality, q_raw,
                                            har.quality_beta)
        nb_ewma_new = policy.quality_smooth(state.nb_ewma, obs_nb,
                                            har.quality_beta)
        prev_nb_new = nb_faults
    else:
        quality_new = nb_ewma_new = prev_nb_new = None

    thr = (cfg.reactive_hot_threshold
           if cfg.reactive_hot_threshold is not None
           else jnp.maximum(2, epoch_accesses // (8 * max(k, 1))))

    # -- per-lane selection keys (int32; floats via order-isomorphic bitcast),
    #    eviction estimates, and selection gates: the lane-config arrays that
    #    replace the per-lane Python branching.  Lanes that rank the same
    #    signal (oracle + reactive + the epoch-hot set all rank d_hmu) share
    #    one selection row.
    rows: Dict[str, Tuple[jax.Array, jax.Array]] = {}

    def row(rkey: str, key: jax.Array, est: jax.Array) -> int:
        if rkey not in rows:
            rows[rkey] = (key, est)
        return list(rows).index(rkey)

    hmu_row = row("hmu", d_hmu, d_hmu_f)
    # -- collector fallback (hardening): when a lane's primary collector's
    #    smoothed quality is below the floor, the lane's selection key AND
    #    eviction estimate are swapped — branchlessly, one jnp.where on the
    #    quality scalar — to the named healthy collector's served delta.
    fb_map = dict(har.fallback) if har is not None else {}
    col_key = {"hmu": d_hmu, "pebs": d_pebs, "nb": nb_faults}

    def fall_back(name: str, key: jax.Array, est: jax.Array):
        alt = col_key[fb_map[name]]
        ok = quality_new[COLLECTORS.index(LANE_COLLECTOR[name])] \
            >= har.quality_floor
        return ok, jnp.where(ok, key, alt), \
            jnp.where(ok, est, alt.astype(jnp.float32))

    pred_new = state.pred
    lane_row, min_keys, caps, is_reactive, healthy = [], [], [], [], []
    for name in lanes:
        if name == "hmu_oracle":
            r, min_key, cap = hmu_row, 1, k
            key, est = d_hmu, d_hmu_f
        elif name == "nb_two_touch":
            cap = k if cfg.nb_rate_limit is None else min(k, cfg.nb_rate_limit)
            min_key = 2
            r = row("nb", nb_faults, nb_faults.astype(jnp.float32))
            key, est = nb_faults, nb_faults.astype(jnp.float32)
        elif name == "reactive_watermark":
            r, min_key, cap = hmu_row, 0, k      # 0 = thr placeholder (traced)
            key, est = d_hmu, d_hmu_f
        elif name == "proactive_ewma":
            pred_new = (cfg.ewma_alpha * d_hmu_f
                        + (1.0 - cfg.ewma_alpha) * state.pred)
            key, est = selectk.sortable_key(pred_new), pred_new
            r = row("pred", key, est)
            min_key, cap = 1, k
        elif name == "hinted":
            # exact argsort(argsort(d_pebs)): positives are bounded by this
            # epoch's PEBS samples, so rank the sparse support only
            t_rank = selectk.stable_rank_sparse(d_pebs, s_max)
            score = policy.hinted_score(d_pebs, t_rank, state.hint_rank,
                                        cfg.hint_weight)
            key, est = selectk.sortable_key(score), d_pebs.astype(jnp.float32)
            r = row("score", key, est)
            min_key, cap = 0, k
        elif name == "prefetch":
            # lookahead rank in [0,1]; min_key 1 gates rank > 0 (int32 bits of
            # any positive float are >= 1), matching policy.prefetch's gate
            r = row("la", selectk.sortable_key(state.prefetch_rank),
                    state.prefetch_rank)
            min_key, cap = 1, k
        else:  # pragma: no cover - guarded in __init__
            raise ValueError(name)
        if name in fb_map:
            ok, key, est = fall_back(name, key, est)
            r = row(f"fb:{name}", key, est)
            healthy.append(ok)
        else:
            healthy.append(None)
        lane_row.append(r)
        min_keys.append(min_key)
        caps.append(cap)
        is_reactive.append(name == "reactive_watermark")

    key_rows = jnp.stack([kv[0] for kv in rows.values()])   # (U, n) int32
    est_rows = jnp.stack([kv[1] for kv in rows.values()])   # (U, n) f32
    lane_row = np.asarray(lane_row)
    est_lanes = est_rows[lane_row]                          # (L, n) f32
    reactive_arr = jnp.asarray(is_reactive)
    min_key_arr = jnp.where(reactive_arr, thr,
                            jnp.asarray(min_keys, jnp.int32))
    if fb_map:
        # a fallen-back lane keys on a raw collector delta whatever its
        # normal key space was; gate at >= max(min_key, 1) so zero-signal
        # blocks are never promoted just to fill k
        healthy_arr = jnp.stack([jnp.asarray(True) if h is None else h
                                 for h in healthy])
        min_key_arr = jnp.where(healthy_arr, min_key_arr,
                                jnp.maximum(min_key_arr, 1))
    min_key_arr = min_key_arr[:, None]
    cap_arr = jnp.asarray(caps, jnp.int32)

    # -- multi-tenant quotas: a segment-capped select replaces the global
    #    one.  Every unique key row is masked to int32.min outside each
    #    tenant's own top-caps[t] (selectk.segment_top_k_mask over the
    #    static tenant bounds), so a lane's top-k candidate list always
    #    carries every tenant's best blocks BY THAT LANE'S KEY — a noisy
    #    neighbour can no longer crowd a quieter tenant out of selection.
    #    Masked entries fail every lane's value gate (all min_keys >= 0).
    #    The epoch's true hot set stays unmasked: it is workload truth,
    #    not policy.
    ten = cfg.tenancy
    quotas = ten is not None and ten.caps is not None
    if quotas:
        protected = selectk.segment_top_k_mask(key_rows, ten.offsets,
                                               ten.caps, backend=cfg.pallas)
        key_rows = jnp.where(protected, key_rows,
                             jnp.iinfo(jnp.int32).min)

    # -- one O(n) selection per unique signal, fanned out to lanes
    vals_u, ids_u, sel_u = selectk.select_top_k(key_rows, k, return_mask=True,
                                                backend=cfg.pallas)
    vals, ids = vals_u[lane_row], ids_u[lane_row]           # (L, k)

    # -- account the epoch under the placement that served it
    #    (pre-migration).  The hot set is workload truth: with faults or
    #    staleness the hmu selection row no longer ranks the truth, so it
    #    gets its own exact top-K; otherwise the oracle row doubles as it.
    hot = (selectk.top_k_mask(d_true, k, backend=cfg.pallas)
           if quotas or faulty or state.stale is not None
           else sel_u[hmu_row])                    # epoch's true top-K set
    fast0 = state.placement.fast_mask              # (L, n)
    n_fast = jnp.sum(jnp.where(fast0, d_true, 0), axis=-1)
    n_slow = jnp.sum(d_true) - n_fast
    inter = jnp.sum((fast0 & hot).astype(jnp.int32), axis=-1)
    resident0 = state.placement.resident()

    # -- decide: ordered top-k ids per lane, gated per lane config.  With
    #    demote hysteresis a resident block must have looked cold for H
    #    consecutive epochs before the watermark lane frees its slot.
    demote_enable = reactive_arr[:, None]
    if state.cold_streak is not None:
        cold_streak_new = policy.cold_streak(state.cold_streak, est_lanes,
                                             fast0)
        demote_enable = demote_enable & (
            cold_streak_new >= har.demote_hysteresis)
    else:
        cold_streak_new = None
    pl, pre_demoted = demote_idle(state.placement, est_lanes, demote_enable)
    free_slots = jnp.sum((pl.slot_to_block < 0).astype(jnp.int32), axis=-1)
    cap_eff = jnp.where(reactive_arr, jnp.minimum(cap_arr, free_slots),
                        cap_arr)
    ok = (vals >= min_key_arr) & (jnp.arange(k, dtype=jnp.int32)[None, :]
                                  < cap_eff[:, None])
    want = jnp.where(ok, ids, -1)

    # -- migrate: bounded promotion with plan-guarded coldest-victim eviction
    pl, promoted, demoted = apply_plan(pl, want, est_lanes)

    out = {
        "drained_hi": drained.hi, "drained_lo": drained.lo,
        "pebs_host_hi": bundle.pebs.host_events.hi,
        "pebs_host_lo": bundle.pebs.host_events.lo,
        "nb_host_hi": bundle.nb.host_events.hi,
        "nb_host_lo": bundle.nb.host_events.lo,
        "n_fast": n_fast, "n_slow": n_slow,
        "inter": inter, "resident": resident0,
        "promoted": promoted, "demoted": demoted + pre_demoted,
    }
    if har is not None:
        out["quality"] = quality_new
    if ten is not None:
        # Per-tenant accounting: tenant-segment reductions of the same masks
        # the global record sums, plus each tenant's own true-hot set (top
        # hot_k[t] of its id range — the coverage target it would have solo).
        # All outputs are (L, T) scalars-per-tenant; nothing (n,)-sized
        # leaves the device.
        tsum = partial(_per_tenant_sum, tenant_id=state.tenant_id,
                       n_tenants=ten.n_tenants)
        hot_parts = [
            selectk.top_k_mask(
                jax.lax.slice_in_dim(d_true, ten.offsets[t],
                                     ten.offsets[t + 1]),
                ten.hot_k[t], backend=cfg.pallas)
            for t in range(ten.n_tenants)
        ]
        t_hot = jnp.concatenate(hot_parts)
        fast1 = pl.fast_mask
        out["tenant"] = {
            "n_fast": tsum(jnp.where(fast0, d_true, 0)),
            "n_slow": tsum(jnp.where(fast0, 0, d_true)),
            "inter": tsum(fast0 & t_hot),
            "resident": tsum(fast0),
            "promoted": tsum(fast1 & ~fast0),
            "demoted": tsum(fast0 & ~fast1),
        }
    # -- append this epoch's record row to the device-side accumulator
    #    (same pytree structure as out; dtypes fixed by _out_buf_init)
    out_buf = jax.tree_util.tree_map(
        lambda buf, v: buf.at[out_row].set(v.astype(buf.dtype)),
        state.out_buf, out)
    updates = dict(
        bundle=bundle, placement=pl, pred=pred_new,
        prev_hmu=hmu_now, prev_pebs=pebs_now, out_buf=out_buf,
    )
    if state.prev_true is not None:
        updates["prev_true"] = true_now
    if state.stale is not None:
        updates.update(stale=stale_new, stale_ptr=stale_ptr_new)
    if har is not None:
        updates.update(quality=quality_new, nb_ewma=nb_ewma_new,
                       prev_nb=prev_nb_new)
    if state.cold_streak is not None:
        updates["cold_streak"] = cold_streak_new
    return dataclasses.replace(state, **updates)


def _per_tenant_sum(x: jax.Array, tenant_id: jax.Array,
                    n_tenants: int) -> jax.Array:
    """(..., n_blocks) -> (..., T): segment reduction over the tenant leaf."""
    flat = x.astype(jnp.int32).reshape((-1, x.shape[-1]))
    out = jax.vmap(lambda row: jax.ops.segment_sum(
        row, tenant_id, num_segments=n_tenants,
        indices_are_sorted=True))(flat)
    return out.reshape(x.shape[:-1] + (n_tenants,))


class EpochRuntime:
    """Runs all policy lanes over one shared telemetry stream, epoch by epoch.

    One collector set observes the stream once per epoch (fused); each lane
    owns only its placement.  ``step`` consumes one epoch of equal-size
    batches ``(n_batches, batch_size)`` and returns that epoch's records;
    ``run`` drives a whole workload and returns the :class:`Trajectory`.

    ``fused=True`` (default) keeps all lane state on device and executes
    decide+migrate+account as the single ``_epoch_step`` dispatch;
    ``fused=False`` is the pre-refactor per-lane host loop kept as the
    bit-identity reference and benchmark baseline.  ``mesh`` (with a
    ``NamedSharding`` axis named ``axis``) shards every (n_blocks,)-sized
    array — collector histograms and lane placements — across devices for
    paper-scale (5.24 M page) runs; see ``launch.mesh.make_telemetry_mesh``.

    ``hints`` (a :class:`repro.hints.HintPipeline`) refreshes the hinted
    lane's ``hint_rank`` and the prefetch lane's ``prefetch_rank`` every
    epoch from the pipeline's providers; ``run`` buffers the epoch stream by
    the pipeline's lookahead depth so ``step`` sees the queued next epochs.
    ``prefetch_overlap`` in [0,1] is how much of the prefetch lane's boundary
    migration streams concurrently with the epoch it serves (0 = the same
    stop-the-world charging every other lane pays).

    ``sync_every=K`` (fused only; default 1) batches the record sync: K
    epochs of record fields accumulate on device and cross the host
    boundary in one ``device_get`` — ``step`` then returns the epochs it
    flushed (a dict of record *lists*, empty until a buffer fills) instead
    of the K=1 per-epoch record dict, ``run``/``trajectory`` flush the
    partial tail automatically, and :meth:`flush` drains it on demand after
    manual stepping.  Trajectories are bit-identical for every K.
    """

    def __init__(
        self,
        n_blocks: int,
        k_hot: int,
        policies: Sequence[str] = ALL_POLICIES,
        system: MemSystem = CXL_SYSTEM,
        bytes_per_access: float = 256.0,
        block_bytes: float = 4096.0,
        pebs_period: int = 10007,
        nb_scan_rate: Optional[int] = None,
        hmu_log_capacity: int = 1 << 33,
        ewma_alpha: float = 0.5,
        hint_rank: Optional[np.ndarray] = None,
        hint_weight: float = 0.25,
        reactive_hot_threshold: Optional[int] = None,
        nb_rate_limit: Optional[int] = None,
        hints=None,
        prefetch_overlap: float = 1.0,
        fused: bool = True,
        mesh=None,
        mesh_axis: str = "blocks",
        tenancy: Optional[Tenancy] = None,
        sync_every: int = 1,
        faults: Optional[FaultModel] = None,
        hardening: Optional[Hardening] = None,
        export=None,
        use_pallas: Optional[bool] = None,
        pallas_interpret: Optional[bool] = None,
    ):
        unknown = set(policies) - set(ALL_POLICIES)
        if unknown:
            raise ValueError(f"unknown policies {sorted(unknown)}; "
                             f"choose from {ALL_POLICIES}")
        if mesh is not None and not fused:
            raise ValueError("mesh sharding requires the fused epoch step "
                             "(the reference path keeps lane state on the "
                             "host); pass fused=True or drop mesh")
        if (faults is not None or hardening is not None) and not fused:
            raise ValueError("fault injection / hardening run inside the "
                             "fused epoch step; the reference path stays "
                             "the fault-free bit-identity oracle — pass "
                             "fused=True or drop faults/hardening")
        if hardening is not None and not isinstance(hardening, Hardening):
            hardening = Hardening.make(**dict(hardening))
        if hardening is not None:
            hardening.validate()
        # Pallas kernels are single-device VMEM programs; under a mesh the
        # sharded XLA path stays authoritative.  use_pallas=None quietly
        # resolves to off in that case; an explicit True is a config error.
        if use_pallas and mesh is not None:
            raise ValueError("use_pallas=True is incompatible with mesh "
                             "sharding (the kernels carry whole histograms "
                             "in one core's VMEM); drop mesh or use_pallas")
        if use_pallas and not fused:
            raise ValueError("the Pallas kernels run inside the fused epoch "
                             "step; the reference path stays the pure-XLA "
                             "bit-identity oracle — pass fused=True or drop "
                             "use_pallas")
        self._pallas = (resolve_backend(use_pallas, pallas_interpret)
                        if fused and mesh is None else None)
        self.sync_every = int(sync_every)
        if self.sync_every < 1:
            raise ValueError(f"sync_every must be >= 1, got {sync_every!r}")
        if self.sync_every > 1 and not fused:
            raise ValueError("sync_every > 1 batches record syncs in the "
                             "fused epoch loop; the reference path stays "
                             "synchronous (it is the bit-identity oracle) — "
                             "pass fused=True or sync_every=1")
        self.n_blocks = int(n_blocks)
        self.k_hot = min(int(k_hot), self.n_blocks)
        self.system = system
        self.bytes_per_access = float(bytes_per_access)
        self.block_bytes = float(block_bytes)
        self.ewma_alpha = float(ewma_alpha)
        self.hint_rank = (np.zeros((n_blocks,), np.float32)
                          if hint_rank is None
                          else np.asarray(hint_rank, np.float32))
        self.prefetch_rank = np.zeros((n_blocks,), np.float32)
        self.hint_weight = float(hint_weight)
        self.reactive_hot_threshold = reactive_hot_threshold
        self.nb_rate_limit = nb_rate_limit
        self.hints = hints                  # Optional[repro.hints.HintPipeline]
        self.prefetch_overlap = float(prefetch_overlap)
        if not 0.0 <= self.prefetch_overlap <= 1.0:
            raise ValueError(f"prefetch_overlap must be in [0, 1], "
                             f"got {prefetch_overlap!r}")
        self._prefetch_pending = 0          # blocks moved at the last boundary
        self._mesh, self._mesh_axis = mesh, mesh_axis
        self.fused = bool(fused)
        self.tenancy = tenancy
        # per-epoch per-tenant raw accounting ((L, T) int64 arrays, lane
        # order = policies); repro.fleet.accounting slices these into
        # TenantRecord rows with the tenants' own cost-model geometry
        self.tenant_records: List[Dict[str, np.ndarray]] = []
        if tenancy is not None:
            tenancy.validate(self.n_blocks, self.k_hot)
            self._tenant_id_host = tenancy.block_tenants()
        else:
            self._tenant_id_host = np.zeros((self.n_blocks,), np.int32)
        self.faults = faults
        self.hardening = hardening
        # Optional repro.export client (duck-typed: export_epoch_record).
        # Records it sees are the ones _flush_records already assembled for
        # self.records, at the record-sync boundary where they are already
        # host-side — export adds no dispatch and must never raise or block
        # here (the client guarantees both).
        self.export = export
        scan = nb_scan_rate if nb_scan_rate is not None else max(n_blocks // 16, 1)
        bundle = tel.bundle_init(
            n_blocks, pebs_period=pebs_period, nb_scan_rate=scan,
            hmu_log_capacity=hmu_log_capacity, faults=faults,
        )
        self._lane_names = tuple(policies)
        self.epoch = 0
        self.records: Dict[str, List[EpochRecord]] = {n: [] for n in policies}
        self._prev_pebs_host = 0.0
        self._prev_nb_host = 0.0
        self._buffered = 0          # dispatched epochs not yet record-synced
        if self.fused:
            L = len(self._lane_names)
            self._cfg = _FusedCfg(
                lanes=self._lane_names, n_blocks=self.n_blocks,
                k_hot=self.k_hot, ewma_alpha=self.ewma_alpha,
                hint_weight=self.hint_weight,
                nb_rate_limit=self.nb_rate_limit,
                reactive_hot_threshold=self.reactive_hot_threshold,
                tenancy=self.tenancy,
                hardening=self.hardening,
                pallas=self._pallas,
            )
            def zeros_n():
                # distinct buffers (not one shared array) so donation works
                return jnp.zeros((self.n_blocks,), jnp.int32)

            # robustness leaves exist only when their subsystem is on, so a
            # fault-free runtime's state structure — and therefore its
            # compiled epoch program — is exactly the seed one
            extra = {}
            if faults is not None:
                extra["prev_true"] = zeros_n()
                if faults.stale_epochs > 0:
                    extra["stale"] = jnp.zeros(
                        (faults.stale_epochs + 1, 3, self.n_blocks),
                        jnp.int32)
                    extra["stale_ptr"] = jnp.zeros((), jnp.int32)
            if hardening is not None:
                extra["quality"] = jnp.ones((3,), jnp.float32)
                extra["nb_ewma"] = jnp.zeros((), jnp.float32)
                extra["prev_nb"] = zeros_n()
                if hardening.demote_hysteresis > 1:
                    extra["cold_streak"] = jnp.zeros(
                        (L, self.n_blocks), jnp.int32)
            self._state = _FusedState(
                bundle=bundle,
                placement=Placement.create(self.n_blocks, self.k_hot, lanes=L),
                pred=jnp.zeros((self.n_blocks,), jnp.float32),
                hint_rank=jnp.asarray(self.hint_rank),
                prefetch_rank=jnp.asarray(self.prefetch_rank),
                prev_hmu=zeros_n(), prev_pebs=zeros_n(),
                tenant_id=jnp.asarray(self._tenant_id_host),
                out_buf=_out_buf_init(self.sync_every, L, self.tenancy,
                                      self.hardening),
                **extra,
            )
            if mesh is not None:
                self._state = _shard_state(self._state, mesh, mesh_axis)
        else:
            self.bundle = bundle
            self._ref_lanes = {
                name: _Lane(
                    name=name,
                    slot_to_block=np.full((self.k_hot,), -1, np.int32),
                    block_to_slot=np.full((self.n_blocks,), -1, np.int32),
                    pred=(np.zeros((self.n_blocks,), np.float32)
                          if name == "proactive_ewma" else None),
                )
                for name in policies
            }
            # epoch-delta baselines (host copies, like the PR-1 loop)
            self._prev_true = np.zeros((n_blocks,), np.int64)
            self._prev_hmu = np.zeros((n_blocks,), np.int64)
            self._prev_pebs = np.zeros((n_blocks,), np.int64)

    # ---------------------------------------------------------- constructors
    @classmethod
    def for_scenario(cls, scenario, *, policies: Sequence[str] = ALL_POLICIES,
                     hints=None, prefetch_overlap: float = 1.0,
                     fused: bool = True, mesh=None, mesh_axis: str = "blocks",
                     **overrides) -> "EpochRuntime":
        """Build a runtime from an :class:`repro.scenarios.AccessScenario`'s
        geometry and cost-model parameters — the scenario supplies what the
        DLRM-shaped callers used to hand-wire (block count, hot-set size,
        per-access and per-block byte sizes, collector rates, memory system).
        A scenario that carries a ``tenancy`` attribute (a :class:`Tenancy` —
        ``repro.fleet.FleetScenario`` does) gets its multi-tenant layout and
        quotas installed too.  ``overrides`` replace any constructor kwarg
        (e.g. ``ewma_alpha=``)."""
        kw = dict(
            policies=policies,
            system=scenario.system,
            bytes_per_access=scenario.bytes_per_access,
            block_bytes=scenario.block_bytes,
            pebs_period=scenario.pebs_period,
            nb_scan_rate=scenario.nb_scan_rate,
            hints=hints, prefetch_overlap=prefetch_overlap,
            fused=fused, mesh=mesh, mesh_axis=mesh_axis,
            tenancy=getattr(scenario, "tenancy", None),
        )
        kw.update(overrides)
        return cls(scenario.n_blocks, scenario.k_hot, **kw)

    # ------------------------------------------------------- state accessors
    @property
    def lanes(self) -> Dict[str, _Lane]:
        """Per-lane placement view (host copies in fused mode)."""
        if not self.fused:
            return self._ref_lanes
        s2b = np.asarray(self._state.placement.slot_to_block)
        b2s = np.asarray(self._state.placement.block_to_slot)
        pred = np.asarray(self._state.pred)
        return {
            name: _Lane(
                name=name, slot_to_block=s2b[i], block_to_slot=b2s[i],
                pred=pred if name == "proactive_ewma" else None)
            for i, name in enumerate(self._lane_names)
        }

    @property
    def pending_migration_s(self) -> float:
        """Migration time of the prefetch lane's last boundary, not yet
        charged to any record: pending migration overlaps the NEXT epoch's
        stream, so at the end of a finite run the final boundary's cost has
        no epoch to land in.  Surfaced here (and in ``run_online``'s summary)
        so lane-total comparisons can account for it instead of it being
        silently dropped — every other lane charges its final boundary into
        its last record even though that migration serves no epoch either.
        Flushes the batched record sync first: ``_prefetch_pending`` is
        replayed during the flush, so a ``sync_every=K`` partial tail must
        be drained before the value is current."""
        if self.fused:
            self._flush_records()
        return self.system.migration_time_s(self._prefetch_pending,
                                            self.block_bytes)

    # ----------------------------------------------------------- hint refresh
    def set_hint_ranks(self, hint_rank: Optional[np.ndarray] = None,
                       prefetch_rank: Optional[np.ndarray] = None) -> None:
        """Replace the hint arrays the next epoch step reads.  On the fused
        path this swaps state leaves — a host-to-device transfer (sharded
        like the rest of the state under ``mesh``), not a dispatch, so the
        epoch stays at two; counted in ``DISPATCH_COUNTS['hint_refresh']``.
        An array that is the SAME object as the current one is skipped (the
        HintPipeline returns its cached static rank until the phase detector
        moves the scale), so an unchanged n-block hint_rank is not
        re-uploaded every epoch — the counter only ticks when something
        actually changed."""
        updates = {}
        if hint_rank is not None and hint_rank is not self.hint_rank:
            self.hint_rank = np.asarray(hint_rank, np.float32)
            updates["hint_rank"] = self.hint_rank
        if prefetch_rank is not None and prefetch_rank is not self.prefetch_rank:
            self.prefetch_rank = np.asarray(prefetch_rank, np.float32)
            updates["prefetch_rank"] = self.prefetch_rank
        if updates:
            DISPATCH_COUNTS["hint_refresh"] += 1
        if self.fused and updates:
            def put(x: np.ndarray) -> jax.Array:
                if self._mesh is None:
                    return jnp.asarray(x)
                from jax.sharding import NamedSharding, PartitionSpec as P
                return jax.device_put(
                    x, NamedSharding(self._mesh, P(self._mesh_axis)))

            _tr = obs_trace.get_tracer()
            cm = (_tr.span("hint_refresh", epoch=self.epoch,
                           arrays=",".join(sorted(updates)))
                  if _tr.enabled else obs_trace.NOOP_SPAN)
            with cm:
                self._state = dataclasses.replace(
                    self._state, **{k: put(v) for k, v in updates.items()})

    # ------------------------------------------------------------- migrate
    def _apply_plan(self, lane: _Lane, plan: policy.MigrationPlan,
                    est: np.ndarray) -> Tuple[int, int]:
        """Reference path: promote the plan into the lane's bounded fast
        tier; evict plan-guarded coldest victims when no slots are free.
        Returns (promoted, demoted) block counts — each is one block copy of
        migration traffic."""
        want = _unique_in_order(np.asarray(plan.promote), self.k_hot)
        if want.size == 0:
            return 0, 0
        new = want[lane.block_to_slot[want] < 0]
        if new.size == 0:
            return 0, 0
        free = np.nonzero(lane.slot_to_block < 0)[0]
        demoted = 0
        need = new.size - free.size
        if need > 0:
            DISPATCH_COUNTS["reference"] += 1
            vic = np.asarray(policy.plan_eviction(
                jnp.asarray(est, jnp.float32), jnp.asarray(want),
                jnp.asarray(lane.slot_to_block), int(need)))
            vic = vic[vic >= 0]
            if vic.size:
                slots = lane.block_to_slot[vic]
                lane.slot_to_block[slots] = -1
                lane.block_to_slot[vic] = -1
                demoted = int(vic.size)
            free = np.nonzero(lane.slot_to_block < 0)[0]
        take = int(min(new.size, free.size))
        if take:
            sel, slots = new[:take], free[:take]
            lane.slot_to_block[slots] = sel
            lane.block_to_slot[sel] = slots
        return take, demoted

    def _demote_untouched(self, lane: _Lane, est: np.ndarray) -> int:
        """Watermark demotion: free every resident block the epoch never
        touched (est == 0) so reactive promotion has slots."""
        resident = lane.resident_ids()
        idle = resident[est[resident] == 0]
        if idle.size:
            slots = lane.block_to_slot[idle]
            lane.slot_to_block[slots] = -1
            lane.block_to_slot[idle] = -1
        return int(idle.size)

    # -------------------------------------------------------------- decide
    def _plan_quota(self, lane: _Lane, d_hmu: np.ndarray, d_pebs: np.ndarray,
                    nb_faults: np.ndarray, epoch_accesses: int,
                    ) -> Tuple[policy.MigrationPlan, np.ndarray, int]:
        """Reference decide under per-tenant quotas: the lane's selection key
        is protected per tenant (each tenant's top ``caps[t]`` keys survive,
        ties lowest-index-first) and masked to ``int32.min`` elsewhere, then
        the lane's value/positional gates run on the globally-ordered masked
        selection — plain numpy sorts, mirroring the spec of the fused
        segment-capped select (``selectk.segment_top_k_mask``).  Float-keyed
        lanes go through the same float32 bit-pattern keys the device uses,
        computed by the same jnp policy helpers, so near-ties cannot split
        the two paths."""
        ten, k, n = self.tenancy, self.k_hot, self.n_blocks
        pre_demoted = 0
        DISPATCH_COUNTS["reference"] += 1

        def f32_key(x: np.ndarray) -> np.ndarray:
            return np.ascontiguousarray(
                np.asarray(x, np.float32)).view(np.int32)

        min_key: int
        cap = k
        if lane.name == "hmu_oracle":
            est, key, min_key = d_hmu, d_hmu, 1
        elif lane.name == "nb_two_touch":
            est, key, min_key = nb_faults, nb_faults, 2
            if self.nb_rate_limit is not None:
                cap = min(k, self.nb_rate_limit)
        elif lane.name == "reactive_watermark":
            est, key = d_hmu, d_hmu
            pre_demoted = self._demote_untouched(lane, est)
            cap = min(k, int(np.sum(lane.slot_to_block < 0)))
            min_key = (self.reactive_hot_threshold
                       if self.reactive_hot_threshold is not None
                       else max(2, epoch_accesses // (8 * max(k, 1))))
        elif lane.name == "proactive_ewma":
            pred, _ = policy.proactive_ewma(
                jnp.asarray(lane.pred), jnp.asarray(d_hmu, jnp.float32), k,
                alpha=self.ewma_alpha)
            lane.pred = np.asarray(pred)
            est, key, min_key = lane.pred, f32_key(lane.pred), 1
        elif lane.name == "hinted":
            est = d_pebs
            t_rank = jnp.argsort(jnp.argsort(jnp.asarray(est, jnp.int32)))
            score = policy.hinted_score(
                jnp.asarray(est, jnp.int32), t_rank,
                jnp.asarray(self.hint_rank), self.hint_weight)
            key, min_key = f32_key(np.asarray(score)), 0
        elif lane.name == "prefetch":
            est = self.prefetch_rank
            key, min_key = f32_key(est), 1
        else:  # pragma: no cover - guarded in __init__
            raise ValueError(lane.name)

        key = np.asarray(key, np.int64)
        protected = np.zeros((n,), bool)
        for t, tcap in enumerate(ten.caps):
            off, end = ten.offsets[t], ten.offsets[t + 1]
            order = np.argsort(-key[off:end], kind="stable")
            protected[off + order[:tcap]] = True
        masked = np.where(protected, key, np.iinfo(np.int32).min)
        ids = np.argsort(-masked, kind="stable")[:k]
        ok = (masked[ids] >= min_key) & (np.arange(ids.size) < cap)
        return (policy.MigrationPlan(promote=np.where(ok, ids, -1)),
                np.asarray(est), pre_demoted)

    def _plan(self, lane: _Lane, d_hmu: np.ndarray, d_pebs: np.ndarray,
              nb_faults: np.ndarray, epoch_accesses: int,
              ) -> Tuple[policy.MigrationPlan, np.ndarray, int]:
        """Reference path: one lane's decide step -> (plan, estimate,
        pre-demotions)."""
        if self.tenancy is not None and self.tenancy.caps is not None:
            return self._plan_quota(lane, d_hmu, d_pebs, nb_faults,
                                    epoch_accesses)
        k = self.k_hot
        pre_demoted = 0
        DISPATCH_COUNTS["reference"] += 1
        if lane.name == "hmu_oracle":
            est = d_hmu
            plan = policy.oracle_top_k(jnp.asarray(est, jnp.int32), k)
        elif lane.name == "nb_two_touch":
            est = nb_faults
            plan = policy.nb_two_touch(jnp.asarray(est, jnp.int32), k,
                                       self.nb_rate_limit)
        elif lane.name == "reactive_watermark":
            est = d_hmu
            pre_demoted = self._demote_untouched(lane, est)
            free = int(np.sum(lane.slot_to_block < 0))
            thr = (self.reactive_hot_threshold
                   if self.reactive_hot_threshold is not None
                   else max(2, epoch_accesses // (8 * max(k, 1))))
            plan = policy.reactive_watermark(
                jnp.asarray(est, jnp.int32), int(thr),
                jnp.asarray(free), max_moves=k)
        elif lane.name == "proactive_ewma":
            pred, plan = policy.proactive_ewma(
                jnp.asarray(lane.pred), jnp.asarray(d_hmu, jnp.float32), k,
                alpha=self.ewma_alpha)
            lane.pred = np.asarray(pred)
            est = lane.pred
        elif lane.name == "hinted":
            est = d_pebs
            plan = policy.hinted(jnp.asarray(est, jnp.int32),
                                 jnp.asarray(self.hint_rank), k,
                                 hint_weight=self.hint_weight)
        elif lane.name == "prefetch":
            est = self.prefetch_rank
            plan = policy.prefetch(jnp.asarray(est), k)
        else:  # pragma: no cover - guarded in __init__
            raise ValueError(lane.name)
        return plan, np.asarray(est), pre_demoted

    # ---------------------------------------------------------------- step
    def step(self, batches, lookahead: Sequence = ()) -> Dict[str, EpochRecord]:
        """Consume one epoch ``(n_batches, batch_size)``: fused observe, then
        decide/migrate/account every lane.  ``lookahead`` is the queued
        upcoming epochs (the dataloader's prefetch queue) handed to the hint
        pipeline, if any.  Returns this epoch's records."""
        batches = np.ascontiguousarray(np.asarray(batches, np.int32))
        if batches.ndim != 2:
            raise ValueError(f"epoch batches must be 2-D, got {batches.shape}")
        if self.hints is not None:
            self.set_hint_ranks(*self.hints.epoch_ranks(batches, lookahead))
        if self.fused:
            return self._step_fused(batches)
        return self._step_reference(batches)

    def _record(self, name: str, epoch: int, n_fast: float, n_slow: float,
                host_events: float, promoted: int, demoted: int,
                resident: int, inter: int,
                quality: float = 1.0) -> EpochRecord:
        """Shared epoch accounting (host float64 scalar math, both paths).
        ``epoch`` is explicit because the batched sync assembles records
        for epochs that were dispatched several steps ago."""
        access_s = self.system.access_time_s(
            n_fast, n_slow, self.bytes_per_access)
        per_event = (NB_FAULT_COST_S if name == "nb_two_touch" else
                     PEBS_SAMPLE_COST_S if name == "hinted" else
                     0.0 if name == "prefetch" else
                     HMU_DRAIN_COST_S)
        host_tax_s = host_events * per_event
        hidden_s = 0.0
        if name == "prefetch":
            # Lookahead lets the prefetch lane issue its boundary migration
            # ahead of the epoch it serves, so the migration charged here is
            # the one issued at the PREVIOUS boundary — it streamed under
            # THIS epoch's accesses, and the overlapped share is hidden
            # (MemSystem.overlapped_epoch_time_s).  Every other lane pays its
            # boundary migration stop-the-world, same as before.
            moved = self._prefetch_pending
            self._prefetch_pending = promoted + demoted
            migration_s = self.system.migration_time_s(moved, self.block_bytes)
            hidden_s = self.system.migration_overlap_s(
                n_slow, self.bytes_per_access, moved, self.block_bytes,
                self.prefetch_overlap)
        else:
            migration_s = self.system.migration_time_s(
                promoted + demoted, self.block_bytes)
        return EpochRecord(
            epoch=epoch, lane=name,
            time_s=access_s + host_tax_s + migration_s - hidden_s,
            access_s=access_s, host_tax_s=host_tax_s, migration_s=migration_s,
            accuracy=(inter / resident) if resident else 0.0,
            coverage=(inter / self.k_hot) if self.k_hot else 0.0,
            resident=resident, promoted=promoted, demoted=demoted,
            host_events=host_events, hidden_s=hidden_s, quality=quality,
        )

    def _step_fused(self, batches: np.ndarray):
        state = self._state
        # obs spans are attribution only: tracing-off uses the shared no-op
        # context manager (zero allocations), tracing-on wraps the very same
        # dispatch calls — the --obs bench gates bit-identical records and
        # equal DISPATCH_COUNTS either way.
        _tr = obs_trace.get_tracer()
        DISPATCH_COUNTS["observe_all"] += 1
        cm = (_tr.span("observe_all", epoch=self.epoch)
              if _tr.enabled else obs_trace.NOOP_SPAN)
        with cm:
            bundle = tel.observe_all(state.bundle, jnp.asarray(batches),
                                     pallas=self._pallas)
        state = dataclasses.replace(state, bundle=bundle)
        # Pipelining: this epoch's observe_all is already dispatched when a
        # full record buffer forces the previous K epochs' batched sync, so
        # the device never idles against the pull.  (The flush reads
        # self._state.out_buf — untouched by observe_all, not yet donated
        # to this epoch's _epoch_step.)
        flushed: Dict[str, List[EpochRecord]] = {}
        if self._buffered >= self.sync_every:
            flushed = self._flush_records()
        # static PEBS-positives bound, quantized to the next power of two so
        # ragged epoch sizes don't retrace the epoch program
        bound = int(batches.size) // state.bundle.pebs.period + 2
        s_max = min(self.n_blocks, 1 << (bound - 1).bit_length())
        DISPATCH_COUNTS["epoch_step"] += 1
        cm = (_tr.span("epoch_step", epoch=self.epoch)
              if _tr.enabled else obs_trace.NOOP_SPAN)
        with cm:
            self._state = _epoch_step(
                state, jnp.asarray(batches.size, jnp.int32),
                jnp.asarray(self._buffered, jnp.int32),
                cfg=self._cfg, s_max=s_max)
        self.epoch += 1
        self._buffered += 1
        if self.sync_every == 1:
            flushed = self._flush_records()   # synchronous loop: pull now
            return {name: recs[0] for name, recs in flushed.items()}
        return flushed

    def _flush_records(self) -> Dict[str, List[EpochRecord]]:
        """Pull the buffered epochs' record fields in ONE device->host sync
        (``jax.device_get`` of the stacked ``(sync_every,)`` accumulator)
        and assemble their :class:`EpochRecord`s / per-tenant rows in
        dispatch order — bit-identical to the per-epoch sync it batches."""
        n_buf = self._buffered
        if not self.fused or n_buf == 0:
            return {}
        base = self.epoch - n_buf
        DISPATCH_COUNTS["record_sync"] += 1
        _tr = obs_trace.get_tracer()
        cm = (_tr.span("record_sync", epoch_base=base, n_epochs=n_buf)
              if _tr.enabled else obs_trace.NOOP_SPAN)
        with cm:
            host = jax.device_get(self._state.out_buf)
        tenant = host.get("tenant")
        qual = host.get("quality")
        flushed: Dict[str, List[EpochRecord]] = {
            name: [] for name in self._lane_names}

        def c64(field: str, j: int) -> float:
            # recombine the exact hi/lo int32 pair in float64 (exact < 2**53)
            return (float(host[field + "_hi"][j]) * CARRY_BASE
                    + float(host[field + "_lo"][j]))

        for j in range(n_buf):                 # rows beyond n_buf are stale
            pebs_host = c64("pebs_host", j)
            nb_host = c64("nb_host", j)
            d_pebs_host = pebs_host - self._prev_pebs_host
            d_nb_host = nb_host - self._prev_nb_host
            self._prev_pebs_host, self._prev_nb_host = pebs_host, nb_host
            drained = c64("drained", j)
            if tenant is not None:
                self.tenant_records.append({
                    key: np.asarray(val[j], np.int64)
                    for key, val in tenant.items()})
            for i, name in enumerate(self._lane_names):
                host_events = (d_nb_host if name == "nb_two_touch" else
                               d_pebs_host if name == "hinted" else
                               0.0 if name == "prefetch" else drained)
                col = LANE_COLLECTOR[name]
                quality = (float(qual[j, COLLECTORS.index(col)])
                           if qual is not None and col is not None else 1.0)
                rec = self._record(
                    name, epoch=base + j, quality=quality,
                    n_fast=float(host["n_fast"][j, i]),
                    n_slow=float(host["n_slow"][j, i]),
                    host_events=host_events,
                    promoted=int(host["promoted"][j, i]),
                    demoted=int(host["demoted"][j, i]),
                    resident=int(host["resident"][j, i]),
                    inter=int(host["inter"][j, i]),
                )
                self.records[name].append(rec)
                flushed[name].append(rec)
                if self.export is not None:
                    self.export.export_epoch_record(rec)
        self._buffered = 0
        return flushed

    def flush(self) -> Dict[str, List[EpochRecord]]:
        """Force the batched record sync for any still-buffered epochs (the
        ``sync_every=K`` partial tail).  ``run`` calls this on loop exit;
        call it after manual ``step``-ing with ``sync_every > 1`` before
        reading ``records``/``tenant_records``.  No-op on the reference
        path and on an empty buffer."""
        return self._flush_records()

    def block_until_ready(self) -> "EpochRuntime":
        """Block until all dispatched device work has finished — the honest
        stopping point for wall-clock timers under async dispatch (records
        may already be flushed while the final epoch's state updates are
        still in flight)."""
        jax.block_until_ready(self._state if self.fused else self.bundle)
        return self

    def _step_reference(self, batches: np.ndarray) -> Dict[str, EpochRecord]:
        _tr = obs_trace.get_tracer()
        cm = (_tr.span("reference_step", epoch=self.epoch)
              if _tr.enabled else obs_trace.NOOP_SPAN)
        with cm:
            return self._step_reference_impl(batches)

    def _step_reference_impl(self, batches: np.ndarray) -> Dict[str, EpochRecord]:
        epoch_accesses = int(batches.size)

        # -- observe (one dispatch) + drain the HMU log
        DISPATCH_COUNTS["observe_all"] += 1
        self.bundle = tel.observe_all(self.bundle, jnp.asarray(batches))
        drained = float(self.bundle.hmu.log_used)
        self.bundle = dataclasses.replace(
            self.bundle, hmu=tel.hmu_drain_cost(self.bundle.hmu))

        # -- epoch-local estimates (four full-array pulls per epoch)
        DISPATCH_COUNTS["reference"] += 4
        true_now = np.asarray(self.bundle.true_counts, np.int64)
        hmu_now = np.asarray(tel.hmu_estimate(self.bundle.hmu), np.int64)
        pebs_now = np.asarray(tel.pebs_estimate(self.bundle.pebs), np.int64)
        d_true = true_now - self._prev_true
        d_hmu = hmu_now - self._prev_hmu
        d_pebs = pebs_now - self._prev_pebs
        nb_faults = np.asarray(tel.nb_estimate(self.bundle.nb), np.int64)
        pebs_host = float(self.bundle.pebs.host_events)
        nb_host = float(self.bundle.nb.host_events)
        d_pebs_host = pebs_host - self._prev_pebs_host
        d_nb_host = nb_host - self._prev_nb_host
        self._prev_true, self._prev_hmu, self._prev_pebs = true_now, hmu_now, pebs_now
        self._prev_pebs_host, self._prev_nb_host = pebs_host, nb_host

        epoch_hot = metrics.true_top_k(d_true, self.k_hot)
        ten = self.tenancy
        if ten is not None:
            # per-tenant true-hot mask: top hot_k[t] of each tenant's range
            # (same stable tie-break as the fused selectk.top_k_mask)
            t_hot_mask = np.zeros((self.n_blocks,), bool)
            for t in range(ten.n_tenants):
                off, end = ten.offsets[t], ten.offsets[t + 1]
                t_hot_mask[off + metrics.true_top_k(d_true[off:end],
                                                    ten.hot_k[t])] = True
            t_rows = {key: [] for key in ("n_fast", "n_slow", "inter",
                                          "resident", "promoted", "demoted")}
        out: Dict[str, EpochRecord] = {}
        for lane in self._ref_lanes.values():
            # -- account the epoch under the placement that served it
            served = lane.resident_ids().copy()
            fast_before = lane.fast_mask.copy()
            n_fast, n_slow = split_accesses_by_tier(d_true, fast_before)
            host_events = (d_nb_host if lane.name == "nb_two_touch" else
                           d_pebs_host if lane.name == "hinted" else
                           0.0 if lane.name == "prefetch" else drained)

            # -- decide + migrate for the NEXT epoch
            plan, est, pre_demoted = self._plan(
                lane, d_hmu, d_pebs, nb_faults, epoch_accesses)
            promoted, demoted = self._apply_plan(lane, plan, est)
            inter = int(np.intersect1d(served, epoch_hot).size)
            if ten is not None:
                fast_after = lane.fast_mask
                lane_masks = {
                    "n_fast": np.where(fast_before, d_true, 0),
                    "n_slow": np.where(fast_before, 0, d_true),
                    "inter": fast_before & t_hot_mask,
                    "resident": fast_before,
                    "promoted": fast_after & ~fast_before,
                    "demoted": fast_before & ~fast_after,
                }
                for key, arr in lane_masks.items():
                    t_rows[key].append(np.array([
                        int(arr[ten.offsets[t]:ten.offsets[t + 1]].sum())
                        for t in range(ten.n_tenants)], np.int64))
            rec = self._record(
                lane.name, epoch=self.epoch, n_fast=n_fast, n_slow=n_slow,
                host_events=host_events, promoted=promoted,
                demoted=demoted + pre_demoted,
                resident=int(served.size), inter=inter,
            )
            self.records[lane.name].append(rec)
            out[lane.name] = rec
            if self.export is not None:
                self.export.export_epoch_record(rec)
        if ten is not None:
            self.tenant_records.append(
                {key: np.stack(rows) for key, rows in t_rows.items()})
        self.epoch += 1
        return out

    # ----------------------------------------------------------------- run
    def run(self, epochs: Iterable) -> Trajectory:
        """Drive a whole epoch stream.  With a hint pipeline attached, the
        stream is buffered by the pipeline's lookahead depth so each ``step``
        sees the queued next epochs — the dataloader's prefetch queue, which
        is what the lookahead provider models.

        Each ``run`` is one workload: the prefetch lane's pending boundary
        migration is cleared on entry, so a runtime reused for a second
        ``run`` does not charge the previous stream's final boundary (already
        surfaced via :attr:`pending_migration_s`) against the new stream's
        first epoch — and the returned :class:`Trajectory` holds only THIS
        stream's records (earlier manual ``step``/``run`` history stays in
        :attr:`records` / :meth:`trajectory`)."""
        self._flush_records()     # manual-step leftovers belong to their own
        self._prefetch_pending = 0                              # stream
        starts = {name: len(recs) for name, recs in self.records.items()}
        depth = self.hints.lookahead_depth if self.hints is not None else 0
        it = iter(epochs)
        buf: deque = deque()                # current epoch + queued lookahead
        try:
            while True:
                if not buf:
                    buf.extend(itertools.islice(it, 1))
                    if not buf:
                        break
                batches = buf.popleft()
                buf.extend(itertools.islice(it, depth - len(buf)))
                self.step(batches, lookahead=tuple(buf))
        finally:
            # sync_every=K partial tail — also on exception, so a run killed
            # mid-stream still lands (and exports) every dispatched epoch
            self._flush_records()
        return Trajectory(n_blocks=self.n_blocks, k_hot=self.k_hot,
                          records={name: recs[starts[name]:]
                                   for name, recs in self.records.items()})

    def trajectory(self) -> Trajectory:
        """Full record history across every ``step``/``run`` on this runtime
        (each ``run`` additionally returns its own stream's slice)."""
        self._flush_records()
        return Trajectory(n_blocks=self.n_blocks, k_hot=self.k_hot,
                          records=self.records)


def _shard_state(state: _FusedState, mesh, axis: str) -> _FusedState:
    """Distribute every (n_blocks,)-sized leaf (collector histograms, lane
    placements, EWMA state) over ``mesh``'s ``axis``; scalars and slot maps
    are replicated.  jit then partitions observe_all and epoch_step via
    GSPMD — the decision loop runs where the counters live."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    n_blocks = state.bundle.true_counts.shape[0]

    def put(x):
        if getattr(x, "ndim", 0) >= 1 and x.shape[-1] == n_blocks:
            spec = P(*([None] * (x.ndim - 1) + [axis]))
        else:
            spec = P()
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, state)
