"""Hotness/placement quality metrics — the quantities in Fig. 3 and §III.

Definitions match the paper's usage:

* accuracy(promoted | true hot set): of the blocks a strategy promoted, what
  fraction are truly hot ("PEBS achieved 87% accuracy confirmed by HMU").
* coverage(promoted | K): what fraction of the true top-K a strategy promoted
  ("it only promoted 6% of K pages as hot").
* overlap(A, B): |A ∩ B| / K for two promotion sets ("75% overlap between NB
  and HMU selections").
"""
from __future__ import annotations

import numpy as np


def _valid(ids) -> np.ndarray:
    a = np.asarray(ids).reshape(-1)
    return np.unique(a[a >= 0])


def true_top_k(true_counts, k: int) -> np.ndarray:
    """Top-k with deterministic (stable, lowest-index-first) tie-break, so a
    collector that sees the exact stream selects the identical set."""
    c = np.asarray(true_counts)
    k = min(k, c.shape[0])
    return np.argsort(-c, kind="stable")[:k]


def accuracy(promoted, true_hot) -> float:
    p, t = _valid(promoted), _valid(true_hot)
    if p.size == 0:
        return 0.0
    return float(np.intersect1d(p, t).size / p.size)


def coverage(promoted, true_hot, k: int | None = None) -> float:
    p, t = _valid(promoted), _valid(true_hot)
    denom = k if k is not None else t.size
    if denom == 0:
        return 0.0
    return float(np.intersect1d(p, t).size / denom)


def overlap(promoted_a, promoted_b, k: int | None = None) -> float:
    a, b = _valid(promoted_a), _valid(promoted_b)
    denom = k if k is not None else max(min(a.size, b.size), 1)
    return float(np.intersect1d(a, b).size / denom)


def hotness_cdf(counts, n_points: int = 100):
    """Fig. 3: fraction of accesses covered by the hottest x% of (accessed)
    pages.  Returns (page_fraction, access_fraction) arrays."""
    c = np.asarray(counts, np.float64)
    c = c[c > 0]
    if c.size == 0:
        return np.zeros(1), np.zeros(1)
    c.sort()
    c = c[::-1]
    cum = np.cumsum(c) / c.sum()
    xs = np.linspace(0, 1, n_points + 1)[1:]
    idx = np.clip((xs * c.size).astype(int) - 1, 0, c.size - 1)
    return xs, cum[idx]


def pages_for_access_fraction(counts, frac: float) -> float:
    """Smallest fraction of accessed pages covering ``frac`` of accesses
    (paper: ~10% of pages -> ~90% of accesses)."""
    xs, cdf = hotness_cdf(counts, n_points=1000)
    hit = np.searchsorted(cdf, frac)
    return float(xs[min(hit, xs.size - 1)])
