"""Placement — the one bounded-fast-tier indirection substrate.

A placement is the pair of mutually-inverse maps every tiered object in this
repo carries:

  ``slot_to_block``  (..., n_slots)   block id in each fast slot, -1 = free
  ``block_to_slot``  (..., n_blocks)  fast slot of each block,   -1 = slow-only

plus the *bounded-promotion invariant* around ``policy.plan_eviction``: a
promotion plan fills free slots first in priority order; when slots run out,
the epoch-coldest residents are demoted — never a block the plan still wants
ahead of an empty slot.

Before this module the sequence was duplicated three ways (EpochRuntime's
per-lane numpy maps, TieredEmbedding.rebalance, TieredStore's
demote-on-overwrite); now it lives here once:

* :func:`apply_plan` — pure ``jnp`` promote+evict, usable inside ``jit`` and
  ``vmap``-stacked over policy lanes ((L, n_slots)/(L, n_blocks) leading
  axes).  This is what the fused ``epoch_step`` runs.
* :func:`demote_idle` — watermark demotion (free residents an epoch never
  touched), same pure form.
* :func:`plan_promotion` — the host-side variant for stores that must *move
  payload bytes* along with the maps: returns the victims to demote so the
  caller can drive ``TieredStore.migrate`` (TieredEmbedding's control plane).

Everything is functional; ``Placement`` is a pytree and can be sharded,
donated, and carried through ``lax``-land like any other state.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from . import policy, selectk

__all__ = ["Placement", "apply_plan", "demote_idle", "plan_promotion"]

# Free fast slots sort at this heat in eviction order: after every finite
# resident (so cold residents are demoted first) but before +inf-guarded
# still-wanted residents — exactly policy.coldest_victims' convention.
_FREE_HEAT = float(np.iinfo(np.int32).max)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Placement:
    """Bounded fast-tier indirection maps (optionally lane-stacked)."""

    slot_to_block: jax.Array     # (..., n_slots) int32, -1 = free
    block_to_slot: jax.Array     # (..., n_blocks) int32, -1 = slow-only

    @staticmethod
    def create(n_blocks: int, n_slots: int, lanes: Optional[int] = None,
               ) -> "Placement":
        """Everything slow-resident (the paper's profiling phase).  With
        ``lanes`` the maps get a leading lane axis (one placement per policy
        lane, vmapped together by the fused runtime)."""
        lead = () if lanes is None else (int(lanes),)
        return Placement(
            slot_to_block=jnp.full(lead + (int(n_slots),), -1, jnp.int32),
            block_to_slot=jnp.full(lead + (int(n_blocks),), -1, jnp.int32),
        )

    @property
    def n_slots(self) -> int:
        return self.slot_to_block.shape[-1]

    @property
    def n_blocks(self) -> int:
        return self.block_to_slot.shape[-1]

    @property
    def fast_mask(self) -> jax.Array:
        return self.block_to_slot >= 0

    def resident(self) -> jax.Array:
        """Occupied-slot count (per lane, if stacked)."""
        return jnp.sum((self.slot_to_block >= 0).astype(jnp.int32), axis=-1)


def _scatter_ids(arr: jax.Array, idx: jax.Array, valid: jax.Array,
                 val: jax.Array) -> jax.Array:
    """Batched last-axis ``arr[..., idx] = val`` where ``valid``; invalid
    entries are routed out of bounds and dropped (no undefined duplicate
    writes at a clamped index)."""
    oob = jnp.asarray(arr.shape[-1], idx.dtype)
    return jnp.put_along_axis(arr, jnp.where(valid, idx, oob),
                              val.astype(arr.dtype), axis=-1,
                              inplace=False, mode="drop")


def demote_idle(p: Placement, est: jax.Array, enable) -> Tuple[Placement, jax.Array]:
    """Watermark demotion: free every resident block whose epoch estimate is
    zero (else a reactive tier fills once and freezes).  ``enable`` gates the
    whole operation (scalar or per-lane bool).  Returns (placement, count)."""
    idle = p.fast_mask & (est == 0) & enable
    b2s = jnp.where(idle, -1, p.block_to_slot)
    occ = p.slot_to_block >= 0
    blk = jnp.maximum(p.slot_to_block, 0)
    slot_idle = occ & jnp.take_along_axis(idle, blk, axis=-1)
    s2b = jnp.where(slot_idle, -1, p.slot_to_block)
    return (Placement(slot_to_block=s2b, block_to_slot=b2s),
            jnp.sum(idle.astype(jnp.int32), axis=-1))


def apply_plan(p: Placement, want: jax.Array, est: jax.Array,
               ) -> Tuple[Placement, jax.Array, jax.Array]:
    """Promote ``want`` (priority-ordered unique block ids, -1 padding) into
    the bounded fast tier; when free slots run short, demote the coldest
    residents by ``est`` with plan-guarded victims (``policy.plan_eviction``'s
    invariant).  Pure jnp over the trailing axis — works per lane and
    lane-stacked.  Returns (placement, promoted, demoted) counts.
    """
    n, k = p.n_blocks, p.n_slots
    s2b, b2s = p.slot_to_block, p.block_to_slot

    valid = want >= 0
    safe_want = jnp.maximum(want, 0)
    wanted = _scatter_ids(jnp.zeros(b2s.shape, jnp.bool_), want, valid,
                          jnp.ones(want.shape, jnp.bool_))
    new = valid & (jnp.take_along_axis(b2s, safe_want, axis=-1) < 0)
    n_new = jnp.sum(new.astype(jnp.int32), axis=-1, keepdims=True)
    n_free = jnp.sum((s2b < 0).astype(jnp.int32), axis=-1, keepdims=True)
    need = n_new - n_free

    # eviction order: finite-heat residents coldest-first, then free slots,
    # then +inf-guarded wanted residents (identical to policy.plan_eviction);
    # the `need` coldest slots come from an O(n_slots) threshold selection
    # with stable (lowest-slot-first) tie-break — no sort.
    occ = s2b >= 0
    blk = jnp.maximum(s2b, 0)
    heat = jnp.where(
        occ,
        jnp.where(jnp.take_along_axis(wanted, blk, axis=-1), jnp.inf,
                  jnp.take_along_axis(est.astype(jnp.float32), blk, axis=-1)),
        _FREE_HEAT)
    victim = occ & selectk.bottom_k_mask(selectk.sortable_key(heat),
                                         jnp.squeeze(need, -1))
    demoted = jnp.sum(victim.astype(jnp.int32), axis=-1)

    b2s = _scatter_ids(b2s, s2b, victim, jnp.full(s2b.shape, -1, jnp.int32))
    s2b = jnp.where(victim, -1, s2b)

    # fill free slots (ascending slot index) with new blocks in plan order:
    # the j-th new block lands in the j-th free slot, located by prefix count
    free = s2b < 0
    cfree = jnp.cumsum(free.astype(jnp.int32), axis=-1)
    n_free = cfree[..., -1:]
    new_rank = jnp.cumsum(new.astype(jnp.int32), axis=-1) - 1
    assign = new & (new_rank < n_free)
    free_slot = selectk.compact(cfree, k)           # (..., k), fill -> k
    slot_for = jnp.take_along_axis(
        free_slot, jnp.clip(new_rank, 0, k - 1), axis=-1)
    s2b = _scatter_ids(s2b, slot_for, assign, want)
    b2s = _scatter_ids(b2s, want, assign, slot_for)
    promoted = jnp.sum(assign.astype(jnp.int32), axis=-1)
    return Placement(slot_to_block=s2b, block_to_slot=b2s), promoted, demoted


def plan_promotion(p: Placement, want, est) -> Tuple[np.ndarray, Optional[jax.Array]]:
    """Host-side control-plane variant for payload-carrying stores: given a
    plan's ids and the epoch estimate, return ``(want_ids, victims)`` where
    ``victims`` (or None) are the demotions that make the promotions fit —
    exactly the sequence ``TieredStore.migrate`` expects.  The eviction
    choice is the same ``policy.plan_eviction`` the device path applies."""
    want = np.asarray(want).reshape(-1)
    want = want[want >= 0]
    b2s = np.asarray(p.block_to_slot)
    n_new = int(np.sum(b2s[want] < 0)) if want.size else 0
    free = p.n_slots - int(np.sum(np.asarray(p.slot_to_block) >= 0))
    need = n_new - free
    victims = None
    if need > 0:
        victims = policy.plan_eviction(
            jnp.asarray(np.asarray(est, np.float32)), jnp.asarray(want),
            p.slot_to_block, int(need))
    return want, victims
