"""Promotion policies: estimated hotness -> migration plan.

The paper's methodology ("Oracle" Hotness-based Tiering, §III) promotes the
top-K blocks by profiled access count, K sized to the fast tier / hot region.
We implement that plus the reactive / proactive / hinted variants the paper
proposes for programmable memory-side telemetry (§VI).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MigrationPlan:
    """Block ids to promote (padded with -1), in priority order."""
    promote: jax.Array
    demote: Optional[jax.Array] = None


def oracle_top_k(est_counts: jax.Array, k: int, min_count: int = 1) -> MigrationPlan:
    """Promote the top-k blocks by estimated count ('Oracle Hotness-based
    Tiering').  Blocks with count < min_count are never promoted — this is
    what limits PEBS: unsampled hot blocks have count 0 and stay cold, so its
    *coverage* of K is low even when its *accuracy* is high."""
    k = min(k, est_counts.shape[0])
    counts, ids = jax.lax.top_k(est_counts, k)
    return MigrationPlan(promote=jnp.where(counts >= min_count, ids, -1))


def nb_two_touch(faults: jax.Array, k: int, rate_limit: Optional[int] = None) -> MigrationPlan:
    """Linux NB promotion: candidates need >= 2 hint faults; ranked by fault
    count (a recency proxy, NOT true frequency).  ``rate_limit`` models the
    kernel's promotion rate limiting (pages per epoch)."""
    k = min(k, faults.shape[0])
    if rate_limit is not None:
        k = min(k, rate_limit)
    counts, ids = jax.lax.top_k(faults, k)
    return MigrationPlan(promote=jnp.where(counts >= 2, ids, -1))


def reactive_watermark(
    est_counts: jax.Array,
    hot_threshold: int,
    free_slots: jax.Array,
    max_moves: int,
) -> MigrationPlan:
    """Reactive placement: promote any block whose counter crosses the hot
    threshold, bounded by free fast-tier capacity this epoch."""
    k = int(max_moves)
    counts, ids = jax.lax.top_k(est_counts, min(k, est_counts.shape[0]))
    rank = jnp.arange(counts.shape[0])
    ok = (counts >= hot_threshold) & (rank < free_slots)
    return MigrationPlan(promote=jnp.where(ok, ids, -1))


def proactive_ewma(
    prev_pred: jax.Array, est_counts: jax.Array, k: int, alpha: float = 0.5
) -> tuple[jax.Array, MigrationPlan]:
    """Proactive data movement (paper §VI): EWMA trend prediction per block;
    promote blocks *predicted* hot next epoch, before they are re-touched."""
    pred = alpha * est_counts.astype(jnp.float32) + (1.0 - alpha) * prev_pred
    k = min(k, pred.shape[0])
    vals, ids = jax.lax.top_k(pred, k)
    return pred, MigrationPlan(promote=jnp.where(vals > 0, ids, -1))


def hinted_score(est_counts: jax.Array, t_rank: jax.Array,
                 hint_rank: jax.Array, hint_weight: float) -> jax.Array:
    """The hinted lane's blended score: telemetry rank mixed with the static
    priority in rank space (so magnitudes are comparable), with blocks that
    have neither telemetry nor a hint pushed to a -1 sentinel so they are
    never promoted.  Shared by the eager :func:`hinted` policy and the fused
    epoch step (which supplies ``t_rank`` from a sparse exact ranking) so
    both paths select identical ids."""
    n = est_counts.shape[0]
    score = ((1.0 - hint_weight) * (t_rank / max(n - 1, 1))
             + hint_weight * hint_rank)
    eligible = (est_counts > 0) | (hint_rank > 0)
    return jnp.where(eligible, score, -1.0)


def hinted(
    est_counts: jax.Array, hint_rank: jax.Array, k: int, hint_weight: float = 0.25
) -> MigrationPlan:
    """Programmer/compiler hints (paper §VI): blend telemetry rank with a
    static priority.  ``hint_rank`` in [0,1], larger = more important.
    Blocks with zero telemetry *and* zero hint are masked out (score
    sentinel -1) — like every other policy, untouched unhinted blocks are
    never promoted just to fill k, which would churn migration traffic."""
    n = est_counts.shape[0]
    t_rank = jnp.argsort(jnp.argsort(est_counts))
    score = hinted_score(est_counts, t_rank, hint_rank, hint_weight)
    k = min(k, n)
    vals, ids = jax.lax.top_k(score, k)
    return MigrationPlan(promote=jnp.where(vals >= 0, ids, -1))


def prefetch(lookahead_rank: jax.Array, k: int) -> MigrationPlan:
    """Lookahead prefetch (paper §VI: proactive movement driven by compiler
    hints): promote the blocks a bounded lookahead window says the *next*
    epoch will touch, heaviest first, before the accesses land.
    ``lookahead_rank`` in [0,1]; blocks outside the window (rank 0) are never
    promoted — an empty window is a no-op, not a churn source."""
    k = min(k, lookahead_rank.shape[0])
    vals, ids = jax.lax.top_k(lookahead_rank, k)
    return MigrationPlan(promote=jnp.where(vals > 0, ids, -1))


def quality_estimate(observed_mass: jax.Array,
                     expected_mass: jax.Array) -> jax.Array:
    """Per-collector signal quality: the fraction of the expected epoch
    access mass the collector's (served) epoch-delta estimate actually
    reported, clipped to [0, 1].  A healthy HMU reports ~1.0 (it counts
    every access); a healthy PEBS also ~1.0 *after period scaling*.  Drops,
    saturation and reset events all shrink the observed mass, so one scalar
    covers every fault lane — this is the on-device signal
    ``repro.faults.Hardening`` gates its fallback swap on."""
    return jnp.clip(observed_mass / jnp.maximum(expected_mass, 1.0), 0.0, 1.0)


def quality_smooth(prev_q: jax.Array, raw_q: jax.Array,
                   beta: float) -> jax.Array:
    """EWMA smoothing of the raw quality signal, so one noisy epoch does not
    flap the fallback swap (``beta`` = weight of the new observation)."""
    return beta * raw_q + (1.0 - beta) * prev_q


def cold_streak(streak: jax.Array, est: jax.Array,
                fast_mask: jax.Array) -> jax.Array:
    """Consecutive cold epochs per resident block: increments where a
    resident block's epoch estimate is exactly 0, resets to 0 on any touch
    or when the block is not resident.  Demotion hysteresis gates the
    watermark lane's ``demote_idle`` on ``streak >= H`` — under lossy
    telemetry a hot block can *look* cold for an epoch, and without
    hysteresis one dropped sample costs a demote + re-promote pair."""
    return jnp.where(fast_mask & (est == 0), streak + 1, 0)


def coldest_victims(est_counts: jax.Array, slot_to_block: jax.Array, n: int) -> jax.Array:
    """Pick the n coldest currently-fast blocks as demotion victims."""
    occ = slot_to_block >= 0
    blk = jnp.maximum(slot_to_block, 0)
    heat = jnp.where(occ, est_counts[blk], jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(heat)
    sel = order[: min(n, order.shape[0])]
    return jnp.where(occ[sel], slot_to_block[sel], -1)


def plan_eviction(est_counts: jax.Array, want: jax.Array,
                  slot_to_block: jax.Array, n: int) -> jax.Array:
    """Victims to free ``n`` slots for a promotion plan: the coldest resident
    blocks by ``est_counts``, with blocks in ``want`` (the plan's ids, -1
    padding allowed) guarded by +inf heat so a still-wanted resident is never
    evicted ahead of empty slots.  Shared by EpochRuntime and
    TieredEmbedding so the eviction invariant lives in one place."""
    est = est_counts.astype(jnp.float32)
    if want.shape[0]:
        safe = jnp.maximum(want, 0)
        est = est.at[safe].set(jnp.where(want >= 0, jnp.inf, est[safe]))
    return coldest_victims(est, slot_to_block, n)
