"""TieredEmbedding — the paper's technique wired into the LM stack.

The vocab embedding table is a TieredStore (hot rows fast-tier / cold rows
capacity-tier) managed by HMU-style telemetry:

  * **telemetry**: the training/serving step already touches every token id;
    exact per-block access counts are a segment-sum of the token stream —
    the jit-side analogue of the gather_count Pallas kernel's fused counters
    (which is what runs on real TPU hardware).
  * **policy**: oracle top-K / reactive / proactive from core.policy.
  * **placement**: block promotions between steps (host-side control plane,
    like the paper's Tiering Agent); the data plane (gather) is tier-oblivious
    because the TieredStore address space makes promoted rows transparent.
  * **accounting**: the cost model (TPU profile: HBM vs host-over-PCIe)
    converts the per-tier access mix into modeled embed-lookup time, so runs
    report the tiering benefit the way Table 1 does.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from .blockstore import TieredStore
from . import policy as policy_lib
from .costmodel import MemSystem, TPU_V5E_SYSTEM


@dataclasses.dataclass
class TieredEmbedding:
    store: TieredStore
    counts: np.ndarray                   # exact per-block access counts (HMU)
    system: MemSystem = TPU_V5E_SYSTEM
    policy: str = "oracle"               # oracle | proactive
    _pred: Optional[np.ndarray] = None   # EWMA state for proactive

    @staticmethod
    def create(table: jax.Array, block_rows: int = 8,
               fast_fraction: float = 0.1, **kw) -> "TieredEmbedding":
        n_rows = table.shape[0]
        n_blocks = n_rows // block_rows
        n_slots = max(int(n_blocks * fast_fraction), 1)
        store = TieredStore.create(table, block_rows=block_rows, n_slots=n_slots)
        return TieredEmbedding(store=store,
                               counts=np.zeros(n_blocks, np.int64), **kw)

    # ------------------------------------------------------------- telemetry
    def observe_tokens(self, tokens) -> None:
        """Feed the step's token ids (any shape) — memory-side counting."""
        blocks = np.asarray(tokens).reshape(-1) // self.store.block_rows
        np.add.at(self.counts, blocks, 1)

    # --------------------------------------------------------------- control
    def rebalance(self) -> int:
        """Run the promotion policy; returns #blocks promoted this epoch."""
        k = self.store.n_slots
        if self.policy == "proactive":
            pred = self.counts.astype(np.float32) if self._pred is None \
                else 0.5 * self.counts + 0.5 * self._pred
            self._pred = pred
            plan = policy_lib.oracle_top_k(jnp.asarray(pred.astype(np.int32)), k)
        else:
            plan = policy_lib.oracle_top_k(jnp.asarray(
                self.counts.astype(np.int32)), k)
        before = int(self.store.fast_occupancy())
        self.store = self.store.promote(plan.promote)
        return int(self.store.fast_occupancy()) - before

    # ------------------------------------------------------------ accounting
    def modeled_lookup_time_s(self, n_lookups_by_block: Optional[np.ndarray]
                              = None) -> dict:
        counts = (n_lookups_by_block if n_lookups_by_block is not None
                  else self.counts)
        fast_mask = np.asarray(self.store.block_to_slot) >= 0
        n_fast = float(counts[fast_mask].sum())
        n_slow = float(counts.sum() - n_fast)
        bpa = self.store.dim * self.store.storage.dtype.itemsize
        return {
            "tiered_s": self.system.access_time_s(n_fast, n_slow, bpa),
            "all_fast_s": self.system.access_time_s(n_fast + n_slow, 0, bpa),
            "all_slow_s": self.system.access_time_s(0, n_fast + n_slow, bpa),
            "fast_hit_rate": n_fast / max(n_fast + n_slow, 1.0),
            "fast_bytes": int(fast_mask.sum()) * self.store.block_rows * bpa,
        }
