"""TieredEmbedding — the paper's technique wired into the LM stack.

The vocab embedding table is a TieredStore (hot rows fast-tier / cold rows
capacity-tier) managed by HMU-style telemetry:

  * **telemetry**: the training/serving step already touches every token id;
    exact per-block access counts are a segment-sum of the token stream —
    the jit-side analogue of the gather_count Pallas kernel's fused counters
    (which is what runs on real TPU hardware).
  * **policy**: oracle top-K / reactive / proactive from core.policy, driven
    per *epoch* (rebalance snapshots the counters, so reactive/proactive see
    epoch-delta hotness, not all-time sums).
  * **placement**: block migrations between steps (host-side control plane,
    like the paper's Tiering Agent): explicit ``coldest_victims`` demotions
    followed by promotions via ``TieredStore.migrate``; the data plane
    (gather) is tier-oblivious because the TieredStore address space makes
    promoted rows transparent.
  * **accounting**: the cost model (TPU profile: HBM vs host-over-PCIe)
    converts the per-tier access mix into modeled embed-lookup time; the
    ``epoch`` loop keeps a per-epoch history the way the EpochRuntime's
    trajectories do.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from .blockstore import TieredStore
from . import placement as placement_lib
from . import policy as policy_lib
from .costmodel import MemSystem, TPU_V5E_SYSTEM


@dataclasses.dataclass
class TieredEmbedding:
    store: TieredStore
    counts: np.ndarray                   # exact per-block access counts (HMU)
    system: MemSystem = TPU_V5E_SYSTEM
    policy: str = "oracle"               # oracle | proactive | reactive
    ewma_alpha: float = 0.5
    reactive_threshold: int = 2
    _pred: Optional[np.ndarray] = None   # EWMA state for proactive
    _last_counts: Optional[np.ndarray] = None   # epoch-delta snapshot
    history: List[dict] = dataclasses.field(default_factory=list)

    @staticmethod
    def create(table: jax.Array, block_rows: int = 8,
               fast_fraction: float = 0.1, **kw) -> "TieredEmbedding":
        n_rows = table.shape[0]
        n_blocks = n_rows // block_rows
        n_slots = max(int(n_blocks * fast_fraction), 1)
        store = TieredStore.create(table, block_rows=block_rows, n_slots=n_slots)
        return TieredEmbedding(store=store,
                               counts=np.zeros(n_blocks, np.int64), **kw)

    # ------------------------------------------------------------- telemetry
    def observe_tokens(self, tokens) -> None:
        """Feed the step's token ids (any shape) — memory-side counting."""
        blocks = np.asarray(tokens).reshape(-1) // self.store.block_rows
        np.add.at(self.counts, blocks, 1)

    def _epoch_counts(self) -> np.ndarray:
        """Counts accumulated since the last rebalance (epoch-local hotness)."""
        if self._last_counts is None:
            return self.counts.copy()
        return self.counts - self._last_counts

    # --------------------------------------------------------------- control
    def rebalance(self) -> int:
        """Run the promotion policy; returns #blocks promoted this epoch."""
        k = self.store.n_slots
        delta = self._epoch_counts()
        clipped = np.minimum(delta, np.iinfo(np.int32).max).astype(np.int32)
        if self.policy == "proactive":
            if self._pred is None:
                self._pred = np.zeros(self.counts.shape, np.float32)
            pred, plan = policy_lib.proactive_ewma(
                jnp.asarray(self._pred), jnp.asarray(clipped, jnp.float32),
                k, alpha=self.ewma_alpha)
            self._pred = np.asarray(pred)
        elif self.policy == "reactive":
            # watermark demotion first: free residents this epoch never
            # touched, else the store fills once and reactive freezes forever
            b2s = np.asarray(self.store.block_to_slot)
            resident = np.nonzero(b2s >= 0)[0]
            idle = resident[delta[resident] == 0]
            if idle.size:
                self.store = self.store.demote(jnp.asarray(idle, jnp.int32))
            free = k - int(self.store.fast_occupancy())
            plan = policy_lib.reactive_watermark(
                jnp.asarray(clipped), self.reactive_threshold,
                jnp.asarray(free), max_moves=k)
        else:
            plan = policy_lib.oracle_top_k(jnp.asarray(
                np.minimum(self.counts, np.iinfo(np.int32).max).astype(np.int32)), k)
        self._last_counts = self.counts.copy()

        # Explicit demotion: when promotions exceed free slots, evict the
        # epoch-coldest residents (never blocks the plan still wants).  The
        # bounded-promotion invariant lives in core.placement — the same
        # sequence the fused EpochRuntime applies lane-stacked on device.
        _, victims = placement_lib.plan_promotion(
            self.store.placement, plan.promote, delta)
        before = int(self.store.fast_occupancy())
        self.store = self.store.migrate(plan.promote, victims)
        return int(self.store.fast_occupancy()) - before + (
            0 if victims is None else int(np.sum(np.asarray(victims) >= 0)))

    def epoch(self, tokens) -> dict:
        """One online epoch: observe the step's tokens, account the modeled
        lookup time under the placement that served them, then rebalance."""
        prev_delta_base = (self._last_counts.copy()
                           if self._last_counts is not None else
                           np.zeros_like(self.counts))
        self.observe_tokens(tokens)
        epoch_counts = self.counts - prev_delta_base
        rep = self.modeled_lookup_time_s(epoch_counts)
        moved = self.rebalance()
        rep = dict(rep, epoch=len(self.history), moved=moved,
                   policy=self.policy)
        self.history.append(rep)
        return rep

    # ------------------------------------------------------------ accounting
    def modeled_lookup_time_s(self, n_lookups_by_block: Optional[np.ndarray]
                              = None) -> dict:
        counts = (n_lookups_by_block if n_lookups_by_block is not None
                  else self.counts)
        fast_mask = np.asarray(self.store.block_to_slot) >= 0
        n_fast = float(counts[fast_mask].sum())
        n_slow = float(counts.sum() - n_fast)
        bpa = self.store.dim * self.store.storage.dtype.itemsize
        return {
            "tiered_s": self.system.access_time_s(n_fast, n_slow, bpa),
            "all_fast_s": self.system.access_time_s(n_fast + n_slow, 0, bpa),
            "all_slow_s": self.system.access_time_s(0, n_fast + n_slow, bpa),
            "fast_hit_rate": n_fast / max(n_fast + n_slow, 1.0),
            "fast_bytes": int(fast_mask.sum()) * self.store.block_rows * bpa,
        }
