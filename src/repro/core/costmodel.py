"""Analytic two-tier memory cost model.

This container has no CXL expander and no HBM, so end-to-end *time* is
modeled, not measured (the relative telemetry quality — coverage/accuracy —
is measured, it emerges from the emulators).  The model is a per-tier
roofline: a batch of accesses costs

    max( latency-bound term,  bandwidth-bound term )   per tier, summed.

* latency-bound: n_accesses * latency / MLP  (MLP = memory-level parallelism,
  i.e. outstanding requests the core/DMA sustains)
* bandwidth-bound: bytes / bandwidth

Two calibrated profiles are provided:
* ``CXL_SYSTEM`` — the paper's platform (Emerald Rapids DDR5 + FPGA CXL card).
* ``TPU_V5E_SYSTEM`` — the TPU mapping (HBM + host memory over PCIe), used by
  the LM-side tiering features.
"""
from __future__ import annotations

import dataclasses


def _check_overlap(overlap: float) -> None:
    """Overlap fractions are physical ratios: anything outside [0,1] (or NaN)
    is a caller bug, not a clampable input."""
    if not 0.0 <= float(overlap) <= 1.0:
        raise ValueError(f"overlap must be in [0, 1], got {overlap!r}")


@dataclasses.dataclass(frozen=True)
class TierSpec:
    name: str
    latency_ns: float
    bandwidth_gbps: float  # GB/s


@dataclasses.dataclass(frozen=True)
class MemSystem:
    fast: TierSpec
    slow: TierSpec
    mlp: float = 16.0  # sustained outstanding requests

    def tier_time_s(self, n_accesses: float, bytes_total: float, tier: TierSpec) -> float:
        lat = n_accesses * tier.latency_ns * 1e-9 / self.mlp
        bw = bytes_total / (tier.bandwidth_gbps * 1e9)
        return max(lat, bw)

    def access_time_s(
        self,
        n_fast: float,
        n_slow: float,
        bytes_per_access: float,
        overlap: float = 0.0,
    ) -> float:
        """Time to service the access mix.  ``overlap`` in [0,1]: fraction of
        slow-tier time hidden under concurrent work (prefetch/NMC overlap);
        0 is the serial sum of the tiers, 1 hides all slow-tier time."""
        _check_overlap(overlap)
        tf = self.tier_time_s(n_fast, n_fast * bytes_per_access, self.fast)
        ts = self.tier_time_s(n_slow, n_slow * bytes_per_access, self.slow)
        return tf + ts * (1.0 - overlap)

    def migration_time_s(self, n_blocks: float, block_bytes: float) -> float:
        """Block migration: read from slow + write to fast (slow side bounds)."""
        return self.tier_time_s(n_blocks, n_blocks * block_bytes, self.slow)

    def migration_overlap_s(
        self,
        n_slow: float,
        bytes_per_access: float,
        n_blocks: float,
        block_bytes: float,
        overlap: float = 1.0,
    ) -> float:
        """Seconds of epoch time hidden when ``n_blocks`` of migration stream
        concurrently with the epoch's accesses (lookahead prefetch): the
        overlapped fraction of whichever leg is shorter — the slow-tier access
        time or the migration DMA — hides under the other.  0 at
        ``overlap=0`` (stop-the-world migration), ``min(ts, mig)`` at 1."""
        _check_overlap(overlap)
        ts = self.tier_time_s(n_slow, n_slow * bytes_per_access, self.slow)
        mig = self.migration_time_s(n_blocks, block_bytes)
        return overlap * min(ts, mig)

    def overlapped_epoch_time_s(
        self,
        n_fast: float,
        n_slow: float,
        bytes_per_access: float,
        n_blocks: float,
        block_bytes: float,
        overlap: float = 1.0,
    ) -> float:
        """Epoch time when the boundary migration overlaps the epoch's access
        stream instead of serializing ahead of it.  The hidden share of the
        slow-tier access time folds out through the ``access_time_s(overlap=)``
        hook, so the total is the serial sum minus ``migration_overlap_s``:
        never more than stop-the-world migration, never less than the longer
        of the two legs."""
        hidden = self.migration_overlap_s(
            n_slow, bytes_per_access, n_blocks, block_bytes, overlap)
        ts = self.tier_time_s(n_slow, n_slow * bytes_per_access, self.slow)
        eff = hidden / ts if ts > 0.0 else 0.0
        return (self.access_time_s(n_fast, n_slow, bytes_per_access,
                                   overlap=eff)
                + self.migration_time_s(n_blocks, block_bytes))


# The paper's platform: Intel Emerald Rapids (DDR5) + FPGA CXL type-3 card.
# DDR5 local socket ~90 ns load-to-use / ~250 GB/s per socket;
# FPGA CXL.mem ~350-400 ns / ~28 GB/s (FPGA prototypes are slower than ASIC CXL).
CXL_SYSTEM = MemSystem(
    fast=TierSpec("host-dram-ddr5", latency_ns=90.0, bandwidth_gbps=250.0),
    slow=TierSpec("cxl-fpga", latency_ns=380.0, bandwidth_gbps=28.0),
    mlp=16.0,
)

# TPU v5e mapping used by the LM tiering features: HBM vs host DRAM over PCIe.
TPU_V5E_SYSTEM = MemSystem(
    fast=TierSpec("hbm", latency_ns=550.0, bandwidth_gbps=819.0),
    slow=TierSpec("host-pcie", latency_ns=2300.0, bandwidth_gbps=16.0),
    mlp=64.0,
)


def split_accesses_by_tier(counts, is_fast):
    """(n_fast_accesses, n_slow_accesses) given per-block true counts and a
    fast-residency mask."""
    import numpy as np

    c = np.asarray(counts, np.float64)
    m = np.asarray(is_fast, bool)
    return float(c[m].sum()), float(c[~m].sum())
