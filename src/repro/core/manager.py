"""TieringManager — the paper's "Tiering Agent" (Fig. 2) as a runtime object.

Wires together: workload access stream -> telemetry collector(s) -> promotion
policy -> TieredStore migration -> cost accounting.  One manager instance per
tiered object (embedding table, expert bank, KV pool).

The evaluation flow matches the paper's methodology exactly:
  1. *Profiling phase*: allocations land in the slow tier; collectors observe
     the stream ("allocation requests directed to CXL memory").
  2. *Promotion*: policy selects blocks from each collector's estimate; the
     top-K (K = fast-tier capacity) are migrated.
  3. *Measurement phase*: the stream is replayed against the placement; the
     cost model converts the per-tier access mix into time.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Optional

import numpy as np
import jax.numpy as jnp

from . import telemetry as tel
from . import metrics, policy
from .costmodel import MemSystem, split_accesses_by_tier


@dataclasses.dataclass
class StrategyResult:
    name: str
    promoted: np.ndarray           # block ids promoted (>=0, unique)
    est_counts: np.ndarray         # collector's hotness estimate
    accuracy: float                # vs true top-K
    coverage: float                # fraction of true top-K promoted
    host_events: int               # host-side work the collector cost
    time_s: Optional[float] = None
    fast_bytes: Optional[float] = None
    slow_bytes: Optional[float] = None


class TieringManager:
    """Runs the three telemetry strategies side-by-side over one stream."""

    def __init__(
        self,
        n_blocks: int,
        k_hot: int,
        pebs_period: int = 10007,
        nb_scan_rate: Optional[int] = None,
        hmu_log_capacity: int = 1 << 33,
    ):
        self.n_blocks = n_blocks
        self.k_hot = min(k_hot, n_blocks)
        # Linux default scan window covers the whole VMA over ~scan_period;
        # default: one full pass every ~16 observe calls.
        scan = nb_scan_rate if nb_scan_rate is not None else max(n_blocks // 16, 1)
        self.hmu = tel.hmu_init(n_blocks, log_capacity=hmu_log_capacity)
        self.pebs = tel.pebs_init(n_blocks, period=pebs_period)
        self.nb = tel.nb_init(n_blocks, scan_rate=scan)
        self.true_counts = np.zeros((n_blocks,), np.int64)

    # ---------------------------------------------------------------- observe
    def observe(self, block_ids) -> None:
        """Feed one batch of the ground-truth access stream to all collectors."""
        arr = jnp.asarray(block_ids)
        self.hmu = tel.hmu_observe(self.hmu, arr)
        self.pebs = tel.pebs_observe(self.pebs, arr)
        self.nb = tel.nb_observe(self.nb, arr)
        np.add.at(self.true_counts, np.asarray(arr).reshape(-1), 1)

    def observe_stream(self, stream: Iterable) -> None:
        for batch in stream:
            self.observe(batch)

    # ---------------------------------------------------------------- decide
    def decide(self, nb_rate_limit: Optional[int] = None) -> Dict[str, policy.MigrationPlan]:
        self.hmu = tel.hmu_drain_cost(self.hmu)
        return {
            "hmu": policy.oracle_top_k(tel.hmu_estimate(self.hmu), self.k_hot),
            "pebs": policy.oracle_top_k(tel.pebs_estimate(self.pebs), self.k_hot),
            "nb": policy.nb_two_touch(tel.nb_estimate(self.nb), self.k_hot, nb_rate_limit),
        }

    # --------------------------------------------------------------- evaluate
    def evaluate(
        self,
        system: MemSystem,
        bytes_per_access: float,
        eval_counts: Optional[np.ndarray] = None,
        compute_base_s: float = 0.0,
        nb_rate_limit: Optional[int] = None,
    ) -> Dict[str, StrategyResult]:
        """Promote per strategy, replay the (eval) stream, model the time.

        ``eval_counts`` defaults to the profiled counts (the paper replays the
        same workload).  ``compute_base_s`` is the non-memory compute time.
        """
        true = eval_counts if eval_counts is not None else self.true_counts
        true_hot = metrics.true_top_k(self.true_counts, self.k_hot)
        plans = self.decide(nb_rate_limit=nb_rate_limit)
        ests = {
            "hmu": np.asarray(tel.hmu_estimate(self.hmu)),
            "pebs": np.asarray(tel.pebs_estimate(self.pebs)),
            "nb": np.asarray(tel.nb_estimate(self.nb)),
        }
        host = {
            "hmu": int(float(self.hmu.host_events)),
            "pebs": int(float(self.pebs.host_events)),
            "nb": int(float(self.nb.host_events)),
        }
        out: Dict[str, StrategyResult] = {}
        for name, plan in plans.items():
            promoted = np.asarray(plan.promote)
            promoted = np.unique(promoted[promoted >= 0])
            is_fast = np.zeros((self.n_blocks,), bool)
            is_fast[promoted] = True
            n_fast, n_slow = split_accesses_by_tier(true, is_fast)
            t = compute_base_s + system.access_time_s(n_fast, n_slow, bytes_per_access)
            out[name] = StrategyResult(
                name=name,
                promoted=promoted,
                est_counts=ests[name],
                accuracy=metrics.accuracy(promoted, true_hot),
                coverage=metrics.coverage(promoted, true_hot, self.k_hot),
                host_events=host[name],
                time_s=t,
                fast_bytes=n_fast * bytes_per_access,
                slow_bytes=n_slow * bytes_per_access,
            )
        # reference placements
        for name, mask in (
            ("dram-only", np.ones((self.n_blocks,), bool)),
            ("slow-only", np.zeros((self.n_blocks,), bool)),
        ):
            n_fast, n_slow = split_accesses_by_tier(true, mask)
            out[name] = StrategyResult(
                name=name,
                promoted=np.nonzero(mask)[0],
                est_counts=self.true_counts,
                accuracy=1.0 if mask.any() else 0.0,
                coverage=1.0 if mask.any() else 0.0,
                host_events=0,
                time_s=compute_base_s + system.access_time_s(n_fast, n_slow, bytes_per_access),
                fast_bytes=n_fast * bytes_per_access,
                slow_bytes=n_slow * bytes_per_access,
            )
        return out
