"""TieringManager — the paper's "Tiering Agent" (Fig. 2) as a runtime object.

Wires together: workload access stream -> telemetry collector(s) -> promotion
policy -> TieredStore migration -> cost accounting.  One manager instance per
tiered object (embedding table, expert bank, KV pool).

The evaluation flow matches the paper's methodology exactly:
  1. *Profiling phase*: allocations land in the slow tier; collectors observe
     the stream ("allocation requests directed to CXL memory").
  2. *Promotion*: policy selects blocks from each collector's estimate; the
     top-K (K = fast-tier capacity) are migrated.
  3. *Measurement phase*: the stream is replayed against the placement; the
     cost model converts the per-tier access mix into time.

All collector state (HMU + PEBS + NB + the ground-truth histogram) lives in
one :class:`~repro.core.telemetry.TelemetryBundle` pytree.  Two observe paths
feed it:

* ``observe(batch)``   — reference path: one jit dispatch per collector per
  batch (plus one for the true counter), exactly the per-batch semantics.
* ``observe_epoch(batches)`` — fused path: a single jit dispatch that
  ``lax.scan``s the whole epoch on device; bit-identical to calling
  ``observe`` on each row in order, and what the epoch-driven runtime
  (:mod:`repro.core.runtime`) uses.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional

import numpy as np
import jax.numpy as jnp

from . import telemetry as tel
from . import metrics, policy
from .costmodel import MemSystem, split_accesses_by_tier


@dataclasses.dataclass
class StrategyResult:
    name: str
    promoted: np.ndarray           # block ids promoted (>=0, unique)
    est_counts: np.ndarray         # collector's hotness estimate
    accuracy: float                # vs true top-K
    coverage: float                # fraction of true top-K promoted
    host_events: int               # host-side work the collector cost
    time_s: Optional[float] = None
    fast_bytes: Optional[float] = None
    slow_bytes: Optional[float] = None


class TieringManager:
    """Runs the three telemetry strategies side-by-side over one stream."""

    def __init__(
        self,
        n_blocks: int,
        k_hot: int,
        pebs_period: int = 10007,
        nb_scan_rate: Optional[int] = None,
        hmu_log_capacity: int = 1 << 33,
    ):
        self.n_blocks = n_blocks
        self.k_hot = min(k_hot, n_blocks)
        # Linux default scan window covers the whole VMA over ~scan_period;
        # default: one full pass every ~16 observe calls.
        scan = nb_scan_rate if nb_scan_rate is not None else max(n_blocks // 16, 1)
        self.bundle = tel.bundle_init(
            n_blocks, pebs_period=pebs_period, nb_scan_rate=scan,
            hmu_log_capacity=hmu_log_capacity,
        )

    # ------------------------------------------------- collector accessors
    # (kept as attributes for the pre-bundle callers: tracesim/benchmarks
    # read ``mgr.hmu`` and assign ``mgr.hmu = tel.hmu_drain_cost(mgr.hmu)``)
    @property
    def hmu(self) -> tel.HMUState:
        return self.bundle.hmu

    @hmu.setter
    def hmu(self, state: tel.HMUState) -> None:
        self.bundle = dataclasses.replace(self.bundle, hmu=state)

    @property
    def pebs(self) -> tel.PEBSState:
        return self.bundle.pebs

    @pebs.setter
    def pebs(self, state: tel.PEBSState) -> None:
        self.bundle = dataclasses.replace(self.bundle, pebs=state)

    @property
    def nb(self) -> tel.NBState:
        return self.bundle.nb

    @nb.setter
    def nb(self, state: tel.NBState) -> None:
        self.bundle = dataclasses.replace(self.bundle, nb=state)

    @property
    def true_counts(self) -> np.ndarray:
        """Exact access histogram (host copy, int64 for downstream sums)."""
        return np.asarray(self.bundle.true_counts, np.int64)

    # ---------------------------------------------------------------- observe
    def observe(self, block_ids) -> None:
        """Feed one batch of the ground-truth access stream to all collectors
        (reference per-batch path: one dispatch per collector)."""
        arr = jnp.asarray(block_ids)
        self.bundle = tel.TelemetryBundle(
            hmu=tel.hmu_observe(self.bundle.hmu, arr),
            pebs=tel.pebs_observe(self.bundle.pebs, arr),
            nb=tel.nb_observe(self.bundle.nb, arr),
            true_counts=tel.count_observe(self.bundle.true_counts, arr),
        )

    def observe_epoch(self, batches) -> None:
        """Fused path: observe ``(n_batches, batch_size)`` in ONE dispatch."""
        arr = jnp.asarray(batches)
        if arr.ndim != 2:
            raise ValueError(f"observe_epoch wants (n_batches, batch), got {arr.shape}")
        self.bundle = tel.observe_all(self.bundle, arr)

    def observe_stream(self, stream: Iterable) -> None:
        for batch in stream:
            self.observe(batch)

    # ---------------------------------------------------------------- decide
    def decide(self, nb_rate_limit: Optional[int] = None) -> Dict[str, policy.MigrationPlan]:
        self.hmu = tel.hmu_drain_cost(self.hmu)
        return {
            "hmu": policy.oracle_top_k(tel.hmu_estimate(self.hmu), self.k_hot),
            "pebs": policy.oracle_top_k(tel.pebs_estimate(self.pebs), self.k_hot),
            "nb": policy.nb_two_touch(tel.nb_estimate(self.nb), self.k_hot, nb_rate_limit),
        }

    # --------------------------------------------------------------- evaluate
    def evaluate(
        self,
        system: MemSystem,
        bytes_per_access: float,
        eval_counts: Optional[np.ndarray] = None,
        compute_base_s: float = 0.0,
        nb_rate_limit: Optional[int] = None,
    ) -> Dict[str, StrategyResult]:
        """Promote per strategy, replay the (eval) stream, model the time.

        ``eval_counts`` defaults to the profiled counts (the paper replays the
        same workload).  ``compute_base_s`` is the non-memory compute time.
        """
        true_counts = self.true_counts
        true = eval_counts if eval_counts is not None else true_counts
        true_hot = metrics.true_top_k(true_counts, self.k_hot)
        plans = self.decide(nb_rate_limit=nb_rate_limit)
        ests = {
            "hmu": np.asarray(tel.hmu_estimate(self.hmu)),
            "pebs": np.asarray(tel.pebs_estimate(self.pebs)),
            "nb": np.asarray(tel.nb_estimate(self.nb)),
        }
        host = {
            "hmu": int(float(self.hmu.host_events)),
            "pebs": int(float(self.pebs.host_events)),
            "nb": int(float(self.nb.host_events)),
        }
        out: Dict[str, StrategyResult] = {}
        for name, plan in plans.items():
            promoted = np.asarray(plan.promote)
            promoted = np.unique(promoted[promoted >= 0])
            is_fast = np.zeros((self.n_blocks,), bool)
            is_fast[promoted] = True
            n_fast, n_slow = split_accesses_by_tier(true, is_fast)
            t = compute_base_s + system.access_time_s(n_fast, n_slow, bytes_per_access)
            out[name] = StrategyResult(
                name=name,
                promoted=promoted,
                est_counts=ests[name],
                accuracy=metrics.accuracy(promoted, true_hot),
                coverage=metrics.coverage(promoted, true_hot, self.k_hot),
                host_events=host[name],
                time_s=t,
                fast_bytes=n_fast * bytes_per_access,
                slow_bytes=n_slow * bytes_per_access,
            )
        # reference placements
        for name, mask in (
            ("dram-only", np.ones((self.n_blocks,), bool)),
            ("slow-only", np.zeros((self.n_blocks,), bool)),
        ):
            n_fast, n_slow = split_accesses_by_tier(true, mask)
            out[name] = StrategyResult(
                name=name,
                promoted=np.nonzero(mask)[0],
                est_counts=true_counts,
                accuracy=1.0 if mask.any() else 0.0,
                coverage=1.0 if mask.any() else 0.0,
                host_events=0,
                time_s=compute_base_s + system.access_time_s(n_fast, n_slow, bytes_per_access),
                fast_bytes=n_fast * bytes_per_access,
                slow_bytes=n_slow * bytes_per_access,
            )
        return out
