"""Telemetry collectors: three observers of one ground-truth access stream.

The paper's central experiment is to feed the *same* workload to three hotness
trackers and compare what each believes the hot set is:

* ``HMU``  — memory-side Hotness Monitoring Unit: sees **every** request the
  memory device services (the CXL Data Logger snoops all CXL.mem packets).
  Exact per-block counters, zero host cost for collection; host cost only to
  drain/process the log.
* ``PEBS`` — CPU-assisted sampling: sees every ``period``-th memory access
  (Intel PEBS semantics).  Full-address precision on sampled events but
  **coverage** is bounded by the sampling period; each sample costs host work.
* ``NB``   — OS-level NUMA-balancing hints: the kernel *unmaps* pages in a
  cyclic scan; the next touch of an unmapped page raises a hint fault.  The OS
  therefore observes **recency, not frequency**: one touch after a scan looks
  identical to ten thousand touches.  Each fault costs host work.

All collectors are functional pytrees; ``observe`` is jit-able and is driven
with batches of row/page indices (the "physical addresses" in the log).  The
access stream itself is produced by the workloads (mmap-bench, DLRM, the LM
embedding / expert / KV layers).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "HMUState", "PEBSState", "NBState", "TelemetryBundle",
    "hmu_init", "hmu_observe", "hmu_estimate", "hmu_drain_cost",
    "pebs_init", "pebs_observe", "pebs_estimate",
    "nb_init", "nb_observe", "nb_estimate",
    "bundle_init", "observe_all", "count_observe",
]


# =====================================================================  HMU
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HMUState:
    """Memory-side exact counters + bounded request-log emulation.

    ``counts`` is what a counter-mode HMU exposes.  ``log_used``/``log_dropped``
    model the paper's log-DRAM capacity (256 GB on the FPGA card): in log mode
    every request consumes one record until the log fills; software must drain
    it (``hmu_drain``) or subsequent records are dropped.  Drops only affect
    log mode — counter mode never loses events.
    """
    counts: jax.Array          # (n_blocks,) int64-ish exact access counts
    log_used: jax.Array        # scalar: records currently in the log
    log_dropped: jax.Array     # scalar: records lost to log overflow
    log_capacity: int = dataclasses.field(metadata=dict(static=True))
    host_events: jax.Array     # scalar: host work units spent (drain only)


def hmu_init(n_blocks: int, log_capacity: int = 1 << 33) -> HMUState:
    # Scalar accounting uses float32 (x64 is disabled; these model counters can
    # exceed int32 range for a 256 GB log -> billions of records).  Distinct
    # arrays (not one shared buffer) so donation works.
    return HMUState(
        counts=jnp.zeros((n_blocks,), jnp.int32),
        log_used=jnp.zeros((), jnp.float32),
        log_dropped=jnp.zeros((), jnp.float32),
        log_capacity=int(log_capacity),
        host_events=jnp.zeros((), jnp.float32),
    )


def _hmu_observe(state: HMUState, block_ids: jax.Array, weight: int = 1) -> HMUState:
    """Pure (un-jitted) HMU update — shared by the per-batch jit and the
    fused epoch scan so both paths are the *same traced computation* and
    therefore bit-identical."""
    flat = block_ids.reshape(-1)
    counts = state.counts.at[flat].add(weight, mode="drop")
    n = jnp.asarray(flat.shape[0] * weight, jnp.float32)
    free = jnp.maximum(jnp.float32(state.log_capacity) - state.log_used, 0.0)
    appended = jnp.minimum(n, free)
    return dataclasses.replace(
        state,
        counts=counts,
        log_used=state.log_used + appended,
        log_dropped=state.log_dropped + (n - appended),
    )


@partial(jax.jit, donate_argnums=0, static_argnums=2)
def hmu_observe(state: HMUState, block_ids: jax.Array, weight: int = 1) -> HMUState:
    """Device-side: every access counted. No host involvement."""
    return _hmu_observe(state, block_ids, weight)


def hmu_estimate(state: HMUState) -> jax.Array:
    return state.counts


def hmu_drain_cost(state: HMUState, per_record_cost: float = 1.0) -> HMUState:
    """Host drains/processes the log (paper: 'process the trace immediately').
    This is the only host cost HMU incurs; NMC (paper §VI) would shrink it."""
    return dataclasses.replace(
        state,
        host_events=state.host_events + state.log_used * per_record_cost,
        log_used=jnp.zeros((), jnp.float32),
    )


# =====================================================================  PEBS
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PEBSState:
    sampled: jax.Array        # (n_blocks,) number of *sampled* hits per block
    cursor: jax.Array         # scalar int32: global access index mod period
    period: int = dataclasses.field(metadata=dict(static=True))
    host_events: jax.Array    # scalar: one per PEBS record (interrupt+parse)


def pebs_init(n_blocks: int, period: int = 10007) -> PEBSState:
    return PEBSState(
        sampled=jnp.zeros((n_blocks,), jnp.int32),
        cursor=jnp.zeros((), jnp.int32),
        period=int(period),
        host_events=jnp.zeros((), jnp.float32),
    )


def _pebs_observe(state: PEBSState, block_ids: jax.Array) -> PEBSState:
    flat = block_ids.reshape(-1)
    n = flat.shape[0]
    # cursor is an exact int32 carried modulo period: a float32 cursor is only
    # exact for streams < 2^24 accesses, so paper-scale epoch streams would
    # drift the sampling phase.  The modulo keeps it exact forever.
    idx = state.cursor + jnp.arange(n, dtype=jnp.int32)
    hit = (idx % state.period) == 0
    # scatter-add only sampled positions (weight 0/1)
    sampled = state.sampled.at[flat].add(hit.astype(jnp.int32), mode="drop")
    return dataclasses.replace(
        state,
        sampled=sampled,
        cursor=(state.cursor + n) % state.period,
        host_events=state.host_events + jnp.sum(hit).astype(jnp.float32),
    )


@partial(jax.jit, donate_argnums=0)
def pebs_observe(state: PEBSState, block_ids: jax.Array) -> PEBSState:
    """CPU-assisted: only every ``period``-th access in program order is seen.

    The access stream order is the order of ``block_ids`` — identical to what
    the HMU sees, so coverage differences are purely due to sampling.
    """
    return _pebs_observe(state, block_ids)


def pebs_estimate(state: PEBSState) -> jax.Array:
    """Scaled estimate: each sample represents ``period`` accesses."""
    return state.sampled * state.period


# =====================================================================  NB
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class NBState:
    """Linux NUMA-balancing emulation (task_numa_work-style cyclic scanner).

    The scanner unmaps ``scan_rate`` blocks per observe call starting at
    ``scan_ptr``; a *first* touch of an unmapped block raises a hint fault
    (host_events += 1), re-maps the block and bumps its fault count.  Blocks
    are promotion candidates after >= 2 faults (two-touch rule).  Frequency
    beyond the first touch per scan pass is invisible — that is the accuracy
    limitation the paper measures.
    """
    mapped: jax.Array        # (n_blocks,) bool: PTE present (access invisible)
    faults: jax.Array        # (n_blocks,) hint-fault counts
    scan_ptr: jax.Array      # scalar cyclic scan position
    scan_rate: int = dataclasses.field(metadata=dict(static=True))
    host_events: jax.Array   # scalar: hint faults serviced


def nb_init(n_blocks: int, scan_rate: int) -> NBState:
    return NBState(
        mapped=jnp.ones((n_blocks,), jnp.bool_),
        faults=jnp.zeros((n_blocks,), jnp.int32),
        scan_ptr=jnp.zeros((), jnp.int32),
        scan_rate=int(scan_rate),
        host_events=jnp.zeros((), jnp.float32),
    )


def _nb_observe(state: NBState, block_ids: jax.Array) -> NBState:
    n_blocks = state.mapped.shape[0]
    # 1. scanner tick: unmap the next scan_rate blocks (cyclic)
    scan_idx = (state.scan_ptr + jnp.arange(state.scan_rate, dtype=jnp.int32)) % n_blocks
    mapped = state.mapped.at[scan_idx].set(False)
    # 2. workload touches: first touch of an unmapped block faults
    flat = block_ids.reshape(-1)
    touched = jnp.zeros((n_blocks,), jnp.bool_).at[flat].set(True, mode="drop")
    faulted = touched & ~mapped
    faults = state.faults + faulted.astype(jnp.int32)
    mapped = mapped | touched
    return dataclasses.replace(
        state,
        mapped=mapped,
        faults=faults,
        scan_ptr=(state.scan_ptr + state.scan_rate) % n_blocks,
        host_events=state.host_events + jnp.sum(faulted).astype(jnp.float32),
    )


@partial(jax.jit, donate_argnums=0)
def nb_observe(state: NBState, block_ids: jax.Array) -> NBState:
    return _nb_observe(state, block_ids)


def nb_estimate(state: NBState) -> jax.Array:
    """NB's 'hotness' signal: hint-fault counts (recency proxy).
    Two-touch gating is applied by the policy layer (candidates = faults >= 2)."""
    return state.faults


# =====================================================  fused bundle (epoch)
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TelemetryBundle:
    """All three collectors plus the device-side ground-truth counter as ONE
    pytree, so a whole epoch of batches is observed with a single jit
    dispatch (``observe_all`` ``lax.scan``s over the batch axis) instead of
    three dispatches + a host ``np.add.at`` per batch.

    ``true_counts`` is the exact access histogram the evaluation compares
    against — it is what an ideal oracle sees, kept on device so the fused
    path never synchronises with the host mid-epoch.
    """
    hmu: HMUState
    pebs: PEBSState
    nb: NBState
    true_counts: jax.Array     # (n_blocks,) int32 exact histogram


def bundle_init(
    n_blocks: int,
    pebs_period: int = 10007,
    nb_scan_rate: int = 1,
    hmu_log_capacity: int = 1 << 33,
) -> TelemetryBundle:
    return TelemetryBundle(
        hmu=hmu_init(n_blocks, log_capacity=hmu_log_capacity),
        pebs=pebs_init(n_blocks, period=pebs_period),
        nb=nb_init(n_blocks, scan_rate=nb_scan_rate),
        true_counts=jnp.zeros((n_blocks,), jnp.int32),
    )


def _count_observe(counts: jax.Array, block_ids: jax.Array) -> jax.Array:
    flat = block_ids.reshape(-1)
    return counts.at[flat].add(1, mode="drop")


@partial(jax.jit, donate_argnums=0)
def count_observe(counts: jax.Array, block_ids: jax.Array) -> jax.Array:
    """Ground-truth histogram update (device-side ``np.add.at`` analogue)."""
    return _count_observe(counts, block_ids)


def _bundle_observe(bundle: TelemetryBundle, block_ids: jax.Array) -> TelemetryBundle:
    return TelemetryBundle(
        hmu=_hmu_observe(bundle.hmu, block_ids),
        pebs=_pebs_observe(bundle.pebs, block_ids),
        nb=_nb_observe(bundle.nb, block_ids),
        true_counts=_count_observe(bundle.true_counts, block_ids),
    )


# Python-side trace counter: observe_all's body runs once per (shape, static)
# combination; tests use this to prove the fused path compiles once and then
# issues exactly one dispatch per epoch.
TRACE_COUNTS = {"observe_all": 0}


@partial(jax.jit, donate_argnums=0)
def observe_all(bundle: TelemetryBundle, batches: jax.Array) -> TelemetryBundle:
    """Observe a whole epoch in one dispatch.

    ``batches`` is the epoch's access stream as ``(n_batches, batch_size)``
    block ids (equal-size batches; pad with a repeated id if needed — every
    access is still counted, the paper's collectors have no notion of batch
    boundaries).  The scan applies the identical per-batch update the
    unfused path uses, in the same order, so collector states match the
    per-batch path bit-for-bit.

    The bundle operand is donated (``donate_argnums=0``), like every
    observe above: the runtime's epoch loop re-uses the collector buffers
    in place, and — because the call is async-dispatched — the host is
    already free to flush the previous epochs' batched record sync
    (``EpochRuntime`` with ``sync_every=K``) while the scan runs.
    """
    TRACE_COUNTS["observe_all"] += 1

    def step(b: TelemetryBundle, block_ids: jax.Array):
        return _bundle_observe(b, block_ids), None

    out, _ = jax.lax.scan(step, bundle, batches)
    return out
