"""Telemetry collectors: three observers of one ground-truth access stream.

The paper's central experiment is to feed the *same* workload to three hotness
trackers and compare what each believes the hot set is:

* ``HMU``  — memory-side Hotness Monitoring Unit: sees **every** request the
  memory device services (the CXL Data Logger snoops all CXL.mem packets).
  Exact per-block counters, zero host cost for collection; host cost only to
  drain/process the log.
* ``PEBS`` — CPU-assisted sampling: sees every ``period``-th memory access
  (Intel PEBS semantics).  Full-address precision on sampled events but
  **coverage** is bounded by the sampling period; each sample costs host work.
* ``NB``   — OS-level NUMA-balancing hints: the kernel *unmaps* pages in a
  cyclic scan; the next touch of an unmapped page raises a hint fault.  The OS
  therefore observes **recency, not frequency**: one touch after a scan looks
  identical to ten thousand touches.  Each fault costs host work.

All collectors are functional pytrees; ``observe`` is jit-able and is driven
with batches of row/page indices (the "physical addresses" in the log).  The
access stream itself is produced by the workloads (mmap-bench, DLRM, the LM
embedding / expert / KV layers).

**Fault lanes.**  Real collectors are not perfectly reliable, and the limits
study only holds if the degraded regimes are modeled too.  When the
:class:`TelemetryBundle` carries a :class:`repro.faults.FaultModel`
(``bundle_init(faults=...)``), the fused observe path injects — on device,
inside the same ``lax.scan``, so the epoch stays one dispatch:

* **HMU counter saturation** — per-block counters clamp at the model's
  ``hmu_counter_max`` (``2**w - 1`` for a ``w``-bit hardware counter)
  instead of silently wrapping int32; a saturated block's epoch delta reads
  0, so a narrow counter makes the *hottest* blocks invisible.  With no
  model the clamp still applies at int32 max (wrapping is never correct).
* **PEBS sample drops** — each would-be sample is lost with probability
  ``pebs_drop_p`` (scalar, or per-block for per-tenant profiles) before the
  host sees it; the drop count accrues to ``faults.pebs_dropped``.
* **collector resets** — once per epoch, with per-collector probability
  ``reset_p``, a collector's cumulative signal state (HMU counts / PEBS
  sampled histogram / NB fault counts + PTE state) resets to empty.  This
  models drain races: the epoch deltas the runtime computes against its
  pre-reset baselines are garbage for one epoch — exactly the signal the
  degradation machinery in ``core.runtime`` has to survive.
* **NB scan stalls** — with probability ``nb_stall_p`` per observe call the
  scanner makes no progress (no unmapping, no cursor advance), so hint
  faults stop arriving — ``task_numa_work`` skipping its slice under load.
* **staleness** — ``stale_epochs`` delays the estimates the *policies* see
  through a ring buffer (a runtime state leaf, not a collector change).

All event scalars (``log_used``/``log_dropped``/``host_events``) are exact
:class:`repro.faults.Counter64` hi/lo int32 pairs: the float32 scalars they
replace silently stopped incrementing past 2**24 events, which paper-scale
runs exceed within one run.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..faults.model import (
    CARRY_BASE, CARRY_BITS, INT32_MAX, Counter64, FaultModel,
    counter_add, counter_init, counter_scaled_add, counter_zero_like,
)
from ..kernels.observe_scatter import observe_scatter
from ..obs import metrics as obs_metrics

__all__ = [
    "HMUState", "PEBSState", "NBState", "TelemetryBundle",
    "hmu_init", "hmu_observe", "hmu_estimate", "hmu_drain_cost",
    "hmu_saturated",
    "pebs_init", "pebs_observe", "pebs_estimate",
    "nb_init", "nb_observe", "nb_estimate",
    "bundle_init", "observe_all", "count_observe",
]


# =====================================================================  HMU
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HMUState:
    """Memory-side exact counters + bounded request-log emulation.

    ``counts`` is what a counter-mode HMU exposes; updates **saturate** at
    the configured counter width (int32 max by default — a real counter
    clamps, it never wraps to negative).  ``log_used``/``log_dropped`` model
    the paper's log-DRAM capacity (256 GB on the FPGA card): in log mode
    every request consumes one record until the log fills; software must
    drain it (``hmu_drain_cost``) or subsequent records are dropped.  Drops
    only affect log mode — counter mode loses events only to saturation.
    """
    counts: jax.Array          # (n_blocks,) int32 saturating access counts
    log_used: Counter64        # records currently in the log (exact)
    log_dropped: Counter64     # records lost to log overflow (exact)
    log_capacity: int = dataclasses.field(metadata=dict(static=True))
    host_events: Counter64     # host work units spent (drain only; exact)


def hmu_init(n_blocks: int, log_capacity: int = 1 << 33) -> HMUState:
    # Scalar accounting uses exact hi/lo int32 pairs (x64 is disabled; these
    # model counters exceed both int32 range AND float32 exactness — a
    # 256 GB log is billions of records, and float32 stops incrementing at
    # 2**24).  Distinct arrays per counter so donation works.
    return HMUState(
        counts=jnp.zeros((n_blocks,), jnp.int32),
        log_used=counter_init(),
        log_dropped=counter_init(),
        log_capacity=int(log_capacity),
        host_events=counter_init(),
    )


def _hmu_observe(state: HMUState, block_ids: jax.Array, weight: int = 1,
                 counter_max: Optional[jax.Array] = None,
                 hist: Optional[jax.Array] = None) -> HMUState:
    """Pure (un-jitted) HMU update — shared by the per-batch jit and the
    fused epoch scan so both paths are the *same traced computation* and
    therefore bit-identical.  ``counter_max`` is the saturation cap from a
    :class:`~repro.faults.FaultModel` (scalar or per-block); without one the
    counters still clamp at int32 max instead of wrapping.  ``hist`` (the
    batch's precomputed (n_blocks,) access histogram, from the fused
    ``observe_scatter`` kernel) replaces the scatter-add with the
    elementwise-identical ``counts + hist * weight``."""
    flat = block_ids.reshape(-1)
    n = flat.shape[0] * weight
    if n >= CARRY_BASE:                      # static shape check
        raise ValueError(
            f"one observe call adds {n} events; split calls below "
            f"{CARRY_BASE} so the hi/lo log counters carry exactly")
    cap = jnp.int32(INT32_MAX) if counter_max is None else counter_max
    summed = (state.counts.at[flat].add(weight, mode="drop")
              if hist is None else state.counts + hist * weight)
    # Saturate instead of wrapping: a wrapped sum reads *less* than the old
    # count (two's complement), so `summed < counts` flags exactly the
    # blocks that crossed int32 max this call (per-call mass << 2**31).
    counts = jnp.where(summed < state.counts, cap, jnp.minimum(summed, cap))
    # Log free space in exact hi/lo arithmetic: when at least 2 hi-words
    # (2**24 records) are free, the whole batch fits; otherwise the exact
    # small remainder decides.  (The unused free_small product may wrap
    # int32 for huge free space — it is masked out in exactly that case.)
    cap_hi = jnp.int32(state.log_capacity >> CARRY_BITS)
    cap_lo = jnp.int32(state.log_capacity & (CARRY_BASE - 1))
    diff_hi = cap_hi - state.log_used.hi
    free_small = diff_hi * CARRY_BASE + (cap_lo - state.log_used.lo)
    n_arr = jnp.int32(n)
    appended = jnp.where(diff_hi >= 2, n_arr, jnp.clip(free_small, 0, n_arr))
    return dataclasses.replace(
        state,
        counts=counts,
        log_used=counter_add(state.log_used, appended),
        log_dropped=counter_add(state.log_dropped, n_arr - appended),
    )


@partial(jax.jit, donate_argnums=0, static_argnums=2)
def hmu_observe(state: HMUState, block_ids: jax.Array, weight: int = 1) -> HMUState:
    """Device-side: every access counted. No host involvement."""
    return _hmu_observe(state, block_ids, weight)


def hmu_estimate(state: HMUState) -> jax.Array:
    return state.counts


def hmu_saturated(state: HMUState,
                  counter_max: Optional[jax.Array] = None) -> jax.Array:
    """Number of blocks pinned at the saturation cap — the blocks whose
    epoch deltas now read 0 even while they are the hottest in the system.
    Pass the :class:`~repro.faults.FaultModel`'s ``hmu_counter_max`` for a
    width-limited counter; the default audits the int32 clamp."""
    cap = jnp.int32(INT32_MAX) if counter_max is None else counter_max
    return jnp.sum((state.counts >= cap).astype(jnp.int32))


def hmu_drain_cost(state: HMUState, per_record_cost: float = 1.0) -> HMUState:
    """Host drains/processes the log (paper: 'process the trace immediately').
    This is the only host cost HMU incurs; NMC (paper §VI) would shrink it.
    ``per_record_cost`` must be a small non-negative integer so the exact
    hi/lo counter math stays exact (scale per-record costs into the
    time-per-event constants instead)."""
    cost = float(per_record_cost)
    if not cost.is_integer() or not 0 <= cost < 64:
        raise ValueError(f"per_record_cost must be a small non-negative "
                         f"integer (exact hi/lo counter math), got "
                         f"{per_record_cost!r}")
    return dataclasses.replace(
        state,
        host_events=counter_scaled_add(state.host_events, state.log_used,
                                       int(cost)),
        log_used=counter_zero_like(state.log_used),
    )


# =====================================================================  PEBS
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PEBSState:
    sampled: jax.Array        # (n_blocks,) number of *sampled* hits per block
    cursor: jax.Array         # scalar int32: global access index mod period
    period: int = dataclasses.field(metadata=dict(static=True))
    host_events: Counter64    # one per PEBS record (interrupt+parse; exact)


def pebs_init(n_blocks: int, period: int = 10007) -> PEBSState:
    return PEBSState(
        sampled=jnp.zeros((n_blocks,), jnp.int32),
        cursor=jnp.zeros((), jnp.int32),
        period=int(period),
        host_events=counter_init(),
    )


def _pebs_sample_mask(state: PEBSState, n: int) -> jax.Array:
    # cursor is an exact int32 carried modulo period: a float32 cursor is only
    # exact for streams < 2^24 accesses, so paper-scale epoch streams would
    # drift the sampling phase.  The modulo keeps it exact forever.
    idx = state.cursor + jnp.arange(n, dtype=jnp.int32)
    return (idx % state.period) == 0


def _pebs_apply(state: PEBSState, flat: jax.Array,
                kept: Optional[jax.Array],
                pebs_hist: Optional[jax.Array] = None,
                n_kept: Optional[jax.Array] = None) -> PEBSState:
    # scatter-add only surviving sampled positions (weight 0/1); the fused
    # kernel path hands the already-scattered histogram and the kept count
    # instead of the per-position mask
    sampled = (state.sampled.at[flat].add(kept.astype(jnp.int32),
                                          mode="drop")
               if pebs_hist is None else state.sampled + pebs_hist)
    if n_kept is None:
        n_kept = jnp.sum(kept).astype(jnp.int32)
    return dataclasses.replace(
        state,
        sampled=sampled,
        cursor=(state.cursor + flat.shape[0]) % state.period,
        host_events=counter_add(state.host_events, n_kept),
    )


def _pebs_observe(state: PEBSState, block_ids: jax.Array) -> PEBSState:
    flat = block_ids.reshape(-1)
    return _pebs_apply(state, flat, _pebs_sample_mask(state, flat.shape[0]))


def _pebs_observe_faulty(state: PEBSState, block_ids: jax.Array,
                         keep: jax.Array) -> Tuple[PEBSState, jax.Array]:
    """Sampling with Bernoulli event loss: ``keep`` is a per-event survival
    mask (drawn by the caller from the fault model's ``pebs_drop_p``).  A
    dropped sample never reaches the host — no histogram update, no host
    event — and is only visible in the returned drop count."""
    flat = block_ids.reshape(-1)
    hit = _pebs_sample_mask(state, flat.shape[0])
    return (_pebs_apply(state, flat, hit & keep),
            jnp.sum(hit & ~keep).astype(jnp.int32))


@partial(jax.jit, donate_argnums=0)
def pebs_observe(state: PEBSState, block_ids: jax.Array) -> PEBSState:
    """CPU-assisted: only every ``period``-th access in program order is seen.

    The access stream order is the order of ``block_ids`` — identical to what
    the HMU sees, so coverage differences are purely due to sampling.
    """
    return _pebs_observe(state, block_ids)


def pebs_estimate(state: PEBSState) -> jax.Array:
    """Scaled estimate: each sample represents ``period`` accesses."""
    return state.sampled * state.period


# =====================================================================  NB
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class NBState:
    """Linux NUMA-balancing emulation (task_numa_work-style cyclic scanner).

    The scanner unmaps ``scan_rate`` blocks per observe call starting at
    ``scan_ptr``; a *first* touch of an unmapped block raises a hint fault
    (host_events += 1), re-maps the block and bumps its fault count.  Blocks
    are promotion candidates after >= 2 faults (two-touch rule).  Frequency
    beyond the first touch per scan pass is invisible — that is the accuracy
    limitation the paper measures.
    """
    mapped: jax.Array        # (n_blocks,) bool: PTE present (access invisible)
    faults: jax.Array        # (n_blocks,) hint-fault counts
    scan_ptr: jax.Array      # scalar cyclic scan position
    scan_rate: int = dataclasses.field(metadata=dict(static=True))
    host_events: Counter64   # hint faults serviced (exact)


def nb_init(n_blocks: int, scan_rate: int) -> NBState:
    return NBState(
        mapped=jnp.ones((n_blocks,), jnp.bool_),
        faults=jnp.zeros((n_blocks,), jnp.int32),
        scan_ptr=jnp.zeros((), jnp.int32),
        scan_rate=int(scan_rate),
        host_events=counter_init(),
    )


def _nb_observe(state: NBState, block_ids: jax.Array,
                stalled: Optional[jax.Array] = None,
                touched: Optional[jax.Array] = None) -> NBState:
    """``stalled`` (a traced bool from the fault model) makes the scanner
    tick a no-op — no unmapping, no cursor advance — while the workload's
    touches still re-map pages as usual: faults stop *arriving*, they are
    not merely delayed, which is what starves the NB lane's signal.
    ``touched`` (fused kernel path) is the batch's precomputed touched-set
    mask, replacing the scatter over the id stream."""
    n_blocks = state.mapped.shape[0]
    # 1. scanner tick: unmap the next scan_rate blocks (cyclic)
    scan_idx = (state.scan_ptr + jnp.arange(state.scan_rate, dtype=jnp.int32)) % n_blocks
    advance = state.scan_rate
    if stalled is not None:
        # a stalled tick unmaps nothing: push the indices out of range (the
        # drop-mode scatter ignores them) and freeze the cursor
        scan_idx = jnp.where(stalled, n_blocks, scan_idx)
        advance = jnp.where(stalled, 0, state.scan_rate)
    mapped = state.mapped.at[scan_idx].set(False, mode="drop")
    # 2. workload touches: first touch of an unmapped block faults
    if touched is None:
        flat = block_ids.reshape(-1)
        touched = jnp.zeros((n_blocks,), jnp.bool_).at[flat].set(
            True, mode="drop")
    faulted = touched & ~mapped
    faults = state.faults + faulted.astype(jnp.int32)
    mapped = mapped | touched
    return dataclasses.replace(
        state,
        mapped=mapped,
        faults=faults,
        scan_ptr=(state.scan_ptr + advance) % n_blocks,
        host_events=counter_add(state.host_events,
                                jnp.sum(faulted).astype(jnp.int32)),
    )


@partial(jax.jit, donate_argnums=0)
def nb_observe(state: NBState, block_ids: jax.Array) -> NBState:
    return _nb_observe(state, block_ids)


def nb_estimate(state: NBState) -> jax.Array:
    """NB's 'hotness' signal: hint-fault counts (recency proxy).
    Two-touch gating is applied by the policy layer (candidates = faults >= 2)."""
    return state.faults


# =====================================================  fused bundle (epoch)
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TelemetryBundle:
    """All three collectors plus the device-side ground-truth counter as ONE
    pytree, so a whole epoch of batches is observed with a single jit
    dispatch (``observe_all`` ``lax.scan``s over the batch axis) instead of
    three dispatches + a host ``np.add.at`` per batch.

    ``true_counts`` is the exact access histogram the evaluation compares
    against — it is what an ideal oracle sees, kept on device so the fused
    path never synchronises with the host mid-epoch.

    ``faults`` (an optional :class:`repro.faults.FaultModel`) rides in the
    same pytree, so fault injection happens inside the same scan and its
    mutable counters are donated with everything else.  ``None`` keeps the
    exact fault-free trace — the structure differs, so the two regimes can
    never share (and therefore never contaminate) a compiled program.
    """
    hmu: HMUState
    pebs: PEBSState
    nb: NBState
    true_counts: jax.Array     # (n_blocks,) int32 exact histogram
    faults: Optional[FaultModel] = None


def bundle_init(
    n_blocks: int,
    pebs_period: int = 10007,
    nb_scan_rate: int = 1,
    hmu_log_capacity: int = 1 << 33,
    faults: Optional[FaultModel] = None,
) -> TelemetryBundle:
    if faults is not None:
        for name, leaf in (("pebs_drop_p", faults.pebs_drop_p),
                           ("hmu_counter_max", faults.hmu_counter_max)):
            if leaf.ndim == 1 and leaf.shape[0] != n_blocks:
                raise ValueError(f"FaultModel.{name} is per-block with "
                                 f"{leaf.shape[0]} entries; this bundle has "
                                 f"n_blocks={n_blocks}")
        # private copy: the bundle is donated every epoch, so sharing one
        # model's buffers across runtimes would delete them under the caller
        faults = jax.tree_util.tree_map(jnp.array, faults)
    return TelemetryBundle(
        hmu=hmu_init(n_blocks, log_capacity=hmu_log_capacity),
        pebs=pebs_init(n_blocks, period=pebs_period),
        nb=nb_init(n_blocks, scan_rate=nb_scan_rate),
        true_counts=jnp.zeros((n_blocks,), jnp.int32),
        faults=faults,
    )


def _count_observe(counts: jax.Array, block_ids: jax.Array,
                   hist: Optional[jax.Array] = None) -> jax.Array:
    if hist is not None:
        return counts + hist
    flat = block_ids.reshape(-1)
    return counts.at[flat].add(1, mode="drop")


@partial(jax.jit, donate_argnums=0)
def count_observe(counts: jax.Array, block_ids: jax.Array) -> jax.Array:
    """Ground-truth histogram update (device-side ``np.add.at`` analogue)."""
    return _count_observe(counts, block_ids)


def _fused_scatter(bundle: TelemetryBundle, flat: jax.Array, pallas,
                   keep: Optional[jax.Array] = None):
    """One ``observe_scatter`` kernel pass over the batch's id stream ->
    the access histogram and PEBS-sampled histogram every collector update
    below is an affine function of."""
    return observe_scatter(
        flat, bundle.pebs.cursor,
        n_blocks=bundle.true_counts.shape[0], period=bundle.pebs.period,
        keep=keep, tile_m=pallas.scatter_tile_m, use_pallas=True,
        interpret=pallas.interpret)


def _bundle_observe(bundle: TelemetryBundle, block_ids: jax.Array,
                    pallas=None) -> TelemetryBundle:
    f = bundle.faults
    flat = block_ids.reshape(-1)
    m = flat.shape[0]
    if f is None:
        hist = pebs_hist = n_kept = touched = None
        if pallas is not None:
            hist, pebs_hist = _fused_scatter(bundle, flat, pallas)
            # hits among the m stream positions = multiples of period in
            # [cursor, cursor + m): exact closed form, no per-position mask
            cur, per = bundle.pebs.cursor, bundle.pebs.period
            n_kept = ((cur + m - 1) // per - (cur - 1) // per
                      ).astype(jnp.int32)
            touched = hist > 0
        return TelemetryBundle(
            hmu=_hmu_observe(bundle.hmu, block_ids, hist=hist),
            pebs=(_pebs_apply(bundle.pebs, flat, None, pebs_hist=pebs_hist,
                              n_kept=n_kept)
                  if pallas is not None
                  else _pebs_observe(bundle.pebs, block_ids)),
            nb=_nb_observe(bundle.nb, block_ids, touched=touched),
            true_counts=_count_observe(bundle.true_counts, block_ids,
                                       hist=hist),
        )
    # fault injection: per-batch Bernoulli draws from the model's traced
    # rates.  Ground truth is never faulted — it is the evaluation's
    # reference, not a collector.
    key, k_drop, k_stall = jax.random.split(f.key, 3)
    drop_p = (f.pebs_drop_p if f.pebs_drop_p.ndim == 0
              else f.pebs_drop_p[flat])
    keep = jax.random.uniform(k_drop, flat.shape) >= drop_p
    stalled = jax.random.bernoulli(k_stall, f.nb_stall_p)
    if pallas is not None:
        hist, pebs_hist = _fused_scatter(bundle, flat, pallas, keep=keep)
        hit = _pebs_sample_mask(bundle.pebs, m)
        pebs = _pebs_apply(bundle.pebs, flat, None, pebs_hist=pebs_hist,
                           n_kept=jnp.sum(hit & keep).astype(jnp.int32))
        n_dropped = jnp.sum(hit & ~keep).astype(jnp.int32)
        touched = hist > 0
    else:
        hist = touched = None
        pebs, n_dropped = _pebs_observe_faulty(bundle.pebs, block_ids, keep)
    return TelemetryBundle(
        hmu=_hmu_observe(bundle.hmu, block_ids,
                         counter_max=f.hmu_counter_max, hist=hist),
        pebs=pebs,
        nb=_nb_observe(bundle.nb, block_ids, stalled=stalled,
                       touched=touched),
        true_counts=_count_observe(bundle.true_counts, block_ids,
                                   hist=hist),
        faults=dataclasses.replace(
            f, key=key,
            pebs_dropped=counter_add(f.pebs_dropped, n_dropped),
            nb_stalls=f.nb_stalls + stalled.astype(jnp.int32)),
    )


def _bundle_resets(bundle: TelemetryBundle) -> TelemetryBundle:
    """Per-epoch collector reset events (drain races): with per-collector
    probability ``reset_p`` the collector's cumulative signal state snaps
    back to empty — HMU counts, the PEBS sampled histogram, NB fault counts
    plus its PTE state (a reset scanner's unmaps are re-established).  The
    *consumer's* epoch-delta baselines are not touched, which is the point:
    the next delta the runtime computes is garbage for one epoch, exactly
    like a log drained underneath the reader."""
    f = bundle.faults
    key, kr = jax.random.split(f.key)
    r = jax.random.uniform(kr, (3,)) < f.reset_p       # COLLECTORS order
    hmu = dataclasses.replace(
        bundle.hmu, counts=jnp.where(r[0], 0, bundle.hmu.counts))
    pebs = dataclasses.replace(
        bundle.pebs, sampled=jnp.where(r[1], 0, bundle.pebs.sampled))
    nb = dataclasses.replace(
        bundle.nb, faults=jnp.where(r[2], 0, bundle.nb.faults),
        mapped=bundle.nb.mapped | r[2])
    return dataclasses.replace(
        bundle, hmu=hmu, pebs=pebs, nb=nb,
        faults=dataclasses.replace(f, key=key,
                                   resets=f.resets + r.astype(jnp.int32)))


# Python-side trace counter: observe_all's body runs once per (shape, static)
# combination; tests use this to prove the fused path compiles once and then
# issues exactly one dispatch per epoch.  A CounterDict view over the same
# repro_trace_total registry family core.runtime uses (kind="observe_all"),
# keeping the historical dict API.
TRACE_COUNTS = obs_metrics.CounterDict(
    obs_metrics.REGISTRY.counter(
        "repro_trace_total",
        help="XLA (re)traces of the fused epoch step / observe_all"),
    "kind", keys=("observe_all",))


@partial(jax.jit, donate_argnums=0, static_argnames=("pallas",))
def observe_all(bundle: TelemetryBundle, batches: jax.Array,
                pallas=None) -> TelemetryBundle:
    """Observe a whole epoch in one dispatch.

    ``batches`` is the epoch's access stream as ``(n_batches, batch_size)``
    block ids (equal-size batches; pad with a repeated id if needed — every
    access is still counted, the paper's collectors have no notion of batch
    boundaries).  The scan applies the identical per-batch update the
    unfused path uses, in the same order, so collector states match the
    per-batch path bit-for-bit.

    With a fault model attached, epoch-granularity reset events are drawn
    once before the scan and the per-batch injections (drops, stalls,
    saturation caps) ride inside it — still one dispatch, and a model with
    all rates at zero leaves every collector value bit-identical.

    The bundle operand is donated (``donate_argnums=0``), like every
    observe above: the runtime's epoch loop re-uses the collector buffers
    in place, and — because the call is async-dispatched — the host is
    already free to flush the previous epochs' batched record sync
    (``EpochRuntime`` with ``sync_every=K``) while the scan runs.

    ``pallas`` (a static ``repro.kernels.dispatch.PallasBackend``) swaps
    the per-collector scatters inside the scan for ONE ``observe_scatter``
    kernel pass per batch — one read of the id stream feeding all four
    collector updates — still a single dispatch, bit-identical states.
    """
    TRACE_COUNTS["observe_all"] += 1
    if bundle.faults is not None:
        bundle = _bundle_resets(bundle)

    def step(b: TelemetryBundle, block_ids: jax.Array):
        return _bundle_observe(b, block_ids, pallas=pallas), None

    out, _ = jax.lax.scan(step, bundle, batches)
    return out
