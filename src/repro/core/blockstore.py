"""Two-tier block store with an indirection map — the memory system under study.

The paper's platform is Host-DRAM (fast tier) + CXL expander (slow tier) with
4 KiB pages migrated by the OS.  The TPU-native equivalent implemented here is a
single *tiered address space*:

  ``storage[0 : fast_rows)``                  -- fast tier (HBM-resident region)
  ``storage[fast_rows : fast_rows + n_rows)`` -- slow tier (capacity region; on a
                                                 real system: host memory / CXL)

Data is organised in fixed-size **blocks** (``block_rows`` rows each — the 4 KiB
page analogue).  The slow region permanently backs every block; a block may
additionally be *promoted* into a fast-region slot, after which the indirection
map resolves its rows to the fast copy.  Promotion/demotion are block copies
plus an indirection update — exactly ``migrate_pages()`` semantics.

Everything is a pytree of jnp arrays and functional, so the store can live
inside jit/pjit programs and be sharded like any other model state.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .placement import Placement


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TieredStore:
    """Two-tier row store with block-granular promotion.

    The indirection maps live in a :class:`~repro.core.placement.Placement`
    — the same substrate the epoch runtime stacks per policy lane — so the
    slot/block invariants are defined in exactly one place; the store adds
    the payload bytes and their migration."""

    # (fast_rows + n_rows, dim): fast region followed by the slow backing region.
    storage: jax.Array
    # slot<->block indirection (slot_to_block: (n_slots,), block_to_slot:
    # (n_blocks,), -1 = free / slow-only).
    placement: Placement
    # static metadata
    block_rows: int = dataclasses.field(metadata=dict(static=True))
    n_rows: int = dataclasses.field(metadata=dict(static=True))

    # ------------------------------------------------------------------ sizes
    @property
    def block_to_slot(self) -> jax.Array:
        return self.placement.block_to_slot

    @property
    def slot_to_block(self) -> jax.Array:
        return self.placement.slot_to_block

    @property
    def n_blocks(self) -> int:
        return self.block_to_slot.shape[0]

    @property
    def n_slots(self) -> int:
        return self.slot_to_block.shape[0]

    @property
    def fast_rows(self) -> int:
        return self.n_slots * self.block_rows

    @property
    def dim(self) -> int:
        return self.storage.shape[-1]

    # ------------------------------------------------------------ construction
    @staticmethod
    def create(data: jax.Array, block_rows: int, n_slots: int) -> "TieredStore":
        """All blocks start in the slow tier (the paper's profiling phase:
        'memory allocation operations are directed to CXL memory')."""
        n_rows, dim = data.shape
        if n_rows % block_rows:
            raise ValueError(f"n_rows {n_rows} not a multiple of block_rows {block_rows}")
        n_blocks = n_rows // block_rows
        if n_slots > n_blocks:
            raise ValueError("fast tier larger than dataset; nothing to tier")
        fast = jnp.zeros((n_slots * block_rows, dim), data.dtype)
        return TieredStore(
            storage=jnp.concatenate([fast, data], axis=0),
            placement=Placement.create(n_blocks, n_slots),
            block_rows=block_rows,
            n_rows=n_rows,
        )

    # ------------------------------------------------------------- resolution
    def resolve(self, rows: jax.Array) -> jax.Array:
        """Logical row ids -> physical addresses in the tiered address space."""
        block = rows // self.block_rows
        offset = rows % self.block_rows
        slot = self.block_to_slot[block]
        fast_addr = slot * self.block_rows + offset
        slow_addr = self.fast_rows + rows
        return jnp.where(slot >= 0, fast_addr, slow_addr)

    def is_fast(self, rows: jax.Array) -> jax.Array:
        return self.block_to_slot[rows // self.block_rows] >= 0

    def gather(self, rows: jax.Array) -> jax.Array:
        """Tier-aware gather (the pure-jnp reference; the Pallas gather_count
        kernel fuses this with HMU counter updates)."""
        return jnp.take(self.storage, self.resolve(rows), axis=0)

    # ------------------------------------------------------------- migration
    def promote(self, block_ids: jax.Array) -> "TieredStore":
        """Promote ``block_ids`` (padded with -1) into fast slots.

        Eviction is demote-on-overwrite: we fill free slots first, then evict
        the current occupants of the lowest-index used slots (the policy layer
        orders candidates so victims are its coldest choices — see
        ``policy.plan_migration`` which emits explicit (victim, winner) pairs).
        Blocks already fast are skipped.  Fully functional / jit-safe.
        """
        return _promote(self, block_ids)

    def demote(self, block_ids: jax.Array) -> "TieredStore":
        """Write fast copies back to the slow region and free the slots."""
        return _demote(self, block_ids)

    def migrate(self, promote_ids: jax.Array,
                demote_ids: Optional[jax.Array] = None) -> "TieredStore":
        """One epoch's migration: explicit demotions (e.g. the policy layer's
        ``coldest_victims``) first so promotions land in the freed slots
        instead of evicting demote-on-overwrite's arbitrary lowest-index
        occupants."""
        st = self if demote_ids is None else self.demote(demote_ids)
        return st.promote(promote_ids)

    # ---------------------------------------------------------------- updates
    def scatter_update(self, rows: jax.Array, values: jax.Array) -> "TieredStore":
        """Write-through update at whatever tier each row resides in."""
        addr = self.resolve(rows)
        return dataclasses.replace(
            self, storage=self.storage.at[addr].set(values.astype(self.storage.dtype))
        )

    def fast_occupancy(self) -> jax.Array:
        return jnp.sum(self.slot_to_block >= 0)


@partial(jax.jit, donate_argnums=0)
def _promote(store: TieredStore, block_ids: jax.Array) -> TieredStore:
    block_ids = block_ids.astype(jnp.int32)
    n_slots = store.n_slots
    br = store.block_rows

    valid = block_ids >= 0
    already_fast = jnp.where(valid, store.block_to_slot[block_ids] >= 0, True)
    need = valid & ~already_fast

    # Assign the i-th needed block to the i-th target slot: free slots first,
    # then occupied slots in ascending order (their occupants get evicted).
    free = store.slot_to_block < 0
    slot_order = jnp.argsort(~free, stable=True)  # free slots first
    rank = jnp.cumsum(need) - 1  # dense rank among needed blocks
    slot_for = jnp.where(need & (rank < n_slots), slot_order[jnp.clip(rank, 0, n_slots - 1)], -1)

    # Evict current occupants of targeted slots (write fast copy back to slow).
    victim = jnp.where(slot_for >= 0, store.slot_to_block[jnp.clip(slot_for, 0, n_slots - 1)], -1)

    storage = store.storage
    b2s = store.block_to_slot
    s2b = store.slot_to_block

    def body(i, carry):
        storage, b2s, s2b = carry
        blk, slot, vic = block_ids[i], slot_for[i], victim[i]

        def do(args):
            storage, b2s, s2b = args
            safe_slot = jnp.maximum(slot, 0)
            fast_base = safe_slot * br
            # 1. write back the victim's fast copy
            def writeback(st):
                vic_rows = jax.lax.dynamic_slice_in_dim(st, fast_base, br, axis=0)
                return jax.lax.dynamic_update_slice_in_dim(
                    st, vic_rows, store.fast_rows + jnp.maximum(vic, 0) * br, axis=0
                )
            storage2 = jax.lax.cond(vic >= 0, writeback, lambda st: st, storage)
            # 2. copy the new block from slow into the slot
            src = jax.lax.dynamic_slice_in_dim(storage2, store.fast_rows + blk * br, br, axis=0)
            storage2 = jax.lax.dynamic_update_slice_in_dim(storage2, src, fast_base, axis=0)
            # 3. indirection updates
            b2s2 = b2s.at[jnp.maximum(vic, 0)].set(
                jnp.where(vic >= 0, -1, b2s[jnp.maximum(vic, 0)])
            )
            b2s2 = b2s2.at[blk].set(slot)
            s2b2 = s2b.at[safe_slot].set(blk)
            return storage2, b2s2, s2b2

        # re-check residency against the *current* map so duplicate ids within
        # one call are promoted only once
        fresh = jnp.where(blk >= 0, b2s[jnp.maximum(blk, 0)] < 0, False)
        return jax.lax.cond((slot >= 0) & fresh, do, lambda a: a, (storage, b2s, s2b))

    storage, b2s, s2b = jax.lax.fori_loop(0, block_ids.shape[0], body, (storage, b2s, s2b))
    return dataclasses.replace(
        store, storage=storage,
        placement=Placement(slot_to_block=s2b, block_to_slot=b2s))


@partial(jax.jit, donate_argnums=0)
def _demote(store: TieredStore, block_ids: jax.Array) -> TieredStore:
    block_ids = block_ids.astype(jnp.int32)
    br = store.block_rows

    def body(i, carry):
        storage, b2s, s2b = carry
        blk = block_ids[i]
        slot = jnp.where(blk >= 0, b2s[jnp.maximum(blk, 0)], -1)

        def do(args):
            storage, b2s, s2b = args
            safe_slot = jnp.maximum(slot, 0)
            rows = jax.lax.dynamic_slice_in_dim(storage, safe_slot * br, br, axis=0)
            storage2 = jax.lax.dynamic_update_slice_in_dim(
                storage, rows, store.fast_rows + blk * br, axis=0
            )
            return storage2, b2s.at[blk].set(-1), s2b.at[safe_slot].set(-1)

        return jax.lax.cond(slot >= 0, do, lambda a: a, (storage, b2s, s2b))

    storage, b2s, s2b = jax.lax.fori_loop(
        0, block_ids.shape[0], body, (store.storage, store.block_to_slot, store.slot_to_block)
    )
    return dataclasses.replace(
        store, storage=storage,
        placement=Placement(slot_to_block=s2b, block_to_slot=b2s))
