"""Exact top-k selection without full-length sorts — the fused runtime's core.

``jax.lax.top_k`` and ``jnp.argsort`` on the XLA CPU backend cost hundreds of
milliseconds per million elements (a full O(n log n) sort each), which is why
the per-lane epoch loop cannot reach paper scale: five policy lanes issue
five ``top_k``s plus two ``argsort``s per epoch.  The fused ``epoch_step``
replaces them with O(n) primitives built from compare+reduce passes (~5 ms
per million on the same backend):

* :func:`select_top_k` — bit-identical replacement for ``lax.top_k(key, k)``
  (values descending, ties broken lowest-index-first): a 32-step bitwise
  binary search finds the k-th largest key, a cumsum+searchsorted compacts
  the selected indices, and only the k survivors are sorted.
* :func:`top_k_mask` — membership mask of the same selection, for consumers
  that need set intersections (epoch-hot scoring) rather than order.
* :func:`stable_rank_sparse` — ``argsort(argsort(x))`` for non-negative
  arrays with a static bound on the number of positives (PEBS epoch deltas:
  at most one positive block per sample), again sorting only the positives.

All keys are int32.  Non-negative float32 scores participate via
:func:`sortable_key` (IEEE-754 bit patterns of non-negative floats are
order-isomorphic to their int32 interpretation), so float and integer lanes
share one selection kernel.  Every function is shape-polymorphic over leading
batch (lane) axes and safe under ``vmap``/``jit``/SPMD partitioning.

All selection entry points take an optional ``backend`` (a
``repro.kernels.dispatch.PallasBackend``): when set and ``k`` is static, the
32-round threshold search is replaced by the ``kernels.hist_select`` Pallas
radix-histogram kernel (4 grid passes instead of 32), bit-identical by the
same largest-``t``-with-``count(u >= t) >= k`` definition.  ``None`` (the
default) keeps the pure-XLA path.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels import hist_select

__all__ = [
    "sortable_key", "select_top_k", "top_k_mask", "stable_rank_sparse",
    "compact", "segment_top_k_mask",
]

_SIGN = jnp.uint32(0x80000000)

# Eager-input contract checking for sortable_key (skipped under tracing —
# the fused epoch step cannot afford a host round-trip); set False to
# silence in long host-loop runs.
CHECK_SORTABLE_KEYS = True


def sortable_key(x: jax.Array) -> jax.Array:
    """float32 -> int32 key with the same ordering.

    Contract: every value must be either **non-negative** or equal to **one
    shared negative sentinel** (e.g. the hinted lane's score sentinel -1).
    IEEE-754 bit patterns of non-negative floats are order-isomorphic to
    their int32 interpretation, and any negative float's pattern compares
    below all non-negative ones — but order *among distinct* negatives is
    REVERSED, so two different negative values would rank backwards.
    Concrete (non-traced) inputs are checked; traced inputs are the
    caller's responsibility (the fused runtime's keys are non-negative by
    construction)."""
    x32 = x.astype(jnp.float32)
    if CHECK_SORTABLE_KEYS and not isinstance(x32, jax.core.Tracer):
        neg = np.asarray(x32)
        neg = neg[neg < 0]
        if neg.size and np.unique(neg).size > 1:
            raise ValueError(
                "sortable_key: negative inputs must all equal one shared "
                f"sentinel; got distinct negatives {np.unique(neg)[:4]} — "
                "their relative order would be reversed")
    return jax.lax.bitcast_convert_type(x32, jnp.int32)


def _to_u(key: jax.Array) -> jax.Array:
    """int32 -> uint32, order-preserving (flip the sign bit)."""
    return jax.lax.bitcast_convert_type(key, jnp.uint32) ^ _SIGN


def prefix_sum(x: jax.Array, chunk: int = 256) -> jax.Array:
    """Inclusive int32 prefix sum along the last axis.  XLA's cumsum on CPU
    runs log(n) full passes; chunking to (m, chunk) caps the scanned width,
    cutting ~1/3 of the wall time at 1M elements.  Non-dividing lengths are
    zero-padded up to the next chunk multiple (padding past the end never
    feeds back into the first n prefixes), so the chunked path is taken for
    EVERY length — it used to fall back to a full ``jnp.cumsum`` whenever
    ``n % chunk != 0``, silently costing the log(n) passes on exactly the
    ragged sizes real segment slices produce."""
    xi = x.astype(jnp.int32)
    n = xi.shape[-1]
    if n == 0:
        return xi
    pad = (-n) % chunk
    if pad:
        xi = jnp.pad(xi, [(0, 0)] * (xi.ndim - 1) + [(0, pad)])
    xr = xi.reshape(xi.shape[:-1] + (xi.shape[-1] // chunk, chunk))
    within = jnp.cumsum(xr, axis=-1)
    tot = within[..., -1]
    offs = jnp.cumsum(tot, axis=-1) - tot
    out = (within + offs[..., None]).reshape(xi.shape)
    return out[..., :n] if pad else out


def _kth_largest(u: jax.Array, k) -> jax.Array:
    """Largest threshold ``t`` with ``count(u >= t) >= k`` per leading batch
    element, without a sort: a bitwise binary search — 32 rounds, each one
    compare+sum pass over the data (XLA fuses compare and reduce; resolving
    more bits per round costs a full extra pass, so one bit per round wins).
    ``k`` may be a static int or a per-batch traced array (dynamic sizes)."""
    def body(i, t):
        cand = t | (jnp.uint32(1) << (31 - i))
        n_ge = jnp.sum((u >= cand[..., None]).astype(jnp.int32), axis=-1)
        return jnp.where(n_ge >= k, cand, t)

    return jax.lax.fori_loop(0, 32, body, jnp.zeros(u.shape[:-1], jnp.uint32))


def _kth_dispatch(u: jax.Array, k, backend) -> jax.Array:
    """k-th-largest threshold: the hist_select radix kernel when a Pallas
    backend is live and ``k`` is static (4 grid passes), the 32-round
    bitwise search otherwise.  Identical thresholds either way: both
    compute the largest ``t`` with ``count(u >= t) >= k``."""
    if (backend is None or not isinstance(k, int)
            or u.shape[-1] > hist_select.MAX_N):
        return _kth_largest(u, k)
    n = u.shape[-1]
    t = hist_select.kth_key_u(
        u.reshape((-1, n)), jnp.zeros((n,), jnp.int32), (k,),
        tile_n=backend.select_tile_n, use_pallas=True,
        interpret=backend.interpret)
    return t.reshape(u.shape[:-1])


def _selection_mask(u: jax.Array, k, backend=None):
    """Boolean mask of the k largest (ties resolved lowest-index-first) and
    its inclusive prefix count.  ``k``: static int or per-batch array."""
    k_b = k[..., None] if isinstance(k, jax.Array) else k
    t = _kth_dispatch(u, k, backend)[..., None]
    gt = u > t
    eq = u == t
    n_gt = jnp.sum(gt.astype(jnp.int32), axis=-1, keepdims=True)
    eq_rank = prefix_sum(eq) - 1
    sel = gt | (eq & (eq_rank < (k_b - n_gt)))
    return sel, prefix_sum(sel)


def top_k_mask(key: jax.Array, k: int, *, backend=None) -> jax.Array:
    """(..., n) bool: membership in ``lax.top_k(key, k)``'s selection."""
    return _selection_mask(_to_u(key), min(k, key.shape[-1]), backend)[0]


def bottom_k_mask(key: jax.Array, counts) -> jax.Array:
    """(..., n) bool: the per-batch ``counts`` smallest keys, ties resolved
    lowest-index-first — the first ``counts`` entries of a stable ascending
    argsort, as a mask.  ``counts`` may be traced (clipped to [0, n])."""
    n = key.shape[-1]
    counts = jnp.clip(counts, 0, n)
    return _selection_mask(~_to_u(key), counts)[0]


def compact(csel: jax.Array, k: int) -> jax.Array:
    """Indices of the first k selected elements in ascending order, given the
    inclusive prefix count of a selection mask along the last axis (fewer
    than k true entries fill with n).  Shared by :func:`select_top_k` and
    ``placement.apply_plan``'s free-slot assignment."""
    targets = jnp.arange(1, k + 1, dtype=csel.dtype)

    def pick(cs):
        return jnp.searchsorted(cs, targets, side="left").astype(jnp.int32)

    for _ in range(csel.ndim - 1):
        pick = jax.vmap(pick)
    return pick(csel)


def select_top_k(key: jax.Array, k: int, return_mask: bool = False,
                 *, backend=None):
    """Drop-in ``lax.top_k(key, k)`` on int32 keys: ``(values, indices)``,
    values descending, ties lowest-index-first — in O(n) passes plus one
    O(k log k) sort of the survivors.  ``return_mask=True`` also returns the
    (..., n) membership mask (an intermediate, free to expose)."""
    n = key.shape[-1]
    k = min(k, n)
    u = _to_u(key)
    sel, csel = _selection_mask(u, k, backend)
    ids = compact(csel, k)                        # ascending index order
    u_sel = jnp.take_along_axis(u, ids, axis=-1)

    def order(us, i):
        # ascending ~u == descending u; stable keeps ascending-index ties
        return jax.lax.sort_key_val(~us, i, is_stable=True)[1]

    for _ in range(key.ndim - 1):
        order = jax.vmap(order)
    ids_sorted = order(u_sel, ids)
    vals = jnp.take_along_axis(key, ids_sorted, axis=-1)
    if return_mask:
        return vals, ids_sorted, sel
    return vals, ids_sorted


def segment_top_k_mask(key: jax.Array, bounds, caps, *,
                       backend=None) -> jax.Array:
    """Per-segment top-k membership over static contiguous segments.

    ``key`` (..., n) int32 selection keys; ``bounds`` a static length-(S+1)
    cumulative offset tuple partitioning the last axis into S segments
    (``bounds[s]:bounds[s+1]``); ``caps`` a static per-segment selection
    width.  Returns the (..., n) bool mask marking, within every segment
    independently, that segment's ``min(caps[s], len)`` largest keys (ties
    lowest-index-first, exactly :func:`top_k_mask`'s tie-break).

    This is the fused runtime's multi-tenant quota primitive: masking a
    lane's selection key to ``int32.min`` outside this mask turns the global
    top-k select into a *segment-capped* select — every tenant keeps its own
    ``caps[t]`` best candidates in the running no matter how loud a
    neighbouring tenant's counters are, at the cost of one O(n_t)
    threshold-select per segment (no sorts).

    With a Pallas ``backend`` the per-segment thresholds all come out of ONE
    ``hist_select`` invocation (the caps become per-tenant rows of the radix
    histogram) and the per-segment tie-break ranks are recovered from global
    prefix sums rebased at the static segment starts — bit-identical to the
    per-slice path, without its S separate selects.
    """
    if backend is None:
        parts = [
            top_k_mask(jax.lax.slice_in_dim(key, int(a), int(b), axis=-1),
                       min(int(cap), int(b) - int(a)))
            for a, b, cap in zip(bounds, bounds[1:], caps)
        ]
        return jnp.concatenate(parts, axis=-1)

    n = key.shape[-1]
    edges = [int(b) for b in bounds]
    lens = np.diff(np.asarray(edges))
    ks = tuple(min(int(c), int(l)) for c, l in zip(caps, lens))
    seg = np.repeat(np.arange(len(ks), dtype=np.int32), lens)
    u = _to_u(key).reshape((-1, n))
    t = hist_select.kth_key_u(
        u, jnp.asarray(seg), ks, tile_n=backend.select_tile_n,
        use_pallas=True, interpret=backend.interpret)       # (B, S) uint32

    def widen(per_seg):             # (B, S) -> (B, n), constant per segment
        return jnp.repeat(per_seg, lens, axis=-1, total_repeat_length=n)

    t_elem = widen(t)
    gt = u > t_elem
    eq = u == t_elem
    # per-segment prefix ranks = global inclusive prefix sums rebased at the
    # (static) segment starts; exclusive-at-start values read via a 0-column
    zero = jnp.zeros(u.shape[:-1] + (1,), jnp.int32)
    cgt = jnp.concatenate([zero, prefix_sum(gt)], axis=-1)
    ceq = jnp.concatenate([zero, prefix_sum(eq)], axis=-1)
    n_gt = cgt[..., edges[1:]] - cgt[..., edges[:-1]]       # (B, S)
    allow_eq = jnp.asarray(ks, jnp.int32)[None, :] - n_gt
    eq_rank = ceq[..., 1:] - widen(ceq[..., edges[:-1]]) - 1
    sel = gt | (eq & (eq_rank < widen(allow_eq)))
    return sel.reshape(key.shape)


def stable_rank_sparse(x: jax.Array, max_positive: int) -> jax.Array:
    """``jnp.argsort(jnp.argsort(x))`` for a 1-D non-negative int32 array with
    at most ``max_positive`` positive entries (a *static* bound).

    A stable ascending argsort of such an array ranks the zeros first in
    index order, then the positives by (value, index) — so the full-length
    double sort reduces to a cumsum over the zeros plus a sort of just the
    positives.  Exact whenever the bound holds (the fused runtime derives it
    from the epoch's access count and the PEBS period).
    """
    n = x.shape[0]
    s = min(max_positive, n)
    pos = x > 0
    n_zero = n - jnp.sum(pos.astype(jnp.int32))
    rank = prefix_sum(~pos) - 1                          # zero ranks
    cpos = prefix_sum(pos)
    ids = jnp.searchsorted(cpos, jnp.arange(1, s + 1, dtype=cpos.dtype),
                           side="left").astype(jnp.int32)  # fill -> n
    vals = jnp.where(ids < n, x[jnp.minimum(ids, n - 1)], jnp.iinfo(jnp.int32).max)
    _, ids_sorted = jax.lax.sort_key_val(_to_u(vals), ids, is_stable=True)
    return rank.at[jnp.where(ids_sorted < n, ids_sorted, n)].set(
        n_zero + jnp.arange(s, dtype=jnp.int32), mode="drop")
