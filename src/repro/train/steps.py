"""Train-step builders: loss -> grad -> (optional accumulation/compression)
-> optimizer update.  Pure functions of (params, opt_state, batch, step);
sharding is applied by the caller (launch/dryrun.py, launch/train.py) via jit
in_shardings/out_shardings built from the same schema the params come from.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.model import ModelConfig, forward, loss_fn
from ..optim.optimizers import Optimizer, clip_by_global_norm


def compute_loss(params, cfg: ModelConfig, batch: Dict[str, Any]):
    """batch: {"tokens" (B,S)} or {"embeds" (B,S,D)}, plus "labels" (B,S),
    optional "positions", "mask"."""
    hidden, aux = forward(
        params, cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        positions=batch.get("positions"),
    )
    loss = loss_fn(params, cfg, hidden, batch["labels"], batch.get("mask"))
    if "moe_aux_loss" in aux:
        loss = loss + 0.01 * aux["moe_aux_loss"]
    return loss, aux


def make_train_step(
    cfg: ModelConfig,
    optimizer: Optimizer,
    lr_schedule: Callable[[jax.Array], jax.Array],
    grad_accum: int = 1,
    max_grad_norm: float = 1.0,
    grad_transform: Optional[Callable] = None,   # e.g. compression hook
):
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics).  With grad_accum > 1 the batch's leading
    dim is split into microbatches scanned sequentially (activation memory
    divided by grad_accum; XLA overlaps the DP all-reduce of the final
    gradient with the optimizer update)."""

    def loss_wrapper(params, mb):
        loss, aux = compute_loss(params, cfg, mb)
        return loss, aux

    grad_fn = jax.value_and_grad(loss_wrapper, has_aux=True)

    def single(params, batch):
        (loss, aux), grads = grad_fn(params, batch)
        return loss, aux, grads

    def accumulated(params, batch):
        def micro(i, _):
            mb = jax.tree.map(
                lambda t: t.reshape((grad_accum, t.shape[0] // grad_accum)
                                    + t.shape[1:])[i], batch)
            (loss, aux), grads = grad_fn(params, mb)
            return loss, grads

        def body(carry, i):
            tot_loss, tot_grads = carry
            loss, grads = micro(i, None)
            tot_grads = jax.tree.map(jnp.add, tot_grads, grads)
            return (tot_loss + loss, tot_grads), None

        loss0, grads0 = micro(0, None)
        (loss, grads), _ = jax.lax.scan(
            body, (loss0, grads0), jnp.arange(1, grad_accum))
        scale = 1.0 / grad_accum
        return loss * scale, {}, jax.tree.map(lambda g: g * scale, grads)

    def train_step(params, opt_state, batch):
        if grad_accum > 1:
            loss, aux, grads = accumulated(params, batch)
        else:
            loss, aux, grads = single(params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = lr_schedule(opt_state.step + 1)   # 1-based: step 0 is warmup's first
        params, opt_state = optimizer.update(grads, opt_state, params, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        if "expert_counts" in aux:
            metrics["expert_counts"] = aux["expert_counts"]
        return params, opt_state, metrics

    return train_step
