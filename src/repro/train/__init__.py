"""Training runtime: loss/step builders, grad accumulation, compression."""
