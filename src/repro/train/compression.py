"""Gradient compression for the cross-pod (DCN) all-reduce.

Within a pod, ICI bandwidth makes full-precision gradient reduction cheap;
*across* pods the DCN link is the bottleneck at scale.  Two standard
compressors with **error feedback** (the residual is carried and re-added
next step so compression bias does not accumulate — Karimireddy et al.):

  * int8 stochastic-free linear quantization (per-leaf absmax scaling)
  * top-k magnitude sparsification (per-leaf)

These are grad *transforms* plugged into make_train_step(grad_transform=...)
— in a real multi-pod launch the transform wraps the pod-boundary reduce
(shard_map over the "pod" axis); here the math and the error-feedback state
management are identical.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_compress_grads(grads, ef_state):
    """Error-feedback int8 round trip (what the wire would carry is q/scale).
    Returns (decompressed grads, new ef_state, wire_bytes_est)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), x - deq

    out = jax.tree.map(one, grads, ef_state)
    new_g = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    wire = sum(int(x.size) for x in jax.tree.leaves(grads))  # 1 byte/elem
    return new_g, new_e, wire


def topk_compress_grads(grads, ef_state, k_fraction: float = 0.01):
    """Error-feedback magnitude top-k (per leaf)."""
    def one(g, e):
        x = (g.astype(jnp.float32) + e).reshape(-1)
        k = max(int(x.size * k_fraction), 1)
        vals, idx = jax.lax.top_k(jnp.abs(x), k)
        mask = jnp.zeros_like(x).at[idx].set(1.0)
        kept = x * mask
        return kept.reshape(g.shape).astype(g.dtype), (x - kept).reshape(g.shape)

    out = jax.tree.map(one, grads, ef_state)
    new_g = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    wire = sum(max(int(x.size * k_fraction), 1) * 8
               for x in jax.tree.leaves(grads))   # value+index per entry
    return new_g, new_e, wire
