"""Labeled metrics registry — counters, gauges, bounded-bucket histograms.

One process-wide :class:`MetricsRegistry` (module default: :data:`REGISTRY`)
owns every metric family.  A *family* is a named metric plus a fixed kind
(``counter`` / ``gauge`` / ``histogram``); ``family.labels(**labels)``
returns (creating on demand) the *child* for one label combination.  All
mutation goes through a single registry lock, so families are safe to tick
from the epoch loop and the export flusher thread concurrently.

Naming follows the export schema's unit convention (`docs/observability.md`):
``_total`` for counters, ``_s``/``_us`` embedded unit suffixes for
durations, ``_count`` for event counts.

:class:`CounterDict` is the compatibility bridge for ``core.runtime``'s
``DISPATCH_COUNTS`` / ``TRACE_COUNTS`` module dicts: a dict-API view over
one counter family with a fixed label key, so ``counts["observe_all"] += 1``
increments ``repro_dispatch_total{kind="observe_all"}`` while every existing
caller (``dict(view)``, ``counting()``'s ``_CounterView``, test equality
checks) keeps working unchanged.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricFamily", "MetricsRegistry",
    "CounterDict", "REGISTRY", "DEFAULT_LATENCY_BUCKETS_S",
]

# Latency buckets (seconds) sized for host-side dispatch/sync work: 10us to
# ~10s, roughly x4 per step.  Bounded: 10 finite bounds + overflow.
DEFAULT_LATENCY_BUCKETS_S: Tuple[float, ...] = (
    1e-5, 4e-5, 1.6e-4, 6.4e-4, 2.56e-3, 1.024e-2,
    4.096e-2, 1.6384e-1, 6.5536e-1, 2.62144,
)

_MAX_BUCKETS = 64
_MAX_CHILDREN = 4096       # per-family cardinality bound


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotone counter child.  ``set`` exists only for the legacy dict
    views (``CounterDict.__setitem__`` writes absolute values through)."""

    __slots__ = ("labels", "value", "_lock")

    def __init__(self, labels: Tuple[Tuple[str, str], ...],
                 lock: threading.RLock) -> None:
        self.labels = labels
        self.value = 0
        self._lock = lock

    def inc(self, n=1) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        with self._lock:
            self.value += n

    def set(self, value) -> None:
        with self._lock:
            self.value = value


class Gauge:
    """Last-value gauge child."""

    __slots__ = ("labels", "value", "_lock")

    def __init__(self, labels: Tuple[Tuple[str, str], ...],
                 lock: threading.RLock) -> None:
        self.labels = labels
        self.value = 0.0
        self._lock = lock

    def set(self, value) -> None:
        with self._lock:
            self.value = float(value)

    def inc(self, n=1.0) -> None:
        with self._lock:
            self.value += n


class Histogram:
    """Bounded-bucket histogram child (cumulative rendering happens in the
    Prometheus sink; storage here is per-bucket counts + sum + count)."""

    __slots__ = ("labels", "bounds", "bucket_counts", "sum", "count", "_lock")

    def __init__(self, labels: Tuple[Tuple[str, str], ...],
                 bounds: Tuple[float, ...], lock: threading.RLock) -> None:
        self.labels = labels
        self.bounds = bounds
        self.bucket_counts = [0] * (len(bounds) + 1)   # +1 overflow bucket
        self.sum = 0.0
        self.count = 0
        self._lock = lock

    def observe(self, value) -> None:
        v = float(value)
        with self._lock:
            i = 0
            for i, bound in enumerate(self.bounds):        # noqa: B007
                if v <= bound:
                    break
            else:
                i = len(self.bounds)                       # overflow
            self.bucket_counts[i] += 1
            self.sum += v
            self.count += 1


_KIND_CHILD = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricFamily:
    """A named metric of one kind with a set of labeled children."""

    def __init__(self, name: str, kind: str, help: str = "", unit: str = "",
                 buckets: Optional[Sequence[float]] = None,
                 _lock: Optional[threading.RLock] = None) -> None:
        if kind not in _KIND_CHILD:
            raise ValueError(f"unknown metric kind {kind!r}")
        if kind == "histogram":
            buckets = tuple(float(b) for b in
                            (buckets or DEFAULT_LATENCY_BUCKETS_S))
            if not buckets or len(buckets) > _MAX_BUCKETS:
                raise ValueError(
                    f"histogram needs 1..{_MAX_BUCKETS} bounds, "
                    f"got {len(buckets)}")
            if list(buckets) != sorted(buckets) or len(set(buckets)) != len(buckets):
                raise ValueError("histogram bounds must be strictly increasing")
        elif buckets is not None:
            raise ValueError(f"buckets only apply to histograms, not {kind}")
        self.name = name
        self.kind = kind
        self.help = help
        self.unit = unit
        self.buckets: Optional[Tuple[float, ...]] = (
            tuple(buckets) if kind == "histogram" else None)
        self._lock = _lock or threading.RLock()
        self._children: Dict[Tuple[Tuple[str, str], ...], object] = {}

    def labels(self, **labels: str):
        """Child for one label combination, created on first use."""
        key = _label_key(labels)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.get(key)
                if child is None:
                    if len(self._children) >= _MAX_CHILDREN:
                        raise ValueError(
                            f"{self.name}: label cardinality bound "
                            f"({_MAX_CHILDREN}) exceeded")
                    if self.kind == "histogram":
                        child = Histogram(key, self.buckets, self._lock)
                    else:
                        child = _KIND_CHILD[self.kind](key, self._lock)
                    self._children[key] = child
        return child

    def children(self) -> List[object]:
        with self._lock:
            return list(self._children.values())


class MetricsRegistry:
    """Thread-safe collection of metric families, keyed by name."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._families: Dict[str, MetricFamily] = {}

    def _get_or_create(self, name: str, kind: str, help: str, unit: str,
                       buckets: Optional[Sequence[float]]) -> MetricFamily:
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind:
                    raise ValueError(
                        f"metric {name!r} already registered as {fam.kind}, "
                        f"requested {kind}")
                if help and not fam.help:
                    fam.help = help
                return fam
            fam = MetricFamily(name, kind, help=help, unit=unit,
                               buckets=buckets, _lock=self._lock)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "", unit: str = "") -> MetricFamily:
        return self._get_or_create(name, "counter", help, unit, None)

    def gauge(self, name: str, help: str = "", unit: str = "") -> MetricFamily:
        return self._get_or_create(name, "gauge", help, unit, None)

    def histogram(self, name: str, help: str = "", unit: str = "",
                  buckets: Optional[Sequence[float]] = None) -> MetricFamily:
        return self._get_or_create(name, "histogram", help, unit, buckets)

    def families(self) -> List[MetricFamily]:
        with self._lock:
            return list(self._families.values())

    def publish(self, sink) -> None:
        """Push every family into a Prometheus-style sink.

        Counters/gauges go through ``set_counter`` / ``set_gauge`` (falling
        back to ``set_counter`` when the sink predates gauges), histograms
        through ``set_histogram``.  Sinks missing a hook skip that family —
        publication is best-effort by design.
        """
        set_counter = getattr(sink, "set_counter", None)
        set_gauge = getattr(sink, "set_gauge", None) or set_counter
        set_histogram = getattr(sink, "set_histogram", None)
        for fam in self.families():
            for child in fam.children():
                labels = dict(child.labels)
                if fam.kind == "counter" and set_counter is not None:
                    set_counter(fam.name, child.value, help=fam.help, **labels)
                elif fam.kind == "gauge" and set_gauge is not None:
                    set_gauge(fam.name, child.value, help=fam.help, **labels)
                elif fam.kind == "histogram" and set_histogram is not None:
                    set_histogram(fam.name, fam.buckets, child.bucket_counts,
                                  child.sum, child.count, help=fam.help,
                                  **labels)


#: Process-default registry — the one the runtime's counter dicts live in.
REGISTRY = MetricsRegistry()


class CounterDict:
    """Dict-API view over one counter family with a fixed label key.

    ``view[k]`` reads the child ``{label_key: k}``, ``view[k] = v`` writes
    the absolute value through (so ``view[k] += 1`` is an increment), and
    iteration/``keys``/``items``/``get``/``in``/``dict(view)`` all behave
    like the plain dict this replaces.  New keys may be introduced by
    assignment, exactly as with a dict; reads of unknown keys raise
    ``KeyError`` (the fail-fast contract ``counting()`` relies on).
    """

    __slots__ = ("_family", "_label", "_keys")

    def __init__(self, family: MetricFamily, label: str,
                 keys: Sequence[str] = ()) -> None:
        if family.kind != "counter":
            raise ValueError(f"CounterDict needs a counter family, "
                             f"got {family.kind}")
        self._family = family
        self._label = label
        self._keys: List[str] = []
        for k in keys:
            self._ensure(k)

    def _ensure(self, key: str) -> Counter:
        child = self._family.labels(**{self._label: key})
        if key not in self._keys:
            self._keys.append(key)
        return child

    def __getitem__(self, key: str):
        if key not in self._keys:
            raise KeyError(key)
        return self._family.labels(**{self._label: key}).value

    def __setitem__(self, key: str, value) -> None:
        self._ensure(key).set(value)

    def get(self, key: str, default=None):
        if key not in self._keys:
            return default
        return self[key]

    def keys(self):
        return tuple(self._keys)

    def values(self):
        return tuple(self[k] for k in self._keys)

    def items(self):
        return tuple((k, self[k]) for k in self._keys)

    def __iter__(self) -> Iterator[str]:
        return iter(tuple(self._keys))

    def __contains__(self, key) -> bool:
        return key in self._keys

    def __len__(self) -> int:
        return len(self._keys)

    def __eq__(self, other) -> bool:
        if isinstance(other, (dict, CounterDict)):
            return dict(self.items()) == dict(other.items()) \
                if isinstance(other, CounterDict) else dict(self.items()) == other
        return NotImplemented

    def __ne__(self, other) -> bool:
        eq = self.__eq__(other)
        return NotImplemented if eq is NotImplemented else not eq

    def __repr__(self) -> str:
        return f"CounterDict({dict(self.items())!r})"
