"""repro.obs — runtime self-observability.

Three pieces, one contract (non-interference with the 2-dispatch epoch
loop; CI-gated by ``benchmarks/run.py --obs``):

* :mod:`repro.obs.metrics` — labeled metrics registry (counters, gauges,
  bounded-bucket histograms).  ``core.runtime``'s ``DISPATCH_COUNTS`` /
  ``TRACE_COUNTS`` are :class:`~repro.obs.metrics.CounterDict` views over
  it, keeping the historical dict API and ``counting()`` semantics.
* :mod:`repro.obs.trace` — host-side span tracer with an injectable
  monotonic clock and a zero-allocation disabled mode; also the audited
  ``now_s`` / ``elapsed_s`` timing helpers the benchmarks use.
* :mod:`repro.obs.chrometrace` — Chrome trace-event JSON writer +
  ``pipelining_visible``, turning the pipelined record-sync proof into a
  timeline artifact.

See ``docs/observability.md`` for the span taxonomy and naming rules.
"""
from __future__ import annotations

from .metrics import (                                      # noqa: F401
    Counter, CounterDict, Gauge, Histogram, MetricFamily, MetricsRegistry,
    REGISTRY, DEFAULT_LATENCY_BUCKETS_S,
)
from .trace import (                                        # noqa: F401
    Clock, CLOCK, NOOP_SPAN, NULL_TRACER, NullTracer, Span, SpanTracer,
    disable, elapsed_s, enable, get_tracer, named_scope, now_s, set_tracer,
    tracing,
)
from .chrometrace import (                                  # noqa: F401
    chrome_trace_events, device_track_events, pipelining_visible,
    write_chrome_trace,
)

__all__ = [
    "Counter", "CounterDict", "Gauge", "Histogram", "MetricFamily",
    "MetricsRegistry", "REGISTRY", "DEFAULT_LATENCY_BUCKETS_S",
    "Clock", "CLOCK", "NOOP_SPAN", "NULL_TRACER", "NullTracer", "Span",
    "SpanTracer", "disable", "elapsed_s", "enable", "get_tracer",
    "named_scope", "now_s", "set_tracer", "tracing",
    "chrome_trace_events", "device_track_events", "pipelining_visible",
    "write_chrome_trace",
]
