"""Host-side span tracer — nestable begin/end spans over an injectable clock.

The runtime's hot loop is 2 dispatches per epoch; the tracer must never
change that.  Two tracers implement the same surface:

* :class:`SpanTracer` (``enabled = True``) records a :class:`Span` per
  ``with tracer.span(name, ...):`` block — wall-clock from an injectable
  monotonic clock, thread name (the chrome-trace track), nesting depth, and
  optional args such as the epoch index.  When ``xla_annotations=True`` each
  span also enters ``jax.profiler.TraceAnnotation`` so the same names land
  in XLA profiler timelines.
* :class:`NullTracer` (``enabled = False``, module default) returns one
  shared no-op context manager from every ``span()`` call — zero
  allocations per epoch, no clock reads, nothing retained.

Hot-path call sites keep the disabled cost at a single attribute check by
guarding the kwargs build::

    _tr = obs_trace.get_tracer()
    cm = _tr.span("observe_all", epoch=e) if _tr.enabled else obs_trace.NOOP_SPAN
    with cm:
        ...dispatch...

The module also owns the repo's one audited timing path (`now_s` /
`elapsed_s` on the injectable :class:`Clock`): benchmarks and span
durations read the same clock, so bench rows and trace timelines agree.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

__all__ = [
    "Span", "SpanTracer", "NullTracer", "NOOP_SPAN", "NULL_TRACER",
    "get_tracer", "set_tracer", "enable", "disable", "tracing",
    "Clock", "CLOCK", "now_s", "elapsed_s", "named_scope",
]


# ---------------------------------------------------------------------------
# injectable clock (satellite: bench + spans share one audited code path)
# ---------------------------------------------------------------------------
class Clock:
    """Monotonic clock in seconds; ``now`` is injectable for tests."""

    __slots__ = ("now_s",)

    def __init__(self, now: Callable[[], float] = time.perf_counter) -> None:
        self.now_s = now


#: Process-default clock.  Tests swap ``CLOCK.now_s`` (or build their own
#: Clock and pass it to SpanTracer / elapsed_s) to make time deterministic.
CLOCK = Clock()


def now_s() -> float:
    """Current monotonic time in seconds from the default clock."""
    return CLOCK.now_s()


def elapsed_s(t0: float, *sync, clock: Optional[Clock] = None) -> float:
    """Seconds since ``t0``, after blocking on any in-flight jax values.

    This is the audited bench timer: ``block_until_ready`` on every value
    in ``sync`` first, so async dispatch cannot make work look free, then
    one clock read.
    """
    if sync:
        import jax
        for value in sync:
            jax.block_until_ready(value)
    return (clock or CLOCK).now_s() - t0


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------
@dataclass
class Span:
    """One closed begin/end interval on a host thread."""

    name: str
    t0_s: float                       # clock reading at __enter__
    dur_s: float                      # t1 - t0
    tid: str = "host"                 # thread name -> chrome-trace track
    depth: int = 0                    # nesting depth at __enter__
    epoch: Optional[int] = None       # epoch attribution, when known
    args: Optional[Dict[str, object]] = field(default=None)


class _NoopSpan:
    """Shared do-nothing context manager — the disabled-mode span."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


#: The singleton no-op span.  Identity-stable: every disabled ``span()``
#: call returns exactly this object, so the hot loop allocates nothing.
NOOP_SPAN = _NoopSpan()


class NullTracer:
    """Disabled tracer: ``span()`` always returns :data:`NOOP_SPAN`."""

    enabled = False
    spans: Tuple[Span, ...] = ()

    def span(self, name, **kw):
        return NOOP_SPAN

    def clear(self) -> None:
        pass


#: Shared disabled tracer (also the module default current tracer).
NULL_TRACER = NullTracer()


class _SpanCtx:
    """Context manager recording one Span into its tracer."""

    __slots__ = ("_tracer", "_name", "_epoch", "_args", "_t0", "_ann")

    def __init__(self, tracer: "SpanTracer", name: str,
                 epoch: Optional[int], args: Optional[dict]) -> None:
        self._tracer = tracer
        self._name = name
        self._epoch = epoch
        self._args = args
        self._t0 = 0.0
        self._ann = None

    def __enter__(self):
        tr = self._tracer
        if tr.xla_annotations:
            try:
                import jax
                self._ann = jax.profiler.TraceAnnotation(self._name)
                self._ann.__enter__()
            except Exception:            # profiler unavailable -> host-only
                self._ann = None
        tr._local.depth = getattr(tr._local, "depth", 0) + 1
        self._t0 = tr.clock.now_s()
        return self

    def __exit__(self, *exc):
        tr = self._tracer
        t1 = tr.clock.now_s()
        depth = getattr(tr._local, "depth", 1)
        tr._local.depth = depth - 1
        if self._ann is not None:
            self._ann.__exit__(*exc)
        tr._record(Span(
            name=self._name, t0_s=self._t0, dur_s=t1 - self._t0,
            tid=threading.current_thread().name, depth=depth - 1,
            epoch=self._epoch, args=self._args))
        return False


class SpanTracer:
    """Enabled tracer: records spans; optionally mirrors them into a
    metrics registry as ``repro_span_duration_s{span=...}`` histograms and
    into XLA profiles via ``jax.profiler.TraceAnnotation``."""

    enabled = True

    def __init__(self, clock: Optional[Clock] = None,
                 metrics=None,                      # MetricsRegistry | None
                 xla_annotations: bool = False,
                 max_spans: int = 1_000_000) -> None:
        self.clock = clock or CLOCK
        self.xla_annotations = xla_annotations
        self.max_spans = max_spans
        self.dropped_spans = 0
        self.spans: List[Span] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        self._hist = None
        if metrics is not None:
            self._hist = metrics.histogram(
                "repro_span_duration_s",
                help="Host wall-clock per runtime span", unit="s")

    def span(self, name: str, *, epoch: Optional[int] = None,
             **args) -> _SpanCtx:
        return _SpanCtx(self, name, epoch, args or None)

    def _record(self, span: Span) -> None:
        with self._lock:
            if len(self.spans) >= self.max_spans:
                self.dropped_spans += 1
                return
            self.spans.append(span)
        if self._hist is not None:
            self._hist.labels(span=span.name).observe(span.dur_s)

    def clear(self) -> None:
        with self._lock:
            self.spans = []
            self.dropped_spans = 0


def named_scope(name: str):
    """Pass-through to ``jax.named_scope`` for use *inside* jitted code —
    names operations in XLA/HLO profiles without touching numerics (host
    spans cannot reach inside a traced function; this can)."""
    import jax
    return jax.named_scope(name)


# ---------------------------------------------------------------------------
# current-tracer plumbing
# ---------------------------------------------------------------------------
_CURRENT: List[object] = [NULL_TRACER]


def get_tracer():
    """The tracer hot-path call sites consult (NullTracer when disabled)."""
    return _CURRENT[0]


def set_tracer(tracer):
    """Install ``tracer`` as current; returns the previous one."""
    prev = _CURRENT[0]
    _CURRENT[0] = tracer
    return prev


def enable(clock: Optional[Clock] = None, metrics=None,
           xla_annotations: bool = False,
           max_spans: int = 1_000_000) -> SpanTracer:
    """Install and return a fresh :class:`SpanTracer`."""
    tracer = SpanTracer(clock=clock, metrics=metrics,
                        xla_annotations=xla_annotations, max_spans=max_spans)
    set_tracer(tracer)
    return tracer


def disable():
    """Restore the shared :class:`NullTracer`; returns the previous tracer
    (whose recorded spans stay readable)."""
    return set_tracer(NULL_TRACER)


@contextmanager
def tracing(clock: Optional[Clock] = None, metrics=None,
            xla_annotations: bool = False, max_spans: int = 1_000_000):
    """``with tracing() as tracer:`` — scoped enable/restore."""
    prev = get_tracer()
    tracer = enable(clock=clock, metrics=metrics,
                    xla_annotations=xla_annotations, max_spans=max_spans)
    try:
        yield tracer
    finally:
        set_tracer(prev)
