"""Chrome trace-event JSON writer — the pipelining proof as a timeline.

Converts recorded :class:`~repro.obs.trace.Span` objects into the Trace
Event Format consumed by ``chrome://tracing`` and https://ui.perfetto.dev
(``{"traceEvents": [...]}`` with ``ph: "X"`` complete events, timestamps
in microseconds).  Host threads map to tracks by thread name; on top of
those, :func:`device_track_events` synthesizes a ``device`` track: for each
``record_sync`` span (one ``jax.device_get`` draining K buffered epochs)
it draws the interval from the *first drained epoch's* ``observe_all``
dispatch to the sync's end — the window in which the device stream was
running ahead of the host.

:func:`pipelining_visible` is the structural check behind the PR 6
pipelining claim, now readable off the timeline: with ``sync_every=K>1``
there must exist a ``record_sync`` span that *begins after* the dispatch
of an epoch newer than any epoch it drains — i.e. the host kept feeding
the device while the previous window's records were still in flight.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence

__all__ = [
    "chrome_trace_events", "device_track_events", "write_chrome_trace",
    "pipelining_visible",
]

_PID = 1


def _t_base(spans: Sequence) -> float:
    return min((s.t0_s for s in spans), default=0.0)


def chrome_trace_events(spans: Sequence, *, t_base: Optional[float] = None,
                        cat: str = "runtime") -> List[dict]:
    """Spans -> chrome ``ph:"X"`` complete events (ts/dur in microseconds,
    normalised so the earliest span starts at ts=0)."""
    base = _t_base(spans) if t_base is None else t_base
    events: List[dict] = []
    for s in spans:
        args: Dict[str, object] = {}
        if s.epoch is not None:
            args["epoch"] = s.epoch
        if s.args:
            args.update(s.args)
        events.append({
            "name": s.name, "ph": "X", "cat": cat,
            "ts": (s.t0_s - base) * 1e6, "dur": s.dur_s * 1e6,
            "pid": _PID, "tid": s.tid,
            "args": args,
        })
    return events


def _sync_window(sync_span, spans) -> Optional[dict]:
    """The (t0, t1, epochs) device window one record_sync span drains."""
    args = sync_span.args or {}
    base, n = args.get("epoch_base"), args.get("n_epochs")
    if base is None or n is None:
        return None
    starts = [s.t0_s for s in spans
              if s.name == "observe_all" and s.epoch is not None
              and base <= s.epoch < base + n]
    if not starts:
        return None
    return {"t0": min(starts), "t1": sync_span.t0_s + sync_span.dur_s,
            "epoch_base": base, "n_epochs": n}


def device_track_events(spans: Sequence, *,
                        t_base: Optional[float] = None) -> List[dict]:
    """Synthesized ``device`` track: one span per record_sync window,
    covering first-drained-epoch dispatch -> sync completion."""
    base_t = _t_base(spans) if t_base is None else t_base
    events: List[dict] = []
    for s in spans:
        if s.name != "record_sync":
            continue
        win = _sync_window(s, spans)
        if win is None:
            continue
        lo, hi = win["epoch_base"], win["epoch_base"] + win["n_epochs"]
        events.append({
            "name": f"device epochs [{lo},{hi})", "ph": "X", "cat": "device",
            "ts": (win["t0"] - base_t) * 1e6,
            "dur": (win["t1"] - win["t0"]) * 1e6,
            "pid": _PID, "tid": "device",
            "args": {"epoch_base": lo, "n_epochs": win["n_epochs"]},
        })
    return events


def pipelining_visible(spans: Iterable) -> bool:
    """True iff some record_sync span started after the host had already
    dispatched an epoch newer than every epoch that sync drains.

    ``sync_every=1`` can never satisfy this (each epoch is drained before
    the next is dispatched); ``sync_every=K>1`` must (``_step_fused``
    dispatches ``observe_all`` for epoch *e* before draining epochs
    ``[e-K, e)``), so the check is deterministic, not timing-dependent.
    """
    spans = list(spans)
    observe_starts = {s.epoch: s.t0_s for s in spans
                      if s.name == "observe_all" and s.epoch is not None}
    for s in spans:
        if s.name != "record_sync" or not s.args:
            continue
        base, n = s.args.get("epoch_base"), s.args.get("n_epochs")
        if base is None or n is None:
            continue
        for epoch, t0 in observe_starts.items():
            if epoch >= base + n and t0 <= s.t0_s:
                return True
    return False


def write_chrome_trace(path, spans: Sequence, *, device_track: bool = True,
                       metadata: Optional[dict] = None) -> dict:
    """Write ``{"traceEvents": [...]}`` JSON for chrome://tracing; returns
    the document (also handy for asserting on it in tests)."""
    base = _t_base(spans)
    events = chrome_trace_events(spans, t_base=base)
    if device_track:
        events.extend(device_track_events(spans, t_base=base))
    doc: Dict[str, object] = {
        "traceEvents": sorted(events, key=lambda e: (e["ts"], e["tid"])),
        "displayTimeUnit": "ms",
    }
    if metadata:
        doc["otherData"] = dict(metadata)
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, separators=(",", ":"), sort_keys=True)
        fh.write("\n")
    return doc
