"""jit'd public wrapper for flash attention (TPU kernel / jnp fallback)."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import flash_attention_pallas
from .ref import attention_ref


@partial(jax.jit, static_argnames=(
    "q_per_kv", "causal", "window", "block_q", "block_k", "use_pallas", "interpret"))
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *,
    q_per_kv: int = 1,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    use_pallas: bool | None = None,
    interpret: bool = False,
):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return attention_ref(q, k, v, q_per_kv=q_per_kv, causal=causal, window=window)
    return flash_attention_pallas(
        q, k, v, q_per_kv=q_per_kv, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
