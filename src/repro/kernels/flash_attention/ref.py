"""Pure-jnp oracle: exact causal/windowed GQA attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,   # (BH, Sq, D)
    k: jax.Array,   # (BKH, Sk, D)
    v: jax.Array,
    *,
    q_per_kv: int,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
):
    bh, sq, d = q.shape
    bkh, sk, _ = k.shape
    if sm_scale is None:
        sm_scale = d ** -0.5
    kk = jnp.repeat(k, q_per_kv, axis=0)
    vv = jnp.repeat(v, q_per_kv, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s *= sm_scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos >= qpos - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key (can happen with windows) -> zeros
    p = jnp.where(mask[None].any(-1, keepdims=True), p, 0.0)
    return jnp.einsum("hqk,hkd->hqd", p, vv.astype(jnp.float32)).astype(q.dtype)
