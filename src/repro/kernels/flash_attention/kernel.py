"""Blocked causal (optionally sliding-window) attention for TPU.

Standard flash-attention online-softmax, restructured for the TPU memory
hierarchy: Q/K/V tiles staged by BlockSpec into VMEM, the two matmuls sized
for the MXU (block dims multiples of 128), running (max, sum, acc) carried in
VMEM scratch across the KV-block grid dimension.  GQA is handled in the
BlockSpec index maps (a KV head serves q_per_kv query heads) so KV tiles are
fetched once per group, not per query head.

Causal + window skipping happens at grid level: out-of-range KV blocks are
masked fully and their matmuls skipped with ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(
    q_ref,      # (1, bq, d)
    k_ref,      # (1, bk, d)
    v_ref,      # (1, bk, d)
    o_ref,      # (1, bq, d)
    m_ref,      # (bq, 128) f32 scratch: running max
    l_ref,      # (bq, 128) f32 scratch: running sum
    acc_ref,    # (bq, d) f32 scratch
    *,
    block_q: int,
    block_k: int,
    sm_scale: float,
    causal: bool,
    window: int | None,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # A KV block participates unless fully masked out.
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window is not None:
        needed = needed & (k_start + block_k - 1 >= q_start - window)

    @pl.when(needed)
    def compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale                                           # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos >= qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                                  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                                 # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                        # (bq, 1)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == nk - 1)
    def publish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,   # (BH, Sq, D)  BH = batch * q_heads
    k: jax.Array,   # (BKH, Sk, D) BKH = batch * kv_heads
    v: jax.Array,
    *,
    q_per_kv: int,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
):
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    if sq % block_q or sk % block_k:
        raise ValueError(f"seq lens {(sq, sk)} must tile by {(block_q, block_k)}")
    if sm_scale is None:
        sm_scale = d ** -0.5

    grid = (bh, sq // block_q, sk // block_k)

    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k,
        sm_scale=sm_scale, causal=causal, window=window,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, qi, ki: (h // q_per_kv, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, qi, ki: (h // q_per_kv, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, qi, ki: (h, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
