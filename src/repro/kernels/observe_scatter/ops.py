"""jit'd public wrapper for observe_scatter.

Dispatches to the Pallas TPU kernel on TPU backends (or in interpret mode
for CPU parity runs) and to the pure-jnp reference elsewhere.  Pads the id
stream to the tile size with ``n_blocks`` — out of range for both paths
(negative ids WRAP once, NumPy-style, so they cannot pad) — so callers
pass arbitrary batch sizes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_TILE_M, observe_scatter_pallas
from .ref import observe_scatter_ref

# both histograms ride whole in VMEM across the grid; past ~1M blocks they
# stop fitting alongside the working tiles — callers fall back to XLA
MAX_BLOCKS = 1 << 20


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit,
         static_argnames=("n_blocks", "period", "tile_m", "use_pallas",
                          "interpret"))
def observe_scatter(
    ids: jax.Array,                # (M,) int32 block ids
    cursor: jax.Array,             # () int32 PEBS position mod period
    *,
    n_blocks: int,
    period: int,
    keep: jax.Array | None = None,  # (M,) bool fault-model survival mask
    tile_m: int = DEFAULT_TILE_M,
    use_pallas: bool | None = None,
    interpret: bool = False,
):
    """Fused epoch-batch telemetry scatter -> (hist, pebs_hist)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas or n_blocks > MAX_BLOCKS:
        return observe_scatter_ref(ids, cursor, n_blocks=n_blocks,
                                   period=period, keep=keep)
    m = ids.shape[0]
    tile = min(tile_m, -(-m // 128) * 128)
    pad = (-m) % tile
    if pad:
        ids = jnp.concatenate(
            [ids, jnp.full((pad,), n_blocks, jnp.int32)])
        if keep is not None:
            keep = jnp.concatenate(
                [keep, jnp.zeros((pad,), keep.dtype)])
    return observe_scatter_pallas(ids, cursor, n_blocks=n_blocks,
                                  period=period, keep=keep, tile_m=tile,
                                  interpret=interpret)
