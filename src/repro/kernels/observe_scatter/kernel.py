"""observe_scatter — Pallas TPU fused telemetry scatter.

``gather_count``'s counter-bump pattern, widened from one counter array to
the two histograms the whole telemetry bundle needs: the grid walks
``tile_m``-id tiles of the batch's scalar-prefetched id stream (the same
SMEM-resident index idiom — the core must know the ids to address the
counter cells), carrying both histograms in VMEM across the sequential grid
(zeroed at step 0, revisited every step, race-free on a TPU core).  Per id
the kernel bumps the access histogram and — when the id's stream position
hits the PEBS sampler's ``(cursor + position) % period == 0`` phase, and
survives the optional fault-model keep mask — the sampled histogram.  One
read of the id stream feeds HMU, PEBS, NB and the true counter; the XLA
path reads it four times (one scatter per collector).

Id semantics exactly match the XLA observe path's ``.at[ids].add(...,
mode="drop")``: a negative id wraps once (NumPy-style ``id + n_blocks``)
and anything still outside ``[0, n_blocks)`` is skipped.  The ops wrapper
pads ragged tiles with ``n_blocks`` — out of range for BOTH paths, so
phantom positions never touch either histogram.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_TILE_M = 1024


def _kernel(*refs, tile_m: int, period: int, n_blocks: int, has_keep: bool):
    if has_keep:
        idx_ref, keep_ref, cursor_ref, hist_ref, pebs_ref = refs
    else:
        idx_ref, cursor_ref, hist_ref, pebs_ref = refs
        keep_ref = None
    step = pl.program_id(0)
    base = step * tile_m

    @pl.when(step == 0)
    def _zero():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        pebs_ref[...] = jnp.zeros_like(pebs_ref)

    cursor = cursor_ref[0]

    def bump(i, _):
        raw = idx_ref[base + i]
        blk = jnp.where(raw < 0, raw + n_blocks, raw)
        hit = ((cursor + base + i) % period) == 0
        if keep_ref is not None:
            hit = hit & (keep_ref[base + i] != 0)

        @pl.when((blk >= 0) & (blk < n_blocks))
        def _():
            hist_ref[blk, 0] = hist_ref[blk, 0] + 1

            @pl.when(hit)
            def _():
                pebs_ref[blk, 0] = pebs_ref[blk, 0] + 1

        return ()

    jax.lax.fori_loop(0, tile_m, bump, (), unroll=False)


def observe_scatter_pallas(
    ids: jax.Array,        # (M,) int32, M % tile_m == 0 (n_blocks = padding)
    cursor: jax.Array,     # () or (1,) int32
    *,
    n_blocks: int,
    period: int,
    keep: jax.Array | None = None,   # (M,) int32/bool per-event survival
    tile_m: int = DEFAULT_TILE_M,
    interpret: bool = False,
):
    m = ids.shape[0]
    if m % tile_m:
        raise ValueError(f"M={m} must be a multiple of tile_m={tile_m}")
    has_keep = keep is not None

    operands = [ids.astype(jnp.int32)]
    if has_keep:
        operands.append(keep.astype(jnp.int32))
    operands.append(cursor.reshape(1).astype(jnp.int32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(operands),
        grid=(m // tile_m,),
        in_specs=[],
        out_specs=[
            pl.BlockSpec((n_blocks, 1), lambda i, *_: (0, 0)),
            pl.BlockSpec((n_blocks, 1), lambda i, *_: (0, 0)),
        ],
    )
    hist, pebs_hist = pl.pallas_call(
        functools.partial(_kernel, tile_m=tile_m, period=period,
                          n_blocks=n_blocks, has_keep=has_keep),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return hist.reshape(n_blocks), pebs_hist.reshape(n_blocks)
