"""observe_scatter — fused telemetry scatter for the epoch observe path.

One tiled pass over a batch's block-id stream yields the two histograms
every collector update in ``telemetry.observe_all`` is an affine function
of: the full access histogram (HMU saturating add, NB touched set,
true-count add) and the PEBS-sampled histogram (the in-kernel
``(cursor + position) % period`` sampler, optionally masked by a fault
model's per-event keep draw) — one read of the id stream feeding all four
collectors, replacing their four per-batch scatters.
"""
from .ops import MAX_BLOCKS, observe_scatter
from .ref import observe_scatter_ref

__all__ = ["observe_scatter", "observe_scatter_ref", "MAX_BLOCKS"]
