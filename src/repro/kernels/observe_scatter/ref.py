"""Pure-jnp oracle for observe_scatter.

Exactly the scatter-adds ``telemetry._bundle_observe`` issues per batch,
reduced to their two independent histograms.  ``mode="drop"`` semantics —
a negative id wraps once (NumPy-style) and anything still outside
``[0, n_blocks)`` is dropped — matching both the XLA observe path and the
kernel's wrap + bounds guard.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def observe_scatter_ref(
    ids: jax.Array,                 # (M,) int32 block ids
    cursor: jax.Array,              # () int32 PEBS stream position mod period
    *,
    n_blocks: int,
    period: int,
    keep: Optional[jax.Array] = None,   # (M,) bool per-event survival
) -> Tuple[jax.Array, jax.Array]:
    """-> (hist, pebs_hist): (n_blocks,) int32 access and sampled counts."""
    flat = ids.reshape(-1)
    hist = jnp.zeros((n_blocks,), jnp.int32).at[flat].add(1, mode="drop")
    pos = cursor + jnp.arange(flat.shape[0], dtype=jnp.int32)
    kept = (pos % period) == 0
    if keep is not None:
        kept = kept & keep
    pebs_hist = jnp.zeros((n_blocks,), jnp.int32).at[flat].add(
        kept.astype(jnp.int32), mode="drop")
    return hist, pebs_hist
