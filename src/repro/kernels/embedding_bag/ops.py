"""jit'd public wrapper for embedding_bag (TPU kernel / jnp fallback)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import embedding_bag_pallas
from .ref import embedding_bag_ref


@partial(jax.jit, static_argnames=("block_rows", "use_pallas", "interpret"))
def embedding_bag(
    storage: jax.Array,
    indices: jax.Array,
    counts: jax.Array,
    weights: jax.Array | None = None,
    *,
    block_rows: int,
    use_pallas: bool | None = None,
    interpret: bool = False,
):
    """Batched (weighted) embedding-bag with fused HMU counters.

    Returns (pooled (B, D), new_counts)."""
    if weights is None:
        weights = jnp.ones(indices.shape, jnp.float32)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return embedding_bag_ref(storage, indices, weights, counts, block_rows=block_rows)
    return embedding_bag_pallas(
        storage, indices, weights, counts, block_rows=block_rows, interpret=interpret
    )
