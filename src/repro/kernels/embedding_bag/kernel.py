"""embedding_bag — FBGEMM-style batched embedding-bag with HMU telemetry.

The core DLRM inference op (paper §III.B: "batched embedding bag operations
are the core computational kernels in large-scale personalized
recommendation systems").  For each output sample, ``bag_len`` rows are
gathered from the (possibly tiered) table and sum/weighted-sum pooled.

TPU design:
  * one grid step per bag; the bag's rows are fetched HBM->VMEM with
    ``bag_len`` concurrent async copies driven by scalar-prefetched indices;
  * pooling is a (1, L) x (L, D) matmul against the per-bag weights — the
    reduction runs on the MXU while the next bag's DMAs are in flight
    (sequential grid: Pallas overlaps via the implicit pipeline);
  * per-block HMU counters are bumped in the same pass (aliased VMEM
    buffer), giving exact, host-free access telemetry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    idx_ref,          # (B, L) int32, scalar-prefetched
    storage_ref,      # (N, D) ANY/HBM
    weights_ref,      # (1, L) per-bag pooling weights, VMEM
    counts_in_ref,    # (n_blocks, 1) int32 VMEM (aliased)
    out_ref,          # (1, D) VMEM
    counts_out_ref,   # aliased
    rows_ref,         # (L, D) VMEM scratch
    sem,              # (L,) DMA semaphores
    *,
    bag_len: int,
    block_rows: int,
):
    b = pl.program_id(0)

    def issue(i, _):
        row = idx_ref[b, i]
        pltpu.make_async_copy(
            storage_ref.at[pl.ds(row, 1), :], rows_ref.at[pl.ds(i, 1), :], sem.at[i]
        ).start()
        return ()

    jax.lax.fori_loop(0, bag_len, issue, (), unroll=False)

    # memory-side telemetry (while DMAs fly)
    def bump(i, _):
        blk = idx_ref[b, i] // block_rows
        counts_out_ref[blk, 0] = counts_out_ref[blk, 0] + 1
        return ()

    jax.lax.fori_loop(0, bag_len, bump, (), unroll=False)

    def wait(i, _):
        pltpu.make_async_copy(
            storage_ref.at[pl.ds(idx_ref[b, i], 1), :], rows_ref.at[pl.ds(i, 1), :],
            sem.at[i],
        ).wait()
        return ()

    jax.lax.fori_loop(0, bag_len, wait, (), unroll=False)

    # (1, L) @ (L, D) weighted pool on the MXU, accumulate in f32
    out_ref[...] = jnp.dot(
        weights_ref[...], rows_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)


def embedding_bag_pallas(
    storage: jax.Array,    # (N, D)
    indices: jax.Array,    # (B, L) int32
    weights: jax.Array,    # (B, L) pooling weights
    counts: jax.Array,     # (n_blocks,) int32
    *,
    block_rows: int,
    interpret: bool = False,
):
    b, l = indices.shape
    n, d = storage.shape
    n_blocks = counts.shape[0]
    counts2d = counts.reshape(n_blocks, 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),                # storage in HBM
            pl.BlockSpec((1, l), lambda i, idx: (i, 0)),         # weights row
            pl.BlockSpec((n_blocks, 1), lambda i, idx: (0, 0)),  # counts
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i, idx: (i, 0)),
            pl.BlockSpec((n_blocks, 1), lambda i, idx: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((l, d), storage.dtype),
            pltpu.SemaphoreType.DMA((l,)),
        ],
    )

    out, counts_new = pl.pallas_call(
        functools.partial(_kernel, bag_len=l, block_rows=block_rows),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, d), storage.dtype),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
        ],
        input_output_aliases={3: 1},
        interpret=interpret,
    )(indices.astype(jnp.int32), storage, weights.astype(jnp.float32), counts2d)
    return out, counts_new.reshape(n_blocks)
