"""Pure-jnp oracle for embedding_bag."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(
    storage: jax.Array,   # (N, D)
    indices: jax.Array,   # (B, L)
    weights: jax.Array,   # (B, L)
    counts: jax.Array,    # (n_blocks,)
    *,
    block_rows: int,
):
    rows = jnp.take(storage, indices, axis=0).astype(jnp.float32)     # (B, L, D)
    out = jnp.einsum("bl,bld->bd", weights.astype(jnp.float32), rows)
    blk = indices.astype(jnp.int32) // block_rows
    new_counts = counts.at[blk.reshape(-1)].add(1)
    return out.astype(storage.dtype), new_counts
