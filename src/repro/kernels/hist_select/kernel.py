"""hist_select — Pallas TPU radix-histogram threshold select.

The paper's HMU must rank-select on-module in a bounded number of passes
over its counter SRAM; ``selectk``'s bitwise search emulates that with 32
full compare+reduce passes (one per bit).  This kernel descends the same
threshold in 4 byte levels: per level it streams the key tiles once,
accumulating a per-segment 256-bin histogram of the current byte (restricted
to keys matching the already-resolved high-byte prefix) in VMEM, then — on
the level's last tile — cumulates the histogram from the top to find the bin
holding the k-th largest key, folds that byte into the prefix, and rebases k
to the bin-local rank.  After level 3 the prefix IS the k-th largest key:
the exact value ``selectk._kth_largest`` returns, in 4 grid passes over the
data instead of 32.

Layout per grid step ``(b, level, tile)`` (grid is sequential on a TPU core,
so the VMEM scratch carries state across steps race-free):

  * keys tile ``(1, tile_n)`` uint32 (the order-isomorphic ``_to_u`` image);
  * segment-id tile ``(1, tile_n)`` int32 (-1 = padding, matches no segment);
  * histogram scratch ``(S, 256)`` f32, accumulated via a segment-one-hot ×
    byte-one-hot matmul — ``gather_count``'s one-hot tile pattern, MXU-shaped
    on TPU; f32 accumulation is exact below 2**24 counts (``ops.MAX_N``);
  * prefix / k-remaining scratch ``(S, 1)`` in VMEM (per-segment select
    state), reset at ``(level==0, tile==0)`` per batch row.

Per-segment caps ride in as a ``(S, 1)`` VMEM input — the "segment caps
become per-tenant histogram offsets" form of ``segment_top_k_mask``: one
kernel invocation resolves every tenant's threshold instead of one
dispatch per tenant slice.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_TILE_N = 2048
_LEVELS = 4                       # 32-bit keys, one byte per level


def _kernel(
    keys_ref,        # (1, tile_n) uint32 key tile (u-domain)
    seg_ref,         # (1, tile_n) int32 segment ids (-1 = padding)
    ks_ref,          # (S, 1) int32 per-segment selection widths
    out_ref,         # (1, S, 1) uint32 thresholds for this batch row
    hist_ref,        # (S, 256) f32 scratch
    prefix_ref,      # (S, 1) uint32 scratch: resolved high bytes
    krem_ref,        # (S, 1) int32 scratch: rank within the current prefix
    *,
    n_segments: int,
    n_tiles: int,
    tile_n: int,
):
    level = pl.program_id(1)
    tile = pl.program_id(2)
    s = n_segments

    @pl.when((level == 0) & (tile == 0))
    def _init_row():
        prefix_ref[...] = jnp.zeros((s, 1), jnp.uint32)
        krem_ref[...] = ks_ref[...]

    @pl.when(tile == 0)
    def _zero_hist():
        hist_ref[...] = jnp.zeros((s, 256), jnp.float32)

    # ---- accumulate this tile's per-segment histogram of the level's byte
    u = keys_ref[...]                                   # (1, tile_n) uint32
    lvl = level.astype(jnp.uint32)
    shift = jnp.uint32(8) * (jnp.uint32(3) - lvl)
    byte = ((u >> shift) & jnp.uint32(0xFF)).astype(jnp.int32)
    # keys still in the running match the resolved prefix on every byte
    # above this level (level 0: mask 0, everything matches)
    hi_mask = ~(jnp.uint32(0xFFFFFFFF) >> (jnp.uint32(8) * lvl))
    matched = (u & hi_mask) == prefix_ref[...]          # (S, tile_n)
    seg_oh = (seg_ref[...] ==
              jax.lax.broadcasted_iota(jnp.int32, (s, tile_n), 0))
    contrib = (seg_oh & matched).astype(jnp.float32)    # (S, tile_n)
    byte_col = byte.reshape(tile_n, 1)
    byte_oh = (byte_col ==
               jax.lax.broadcasted_iota(jnp.int32, (tile_n, 256), 1)
               ).astype(jnp.float32)
    hist_ref[...] += jnp.dot(contrib, byte_oh,
                             preferred_element_type=jnp.float32)

    # ---- level boundary: localize the k-th key's bin, refine prefix and k
    @pl.when(tile == n_tiles - 1)
    def _resolve():
        hist = hist_ref[...]                            # (S, 256)
        cum = jnp.cumsum(hist, axis=1)                  # inclusive, from 0
        total = cum[:, 255][:, None]
        from_top = total - cum + hist                   # count(byte >= j)
        krem = krem_ref[...].astype(jnp.float32)        # (S, 1)
        # from_top is non-increasing in j: the chosen bin is the largest j
        # with from_top[j] >= k, i.e. (number of qualifying bins) - 1.
        # k == 0 qualifies every bin -> bin 255 -> prefix byte 0xFF, exactly
        # the all-ones threshold the bitwise search degenerates to.
        n_ge = jnp.sum((from_top >= krem).astype(jnp.float32), axis=1)
        b_idx = jnp.maximum(n_ge - 1.0, 0.0)[:, None]   # (S, 1)
        iota = jax.lax.broadcasted_iota(jnp.float32, (s, 256), 1)
        oh = (iota == b_idx).astype(jnp.float32)
        above = jnp.sum(oh * (from_top - hist), axis=1)[:, None]
        krem_ref[...] = krem_ref[...] - above.astype(jnp.int32)
        prefix_ref[...] = (prefix_ref[...]
                           | (b_idx.astype(jnp.uint32) << shift))

    @pl.when((level == _LEVELS - 1) & (tile == n_tiles - 1))
    def _emit():
        out_ref[0] = prefix_ref[...]


def kth_key_u_pallas(
    u: jax.Array,          # (B, n) uint32 keys, n % tile_n == 0
    seg_ids: jax.Array,    # (n,) int32, -1 = padding
    ks: jax.Array,         # (S,) int32 per-segment widths
    *,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> jax.Array:            # (B, S) uint32 thresholds
    b, n = u.shape
    if n % tile_n:
        raise ValueError(f"n={n} must be a multiple of tile_n={tile_n}")
    s = ks.shape[0]
    n_tiles = n // tile_n

    out = pl.pallas_call(
        functools.partial(_kernel, n_segments=s, n_tiles=n_tiles,
                          tile_n=tile_n),
        grid=(b, _LEVELS, n_tiles),
        in_specs=[
            pl.BlockSpec((1, tile_n), lambda i, l, t: (i, t)),
            pl.BlockSpec((1, tile_n), lambda i, l, t: (0, t)),
            pl.BlockSpec((s, 1), lambda i, l, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, 1), lambda i, l, t: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, 1), jnp.uint32),
        scratch_shapes=[
            pltpu.VMEM((s, 256), jnp.float32),
            pltpu.VMEM((s, 1), jnp.uint32),
            pltpu.VMEM((s, 1), jnp.int32),
        ],
        interpret=interpret,
    )(u, seg_ids.reshape(1, n), ks.reshape(s, 1).astype(jnp.int32))
    return out.reshape(b, s)
