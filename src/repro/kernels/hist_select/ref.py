"""Pure-jnp oracle for hist_select.

``kth_key_u_ref`` computes, per batch row and per segment, the k-th largest
uint32 key among that segment's elements — the same value ``selectk``'s
bitwise binary search (``_kth_largest``) converges to, by construction: the
largest threshold ``t`` with ``count(u >= t) >= k`` over a set of integers is
exactly the set's k-th largest element.  ``k == 0`` yields the all-ones
threshold (no element compares ``>``, matching the 32-round search that sets
every candidate bit when ``n_ge >= 0`` is vacuously true).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

_ALL_ONES = jnp.uint32(0xFFFFFFFF)


def kth_key_u_ref(u: jax.Array, seg_ids: jax.Array,
                  ks: Sequence[int]) -> jax.Array:
    """(B, n) uint32 keys + (n,) int32 segment ids -> (B, S) uint32
    thresholds: segment s's ``ks[s]``-th largest key per row.

    ``seg_ids`` entries outside [0, S) (padding convention: -1) belong to no
    segment.  Requires ``0 <= ks[s] <= |segment s|`` — the callers clamp.
    """
    b = u.shape[0]
    outs = []
    for s, k in enumerate(ks):
        member = (seg_ids == s)[None, :]
        if int(k) == 0:
            outs.append(jnp.full((b,), _ALL_ONES, jnp.uint32))
            continue
        # non-members sink to 0, the uint32 minimum: with k <= |segment|
        # the k-th largest member is never displaced by them (a displaced
        # threshold would require fewer than k members >= it)
        uu = jnp.where(member, u, jnp.uint32(0))
        outs.append(jnp.sort(uu, axis=-1)[:, -int(k)])
    return jnp.stack(outs, axis=-1)
