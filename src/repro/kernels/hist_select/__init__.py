"""hist_select — one-pass radix-histogram threshold select (Pallas TPU).

Replaces ``selectk``'s 32-round bitwise threshold search (one full
compare+reduce pass over the keys per bit) with a 4-level byte radix descent:
each level streams the keys once, building a 256-bin histogram of the
current byte per segment in VMEM, then localizes the k-th largest key's bin
from the cumulated histogram — 4 grid passes instead of 32, bit-identical
thresholds.
"""
from .ops import MAX_N, kth_key_u
from .ref import kth_key_u_ref

__all__ = ["kth_key_u", "kth_key_u_ref", "MAX_N"]
