"""jit'd public wrapper for hist_select.

``kth_key_u`` is the backend primitive ``selectk`` plugs in: per batch row
and per static segment, the k-th largest uint32 key.  Dispatches to the
Pallas radix-histogram kernel on TPU (or in ``interpret=True`` mode for CPU
parity runs) and to the pure-jnp sort oracle otherwise.  The wrapper pads
the key axis to the tile size with segment id -1, which matches no segment's
one-hot row — padding never enters any histogram.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_TILE_N, kth_key_u_pallas
from .ref import kth_key_u_ref

# f32 histogram accumulation (tile matmul + cumsum) is exact for integer
# counts below 2**24; callers must fall back to the 32-round search past it.
MAX_N = 1 << 23


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("ks", "tile_n", "use_pallas", "interpret"))
def kth_key_u(
    u: jax.Array,                  # (B, n) uint32 keys (selectk's _to_u image)
    seg_ids: jax.Array,            # (n,) int32 segment of each element
    ks: tuple,                     # static per-segment selection widths
    *,
    tile_n: int = DEFAULT_TILE_N,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jax.Array:                    # (B, S) uint32 thresholds
    """Per-(row, segment) k-th largest key.  ``0 <= ks[s] <= |segment s|``."""
    n = u.shape[-1]
    if n > MAX_N:
        raise ValueError(f"n={n} exceeds hist_select's exact-count bound "
                         f"MAX_N={MAX_N}; use the selectk XLA path")
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return kth_key_u_ref(u, seg_ids, ks)

    tile = min(tile_n, -(-n // 128) * 128)    # lane-aligned, never > tile_n
    pad = (-n) % tile
    if pad:
        u = jnp.concatenate([u, jnp.zeros(u.shape[:-1] + (pad,), u.dtype)],
                            axis=-1)
        seg_ids = jnp.concatenate(
            [seg_ids, jnp.full((pad,), -1, jnp.int32)])
    return kth_key_u_pallas(u, seg_ids, jnp.asarray(ks, jnp.int32),
                            tile_n=tile, interpret=interpret)
