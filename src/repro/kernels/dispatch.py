"""Shared Pallas dispatch policy for the telemetry kernels.

Every kernel package in ``repro.kernels`` follows one triad — ``ref.py`` (the
pure-jnp oracle), ``kernel.py`` (the Pallas TPU kernel), ``ops.py`` (a jit'd
wrapper choosing between them) — and the *core* integration points
(``selectk`` / ``telemetry`` / ``runtime``) all make the same choice the same
way: a :class:`PallasBackend` (hashable, so it can ride in static jit config
like ``runtime._FusedCfg``) when the kernels should run, ``None`` when the
XLA path should.

Resolution rule (:func:`resolve_backend`):

* ``use_pallas=None`` (default) — kernels on iff the default JAX backend is
  TPU: compiled Pallas is the point on real hardware, XLA is the oracle
  elsewhere.
* ``use_pallas=True`` off-TPU — the kernels still run, in ``interpret=True``
  mode (Pallas's CPU interpreter), unless ``interpret`` is explicitly
  ``False``.  This is the CI parity path: the kernel *bodies* execute and are
  gated bit-identical against XLA on every push, even though the container
  has no TPU.
* ``use_pallas=False`` — XLA everywhere (the reference / bit-identity
  oracle configuration).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax

__all__ = ["PallasBackend", "resolve_backend"]


class PallasBackend(NamedTuple):
    """Static (hashable) kernel-dispatch config baked into jit traces.

    ``interpret``       — run kernels through the Pallas interpreter (CPU
                          parity mode) instead of compiling for TPU.
    ``select_tile_n``   — hist_select: key elements per grid tile.
    ``scatter_tile_m``  — observe_scatter: id-stream elements per grid tile.
    """
    interpret: bool = False
    select_tile_n: int = 2048
    scatter_tile_m: int = 1024


def resolve_backend(use_pallas: Optional[bool] = None,
                    interpret: Optional[bool] = None,
                    **overrides) -> Optional[PallasBackend]:
    """``None`` = XLA path; a :class:`PallasBackend` = run the kernels."""
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if not use_pallas:
        return None
    if interpret is None:
        interpret = not on_tpu
    return PallasBackend(interpret=bool(interpret), **overrides)
