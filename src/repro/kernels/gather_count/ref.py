"""Pure-jnp oracle for gather_count."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_count_ref(
    storage: jax.Array,   # (N, D)
    indices: jax.Array,   # (M,)
    counts: jax.Array,    # (n_blocks,) int32
    *,
    block_rows: int,
):
    out = jnp.take(storage, indices, axis=0)
    blk = indices.astype(jnp.int32) // block_rows
    new_counts = counts.at[blk].add(1)
    return out, new_counts
