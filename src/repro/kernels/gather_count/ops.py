"""jit'd public wrapper for gather_count.

Dispatches to the Pallas TPU kernel on TPU backends and to the pure-jnp
reference elsewhere (CPU dry-runs / tests run the kernel in interpret mode
explicitly).  The wrapper pads the index vector to the tile size so callers
can pass arbitrary M.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_TILE_M, gather_count_pallas
from .ref import gather_count_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_rows", "tile_m", "use_pallas", "interpret"))
def gather_count(
    storage: jax.Array,
    indices: jax.Array,
    counts: jax.Array,
    *,
    block_rows: int,
    tile_m: int = DEFAULT_TILE_M,
    use_pallas: bool | None = None,
    interpret: bool = False,
):
    """Tier-aware gather + HMU counter update.  Returns (rows, new_counts)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return gather_count_ref(storage, indices, counts, block_rows=block_rows)

    m = indices.shape[0]
    pad = (-m) % tile_m
    if pad:
        # pad with row 0 and subtract the phantom counts afterwards
        indices_p = jnp.concatenate([indices, jnp.zeros((pad,), indices.dtype)])
    else:
        indices_p = indices
    out, new_counts = gather_count_pallas(
        storage, indices_p, counts,
        block_rows=block_rows, tile_m=tile_m, interpret=interpret,
    )
    if pad:
        new_counts = new_counts.at[0].add(-pad)
        out = out[:m]
    return out, new_counts
