from .ops import gather_count
from .ref import gather_count_ref

__all__ = ["gather_count", "gather_count_ref"]
