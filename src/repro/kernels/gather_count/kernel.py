"""gather_count — tier-aware row gather with memory-side access counters.

This is the paper's HMU adapted to the TPU memory system: the per-block
access counters are updated *inside the same kernel pass* that moves the rows
(HBM -> VMEM), so telemetry has full coverage and costs the host nothing —
the TPU analogue of counting CXL.mem packets inside the memory module.

Design (TPU):
  * ``storage`` lives in HBM (``memory_space=ANY``); rows are fetched with
    explicit per-row async copies driven by **scalar-prefetched indices**
    (the standard TPU dynamic-gather pattern: the index vector must be known
    to the core before the DMA can be issued).
  * the grid walks index tiles of ``tile_m`` rows; output tiles are VMEM.
  * ``counts`` (one int32 per block of ``block_rows`` rows) is carried in
    VMEM and aliased input->output, emulating the HMU counter SRAM.  The TPU
    grid is sequential on a core, so read-modify-write is race-free.

The Pallas kernel targets TPU; tests validate it with ``interpret=True``
against ``ref.py`` (CPU containers cannot execute compiled TPU kernels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_TILE_M = 128


def _kernel(
    # scalar-prefetch operands
    idx_ref,            # (M,) int32 row ids, SMEM (scalar prefetch)
    # array operands
    storage_ref,        # (N, D) in ANY/HBM
    counts_in_ref,      # (n_blocks_padded, COUNT_LANES) int32, VMEM (aliased)
    out_ref,            # (tile_m, D) VMEM
    counts_out_ref,     # aliased with counts_in_ref
    scratch_ref,        # (tile_m, D) VMEM staging for DMA
    sem,                # DMA semaphores, one per row in flight
    *,
    tile_m: int,
    block_rows: int,
):
    step = pl.program_id(0)
    base = step * tile_m

    # ---- issue all row DMAs for this tile (HBM -> VMEM scratch)
    def issue(i, _):
        row = idx_ref[base + i]
        cp = pltpu.make_async_copy(
            storage_ref.at[pl.ds(row, 1), :],
            scratch_ref.at[pl.ds(i, 1), :],
            sem.at[i],
        )
        cp.start()
        return ()

    jax.lax.fori_loop(0, tile_m, issue, (), unroll=False)

    # ---- memory-side telemetry: bump the block counter per fetched row.
    # One int32 cell per block; lane 0 of a (pad, 128) layout keeps the
    # scatter vectorizable on the VPU.
    def bump(i, _):
        row = idx_ref[base + i]
        blk = row // block_rows
        cur = counts_out_ref[blk, 0]
        counts_out_ref[blk, 0] = cur + 1
        return ()

    jax.lax.fori_loop(0, tile_m, bump, (), unroll=False)

    # ---- wait for DMAs and publish the tile
    def wait(i, _):
        pltpu.make_async_copy(
            storage_ref.at[pl.ds(idx_ref[base + i], 1), :],
            scratch_ref.at[pl.ds(i, 1), :],
            sem.at[i],
        ).wait()
        return ()

    jax.lax.fori_loop(0, tile_m, wait, (), unroll=False)
    out_ref[...] = scratch_ref[...]


def gather_count_pallas(
    storage: jax.Array,     # (N, D)
    indices: jax.Array,     # (M,) int32
    counts: jax.Array,      # (n_blocks,) int32
    *,
    block_rows: int,
    tile_m: int = DEFAULT_TILE_M,
    interpret: bool = False,
):
    m = indices.shape[0]
    if m % tile_m:
        raise ValueError(f"M={m} must be a multiple of tile_m={tile_m}")
    n_blocks = counts.shape[0]
    d = storage.shape[1]

    counts2d = counts.reshape(n_blocks, 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m // tile_m,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),       # storage stays in HBM
            pl.BlockSpec((n_blocks, 1), lambda i, idx: (0, 0)),  # counts in VMEM
        ],
        out_specs=[
            pl.BlockSpec((tile_m, d), lambda i, idx: (i, 0)),
            pl.BlockSpec((n_blocks, 1), lambda i, idx: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_m, d), storage.dtype),
            pltpu.SemaphoreType.DMA((tile_m,)),
        ],
    )

    out, counts_new = pl.pallas_call(
        functools.partial(_kernel, tile_m=tile_m, block_rows=block_rows),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m, d), storage.dtype),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
        ],
        input_output_aliases={2: 1},   # counts2d (arg 2 incl. prefetch) -> out 1
        interpret=interpret,
    )(indices.astype(jnp.int32), storage, counts2d)
    return out, counts_new.reshape(n_blocks)
