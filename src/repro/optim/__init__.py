from .optimizers import adamw, adafactor, OptState, get_optimizer
from .schedule import cosine_schedule

__all__ = ["adamw", "adafactor", "OptState", "get_optimizer", "cosine_schedule"]
