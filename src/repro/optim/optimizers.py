"""Optimizers, built in JAX (no external deps): AdamW and Adafactor.

Adafactor (factored second moment, no momentum by default) exists for the
1T-param cells: fp32 AdamW state for Kimi-K2 is 12 TB and cannot fit a
single-pod v5e (see EXPERIMENTS.md §Dry-run); factored statistics cut
optimizer state to ~params/1000.

Both are (init_fn, update_fn) pairs over arbitrary pytrees, FSDP-friendly
(state pytrees mirror parameter sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    inner: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any, jax.Array], Tuple[Any, OptState]]


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


# ------------------------------------------------------------------- AdamW
def adamw(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    def init(params):
        inner = {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }
        return OptState(jnp.zeros((), jnp.int32), inner)

    def update(grads, state, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / (1 - b1 ** t)
            vh = v / (1 - b2 ** t)
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state.inner["m"], state.inner["v"], params)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(step, {"m": new_m, "v": new_v})

    return Optimizer(init, update)


# ---------------------------------------------------------------- Adafactor
def adafactor(eps=1e-30, clip_threshold=1.0, decay=0.8,
              weight_decay=0.0) -> Optimizer:
    """Factored second-moment estimator (Shazeer & Stern 2018), no momentum."""

    def _factored(p):
        return p.ndim >= 2 and p.shape[-1] >= 8 and p.shape[-2] >= 8

    def init(params):
        def one(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return OptState(jnp.zeros((), jnp.int32), jax.tree.map(
            one, params, is_leaf=lambda x: isinstance(x, jax.Array)
            or hasattr(x, "shape")))

    def update(grads, state, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]) / jnp.maximum(
                    vr.mean(-1)[..., None, None], eps)
                u = g * jax.lax.rsqrt(denom + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            new_p = p.astype(jnp.float32) - lr * u
            if weight_decay:
                new_p = new_p - lr * weight_decay * p.astype(jnp.float32)
            return new_p.astype(p.dtype), new_s

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state.inner)
        new_p, new_s = [], []
        for g, s, p in zip(flat_g, flat_s, flat_p):
            np_, ns_ = upd(g, s, p)
            new_p.append(np_)
            new_s.append(ns_)
        return treedef.unflatten(new_p), OptState(step, treedef.unflatten(new_s))

    return Optimizer(init, update)


def get_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise KeyError(name)
