"""Elastic scaling: re-plan the mesh when the healthy host set changes.

The checkpoint format is mesh-agnostic (global logical arrays), so elastic
restart = (1) pick the new mesh from surviving hosts, (2) recompute
shardings from the same schema rules, (3) restore onto the new mesh.
This module implements step (1) plus the batch re-split, and validates
divisibility so the restart fails fast (not mid-compile).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    devices_used: int
    grad_accum_factor: int   # extra accumulation to keep global batch fixed


class ElasticPlanner:
    """Chooses (data, model) mesh shapes for the devices that remain.

    Policy: keep the model axis fixed (it encodes the TP/EP layout the
    weights need); shrink the data axis to the largest value that fits the
    surviving device count; recover the lost global batch with gradient
    accumulation so optimization hyperparameters stay valid.
    """

    def __init__(self, model_axis: int, global_batch: int,
                 pod_size: Optional[int] = None):
        self.model_axis = model_axis
        self.global_batch = global_batch
        self.pod_size = pod_size

    def plan(self, healthy_devices: int, baseline_data_axis: int) -> MeshPlan:
        if healthy_devices < self.model_axis:
            raise RuntimeError(
                f"cannot form a model axis of {self.model_axis} from "
                f"{healthy_devices} devices")
        data = healthy_devices // self.model_axis
        # data axis must divide the global batch
        while data > 1 and self.global_batch % data:
            data -= 1
        accum = max(baseline_data_axis // data, 1)
        return MeshPlan(
            shape=(data, self.model_axis),
            axes=("data", "model"),
            devices_used=data * self.model_axis,
            grad_accum_factor=accum,
        )

    def replan_on_failure(self, current: MeshPlan, failed_devices: int
                          ) -> MeshPlan:
        return self.plan(current.devices_used - failed_devices,
                         baseline_data_axis=current.shape[0] *
                         current.grad_accum_factor)
