"""Fault-tolerance runtime: preemption handling + straggler detection.

At 1000+ nodes, preemptions and slow hosts are the steady state, not the
exception.  The trainer composes:

  * ``PreemptionGuard`` — installs SIGTERM/SIGINT handlers that set a flag;
    the training loop checks it each step and performs a final synchronous
    checkpoint before exit.  Combined with the deterministic data pipeline
    (seed, step), restart loses zero batches.
  * ``StragglerDetector`` — per-step wall-time EWMA + deviation; a step (or,
    multi-host, a rank's reported step time) slower than
    ``mean + k * std`` for ``patience`` consecutive steps is flagged.
    Mitigation escalates: log -> within-host retry hint -> exclusion
    proposal handed to the ElasticPlanner.
"""
from __future__ import annotations

import signal
import time
from typing import Callable, List, Optional


class PreemptionGuard:
    def __init__(self, install: bool = True):
        self.preempted = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:      # not main thread (tests)
                    pass

    def _handler(self, signum, frame):
        self.preempted = True

    def trigger(self):                  # for tests / manual drills
        self.preempted = True

    def restore(self):
        for sig, h in self._prev.items():
            signal.signal(sig, h)


class StragglerDetector:
    """EWMA step-time outlier detector with escalation callbacks."""

    def __init__(self, threshold_sigma: float = 3.0, patience: int = 3,
                 alpha: float = 0.05, warmup_steps: int = 10):
        self.threshold = threshold_sigma
        self.patience = patience
        self.alpha = alpha
        self.warmup = warmup_steps
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.n = 0
        self.consecutive = 0
        self.flagged_steps: List[int] = []

    def observe(self, step: int, step_time_s: float) -> Optional[str]:
        """Feed one step time; returns an escalation action or None."""
        self.n += 1
        if self.mean is None:
            self.mean = step_time_s
            return None
        dev = step_time_s - self.mean
        is_outlier = (
            self.n > self.warmup
            and self.var > 0
            and dev > self.threshold * (self.var ** 0.5)
        )
        # EWMA update (skip outliers so stragglers don't poison the baseline)
        if not is_outlier:
            self.mean += self.alpha * dev
            self.var = (1 - self.alpha) * (self.var + self.alpha * dev * dev)
            self.consecutive = 0
            return None
        self.consecutive += 1
        self.flagged_steps.append(step)
        if self.consecutive >= 2 * self.patience:
            return "propose_exclusion"     # hand to ElasticPlanner
        if self.consecutive >= self.patience:
            return "retry_host"            # within-host mitigation
        return "log"


class Heartbeat:
    """Host-liveness tracking (coordinator side).  Hosts report
    (host_id, time); hosts silent past ``timeout_s`` are dead."""

    def __init__(self, timeout_s: float = 60.0):
        self.timeout = timeout_s
        self.last_seen: dict = {}

    def beat(self, host_id: str, now: Optional[float] = None):
        self.last_seen[host_id] = now if now is not None else time.time()

    def dead_hosts(self, now: Optional[float] = None) -> List[str]:
        now = now if now is not None else time.time()
        return [h for h, t in self.last_seen.items() if now - t > self.timeout]
