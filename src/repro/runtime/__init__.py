from .failure import PreemptionGuard, StragglerDetector
from .elastic import ElasticPlanner

__all__ = ["PreemptionGuard", "StragglerDetector", "ElasticPlanner"]
