"""repro — memory-side tiering telemetry (HMU) for JAX training/serving.

Reproduction + extension of "A Limits Study of Memory-side Tiering Telemetry"
(Petrucci, Zacarias, Roberts — Micron, 2025).
"""
__version__ = "0.1.0"
