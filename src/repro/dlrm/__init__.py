"""DLRM embedding-table tiering — the paper's §III.B evaluation workload."""
