"""Synthetic DLRM embedding access traces calibrated to the Meta dataset stats.

Paper (§III.B, Meta production dataset): a typical split table holds 5.12 B
parameters = 20.48 GB; ~2.95 GB of weights are touched per pass => ~14 % of
parameters utilized — a sparse, heavy-tailed popularity distribution.

We model row popularity as Zipf(alpha) over pages (rank randomly assigned to
page ids, as embedding row ids carry no popularity order), with alpha chosen
so the top-K pages (K = the paper's promoted count, ~9 % of pages) carry
~97 % of lookups — the regime in which Table 1's numbers are self-consistent
(HMU within 3 % of DRAM-only while >90 % of pages stay in CXL).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

PAGE_BYTES = 4096


@dataclasses.dataclass(frozen=True)
class DLRMTraceSpec:
    n_params: int = 5_120_000_000       # 5.12 B parameters (fp32)
    emb_dim: int = 256                  # row = 1 KiB
    alpha: float = 1.31                 # Zipf skew (calibrated, see module doc)
    lookups_per_batch: int = 2_400_000  # ~2.4 GB row traffic / inference batch
    page_bytes: int = PAGE_BYTES
    param_bytes: int = 4                # fp32 embeddings

    @property
    def row_bytes(self) -> int:
        return self.emb_dim * self.param_bytes

    @property
    def n_rows(self) -> int:
        return self.n_params // self.emb_dim

    @property
    def rows_per_page(self) -> int:
        return self.page_bytes // self.row_bytes

    @property
    def n_pages(self) -> int:
        return self.n_rows // self.rows_per_page

    @property
    def table_bytes(self) -> int:
        return self.n_params * self.param_bytes

    @property
    def k_hot_paper(self) -> int:
        """The paper's HMU promoted-page count (Table 1)."""
        return 486_587


# Reduced spec for tests: ~5000 pages, same skew.
SMALL = DLRMTraceSpec(n_params=5_120_000, lookups_per_batch=40_000)
PAPER = DLRMTraceSpec()


class ZipfPageSampler:
    """Zipf(alpha) over pages with rank->page-id shuffling, inverse-CDF
    sampling.  Deterministic given seed."""

    def __init__(self, spec: DLRMTraceSpec, seed: int = 0):
        self.spec = spec
        n = spec.n_pages
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, n + 1, dtype=np.float64)
        w = ranks ** (-spec.alpha)
        self.cdf = np.cumsum(w)
        self.cdf /= self.cdf[-1]
        # popularity rank -> page id (ids carry no popularity order)
        self.rank_to_page = rng.permutation(n).astype(np.int32)
        self._rng = np.random.default_rng(seed + 1)

    def sample(self, n: int) -> np.ndarray:
        u = self._rng.random(n)
        rank = np.searchsorted(self.cdf, u)
        return self.rank_to_page[rank]

    def true_top_k_pages(self, k: int) -> np.ndarray:
        return self.rank_to_page[:k]

    def page_probabilities(self) -> np.ndarray:
        p = np.empty_like(self.cdf)
        p[0] = self.cdf[0]
        p[1:] = np.diff(self.cdf)
        out = np.empty_like(p)
        out[self.rank_to_page] = p
        return out


def batches(spec: DLRMTraceSpec, n_batches: int, seed: int = 0) -> Iterator[np.ndarray]:
    s = ZipfPageSampler(spec, seed)
    for _ in range(n_batches):
        yield s.sample(spec.lookups_per_batch)


class PhaseShiftSampler:
    """Zipf popularity whose hot set *rotates* between phases.

    Phase ``p`` maps popularity rank ``r`` to page
    ``rank_to_page[(r + p * rotate_by) % n_pages]`` — same skew, disjoint(ish)
    hot head each phase.  This is the workload where frequency-tracking
    telemetry driven per-epoch (proactive/EWMA over HMU counts) should win
    and recency-based NB collapses: NB's cumulative two-touch faults keep
    ranking the *previous* phase's pages hot, while an epoch-delta counter
    re-ranks within one epoch of the shift (the NeoMem / HybridTier
    phase-change regime).
    """

    def __init__(self, spec: DLRMTraceSpec, rotate_by: Optional[int] = None,
                 seed: int = 0):
        self.spec = spec
        self._base = ZipfPageSampler(spec, seed)
        n = spec.n_pages
        # rotations are modular, so rotate_by >= n_pages wraps (rotate_by == n
        # is the identity rotation) rather than indexing out of bounds
        self.rotate_by = int(rotate_by) if rotate_by is not None else n // 3
        self._rng = np.random.default_rng(seed + 2)

    @property
    def rank_to_page(self) -> np.ndarray:
        """Phase-0 popularity-rank -> page-id layout (what a compiler that
        laid the table out knows; see ``repro.hints.StaticTableHints``)."""
        return self._base.rank_to_page

    def sample(self, n: int, phase: int = 0) -> np.ndarray:
        u = self._rng.random(n)
        rank = np.searchsorted(self._base.cdf, u)
        shifted = (rank + phase * self.rotate_by) % self.spec.n_pages
        return self._base.rank_to_page[shifted]

    def true_top_k_pages(self, k: int, phase: int = 0) -> np.ndarray:
        n = self.spec.n_pages
        ranks = (np.arange(k) + phase * self.rotate_by) % n
        return self._base.rank_to_page[ranks]

    def page_probabilities(self, phase: int = 0) -> np.ndarray:
        """Per-page access probability during ``phase`` (the base Zipf mass
        rotated onto that phase's pages)."""
        n = self.spec.n_pages
        p = self._base.page_probabilities()[self._base.rank_to_page]  # by rank
        shifted = (np.arange(n) + phase * self.rotate_by) % n
        out = np.empty_like(p)
        out[self._base.rank_to_page[shifted]] = p
        return out


def phase_shift_epochs(
    spec: DLRMTraceSpec,
    n_epochs: int,
    batches_per_epoch: int,
    shift_at: int,
    rotate_by: Optional[int] = None,
    seed: int = 0,
) -> Iterator[np.ndarray]:
    """Epoch-shaped stream ``(batches_per_epoch, lookups_per_batch)`` whose
    hot set rotates once at epoch ``shift_at`` (phase 0 before, 1 after)."""
    s = PhaseShiftSampler(spec, rotate_by=rotate_by, seed=seed)
    for e in range(n_epochs):
        phase = int(e >= shift_at)
        yield np.stack([s.sample(spec.lookups_per_batch, phase=phase)
                        for _ in range(batches_per_epoch)])


def _distribution_stats(spec: DLRMTraceSpec, probs: np.ndarray,
                        n_batches: int) -> dict:
    p = np.sort(probs)[::-1]
    total_lookups = spec.lookups_per_batch * n_batches
    exp_unique = float(np.sum(1.0 - np.exp(-total_lookups * p)))
    k = min(spec.k_hot_paper, spec.n_pages)
    return {
        "table_gb": spec.table_bytes / 1e9,
        "touched_fraction": exp_unique / spec.n_pages,
        "touched_gb": exp_unique * spec.page_bytes / 1e9,
        "topk_traffic_share": float(p[:k].sum()),
        "traffic_gb_per_batch": spec.lookups_per_batch * spec.row_bytes / 1e9,
    }


def trace_stats(spec: DLRMTraceSpec, n_batches: int = 20, seed: int = 0,
                phases: Optional[int] = None,
                rotate_by: Optional[int] = None) -> dict:
    """Measured analogues of the paper's dataset stats (computed analytically
    from the popularity distribution; exact in expectation).

    With ``phases`` the trace is a :class:`PhaseShiftSampler` and the result
    gains a ``"phases"`` list with the hot-head drift each rotation causes —
    ``hot_overlap_prev`` / ``hot_overlap_phase0`` (fraction of the hot head
    of size ``k_head`` shared with the previous phase / phase 0; 1.0 means
    the rotation wrapped to an identity, 0.0 a fully disjoint hot head).
    The distribution stats are reported once: a rotation only permutes the
    same Zipf mass onto a different support, so they are identical in every
    phase.  The head is the paper's promoted count capped at a tenth of the
    table, so the drift stays meaningful for reduced specs whose page count
    is below ``k_hot_paper``.  ``rotate_by`` is modular, so values >=
    ``n_pages`` wrap."""
    if phases is None:
        s = ZipfPageSampler(spec, seed)
        return _distribution_stats(spec, s.page_probabilities(), n_batches)
    ps = PhaseShiftSampler(spec, rotate_by=rotate_by, seed=seed)
    k = min(spec.k_hot_paper, max(spec.n_pages // 10, 1))
    out = _distribution_stats(spec, ps.page_probabilities(0), n_batches)
    out["rotate_by"] = ps.rotate_by
    out["k_head"] = k
    out["phases"] = []
    hot0 = prev = ps.true_top_k_pages(k, phase=0)
    for phase in range(int(phases)):
        hot = ps.true_top_k_pages(k, phase=phase)
        out["phases"].append({
            "phase": phase,
            "hot_overlap_prev": float(np.intersect1d(hot, prev).size / k),
            "hot_overlap_phase0": float(np.intersect1d(hot, hot0).size / k),
        })
        prev = hot
    return out
