"""Trace-driven reproduction of the paper's two evaluations.

* ``run_fig3``   — mmap-bench: hotness CDF + PEBS/NB accuracy+coverage and the
  resulting tiering speedups (paper: HMU 2.94x vs PEBS, 1.73x vs NB).
* ``run_table1`` — DLRM embedding-bag inference: HMU vs Linux NB vs DRAM-only
  (paper: 1.94x vs NB, 1.03x slower than DRAM-only, 9% top-tier footprint).
* ``run_online`` — the §VI online regime: the EpochRuntime drives all six
  policies (incl. the hint-fed ``hinted``/``prefetch`` lanes when
  ``hints=True``) over a phase-shifting DLRM trace and returns the per-epoch
  trajectory (time / accuracy / coverage series instead of one end state).
  Since the scenario-layer refactor this is a thin re-export of
  :func:`repro.scenarios.dlrm.run_online` — the DLRM packaging of the
  workload-agnostic :func:`repro.scenarios.run_scenario` driver.

Both run at full paper scale (5.24 M / 2.62 M pages) as *trace* sims: no 20 GB
table is allocated, only per-page counters — exactly the device-side view the
CXL Data Logger provides.

Linux NB is modeled with three handicaps, each traceable to kernel behaviour
(Documentation/mm/numa_balancing; mm/migrate.c):

1. **Saturating hotness signal.**  NB sees hint faults, not accesses: a page
   faults at most once per scan pass and the kernel keeps only the last two
   fault records, so fault counts saturate (cap 2) and every page touched
   soon after each unmap looks identical — ranking among candidates is
   frequency-blind ("NB lacks accuracy / misclassifies super-hot pages").
2. **Promotion throttle + address order.**  Promotion happens on fault
   arrival, throttled at `numa_balancing_promote_rate_limit` (256 MB/s
   default), and the scanner walks VMAs by *address*, so promotion order is
   uncorrelated with hotness.  HMU's oracle methodology batch-promotes the
   exact top-K up-front instead; NB is still mid-ramp during measurement
   ("for fairness, NB had two iterations to promote hot candidates").
3. **Hint-fault tax.**  NB keeps scanning during the measured phase; each
   hint fault costs a trap + bookkeeping.  HMU collects in the memory
   device: zero host-side tax (paper §V).

PEBS is handicapped only by its sampling period (coverage), per the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from ..core import metrics, telemetry as tel
from ..core.costmodel import CXL_SYSTEM, MemSystem
from ..core.manager import TieringManager
from ..scenarios.dlrm import run_online  # noqa: F401  (thin re-export)
from ..workloads import mmap_bench
from . import datagen

# Cost of servicing one NUMA hint fault (trap, rmap walk, task_numa_fault,
# TLB invalidation share) — well-documented AutoNUMA overhead, ~1-3 us.
NB_FAULT_COST_S = 2e-6
# Kernel keeps two fault records per page -> counts saturate at 2.
NB_FAULT_CAP = 2
# numa_balancing_promote_rate_limit_MBps default.
NB_PROMOTE_BYTES_PER_S = 256e6
# Scanner unmap rate: 256 MB per 100 ms scan window (task_numa_work defaults)
# -> ceiling on hint-fault rate while a promotion backlog keeps scanning on.
NB_SCAN_UNMAP_PAGES_PER_S = 625_000.0


def nb_fault_tax_s(
    elapsed_s: float,
    touch_rate_pages_per_s: float,
    scan_pages_per_s: float = NB_SCAN_UNMAP_PAGES_PER_S,
) -> float:
    """Hint-fault servicing time the workload pays while NB keeps scanning:
    fault rate = min(rate pages are (re)touched, scanner unmap rate).  The
    scanner rate is adaptive in Linux (scan_period 100ms..60s); callers pick a
    point in that range per workload phase."""
    rate = min(touch_rate_pages_per_s, scan_pages_per_s)
    return elapsed_s * rate * NB_FAULT_COST_S


@dataclasses.dataclass
class MethodRow:
    name: str
    avg_inference_us: float
    pages_promoted: int
    top_tier_gb: float
    speed_vs_nb: float
    accuracy: float
    coverage: float
    host_events: int
    migration_s: float = 0.0


def nb_select(
    faults: np.ndarray, k: int, fault_cap: int = NB_FAULT_CAP, seed: int = 0
) -> np.ndarray:
    """NB candidates: two-touch, ranked by saturated fault count, ties broken
    blindly; returned in *promotion (address/scan) order*, i.e. shuffled."""
    rng = np.random.default_rng(seed)
    cand = np.nonzero(faults >= 2)[0]
    if cand.size == 0:
        return cand
    sat = np.minimum(faults[cand], fault_cap)
    tie = rng.permutation(cand.size)
    order = np.lexsort((tie, -sat))
    chosen = cand[order[: min(k, cand.size)]]
    return rng.permutation(chosen)  # promotion arrives in address order


def _mask(n: int, ids: np.ndarray) -> np.ndarray:
    m = np.zeros((n,), bool)
    if ids.size:
        m[ids] = True
    return m


def _mem_time_s(system, counts, fast_mask, bpa) -> float:
    n_fast = float(counts[fast_mask].sum())
    n_slow = float(counts.sum()) - n_fast
    return system.access_time_s(n_fast, n_slow, bpa)


# =====================================================================  Table 1
def run_table1(
    spec: datagen.DLRMTraceSpec = datagen.PAPER,
    system: MemSystem = CXL_SYSTEM,
    warmup_iterations: int = 2,   # the paper's "two iterations"
    batches_per_iteration: int = 20,
    eval_batches: int = 30,
    k_hot: Optional[int] = None,
    nb_throttle_bytes_per_s: float = NB_PROMOTE_BYTES_PER_S,
    dram_only_target_us: float = 63_324.0,    # calibrates non-memory compute time
    seed: int = 0,
) -> Dict[str, MethodRow]:
    n_pages = spec.n_pages
    k = min(k_hot if k_hot is not None else spec.k_hot_paper, n_pages)
    warmup_batches = warmup_iterations * batches_per_iteration
    # NB completes one scan pass per iteration (needs >=2 for two-touch).
    scan_rate = max(n_pages // batches_per_iteration, 1)
    mgr = TieringManager(n_pages, k, nb_scan_rate=scan_rate)
    sampler = datagen.ZipfPageSampler(spec, seed)

    # ---- warmup/profiling: allocations in CXL, collectors observe.
    # Fused path: each iteration's batches are observed in ONE jit dispatch
    # (lax.scan over the batch axis) — bit-identical to per-batch observe.
    for _ in range(warmup_iterations):
        mgr.observe_epoch(np.stack([
            sampler.sample(spec.lookups_per_batch)
            for _ in range(batches_per_iteration)]))
    mgr.hmu = tel.hmu_drain_cost(mgr.hmu)

    # ---- eval traffic (expectation replay of the stationary distribution)
    probs = sampler.page_probabilities()
    per_batch = probs * spec.lookups_per_batch
    true_hot = metrics.true_top_k(per_batch, k)

    hmu_counts = np.asarray(tel.hmu_estimate(mgr.hmu))
    hmu_sel = np.argsort(-hmu_counts, kind="stable")[:k]
    hmu_sel = hmu_sel[hmu_counts[hmu_sel] > 0]
    nb_sel = nb_select(np.asarray(tel.nb_estimate(mgr.nb)), k, seed=seed)

    bpa = float(spec.row_bytes)
    mem_all_fast = _mem_time_s(system, per_batch, np.ones((n_pages,), bool), bpa)
    compute_base_s = dram_only_target_us * 1e-6 - mem_all_fast
    assert compute_base_s > 0, "cost model: memory time exceeds calibration target"

    rows: Dict[str, MethodRow] = {}

    def add(name, t_s, promoted, host, migration_s=0.0):
        rows[name] = MethodRow(
            name=name, avg_inference_us=t_s * 1e6,
            pages_promoted=int(promoted.size),
            top_tier_gb=promoted.size * spec.page_bytes / 1e9,
            speed_vs_nb=0.0,
            accuracy=metrics.accuracy(promoted, true_hot) if promoted.size else 0.0,
            coverage=metrics.coverage(promoted, true_hot, k),
            host_events=host, migration_s=migration_s,
        )

    # HMU: exact top-K batch-promoted after warmup (oracle methodology).
    t_hmu = compute_base_s + _mem_time_s(system, per_batch, _mask(n_pages, hmu_sel), bpa)
    add("hmu", t_hmu, hmu_sel, int(float(mgr.hmu.host_events)),
        migration_s=system.migration_time_s(hmu_sel.size, spec.page_bytes))

    add("dram-only", compute_base_s + mem_all_fast, np.arange(n_pages), 0)
    rows["dram-only"].top_tier_gb = spec.table_bytes / 1e9
    t_cxl = compute_base_s + _mem_time_s(system, per_batch, np.zeros((n_pages,), bool), bpa)
    add("cxl-only", t_cxl, np.empty((0,), np.int64), 0)

    # NB: throttled promotion in address order, ramping through the eval.
    # Candidates only confirm (two-touch) during the second scan pass, so the
    # promotion clock starts one iteration into the warmup.
    ramp_elapsed = max(warmup_batches - batches_per_iteration, 0) * t_cxl
    migrated = min(nb_throttle_bytes_per_s * ramp_elapsed,
                   nb_sel.size * spec.page_bytes)
    nb_mask = np.zeros((n_pages,), bool)
    # page (re)touch rate: pages touched per iteration / iteration wall time
    touched_per_iter = float(np.sum(1.0 - np.exp(-per_batch * batches_per_iteration)))
    total_t, eval_faults = 0.0, 0.0
    for _ in range(eval_batches):
        nb_mask[nb_sel[: int(migrated // spec.page_bytes)]] = True
        t = compute_base_s + _mem_time_s(system, per_batch, nb_mask, bpa)
        touch_rate = touched_per_iter / (t * batches_per_iteration)
        tax = nb_fault_tax_s(t, touch_rate)
        eval_faults += tax / NB_FAULT_COST_S
        t += tax
        total_t += t
        migrated = min(migrated + nb_throttle_bytes_per_s * t,
                       nb_sel.size * spec.page_bytes)
    add("nb", total_t / eval_batches, np.nonzero(nb_mask)[0],
        int(float(mgr.nb.host_events) + eval_faults))

    for r in rows.values():
        r.speed_vs_nb = rows["nb"].avg_inference_us / r.avg_inference_us
    return rows


# =====================================================================  Fig. 3
def run_fig3(
    spec: mmap_bench.MmapBenchSpec = mmap_bench.PAPER,
    system: MemSystem = CXL_SYSTEM,
    total_accesses: int = 180_000_000,
    pebs_period: int = 10007,
    nb_scan_passes: float = 16.0,
    n_batches: int = 64,
    nb_throttle_bytes_per_s: float = NB_PROMOTE_BYTES_PER_S,
    nb_eval_scan_pages_per_s: float = 150_000.0,   # steady-state adaptive rate
    nb_profile_credit: float = 0.4,   # fraction of the profile run in which NB
                                      # promotes (scan_delay + two-touch lag)
    nb_fault_cap: int = 12,           # windows the latency threshold resolves
    seed: int = 0,
) -> dict:
    """mmap-bench: profile the full run, promote per strategy, then replay.
    Performance metric is reads/second (latency-bound random access).  NB's
    placement ramps at the kernel throttle during the measured replay."""
    n_pages, k = spec.n_pages, spec.k_hot
    scan_rate = max(int(n_pages * nb_scan_passes / n_batches), 1)
    mgr = TieringManager(n_pages, k, pebs_period=pebs_period, nb_scan_rate=scan_rate)
    batch = total_accesses // n_batches
    for pages in mmap_bench.access_stream(spec, total_accesses, batch=batch, seed=seed):
        mgr.observe(pages)
    mgr.hmu = tel.hmu_drain_cost(mgr.hmu)

    true_hot = mmap_bench.true_hot_pages(spec)
    counts = mgr.true_counts
    reads = float(counts.sum())
    bpa = float(spec.access_bytes)

    hmu_counts = np.asarray(tel.hmu_estimate(mgr.hmu))
    hmu_sel = np.argsort(-hmu_counts, kind="stable")[:k]
    pebs_est = np.asarray(tel.pebs_estimate(mgr.pebs))
    pebs_ids = np.argsort(-pebs_est, kind="stable")
    pebs_sel = pebs_ids[pebs_est[pebs_ids] > 0][:k]
    # With short scan windows (16 passes) per-pass fault counts resolve the
    # hot/cold frequency contrast (the fault-latency threshold in kernel
    # terms), so rank with cap = pass count.
    nb_sel = nb_select(np.asarray(tel.nb_estimate(mgr.nb)), k,
                       fault_cap=nb_fault_cap, seed=seed)

    out = {
        "hotness": {
            "pages_for_90pct": metrics.pages_for_access_fraction(counts, 0.90),
            "cdf": metrics.hotness_cdf(counts, n_points=20),
        },
        "methods": {},
    }
    host = {
        "hmu": int(float(mgr.hmu.host_events)),
        "pebs": int(float(mgr.pebs.host_events)),
        "nb": int(float(mgr.nb.host_events)),
    }

    # HMU/PEBS: batch-promote up-front, steady-state replay.
    for name, ids in (("hmu", hmu_sel), ("pebs", pebs_sel)):
        t = _mem_time_s(system, counts, _mask(n_pages, ids), bpa)
        out["methods"][name] = {
            "reads_per_s": reads / t,
            "accuracy": metrics.accuracy(ids, true_hot),
            "coverage": metrics.coverage(ids, true_hot, k),
            "promoted": int(ids.size), "host_events": host[name],
        }

    # NB: replay in chunks with the promotion ramp + fault tax (scan-capped:
    # mmap-bench touches pages far faster than the scanner unmaps them).
    # Promotion credit accrues during the profiling run (the same workload is
    # executing while the kernel migrates at the throttle rate).
    nb_mask = np.zeros((n_pages,), bool)
    t_profile = _mem_time_s(system, counts, nb_mask, bpa)
    t_profile += nb_fault_tax_s(t_profile, float("inf"), nb_eval_scan_pages_per_s)
    migrated = min(nb_throttle_bytes_per_s * t_profile * nb_profile_credit,
                   nb_sel.size * spec.page_bytes)
    total_t, eval_faults = 0.0, 0.0
    chunk_counts = counts / n_batches
    for _ in range(n_batches):
        nb_mask[nb_sel[: int(migrated // spec.page_bytes)]] = True
        t = _mem_time_s(system, chunk_counts, nb_mask, bpa)
        tax = nb_fault_tax_s(t, float("inf"), nb_eval_scan_pages_per_s)
        eval_faults += tax / NB_FAULT_COST_S
        t += tax
        total_t += t
        migrated = min(migrated + nb_throttle_bytes_per_s * t,
                       nb_sel.size * spec.page_bytes)
    nb_final = np.nonzero(nb_mask)[0]
    out["methods"]["nb"] = {
        "reads_per_s": reads / total_t,
        "accuracy": metrics.accuracy(nb_final, true_hot),
        "coverage": metrics.coverage(nb_final, true_hot, k),
        "promoted": int(nb_final.size),
        "host_events": host["nb"] + int(eval_faults),
    }

    for name, mask in (("dram-only", np.ones((n_pages,), bool)),
                       ("cxl-only", np.zeros((n_pages,), bool))):
        out["methods"][name] = {
            "reads_per_s": reads / _mem_time_s(system, counts, mask, bpa),
            "accuracy": 1.0, "coverage": 1.0,
            "promoted": int(mask.sum()), "host_events": 0,
        }
    m = out["methods"]
    m["hmu"]["speedup_vs_pebs"] = m["hmu"]["reads_per_s"] / m["pebs"]["reads_per_s"]
    m["hmu"]["speedup_vs_nb"] = m["hmu"]["reads_per_s"] / m["nb"]["reads_per_s"]
    out["overlap_nb_hmu"] = metrics.overlap(nb_final, hmu_sel, k)
    return out


# =====================================================================  online
# run_online lives in repro.scenarios.dlrm (the DLRM packaging of the
# workload-agnostic scenario driver); imported above for compatibility.
