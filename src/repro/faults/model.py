"""Fault-model pytrees: what can go wrong with a telemetry collector.

The paper's limits argument is that tiering quality is bounded by what the
telemetry can actually deliver — PEBS coverage bounded by its sampling
period, NB seeing recency instead of frequency, HMU logs overflowing.  The
seed collectors modeled only the last of those; everything else was
perfectly reliable.  This module is the configuration half of closing that
gap (the injection itself lives in ``repro.core.telemetry``, on device,
inside the fused observe path):

* :class:`FaultModel` — a pytree of fault knobs plus the mutable fault
  state (PRNG key, drop/reset/stall counters).  All rates are **traced
  leaves**, so sweeping a fault rate re-uses one compiled epoch program;
  only ``stale_epochs`` (a buffer shape) and the RNG seed are static.
  A default-constructed model is *neutral*: every knob at its no-op value,
  bit-identical records to running with no model at all — the invariant
  the CI ``--faults`` gate pins.
* :class:`Hardening` — the degradation-aware runtime config consumed by
  ``core.runtime``: demotion hysteresis depth, per-lane collector
  fallbacks, and the quality floor/smoothing that drive the branchless
  ``jnp.where`` input swap.
* :class:`Counter64` — an exact hi/lo int32 pair for scalar event
  counters.  float32 scalars silently stop incrementing past 2**24
  (adding 1 to 16 777 216.0 is a no-op), which paper-scale runs exceed
  within one run; x64 is disabled, so exactness comes from carrying the
  value in two int32 words (the same idiom as the PEBS int32 cursor).

Nothing here imports ``repro.core`` — the dependency points the other way
(``core.telemetry`` injects these models), so the package stays a leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Sequence, Tuple, Union

import numpy as np
import jax
import jax.numpy as jnp

__all__ = [
    "COLLECTORS", "Counter64", "FaultModel", "Hardening", "LANE_COLLECTOR",
    "counter_add", "counter_init", "counter_scaled_add", "counter_zero_like",
]

# Collector order used everywhere a (3,)-shaped fault/quality array appears.
COLLECTORS = ("hmu", "pebs", "nb")

INT32_MAX = int(np.iinfo(np.int32).max)

# ====================================================  exact hi/lo counters
# lo carries the low CARRY_BITS of the value, hi the rest:
#   value == hi * 2**CARRY_BITS + lo,   0 <= lo < 2**CARRY_BITS.
# 24 bits keeps every intermediate (lo + delta, small scaled adds) inside
# int32 while mirroring exactly the boundary float32 breaks at.
CARRY_BITS = 24
CARRY_BASE = 1 << CARRY_BITS


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Counter64:
    """Exact scalar event counter as a hi/lo int32 pair.

    The seed carried HMU ``log_used``/``log_dropped``/``host_events`` as
    float32 scalars, which are exact only below 2**24: a 256 GB log holds
    billions of records, so paper-scale runs silently stopped counting.
    Two int32 words hold the value exactly to 2**53-ish (host reads combine
    them in float64 / Python int, both exact at any realistic count).
    """
    hi: jax.Array                # () int32: value >> CARRY_BITS
    lo: jax.Array                # () int32: value & (CARRY_BASE - 1)

    def value(self) -> int:
        """Exact host-side read (concrete arrays only)."""
        return int(self.hi) * CARRY_BASE + int(self.lo)

    def __float__(self) -> float:
        return float(self.value())

    def __int__(self) -> int:
        return self.value()


def counter_init() -> Counter64:
    # distinct arrays (not one shared buffer) so donation works
    return Counter64(hi=jnp.zeros((), jnp.int32), lo=jnp.zeros((), jnp.int32))


def counter_zero_like(c: Counter64) -> Counter64:
    return Counter64(hi=jnp.zeros_like(c.hi), lo=jnp.zeros_like(c.lo))


def counter_add(c: Counter64, n) -> Counter64:
    """``c + n`` for a non-negative int32 delta ``n`` (traced or static),
    ``n < 2**30`` so ``lo + n`` cannot overflow int32 before the carry."""
    lo2 = c.lo + jnp.asarray(n, jnp.int32)
    return Counter64(hi=c.hi + (lo2 >> CARRY_BITS),
                     lo=lo2 & (CARRY_BASE - 1))


def counter_scaled_add(c: Counter64, other: Counter64, scale: int) -> Counter64:
    """``c + other * scale`` for a small static non-negative int ``scale``
    (bounded so ``other.lo * scale`` stays inside int32)."""
    scale = int(scale)
    if not 0 <= scale < 64:
        raise ValueError(f"scale must be a small non-negative int "
                         f"(0 <= scale < 64), got {scale!r}")
    lo2 = c.lo + other.lo * scale
    return Counter64(hi=c.hi + other.hi * scale + (lo2 >> CARRY_BITS),
                     lo=lo2 & (CARRY_BASE - 1))


# ==========================================================  the fault model
def _rate_leaf(p, n_blocks: Optional[int], name: str) -> jax.Array:
    """Probability knob as a traced f32 leaf: scalar, or per-block for
    per-tenant fault profiles (``FaultModel.for_segments``)."""
    arr = jnp.asarray(p, jnp.float32)
    if arr.ndim not in (0, 1):
        raise ValueError(f"{name} must be a scalar or (n_blocks,) array, "
                         f"got shape {arr.shape}")
    if arr.ndim == 1 and n_blocks is not None and arr.shape[0] != n_blocks:
        raise ValueError(f"{name} per-block array has {arr.shape[0]} entries, "
                         f"expected n_blocks={n_blocks}")
    vals = np.asarray(arr)
    if vals.size and (vals.min() < 0.0 or vals.max() > 1.0):
        raise ValueError(f"{name} is a probability and must lie in [0, 1], "
                         f"got range [{vals.min()}, {vals.max()}]")
    return arr


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Collector fault knobs + mutable fault state, injected on device.

    Config leaves (traced, so a fault-rate sweep shares one epoch trace):

    * ``hmu_counter_max`` — HMU counters saturate at this value instead of
      wrapping int32 (scalar or per-block).  ``2**bits - 1`` for a
      ``bits``-wide hardware counter; int32 max is the neutral value.
    * ``pebs_drop_p``    — each would-be PEBS sample is lost before the
      host sees it with this probability (scalar or per-block): the
      paper's point that sampling beyond the period is *also* lossy.
    * ``reset_p``        — (3,) per-collector probability, once per epoch,
      that the collector's cumulative signal state resets to empty
      (models drain races: the consumer and the collector disagree about
      what was already read).
    * ``nb_stall_p``     — per-batch probability the NB scanner makes no
      progress (no unmapping, no cursor advance): ``task_numa_work``
      skipping its slice under load.

    Static: ``stale_epochs`` (policy estimates are served from a ring
    buffer this many epochs deep — a shape) and ``seed``.

    Mutable leaves (updated inside the fused observe path): the PRNG key
    and the degradation counters the quality machinery / benchmarks read
    back — ``pebs_dropped`` (exact :class:`Counter64`), per-collector
    ``resets``, and ``nb_stalls``.
    """
    hmu_counter_max: jax.Array       # () or (n_blocks,) int32 saturation cap
    pebs_drop_p: jax.Array           # () or (n_blocks,) f32
    reset_p: jax.Array               # (3,) f32 — COLLECTORS order
    nb_stall_p: jax.Array            # () f32
    key: jax.Array                   # PRNG key (uint32 pair)
    pebs_dropped: Counter64          # events lost to Bernoulli drops
    resets: jax.Array                # (3,) int32 — resets applied so far
    nb_stalls: jax.Array             # () int32 — stalled scanner ticks
    stale_epochs: int = dataclasses.field(metadata=dict(static=True))
    seed: int = dataclasses.field(metadata=dict(static=True))

    @classmethod
    def create(
        cls,
        hmu_counter_bits: int = 31,
        pebs_drop_p=0.0,
        reset_p=0.0,
        nb_stall_p: float = 0.0,
        stale_epochs: int = 0,
        seed: int = 0,
        n_blocks: Optional[int] = None,
        hmu_counter_max=None,
    ) -> "FaultModel":
        """Build a model from human-sized knobs.  All defaults are the
        neutral no-op values — ``FaultModel.create()`` must be (and is
        CI-gated to be) bit-identical to running without a model.

        ``reset_p`` is a scalar (same rate for all three collectors) or a
        3-sequence in :data:`COLLECTORS` order.  ``pebs_drop_p`` and
        ``hmu_counter_max`` may be per-block arrays (see
        :meth:`for_segments`); pass ``n_blocks`` to validate their length.
        """
        if hmu_counter_max is None:
            bits = int(hmu_counter_bits)
            if not 1 <= bits <= 31:
                raise ValueError(f"hmu_counter_bits must be in [1, 31], "
                                 f"got {hmu_counter_bits!r}")
            hmu_counter_max = (1 << bits) - 1
        cap = jnp.asarray(hmu_counter_max, jnp.int32)
        if cap.ndim == 1 and n_blocks is not None and cap.shape[0] != n_blocks:
            raise ValueError(f"hmu_counter_max per-block array has "
                             f"{cap.shape[0]} entries, expected {n_blocks}")
        rp = np.asarray(reset_p, np.float32)
        if rp.ndim == 0:
            rp = np.full((3,), rp, np.float32)
        if rp.shape != (3,):
            raise ValueError(f"reset_p must be a scalar or one rate per "
                             f"collector {COLLECTORS}, got shape {rp.shape}")
        stale = int(stale_epochs)
        if stale < 0:
            raise ValueError(f"stale_epochs must be >= 0, got {stale_epochs!r}")
        return cls(
            hmu_counter_max=cap,
            pebs_drop_p=_rate_leaf(pebs_drop_p, n_blocks, "pebs_drop_p"),
            reset_p=jnp.asarray(rp),
            nb_stall_p=jnp.asarray(float(nb_stall_p), jnp.float32),
            key=jax.random.PRNGKey(int(seed)),
            pebs_dropped=counter_init(),
            resets=jnp.zeros((3,), jnp.int32),
            nb_stalls=jnp.zeros((), jnp.int32),
            stale_epochs=stale,
            seed=int(seed),
        )

    @classmethod
    def for_segments(
        cls,
        offsets: Sequence[int],
        profiles: Sequence[Optional[dict]],
        **global_kwargs,
    ) -> "FaultModel":
        """Per-segment fault profile over one shared block space — the
        fleet's per-tenant degradation.  ``offsets`` are the cumulative
        segment bounds (length T+1, same convention as ``runtime.Tenancy``);
        ``profiles[t]`` is a dict of *per-block-expressible* knobs for
        segment ``t`` (``pebs_drop_p``, ``hmu_counter_bits`` /
        ``hmu_counter_max``) or None for a healthy segment.  Collector-wide
        knobs (``reset_p``, ``nb_stall_p``, ``stale_epochs``, ``seed``) are
        global — a drain race or a stalled scanner hits every tenant — and
        come in through ``global_kwargs``."""
        offsets = tuple(int(o) for o in offsets)
        if len(offsets) != len(profiles) + 1:
            raise ValueError(f"need len(offsets) == len(profiles) + 1, got "
                             f"{len(offsets)} offsets for {len(profiles)} "
                             f"profiles")
        n_blocks = offsets[-1]
        drop_p = np.zeros((n_blocks,), np.float32)
        cap = np.full((n_blocks,), INT32_MAX, np.int32)
        per_block_keys = {"pebs_drop_p", "hmu_counter_bits", "hmu_counter_max"}
        for t, prof in enumerate(profiles):
            if prof is None:
                continue
            unknown = set(prof) - per_block_keys
            if unknown:
                raise ValueError(
                    f"segment profile {t} has non-per-block knobs "
                    f"{sorted(unknown)}; collector-wide knobs (reset_p, "
                    f"nb_stall_p, stale_epochs, seed) are global kwargs")
            sl = slice(offsets[t], offsets[t + 1])
            if "pebs_drop_p" in prof:
                drop_p[sl] = float(prof["pebs_drop_p"])
            if "hmu_counter_max" in prof:
                cap[sl] = int(prof["hmu_counter_max"])
            elif "hmu_counter_bits" in prof:
                cap[sl] = (1 << int(prof["hmu_counter_bits"])) - 1
        return cls.create(hmu_counter_max=cap, pebs_drop_p=drop_p,
                          n_blocks=n_blocks, **global_kwargs)


# ======================================================  hardening config
# Which collector each policy lane's decision input comes from (the prefetch
# lane runs on compiler hints, not a collector — it has nothing to fall back
# from and never degrades with the telemetry).
LANE_COLLECTOR: Dict[str, Optional[str]] = {
    "hmu_oracle": "hmu",
    "reactive_watermark": "hmu",
    "proactive_ewma": "hmu",
    "nb_two_touch": "nb",
    "hinted": "pebs",
    "prefetch": None,
}


def collector_for_lane(lane: str) -> Optional[str]:
    """The collector feeding ``lane``'s decisions (``None`` for lanes that
    consume no telemetry, e.g. ``prefetch``).  Exported telemetry records
    carry this so downstream quality dashboards can join per-lane outcomes
    against per-collector fault state."""
    return LANE_COLLECTOR.get(lane)


class Hardening(NamedTuple):
    """Degradation-aware runtime config (static; baked into the fused trace).

    * ``demote_hysteresis`` — a resident block must look cold for this many
      *consecutive* epochs before watermark demotion frees it (H=1 is the
      seed behaviour).  Lossy telemetry makes a hot block look cold for an
      epoch; without hysteresis one dropped sample costs two migrations.
    * ``fallback`` — ``(lane, collector)`` pairs: when the lane's primary
      collector's smoothed quality drops below ``quality_floor``, the
      lane's decision input is swapped — branchlessly, ``jnp.where`` on
      the quality scalar — to the named healthy collector's estimate.
    * ``quality_floor`` / ``quality_beta`` — the swap threshold and the
      EWMA smoothing of the per-collector observed-mass quality signal.

    Use :meth:`make` to build from a ``{lane: collector}`` dict.
    """
    demote_hysteresis: int = 1
    fallback: Tuple[Tuple[str, str], ...] = ()
    quality_floor: float = 0.5
    quality_beta: float = 0.5

    @classmethod
    def make(cls, fallback: Optional[Dict[str, str]] = None,
             demote_hysteresis: int = 1, quality_floor: float = 0.5,
             quality_beta: float = 0.5) -> "Hardening":
        items = (fallback.items() if isinstance(fallback, dict)
                 else (fallback or ()))
        pairs = tuple(sorted(dict(items).items()))
        h = cls(demote_hysteresis=int(demote_hysteresis), fallback=pairs,
                quality_floor=float(quality_floor),
                quality_beta=float(quality_beta))
        h.validate()
        return h

    def validate(self) -> None:
        if self.demote_hysteresis < 1:
            raise ValueError(f"demote_hysteresis must be >= 1, got "
                             f"{self.demote_hysteresis!r}")
        if not 0.0 <= self.quality_floor <= 1.0:
            raise ValueError(f"quality_floor must be in [0, 1], got "
                             f"{self.quality_floor!r}")
        if not 0.0 < self.quality_beta <= 1.0:
            raise ValueError(f"quality_beta must be in (0, 1], got "
                             f"{self.quality_beta!r}")
        for lane, col in self.fallback:
            if lane not in LANE_COLLECTOR:
                raise ValueError(f"unknown fallback lane {lane!r}; choose "
                                 f"from {sorted(LANE_COLLECTOR)}")
            if LANE_COLLECTOR[lane] is None:
                raise ValueError(f"lane {lane!r} runs on compiler hints, "
                                 f"not a collector — nothing to fall back "
                                 f"from")
            if col not in COLLECTORS:
                raise ValueError(f"unknown fallback collector {col!r}; "
                                 f"choose from {COLLECTORS}")
            if col == LANE_COLLECTOR[lane]:
                raise ValueError(f"lane {lane!r} already reads {col!r}; a "
                                 f"fallback must name a different collector")
