"""repro.faults — telemetry fault injection + degradation-aware tiering.

The collectors in ``repro.core.telemetry`` were perfectly reliable: the only
modeled fault was HMU log overflow, and no policy lane reacted to degraded
signal quality.  Real telemetry at terabyte scale is lossy, stale, and
approximate (Telescope), and real tiering systems fall back when a proactive
signal goes bad (TPP).  This package supplies both halves:

* :class:`FaultModel` — what can go wrong, as a pytree injected **on device
  inside the fused observe path**: HMU counter-width saturation, Bernoulli
  PEBS sample drops, seeded per-collector reset events (drain races),
  NB scan-cursor stalls, and a ``stale_epochs``-deep delay on the estimates
  the policies see.  Fault rates are traced leaves (sweeps share one trace);
  a default-constructed model is bit-identical to running with none.
* :class:`Hardening` — how the runtime degrades gracefully: demotion
  hysteresis (H consecutive cold epochs before a watermark demotion), and a
  branchless per-lane fallback that swaps a lane's decision input to a
  healthy collector when the primary's observed-mass quality (tracked on
  device, EWMA-smoothed) drops below a floor.
* :class:`Counter64` — exact hi/lo int32 scalar counters replacing the
  float32 event scalars that silently stopped incrementing past 2**24.

Entry points: ``EpochRuntime(faults=, hardening=)``,
``run_scenario(faults=, hardening=)``, ``run_fleet(faults=, hardening=)``
with per-tenant profiles via :meth:`FaultModel.for_segments`, the
``benchmarks/run.py --faults`` sweep, and ``examples/degraded_telemetry.py``.
"""
from .model import (
    COLLECTORS, Counter64, FaultModel, Hardening, LANE_COLLECTOR,
    counter_add, counter_init, counter_scaled_add, counter_zero_like,
)

__all__ = [
    "COLLECTORS", "Counter64", "FaultModel", "Hardening", "LANE_COLLECTOR",
    "counter_add", "counter_init", "counter_scaled_add", "counter_zero_like",
]
