"""Paper workloads: mmap-bench (§III.A) and the DLRM embedding trace (§III.B)."""
