"""mmap-bench (paper §III.A): 10 GiB region, 1 GiB hot for 90% of accesses.

"The mmap-bench microbenchmark allocates 10 GiB of memory, with 1 GiB being
accessed for 90% of the execution.  Within this frequently accessed region,
the precise number of pages eligible for promotion is K = 262,144 (4 KiB)
pages."

We reproduce it as an access *stream* at page granularity (the Data Logger's
view: physical page addresses), so a full paper-scale run needs only a few
hundred MB of trace batches, not 10 GiB of data.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

PAGE_BYTES = 4096


@dataclasses.dataclass(frozen=True)
class MmapBenchSpec:
    total_bytes: int = 10 << 30          # 10 GiB
    hot_bytes: int = 1 << 30             # 1 GiB hot region
    hot_access_fraction: float = 0.9     # 90% of accesses hit the hot region
    page_bytes: int = PAGE_BYTES
    access_bytes: int = 64               # one cacheline per access (CXL.mem flit)

    @property
    def n_pages(self) -> int:
        return self.total_bytes // self.page_bytes

    @property
    def k_hot(self) -> int:
        """K — pages eligible for promotion (the paper's 262,144)."""
        return self.hot_bytes // self.page_bytes


# Reduced spec for CI-speed tests: same shape, 4096x smaller.
SMALL = MmapBenchSpec(total_bytes=10 << 18, hot_bytes=1 << 18)
PAPER = MmapBenchSpec()


def access_stream(
    spec: MmapBenchSpec,
    total_accesses: int,
    batch: int = 1 << 21,
    seed: int = 0,
) -> Iterator[np.ndarray]:
    """Ground-truth page-id stream: Bernoulli(hot_fraction) region choice,
    uniform within each region (the paper's benchmark touches the hot GiB
    uniformly — skew across pages comes from the region split)."""
    rng = np.random.default_rng(seed)
    n_hot = spec.k_hot
    n_pages = spec.n_pages
    remaining = total_accesses
    while remaining > 0:
        n = min(batch, remaining)
        hot = rng.random(n) < spec.hot_access_fraction
        pages = np.where(
            hot,
            rng.integers(0, n_hot, n),
            rng.integers(n_hot, n_pages, n),
        ).astype(np.int32)
        yield pages
        remaining -= n


def true_hot_pages(spec: MmapBenchSpec) -> np.ndarray:
    return np.arange(spec.k_hot, dtype=np.int32)
