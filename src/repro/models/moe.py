"""Top-k routed Mixture-of-Experts with capacity dropping + expert telemetry.

Two dispatch formulations:

* ``groups=(1, 1)`` (default, single-device/tests): global sort/scatter
  dispatch — simple, exact, no sharding assumptions.
* ``groups=(gd, gm)`` + ``expert_sharded`` (set by the launcher): the
  dispatch/expert/combine interior runs under **shard_map** with explicit
  all-to-alls on the model axis — exactly the routed-token bytes cross the
  wire (the EP communication floor).  Tokens enter sequence-sharded over
  "model" (and batch-sharded over the data axes); routing, slot assignment
  and the scatter are device-local.

  History (EXPERIMENTS.md §Perf): the naive global scatter formulation let
  GSPMD replicate the (E, C, D) dispatch buffer (62 TB collective wire
  bytes/device on kimi-k2 train_4k); a pure-with_sharding_constraint
  regrouping (A1) made backward resharding WORSE (290 TB, "involuntary full
  rematerialization").  Explicit collectives are the reliable contract.

Formulated with scatter/gather (not one-hot dispatch einsums) so the HLO
contains only true expert FLOPs.

Expert activation counters come out of the router for free — the MoE
analogue of the paper's HMU telemetry (the router *is* a memory-side access
monitor for expert weights), feeding the expert tiering manager.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp


def expert_access_batch(counts) -> np.ndarray:
    """Router telemetry -> the tiering runtime's access-batch format.

    ``counts`` is the ``aux["counts"]`` expert-activation histogram from
    :func:`moe_block` — ``(E,)`` for one layer or ``(L, E)`` stacked by the
    forward scan (layers are summed: expert banks are placed per expert id,
    one block spanning its weights in every layer).  Returns a flat int32
    stream of expert ids with multiplicity — the per-batch access stream an
    :class:`~repro.core.runtime.EpochRuntime` epoch stacks.  Its length is
    ``tokens * top_k * n_layers`` regardless of how routing is distributed,
    so every batch in an epoch has equal size by construction."""
    c = np.asarray(counts)
    if c.ndim == 2:
        c = c.sum(0)
    if c.ndim != 1:
        raise ValueError(f"counts must be (E,) or (L, E), got {c.shape}")
    return np.repeat(np.arange(c.shape[0], dtype=np.int32), c)


def _ambient_mesh():
    """The mesh installed by the caller's ``use_mesh``/``set_mesh`` context,
    portable across jax versions: ``get_abstract_mesh`` on >= 0.6; the
    thread-resources physical mesh (what ``with mesh:`` sets) on 0.4.x."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        return get_abstract()
    from jax._src import mesh as _mesh_internal
    return _mesh_internal.thread_resources.env.physical_mesh


class MoEParams(NamedTuple):
    router: jax.Array          # (D, E)
    w_gate: jax.Array          # (E, D, Fe)
    w_up: jax.Array            # (E, D, Fe)
    w_down: jax.Array          # (E, Fe, D)
    shared_w_gate: Optional[jax.Array]  # (D, Fs) or None
    shared_w_up: Optional[jax.Array]
    shared_w_down: Optional[jax.Array]


def _constrain(x, spec_axes):
    if spec_axes is None:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*spec_axes))


def _dispatch_local(xf, tope, topw, e, capacity, dtype):
    """Device-local slot assignment + scatter.  xf: (T, D); returns
    (x_buf (E, C, D), pos (T*k,), flat_e, dropped mask)."""
    t, d = xf.shape
    k = tope.shape[-1]
    flat_e = tope.reshape(t * k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first_occ = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos_sorted = jnp.arange(t * k) - first_occ[sorted_e]
    pos = jnp.zeros((t * k,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))
    token_of = jnp.arange(t * k) // k
    x_buf = jnp.zeros((e, capacity, d), dtype)
    x_buf = x_buf.at[flat_e, pos].set(xf[token_of], mode="drop")
    return x_buf, pos, flat_e


def _combine_local(y_buf, pos, flat_e, topw, capacity, d, dtype):
    t_k = pos.shape[0]
    k = topw.shape[-1]
    dropped = pos >= capacity
    safe_pos = jnp.minimum(pos, capacity - 1)
    y = y_buf[flat_e, safe_pos]
    y = jnp.where(dropped[:, None], 0.0, y)
    y = y.reshape(t_k // k, k, d) * topw.reshape(t_k // k, k, 1).astype(dtype)
    return y.sum(1)


def _expert_ffn(x_buf, wg, wu, wd, dtype):
    g = jnp.einsum("ecd,edf->ecf", x_buf, wg.astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", x_buf, wu.astype(dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd.astype(dtype))


def moe_block(
    x: jax.Array,              # (B, S, D)
    p: MoEParams,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    router_dtype=jnp.float32,
    groups: Tuple[int, int] = (1, 1),
    batch_axes=None,           # mesh axes of the token dim ("pod","data")...
    expert_sharded: bool = False,  # experts sharded over "model" (EP)?
):
    """Returns (out (B,S,D), aux dict with:
         counts  (E,) int32 — expert activation telemetry (HMU feed)
         aux_loss scalar    — switch-style load-balance loss
    """
    b, s, d = x.shape
    e = p.router.shape[1]
    gd, gm = groups
    t = b * s
    dtype = x.dtype

    # ---- router (global einsum; tiny) + telemetry + balance loss
    logits = jnp.einsum("bsd,de->bse", x.astype(router_dtype),
                        p.router.astype(router_dtype))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, top_k)              # (B,S,k)
    topw = topw / jnp.clip(topw.sum(-1, keepdims=True), 1e-9)
    counts = jnp.zeros((e,), jnp.int32).at[tope.reshape(-1)].add(1)
    f_e = counts.astype(jnp.float32) / jnp.maximum(t * top_k, 1)
    aux_loss = e * jnp.sum(jax.lax.stop_gradient(f_e) * probs.mean((0, 1)))
    aux = {"counts": counts, "aux_loss": aux_loss}

    if gd * gm > 1 and expert_sharded:
        out = _moe_shard_map(x, p, tope, topw, top_k, capacity_factor,
                             groups, batch_axes)
        return out, aux

    # ---- single-program path (tests / replicated experts)
    capacity = max(int(t * top_k * capacity_factor / e), 4)
    x_buf, pos, flat_e = _dispatch_local(
        x.reshape(t, d), tope.reshape(t, top_k), topw.reshape(t, top_k),
        e, capacity, dtype)
    y_buf = _expert_ffn(x_buf, p.w_gate, p.w_up, p.w_down, dtype)
    out = _combine_local(y_buf, pos, flat_e, topw.reshape(t, top_k),
                         capacity, d, dtype).reshape(b, s, d)
    if p.shared_w_gate is not None:
        out = out + _shared_ffn(x, p, dtype)
    return out, aux


def _shared_ffn(x, p: MoEParams, dtype):
    gs = jnp.einsum("bsd,df->bsf", x, p.shared_w_gate.astype(dtype))
    us = jnp.einsum("bsd,df->bsf", x, p.shared_w_up.astype(dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(gs) * us,
                      p.shared_w_down.astype(dtype))


def _moe_shard_map(x, p: MoEParams, tope, topw, top_k, capacity_factor,
                   groups, batch_axes):
    """Expert-parallel interior with explicit all-to-alls (see module doc).

    Device-local token count t_l = T / (gd*gm); local capacity
    C = ceil(t_l*k*cf/E) rounded up to a multiple of gm so the all-to-all
    tiles evenly.  Wire bytes per device per direction = E*C*D — the routed
    token bytes, nothing else."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e = p.router.shape[1]
    gd, gm = groups
    t = b * s
    tl = t // (gd * gm)
    dtype = x.dtype
    capacity = max(int(tl * top_k * capacity_factor / e), 2)
    capacity = -(-capacity // gm) * gm            # multiple of gm

    bax = batch_axes
    mesh = _ambient_mesh()
    xspec = P(bax, "model", None)
    kspec = P(bax, "model", None)

    def interior(x_l, tope_l, topw_l, wg, wu, wd):
        bl, sl, _ = x_l.shape
        t_l = bl * sl
        x_buf, pos, flat_e = _dispatch_local(
            x_l.reshape(t_l, d), tope_l.reshape(t_l, top_k),
            topw_l.reshape(t_l, top_k), e, capacity, dtype)
        # (E, C, D) -> split E across model axis -> (E/gm, gm*C, D)
        x_recv = jax.lax.all_to_all(x_buf, "model", split_axis=0,
                                    concat_axis=1, tiled=True)
        y_recv = _expert_ffn(x_recv, wg, wu, wd, dtype)
        y_buf = jax.lax.all_to_all(y_recv, "model", split_axis=1,
                                   concat_axis=0, tiled=True)
        out = _combine_local(y_buf, pos, flat_e, topw_l.reshape(t_l, top_k),
                             capacity, d, dtype)
        return out.reshape(bl, sl, d)

    fn = shard_map(
        interior, mesh=mesh,
        in_specs=(xspec, kspec, kspec,
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=xspec,
        check_rep=False,
    )
    out = fn(x, tope, topw, p.w_gate, p.w_up, p.w_down)
    out = _constrain(out, (bax, None, None))
    if p.shared_w_gate is not None:
        out = out + _shared_ffn(x, p, dtype)
    return out
