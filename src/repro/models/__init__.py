"""Model substrate: the 10 assigned architectures as one composable stack."""
