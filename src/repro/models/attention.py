"""Attention compute paths (pure JAX; the Pallas kernel is the TPU target).

* ``flash_train``  — chunked causal/windowed attention for train & prefill.
  lax.scan over KV blocks with online softmax => O(S * block) live memory, so
  32 k-token prefill compiles with bounded buffers.  The baseline masks
  non-causal blocks (computes then discards); ``causal_schedule='triangular'``
  unrolls over Q blocks with exact slice bounds, eliminating the ~2x wasted
  FLOPs (a §Perf hillclimb knob).
* ``decode_step``  — single-token attention against a KV cache with optional
  sliding window and per-KV-page attention-mass telemetry (feeds the tiered
  KV cache manager).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _block_attn(q, k, v, qpos, kpos, sm_scale, causal, window):
    """One (Bq x Bk) online-softmax block. q:(B,H,bq,d) k/v:(B,H,bk,d)."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32)
    s *= sm_scale
    mask = jnp.ones((q.shape[2], k.shape[2]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] >= qpos[:, None] - window
    return jnp.where(mask[None, None], s, NEG_INF)


def flash_train(
    q: jax.Array,       # (B, H, S, d)
    k: jax.Array,       # (B, KVH, S, d)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_k: int = 512,
    sm_scale: float | None = None,
    causal_schedule: str = "masked",   # "masked" | "triangular"
) -> jax.Array:
    b, h, s, d = q.shape
    kvh = k.shape[1]
    g = h // kvh
    if sm_scale is None:
        sm_scale = d ** -0.5
    # expand KV heads group-wise without materializing copies per q head:
    # fold groups into batch: q -> (B, KVH, G, S, d) -> treat (KVH) aligned
    q = q.reshape(b, kvh, g, s, d)

    if causal_schedule == "triangular" and causal:
        return _flash_triangular(q, k, v, sm_scale, window, block_k).reshape(b, h, s, d)

    nk = s // block_k if s % block_k == 0 else -1
    if nk < 1:
        # irregular length: single full block
        nk, block_k = 1, s
    kb = k.reshape(b, kvh, nk, block_k, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, kvh, nk, block_k, d).transpose(2, 0, 1, 3, 4)
    qpos = jnp.arange(s)

    def step(carry, xs):
        m, l, acc = carry
        kcur, vcur, j = xs
        kpos = j * block_k + jnp.arange(block_k)
        sblk = jnp.einsum("bgnqd,bgkd->bgnqk", q.astype(jnp.float32),
                          kcur.astype(jnp.float32)) * sm_scale
        mask = jnp.ones((s, block_k), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] >= qpos[:, None] - window
        sblk = jnp.where(mask[None, None, None], sblk, NEG_INF)
        m_new = jnp.maximum(m, sblk.max(-1))
        p = jnp.exp(sblk - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bgnqk,bgkd->bgnqd", p, vcur.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, kvh, g, s), NEG_INF, jnp.float32),
        jnp.zeros((b, kvh, g, s), jnp.float32),
        jnp.zeros((b, kvh, g, s, d), jnp.float32),
    )
    # checkpoint the block step: backward recomputes the (S x block) scores
    # instead of stacking them across the scan (flash-attention backward
    # memory profile without a custom VJP)
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    (m, l, acc), _ = jax.lax.scan(step, init, (kb, vb, jnp.arange(nk)))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).astype(q.dtype)
    return out.reshape(b, h, s, d)


def _flash_triangular(q, k, v, sm_scale, window, block_k):
    """Exact-FLOPs causal schedule: unrolled over Q blocks, each attending
    only its causal KV prefix (static slice bounds per unrolled step)."""
    b, kvh, g, s, d = q.shape
    bq = block_k
    nq = max(s // bq, 1)
    bq = s // nq
    outs = []
    for i in range(nq):
        qi = q[:, :, :, i * bq:(i + 1) * bq].astype(jnp.float32)
        hi = (i + 1) * bq
        lo = 0
        if window is not None:
            lo = max(0, i * bq - ((window // bq) + 1) * bq)
        kk = k[:, :, lo:hi].astype(jnp.float32)
        vv = v[:, :, lo:hi].astype(jnp.float32)
        sblk = jnp.einsum("bgnqd,bgkd->bgnqk", qi, kk) * sm_scale
        qpos = i * bq + jnp.arange(bq)
        kpos = lo + jnp.arange(hi - lo)
        mask = kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] >= qpos[:, None] - window
        sblk = jnp.where(mask[None, None, None], sblk, NEG_INF)
        p = jax.nn.softmax(sblk, axis=-1)
        outs.append(jnp.einsum("bgnqk,bgkd->bgnqd", p, vv))
    return jnp.concatenate(outs, axis=3).astype(q.dtype)


def decode_step(
    q: jax.Array,        # (B, H, d) one new token per sequence
    k_cache: jax.Array,  # (B, KVH, S, d)
    v_cache: jax.Array,
    pos: jax.Array,      # (B,) current lengths (the new token's index)
    *,
    window: int | None = None,
    sm_scale: float | None = None,
    page_size: int = 0,  # >0: also return per-page attention mass (KV telemetry)
):
    b, h, d = q.shape
    kvh, s = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    if sm_scale is None:
        sm_scale = d ** -0.5
    # bf16 dots with f32 accumulation: no f32 copy of the cache is ever
    # materialized (§Perf C1 — the f32-upcast path doubled decode HBM
    # traffic: cache read + f32 cache write + f32 read)
    qg = q.reshape(b, kvh, g, d)
    scores = jnp.einsum("bngd,bnkd->bngk", qg, k_cache,
                        preferred_element_type=jnp.float32) * sm_scale
    kpos = jnp.arange(s)[None, :]                       # (1, S)
    valid = kpos <= pos[:, None]
    if window is not None:
        valid &= kpos >= (pos[:, None] - window)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngk,bnkd->bngd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, h, d).astype(q.dtype)
    if page_size:
        # ceil-divide: a cache length that is not a page multiple leaves a
        # ragged final page, whose mass is the (shorter) tail positions' sum —
        # masked positions carry exactly 0 probability, so zero-padding the
        # per-position mass to the page grid is exact, not an approximation
        npages = -(-s // page_size)
        pos_mass = p.sum((1, 2))                                     # (B, S)
        pad = npages * page_size - s
        if pad:
            pos_mass = jnp.pad(pos_mass, ((0, 0), (0, pad)))
        mass = pos_mass.reshape(b, npages, page_size).sum(-1)        # (B, npages)
        return out, mass
    return out


def update_kv_cache(k_cache, v_cache, k_new, v_new, pos):
    """Insert one token's K/V at ``pos`` per batch row. k_new: (B, KVH, d)."""
    b = k_cache.shape[0]
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, :, pos].set(k_new.astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, :, pos].set(v_new.astype(v_cache.dtype))
    return k_cache, v_cache
