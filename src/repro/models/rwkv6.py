"""RWKV-6 "Finch" — attention-free time mixing with data-dependent decay.

Exact chunked formulation (GLA-style): within a chunk all pairwise decay
factors are exp(negative sums) <= 1, so the math is numerically safe without
rescaling tricks; the inter-chunk state is carried by lax.scan.

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

w_t in (0,1) per channel is data-dependent (lora on the shifted input);
u is the per-channel "bonus" for the current token.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import rms_norm


class RWKV6Params(NamedTuple):
    # data-dependent token shift (ddlerp): 5 mixes (r,k,v,w,g)
    tm_mu: jax.Array        # (5, D)
    tm_lora_a: jax.Array    # (D, 32)
    tm_lora_b: jax.Array    # (5, 32, D)
    # decay
    w0: jax.Array           # (D,)
    w_lora_a: jax.Array     # (D, 64)
    w_lora_b: jax.Array     # (64, D)
    u: jax.Array            # (D,) bonus
    wr: jax.Array           # (D, D)
    wk: jax.Array           # (D, D)
    wv: jax.Array           # (D, D)
    wg: jax.Array           # (D, D)
    wo: jax.Array           # (D, D)
    ln_x: jax.Array         # (D,) per-head group norm scale


def _token_shift(x):
    """x_{t-1} with zero at t=0.  x: (B, S, D)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _ddlerp(x, xprev, p: RWKV6Params):
    """Data-dependent lerp between x_t and x_{t-1} -> 5 mixed streams."""
    base = x + (xprev - x) * p.tm_mu[0].astype(x.dtype)  # mu_x feeds the lora
    lora = jnp.tanh(jnp.einsum("bsd,dk->bsk", base, p.tm_lora_a.astype(x.dtype)))
    mixes = []
    for i in range(5):
        adj = jnp.einsum("bsk,kd->bsd", lora, p.tm_lora_b[i].astype(x.dtype))
        mu = p.tm_mu[i].astype(x.dtype) + adj
        mixes.append(x + (xprev - x) * mu)
    return mixes  # r,k,v,w,g streams


def rwkv6_mix(
    x: jax.Array,            # (B, S, D)
    p: RWKV6Params,
    state: jax.Array | None = None,   # (B, H, dk, dv) carry for decode
    *,
    n_heads: int,
    chunk: int = 64,
    eps: float = 1e-5,
):
    """Returns (out (B,S,D), final_state)."""
    b, s, d = x.shape
    hd = d // n_heads
    dt = x.dtype

    xprev = _token_shift(x)
    xr, xk, xv, xw, xg = _ddlerp(x, xprev, p)

    r = jnp.einsum("bsd,de->bse", xr, p.wr.astype(dt))
    k = jnp.einsum("bsd,de->bse", xk, p.wk.astype(dt))
    v = jnp.einsum("bsd,de->bse", xv, p.wv.astype(dt))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p.wg.astype(dt)))

    logw = -jnp.exp(
        p.w0.astype(jnp.float32)
        + jnp.einsum("bsd,dk,ke->bse", xw.astype(jnp.float32),
                     p.w_lora_a.astype(jnp.float32), p.w_lora_b.astype(jnp.float32))
    )  # (B,S,D) <= 0

    # heads
    def split(t_):
        return t_.reshape(b, s, n_heads, hd)

    r_, k_, v_ = split(r).astype(jnp.float32), split(k).astype(jnp.float32), \
        split(v).astype(jnp.float32)
    lw = logw.reshape(b, s, n_heads, hd)
    u = p.u.astype(jnp.float32).reshape(n_heads, hd)

    if state is None:
        state = jnp.zeros((b, n_heads, hd, hd), jnp.float32)

    # pad to chunk multiple
    pad = (-s) % chunk
    if pad:
        r_, k_, v_, lw = (jnp.pad(t_, ((0, 0), (0, pad), (0, 0), (0, 0)))
                          for t_ in (r_, k_, v_, lw))
    nC = (s + pad) // chunk

    def reshape_chunks(t_):
        return t_.reshape(b, nC, chunk, n_heads, hd).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, lwc = map(reshape_chunks, (r_, k_, v_, lw))  # (nC,B,H,L,hd)

    def step(S, xs):
        rr, kk, vv, ww = xs                     # (B,H,L,hd)
        cs = jnp.cumsum(ww, axis=2)             # inclusive logs
        csm1 = cs - ww                          # exclusive
        # pairwise decay P[t,j] = exp(cs_{t-1} - cs_j), j < t
        pair = csm1[:, :, :, None, :] - cs[:, :, None, :, :]   # (B,H,L,L,hd)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        pair = jnp.where(tri[None, None, :, :, None], pair, -jnp.inf)
        scores = jnp.einsum("bhtd,bhtjd,bhjd->bhtj", rr, jnp.exp(pair), kk)
        o = jnp.einsum("bhtj,bhjd->bhtd", scores, vv)
        # bonus (current token)
        o = o + jnp.einsum("bhtd,hd,bhtd,bhte->bhte", rr, u, kk, vv)
        # carried state
        o = o + jnp.einsum("bhtd,bhde->bhte", rr * jnp.exp(csm1), S)
        # state update
        last = cs[:, :, -1:, :]                 # (B,H,1,hd)
        S_new = S * jnp.exp(last[:, :, 0, :, None]) + jnp.einsum(
            "bhld,bhle->bhde", kk * jnp.exp(last - cs), vv)
        return S_new, o

    # checkpoint: the (L,L,hd) pairwise-decay tensor is recomputed in bwd,
    # never stacked across chunks
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    state, oc = jax.lax.scan(step, state, (rc, kc, vc, lwc))
    o = oc.transpose(1, 0, 3, 2, 4).reshape(b, s + pad, n_heads, hd)[:, :s]

    # per-head group norm, gate, output proj
    o = o.reshape(b, s, n_heads, hd)
    mean = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    o = (o - mean) * jax.lax.rsqrt(var + eps)
    o = o.reshape(b, s, d) * p.ln_x.astype(jnp.float32)
    o = (o.astype(dt) * g)
    return jnp.einsum("bsd,de->bse", o, p.wo.astype(dt)), state


class RWKV6FFNParams(NamedTuple):
    mu_k: jax.Array   # (D,)
    mu_r: jax.Array   # (D,)
    wk: jax.Array     # (D, F)
    wv: jax.Array     # (F, D)
    wr: jax.Array     # (D, D)


def rwkv6_channel_mix(x: jax.Array, p: RWKV6FFNParams):
    xprev = _token_shift(x)
    xk = x + (xprev - x) * p.mu_k.astype(x.dtype)
    xr = x + (xprev - x) * p.mu_r.astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", xk, p.wk.astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p.wv.astype(x.dtype))
    return jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p.wr.astype(x.dtype))) * kv


# ----------------------------------------------------------- single-token step
def rwkv6_mix_step(
    x: jax.Array,        # (B, D) current (already layer-normed)
    x_prev: jax.Array,   # (B, D) previous normed input (token shift state)
    state: jax.Array,    # (B, H, dk, dv) f32
    p: RWKV6Params,
    *,
    n_heads: int,
    eps: float = 1e-5,
):
    """One decode step.  Returns (out (B,D), new_state)."""
    b, d = x.shape
    hd = d // n_heads
    dt = x.dtype

    base = x + (x_prev - x) * p.tm_mu[0].astype(dt)
    lora = jnp.tanh(jnp.einsum("bd,dk->bk", base, p.tm_lora_a.astype(dt)))
    mixes = []
    for i in range(5):
        adj = jnp.einsum("bk,kd->bd", lora, p.tm_lora_b[i].astype(dt))
        mu = p.tm_mu[i].astype(dt) + adj
        mixes.append(x + (x_prev - x) * mu)
    xr, xk, xv, xw, xg = mixes

    r = jnp.einsum("bd,de->be", xr, p.wr.astype(dt)).astype(jnp.float32)
    k = jnp.einsum("bd,de->be", xk, p.wk.astype(dt)).astype(jnp.float32)
    v = jnp.einsum("bd,de->be", xv, p.wv.astype(dt)).astype(jnp.float32)
    g = jax.nn.silu(jnp.einsum("bd,de->be", xg, p.wg.astype(dt)))

    w = jnp.exp(-jnp.exp(
        p.w0.astype(jnp.float32)
        + jnp.einsum("bd,dk,ke->be", xw.astype(jnp.float32),
                     p.w_lora_a.astype(jnp.float32),
                     p.w_lora_b.astype(jnp.float32))))     # (B, D) in (0,1)

    rh = r.reshape(b, n_heads, hd)
    kh = k.reshape(b, n_heads, hd)
    vh = v.reshape(b, n_heads, hd)
    wh = w.reshape(b, n_heads, hd)
    u = p.u.astype(jnp.float32).reshape(n_heads, hd)

    kv = jnp.einsum("bhk,bhv->bhkv", kh, vh)
    o = jnp.einsum("bhk,bhkv->bhv", rh, state + u[None, :, :, None] * kv)
    state = state * wh[..., None] + kv

    mean = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    o = (o - mean) * jax.lax.rsqrt(var + eps)
    o = o.reshape(b, d) * p.ln_x.astype(jnp.float32)
    o = o.astype(dt) * g
    return jnp.einsum("bd,de->be", o, p.wo.astype(dt)), state


def rwkv6_channel_mix_step(x: jax.Array, x_prev: jax.Array, p: RWKV6FFNParams):
    xk = x + (x_prev - x) * p.mu_k.astype(x.dtype)
    xr = x + (x_prev - x) * p.mu_r.astype(x.dtype)
    k = jnp.square(jax.nn.relu(jnp.einsum("bd,df->bf", xk, p.wk.astype(x.dtype))))
    kv = jnp.einsum("bf,fd->bd", k, p.wv.astype(x.dtype))
    return jax.nn.sigmoid(jnp.einsum("bd,de->be", xr, p.wr.astype(x.dtype))) * kv
