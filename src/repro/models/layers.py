"""Shared building blocks: norms, RoPE/M-RoPE, SwiGLU, attention block."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import attention as attn_lib


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, d); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions_3d: jax.Array, sections=(16, 24, 24),
    theta: float = 10000.0,
) -> jax.Array:
    """Qwen2-VL M-RoPE: the rotary dims are split into (t, h, w) sections,
    each rotated by its own position stream.  x: (B, H, S, d);
    positions_3d: (3, B, S)."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(d, theta)                       # (half,)
    # build a (B, S, half) angle tensor with per-section position ids
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        f = freqs[start:start + sec]
        ang = positions_3d[i][..., None].astype(jnp.float32) * f   # (B,S,sec)
        parts.append(ang)
        start += sec
    angles = jnp.concatenate(parts, axis=-1)[:, None]  # (B,1,S,half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- SwiGLU
def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array):
    g = jnp.einsum("bsd,df->bsf", x, w_gate.astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, w_down.astype(x.dtype))


# ------------------------------------------------------------ attention block
class AttnParams(NamedTuple):
    wq: jax.Array            # (D, H*hd)
    wk: jax.Array            # (D, KVH*hd)
    wv: jax.Array            # (D, KVH*hd)
    wo: jax.Array            # (H*hd, D)
    bq: Optional[jax.Array]  # (H*hd,) or None (qwen2 QKV bias)
    bk: Optional[jax.Array]
    bv: Optional[jax.Array]


def attention_block(
    x: jax.Array,                # (B, S, D)
    p: AttnParams,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    positions: jax.Array,        # (B, S) or (3, B, S) for mrope
    rope_mode: str = "rope",     # "rope" | "mrope" | "none"
    rope_theta: float = 10000.0,
    window: int | None = None,
    causal_schedule: str = "masked",
    block_k: int = 512,
    return_kv: bool = False,
):
    b, s, d_model = x.shape
    dt = x.dtype

    def proj(w, bias, nh):
        y = jnp.einsum("bsd,dh->bsh", x, w.astype(dt))
        if bias is not None:
            y = y + bias.astype(dt)
        return y.reshape(b, s, nh, head_dim).transpose(0, 2, 1, 3)

    q = proj(p.wq, p.bq, n_heads)          # (B,H,S,hd)
    k = proj(p.wk, p.bk, n_kv_heads)
    v = proj(p.wv, p.bv, n_kv_heads)

    if rope_mode == "rope":
        q = apply_rope(q, positions[:, None], rope_theta)
        k = apply_rope(k, positions[:, None], rope_theta)
    elif rope_mode == "mrope":
        half = head_dim // 2
        sections = (half - 2 * (half * 3 // 8), half * 3 // 8, half * 3 // 8)
        q = apply_mrope(q, positions, sections, rope_theta)
        k = apply_mrope(k, positions, sections, rope_theta)

    o = attn_lib.flash_train(
        q, k, v, causal=True, window=window,
        causal_schedule=causal_schedule, block_k=block_k,
    )                                       # (B,H,S,hd)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, n_heads * head_dim)
    out = jnp.einsum("bsh,hd->bsd", o, p.wo.astype(dt))
    if return_kv:
        return out, (k, v)
    return out
