"""Unified causal LM covering all 10 assigned architectures.

One ``ModelConfig`` describes any family:
  * ``attn``   — dense decoder-only transformers (llama3.2, qwen2, internlm2,
                 yi, musicgen [audio frontend stub], qwen2-vl [patch stub])
  * ``moe``    — routed-FFN transformers (mixtral [SWA], kimi-k2 [384e
                 shared-expert])
  * ``rwkv6``  — attention-free (RWKV-6 Finch)
  * ``zamba2`` — Mamba2 backbone + shared attention block every N layers

Parameters are generated from a single **schema walk** that yields, per leaf:
shape, dtype, init scale and *logical* sharding axes — so ``init_params``,
``abstract_params`` (dry-run, no allocation) and ``param_pspecs`` (GSPMD)
always agree by construction.

Forward paths: ``forward`` (teacher-forced logits/loss features, scan over
layers + configurable remat), ``prefill`` (returns KV/SSM caches), and
``decode_step`` (one token, updates caches) live in serve/steps modules built
on the block functions here.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn_lib
from .layers import AttnParams, attention_block, rms_norm, swiglu
from .moe import MoEParams, moe_block
from .rwkv6 import RWKV6FFNParams, RWKV6Params, rwkv6_channel_mix, rwkv6_mix
from .mamba2 import Mamba2Params, mamba2_mix


# =============================================================== configuration
@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # attn | moe | rwkv6 | zamba2
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False
    window: Optional[int] = None    # sliding-window attention (mixtral)
    rope: str = "rope"              # rope | mrope | none
    rope_theta: float = 10000.0
    moe: Optional[MoECfg] = None
    ssm_state: int = 64             # zamba2
    zamba_attn_every: int = 6
    frontend: str = "tokens"        # tokens | embeddings (audio/vlm stubs)
    param_dtype: Any = jnp.float32
    activ_dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-5
    causal_schedule: str = "triangular"  # triangular (default; exact-FLOPs)
                                         # | masked (paper-agnostic baseline)
    attn_block_k: int = 512
    loss_chunk: int = 256           # chunked-vocab loss: per scan step logits are (B, loss_chunk, V)
    remat: str = "full"             # full | dots | none
    sub_quadratic: bool = False     # eligible for long_500k
    tie_embeddings: bool = False
    # mesh axes the activation batch dim shards over (set by the launcher;
    # None = no explicit constraint, e.g. single-device runs)
    act_batch_axes: Optional[Tuple[str, ...]] = None
    # MoE group-local routing: (prod(batch axes), model axis size), and
    # whether experts are sharded over "model" (EP) — set by the launcher
    moe_groups: Optional[Tuple[int, int]] = None
    moe_expert_sharded: bool = False

    @property
    def d_inner(self) -> int:       # zamba2 mamba expansion
        return 2 * self.d_model

    @property
    def mamba_heads(self) -> int:
        return self.d_inner // 64

    @property
    def n_shared_attn(self) -> int:
        return self.n_layers // self.zamba_attn_every

    def param_count(self) -> int:
        total = 0
        for _, spec in iter_schema(self):
            total += int(np.prod(spec.shape))
        return total


# ============================================================== schema leaves
@dataclasses.dataclass(frozen=True)
class LeafSpec:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    init: str = "normal"            # normal | zeros | ones | small_normal
    dtype: Any = None               # default: cfg.param_dtype


def _attn_leaves(cfg: ModelConfig, prefix: str, stacked: bool) -> Dict[str, LeafSpec]:
    L = (cfg.n_layers,) if stacked else ()
    lax_ = ("layers",) if stacked else ()
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    leaves = {
        f"{prefix}wq": LeafSpec(L + (d, h * hd), lax_ + ("embed", "heads")),
        f"{prefix}wk": LeafSpec(L + (d, kvh * hd), lax_ + ("embed", "kv_heads")),
        f"{prefix}wv": LeafSpec(L + (d, kvh * hd), lax_ + ("embed", "kv_heads")),
        f"{prefix}wo": LeafSpec(L + (h * hd, d), lax_ + ("heads", "embed")),
    }
    if cfg.qkv_bias:
        leaves |= {
            f"{prefix}bq": LeafSpec(L + (h * hd,), lax_ + ("heads",), "zeros"),
            f"{prefix}bk": LeafSpec(L + (kvh * hd,), lax_ + ("kv_heads",), "zeros"),
            f"{prefix}bv": LeafSpec(L + (kvh * hd,), lax_ + ("kv_heads",), "zeros"),
        }
    return leaves


def _mlp_leaves(cfg: ModelConfig, prefix: str = "") -> Dict[str, LeafSpec]:
    L, lax_ = (cfg.n_layers,), ("layers",)
    d, f = cfg.d_model, cfg.d_ff
    return {
        f"{prefix}w_gate": LeafSpec(L + (d, f), lax_ + ("embed", "mlp")),
        f"{prefix}w_up": LeafSpec(L + (d, f), lax_ + ("embed", "mlp")),
        f"{prefix}w_down": LeafSpec(L + (f, d), lax_ + ("mlp", "embed")),
    }


def iter_schema(cfg: ModelConfig):
    """Yields (path, LeafSpec) for every parameter of the model."""
    d, v = cfg.d_model, cfg.vocab_size
    L, lax_ = (cfg.n_layers,), ("layers",)

    # token embeddings always exist (embedding-frontend archs still embed
    # generated tokens at decode time; the modality frontend is the stub)
    yield "embed", LeafSpec((v, d), ("vocab", "embed"))
    yield "final_norm", LeafSpec((d,), (None,), "ones")
    if not cfg.tie_embeddings:
        yield "lm_head", LeafSpec((d, v), ("embed", "vocab"))

    fam = cfg.family
    if fam in ("attn", "moe"):
        yield from _attn_leaves(cfg, "blocks.", True).items()
        yield "blocks.ln1", LeafSpec(L + (d,), lax_ + (None,), "ones")
        yield "blocks.ln2", LeafSpec(L + (d,), lax_ + (None,), "ones")
        if fam == "attn":
            yield from _mlp_leaves(cfg, "blocks.").items()
        else:
            m = cfg.moe
            e, fe = m.n_experts, m.d_expert
            yield "blocks.router", LeafSpec(L + (d, e), lax_ + ("embed", None), "small_normal")
            yield "blocks.e_gate", LeafSpec(L + (e, d, fe), lax_ + ("experts", "embed", "expert_mlp"))
            yield "blocks.e_up", LeafSpec(L + (e, d, fe), lax_ + ("experts", "embed", "expert_mlp"))
            yield "blocks.e_down", LeafSpec(L + (e, fe, d), lax_ + ("experts", "expert_mlp", "embed"))
            if m.n_shared:
                fs = m.d_expert * m.n_shared
                yield "blocks.s_gate", LeafSpec(L + (d, fs), lax_ + ("embed", "mlp"))
                yield "blocks.s_up", LeafSpec(L + (d, fs), lax_ + ("embed", "mlp"))
                yield "blocks.s_down", LeafSpec(L + (fs, d), lax_ + ("mlp", "embed"))

    elif fam == "rwkv6":
        yield "blocks.ln1", LeafSpec(L + (d,), lax_ + (None,), "ones")
        yield "blocks.ln2", LeafSpec(L + (d,), lax_ + (None,), "ones")
        yield "blocks.tm_mu", LeafSpec(L + (5, d), lax_ + (None, None), "zeros")
        yield "blocks.tm_lora_a", LeafSpec(L + (d, 32), lax_ + ("embed", None), "small_normal")
        yield "blocks.tm_lora_b", LeafSpec(L + (5, 32, d), lax_ + (None, None, "embed"), "zeros")
        yield "blocks.w0", LeafSpec(L + (d,), lax_ + (None,), "ones")
        yield "blocks.w_lora_a", LeafSpec(L + (d, 64), lax_ + ("embed", None), "small_normal")
        yield "blocks.w_lora_b", LeafSpec(L + (64, d), lax_ + (None, "embed"), "zeros")
        yield "blocks.u", LeafSpec(L + (d,), lax_ + (None,), "zeros")
        for w in ("wr", "wk", "wv", "wg", "wo"):
            yield f"blocks.{w}", LeafSpec(L + (d, d), lax_ + ("embed", "heads"))
        yield "blocks.ln_x", LeafSpec(L + (d,), lax_ + (None,), "ones")
        yield "blocks.f_mu_k", LeafSpec(L + (d,), lax_ + (None,), "zeros")
        yield "blocks.f_mu_r", LeafSpec(L + (d,), lax_ + (None,), "zeros")
        yield "blocks.f_wk", LeafSpec(L + (d, cfg.d_ff), lax_ + ("embed", "mlp"))
        yield "blocks.f_wv", LeafSpec(L + (cfg.d_ff, d), lax_ + ("mlp", "embed"))
        yield "blocks.f_wr", LeafSpec(L + (d, d), lax_ + ("embed", "heads"))

    elif fam == "zamba2":
        di, n = cfg.d_inner, cfg.ssm_state
        h = cfg.mamba_heads
        conv_ch = di + 2 * n
        yield "blocks.ln1", LeafSpec(L + (d,), lax_ + (None,), "ones")
        yield "blocks.in_proj", LeafSpec(L + (d, 2 * di + 2 * n + h), lax_ + ("embed", "mlp"))
        yield "blocks.conv_w", LeafSpec(L + (4, conv_ch), lax_ + (None, "mlp"), "small_normal")
        yield "blocks.conv_b", LeafSpec(L + (conv_ch,), lax_ + ("mlp",), "zeros")
        yield "blocks.a_log", LeafSpec(L + (h,), lax_ + (None,), "ones")
        yield "blocks.d_skip", LeafSpec(L + (h,), lax_ + (None,), "ones")
        yield "blocks.dt_bias", LeafSpec(L + (h,), lax_ + (None,), "zeros")
        yield "blocks.norm", LeafSpec(L + (di,), lax_ + (None,), "ones")
        yield "blocks.out_proj", LeafSpec(L + (di, d), lax_ + ("mlp", "embed"))
        # shared transformer block (attention + MLP, applied every
        # zamba_attn_every layers) with per-invocation LoRA adapters on q/k/v
        # — mamba layers themselves carry no MLP (that is what keeps Zamba2
        # at 2.7B despite 54 layers)
        ninv = cfg.n_shared_attn
        for k, spec in _attn_leaves(cfg, "shared_attn.", False).items():
            yield k, spec
        yield "shared_attn.ln", LeafSpec((d,), (None,), "ones")
        yield "shared_attn.ln_mlp", LeafSpec((d,), (None,), "ones")
        yield "shared_attn.w_gate", LeafSpec((d, cfg.d_ff), ("embed", "mlp"))
        yield "shared_attn.w_up", LeafSpec((d, cfg.d_ff), ("embed", "mlp"))
        yield "shared_attn.w_down", LeafSpec((cfg.d_ff, d), ("mlp", "embed"))
        r = 32
        for nm in ("q", "k", "v"):
            yield f"shared_attn.lora_{nm}_a", LeafSpec(
                (ninv, d, r), (None, "embed", None), "small_normal")
            yield f"shared_attn.lora_{nm}_b", LeafSpec(
                (ninv, r, d), (None, None, "heads"), "zeros")
    else:
        raise ValueError(cfg.family)


# ----------------------------------------------------------- schema consumers
def _set(tree: dict, path: str, val):
    parts = path.split(".")
    for p in parts[:-1]:
        tree = tree.setdefault(p, {})
    tree[parts[-1]] = val


def init_params(cfg: ModelConfig, rng: jax.Array) -> dict:
    tree: dict = {}
    leaves = list(iter_schema(cfg))
    keys = jax.random.split(rng, len(leaves))
    for (path, spec), key in zip(leaves, keys):
        dt = spec.dtype or cfg.param_dtype
        if spec.init == "zeros":
            val = jnp.zeros(spec.shape, dt)
        elif spec.init == "ones":
            val = jnp.ones(spec.shape, dt)
        else:
            scale = 0.02 if spec.init == "normal" else 0.006
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            scale = min(scale, fan_in ** -0.5)
            val = (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dt)
        _set(tree, path, val)
    return tree


def abstract_params(cfg: ModelConfig) -> dict:
    tree: dict = {}
    for path, spec in iter_schema(cfg):
        _set(tree, path, jax.ShapeDtypeStruct(spec.shape, spec.dtype or cfg.param_dtype))
    return tree


def param_pspecs(cfg: ModelConfig, rules: Dict[Optional[str], Any]) -> dict:
    from jax.sharding import PartitionSpec as P
    tree: dict = {}
    for path, spec in iter_schema(cfg):
        axes = tuple(rules.get(a) for a in spec.logical_axes)
        _set(tree, path, P(*axes))
    return tree


# ================================================================ block passes
def _attn_params(bp: dict, cfg: ModelConfig) -> AttnParams:
    return AttnParams(
        wq=bp["wq"], wk=bp["wk"], wv=bp["wv"], wo=bp["wo"],
        bq=bp.get("bq"), bk=bp.get("bk"), bv=bp.get("bv"),
    )


def transformer_block(x, bp, cfg: ModelConfig, positions):
    """One dense/moe transformer layer. Returns (x, aux) with aux = expert
    counts (E,) for moe, else None."""
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    h = attention_block(
        h, _attn_params(bp, cfg),
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        positions=positions, rope_mode=cfg.rope, rope_theta=cfg.rope_theta,
        window=cfg.window, causal_schedule=cfg.causal_schedule,
        block_k=cfg.attn_block_k,
    )
    x = x + h
    h = rms_norm(x, bp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        mp = MoEParams(
            router=bp["router"], w_gate=bp["e_gate"], w_up=bp["e_up"],
            w_down=bp["e_down"],
            shared_w_gate=bp.get("s_gate"), shared_w_up=bp.get("s_up"),
            shared_w_down=bp.get("s_down"),
        )
        bax = None
        if cfg.act_batch_axes:
            bax = (tuple(cfg.act_batch_axes) if len(cfg.act_batch_axes) > 1
                   else cfg.act_batch_axes[0])
        h, moe_aux = moe_block(
            h, mp, top_k=cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity_factor,
            groups=cfg.moe_groups or (1, 1), batch_axes=bax,
            expert_sharded=cfg.moe_expert_sharded)
        return x + h, moe_aux
    h = swiglu(h, bp["w_gate"], bp["w_up"], bp["w_down"])
    return x + h, None


def rwkv6_block(x, bp, cfg: ModelConfig, state=None):
    p = RWKV6Params(
        tm_mu=bp["tm_mu"], tm_lora_a=bp["tm_lora_a"], tm_lora_b=bp["tm_lora_b"],
        w0=bp["w0"], w_lora_a=bp["w_lora_a"], w_lora_b=bp["w_lora_b"], u=bp["u"],
        wr=bp["wr"], wk=bp["wk"], wv=bp["wv"], wg=bp["wg"], wo=bp["wo"],
        ln_x=bp["ln_x"],
    )
    n_heads = cfg.d_model // 64
    h, state = rwkv6_mix(rms_norm(x, bp["ln1"], cfg.norm_eps), p, state,
                         n_heads=n_heads)
    x = x + h
    fp = RWKV6FFNParams(mu_k=bp["f_mu_k"], mu_r=bp["f_mu_r"],
                        wk=bp["f_wk"], wv=bp["f_wv"], wr=bp["f_wr"])
    x = x + rwkv6_channel_mix(rms_norm(x, bp["ln2"], cfg.norm_eps), fp)
    return x, state


def zamba2_mamba_block(x, bp, cfg: ModelConfig, state=None):
    p = Mamba2Params(
        in_proj=bp["in_proj"], conv_w=bp["conv_w"], conv_b=bp["conv_b"],
        a_log=bp["a_log"], d_skip=bp["d_skip"], dt_bias=bp["dt_bias"],
        norm=bp["norm"], out_proj=bp["out_proj"],
    )
    h, state = mamba2_mix(rms_norm(x, bp["ln1"], cfg.norm_eps), p, state,
                          d_inner=cfg.d_inner, n_heads=cfg.mamba_heads,
                          d_state=cfg.ssm_state)
    return x + h, state


def zamba2_shared_attention(x, sp: dict, cfg: ModelConfig, inv: int, positions):
    """Shared attention block with per-invocation LoRA deltas on q/k/v."""
    def lora(nm):
        a = jax.lax.dynamic_index_in_dim(sp[f"lora_{nm}_a"], inv, 0, keepdims=False)
        b_ = jax.lax.dynamic_index_in_dim(sp[f"lora_{nm}_b"], inv, 0, keepdims=False)
        return a, b_

    h = rms_norm(x, sp["ln"], cfg.norm_eps)
    deltas = {}
    for nm in ("q", "k", "v"):
        a, b_ = lora(nm)
        deltas[nm] = jnp.einsum("bsd,dr,re->bse", h, a.astype(h.dtype),
                                b_.astype(h.dtype))
    p = AttnParams(wq=sp["wq"], wk=sp["wk"], wv=sp["wv"], wo=sp["wo"],
                   bq=None, bk=None, bv=None)
    # apply lora additively by adjusting the projections inline
    b, s, d = h.shape
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads

    def proj(w, delta, n):
        y = jnp.einsum("bsd,dh->bsh", h, w.astype(h.dtype)) + delta[..., : n * hd]
        return y.reshape(b, s, n, hd).transpose(0, 2, 1, 3)

    q = proj(p.wq, deltas["q"], nh)
    k = proj(p.wk, deltas["k"], nkv)
    v = proj(p.wv, deltas["v"], nkv)
    from .layers import apply_rope
    q = apply_rope(q, positions[:, None], cfg.rope_theta)
    k = apply_rope(k, positions[:, None], cfg.rope_theta)
    o = attn_lib.flash_train(q, k, v, causal=True, window=cfg.window,
                             causal_schedule=cfg.causal_schedule,
                             block_k=cfg.attn_block_k)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
    x = x + jnp.einsum("bsh,hd->bsd", o, p.wo.astype(h.dtype))
    # shared MLP
    hm = rms_norm(x, sp["ln_mlp"], cfg.norm_eps)
    return x + swiglu(hm, sp["w_gate"], sp["w_up"], sp["w_down"])


# ================================================================== forward
def constrain_batch(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Pin the activation batch dim to the data axes (GSPMD otherwise may
    propagate a weight layout onto the layer carry and replicate batch —
    a 16x compute blowup we hit in the first dry-runs)."""
    if not cfg.act_batch_axes:
        return x
    from jax.sharding import PartitionSpec as P
    axes = tuple(cfg.act_batch_axes)
    b = axes if len(axes) > 1 else axes[0]
    spec = P(b, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    return jax.checkpoint_policies.nothing_saveable


def forward(params: dict, cfg: ModelConfig, tokens=None, embeds=None,
            positions=None) -> Tuple[jax.Array, Dict[str, Any]]:
    """Teacher-forced forward pass -> (hidden (B,S,D), aux).

    aux["expert_counts"]: (L, E) for moe — the HMU-style telemetry feed.
    """
    if embeds is not None:
        x = embeds.astype(cfg.activ_dtype)
    else:
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activ_dtype)
    x = constrain_batch(x, cfg)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if cfg.rope == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, b, s))

    aux: Dict[str, Any] = {}
    policy = _remat_policy(cfg)

    if cfg.family in ("attn", "moe"):
        def body(x, bp):
            x = constrain_batch(x, cfg)
            x, moe_aux = transformer_block(x, bp, cfg, positions)
            return constrain_batch(x, cfg), moe_aux
        if policy is not None:
            body = jax.checkpoint(body, policy=policy, prevent_cse=False)
        x, moe_aux = jax.lax.scan(body, x, params["blocks"])
        if cfg.family == "moe":
            aux["expert_counts"] = moe_aux["counts"]      # (L, E) telemetry
            aux["moe_aux_loss"] = moe_aux["aux_loss"].mean()

    elif cfg.family == "rwkv6":
        def body(x, bp):
            x = constrain_batch(x, cfg)
            x, _ = rwkv6_block(x, bp, cfg)
            return constrain_batch(x, cfg), None
        if policy is not None:
            body = jax.checkpoint(body, policy=policy, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["blocks"])

    elif cfg.family == "zamba2":
        every = cfg.zamba_attn_every
        ninv = cfg.n_shared_attn
        blocks = params["blocks"]
        # regroup stacked layers into (ninv, every, ...)
        grouped = jax.tree.map(
            lambda t: t.reshape((ninv, every) + t.shape[1:]), blocks)

        def group_body(x, xs):
            gp, inv = xs

            def inner(x, bp):
                x = constrain_batch(x, cfg)
                x, _ = zamba2_mamba_block(x, bp, cfg)
                return constrain_batch(x, cfg), None
            if policy is not None:
                inner = jax.checkpoint(inner, policy=policy, prevent_cse=False)
            x, _ = jax.lax.scan(inner, x, gp)
            x = zamba2_shared_attention(x, params["shared_attn"], cfg, inv, positions)
            return x, None

        if policy is not None:
            group_body = jax.checkpoint(group_body, policy=policy, prevent_cse=False)
        x, _ = jax.lax.scan(group_body, x, (grouped, jnp.arange(ninv)))

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def logits_fn(params: dict, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", hidden, head.astype(hidden.dtype))


def loss_fn(params: dict, cfg: ModelConfig, hidden: jax.Array,
            labels: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    """Chunked-vocab softmax cross entropy (never materializes (B,S,V) in f32
    all at once when loss_chunk < S)."""
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    b, s, d = hidden.shape
    chunk = min(cfg.loss_chunk or s, s)
    n_chunks = s // chunk if s % chunk == 0 else 1
    if s % chunk != 0:
        chunk = s
    hs = hidden.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    ms = mask.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def step(carry, xs):
        h, lab, m = xs
        logits = jnp.einsum("bsd,dv->bsv", h, head.astype(h.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m
        return (carry[0] + nll.sum(), carry[1] + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)), (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
