"""Mamba-2 (SSD) block — chunked state-space duality formulation.

    h_t = a_t h_{t-1} + dt_t * x_t B_t^T      (per head; a_t = exp(-exp(A)dt))
    y_t = C_t h_t + D * x_t

Chunked exactly like the RWKV6 path: intra-chunk pairwise decays are
exp(non-positive sums); inter-chunk state (H, P, N) carried by lax.scan.
Used standalone (a pure-Mamba model) and inside Zamba2 hybrid blocks.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Mamba2Params(NamedTuple):
    in_proj: jax.Array    # (D, 2*d_inner + 2*N + H)   [z, x, B, C, dt] (1 group)
    conv_w: jax.Array     # (4, d_inner + 2*N)         depthwise conv kernel
    conv_b: jax.Array     # (d_inner + 2*N,)
    a_log: jax.Array      # (H,)
    d_skip: jax.Array     # (H,)
    dt_bias: jax.Array    # (H,)
    norm: jax.Array       # (d_inner,) gated RMSNorm scale
    out_proj: jax.Array   # (d_inner, D)


def _depthwise_conv(x, w, b):
    """Causal depthwise conv, kernel 4.  x: (B, S, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :]


def mamba2_mix(
    x: jax.Array,              # (B, S, D)
    p: Mamba2Params,
    state: jax.Array | None = None,   # (B, H, P, N)
    conv_state: jax.Array | None = None,  # unused in train (full conv)
    *,
    d_inner: int,
    n_heads: int,
    d_state: int,
    chunk: int = 64,
    eps: float = 1e-5,
):
    """Returns (out (B,S,D), final_state)."""
    b, s, d = x.shape
    hp = d_inner // n_heads  # head dim P
    n = d_state
    dt_ = x.dtype

    zxbcdt = jnp.einsum("bsd,de->bse", x, p.in_proj.astype(dt_))
    z, xin, bc, dt_raw = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + 2 * n], axis=-1)
    xbc = jnp.concatenate([xin, bc], axis=-1)
    xbc = jax.nn.silu(_depthwise_conv(xbc, p.conv_w.astype(dt_), p.conv_b.astype(dt_)))
    xin, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p.dt_bias.astype(jnp.float32))
    loga = -jnp.exp(p.a_log.astype(jnp.float32))          # (H,) negative
    lw = dt * loga[None, None, :]                         # (B,S,H) log decay <= 0

    xh = xin.reshape(b, s, n_heads, hp).astype(jnp.float32)
    bmat = bmat.astype(jnp.float32)                       # (B,S,N) single group
    cmat = cmat.astype(jnp.float32)

    if state is None:
        state = jnp.zeros((b, n_heads, hp, n), jnp.float32)

    pad = (-s) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        lw = jnp.pad(lw, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk

    xc = xh.reshape(b, nc, chunk, n_heads, hp).transpose(1, 0, 3, 2, 4)   # (nc,B,H,L,P)
    bc_ = bmat.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)             # (nc,B,L,N)
    cc_ = cmat.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    lc = lw.reshape(b, nc, chunk, n_heads).transpose(1, 0, 3, 2)          # (nc,B,H,L)
    dc = dt.reshape(b, nc, chunk, n_heads).transpose(1, 0, 3, 2)

    def step(S, xs):
        xx, bb, cc, ll, dd = xs               # (B,H,L,P) (B,L,N) (B,L,N) (B,H,L) (B,H,L)
        cs = jnp.cumsum(ll, axis=-1)          # inclusive
        # intra: scores[t,j] = C_t.B_j * exp(cs_t - cs_j) * dt_j,  j <= t
        pair = cs[:, :, :, None] - cs[:, :, None, :]          # (B,H,L,L)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        pair = jnp.where(tri[None, None], pair, -jnp.inf)
        cb = jnp.einsum("btn,bjn->btj", cc, bb)               # (B,L,L)
        scores = jnp.exp(pair) * cb[:, None] * dd[:, :, None, :]
        o = jnp.einsum("bhtj,bhjp->bhtp", scores, xx)
        # carried state: y_t += C_t (exp(cs_t) S)
        o = o + jnp.einsum("btn,bhpn,bht->bhtp", cc, S, jnp.exp(cs))
        # state update
        last = cs[:, :, -1:]
        S_new = S * jnp.exp(last)[..., None] + jnp.einsum(
            "bhl,bhlp,bln->bhpn", jnp.exp(last - cs) * dd, xx, bb)
        return S_new, o

    # checkpoint: intra-chunk (L,L) score tensors recomputed in bwd
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    state, oc = jax.lax.scan(step, state, (xc, bc_, cc_, lc, dc))
    o = oc.transpose(1, 0, 3, 2, 4).reshape(b, s + pad, n_heads, hp)[:, :s]

    # D skip + gated RMSNorm + out proj
    o = o + xh[:, :s] * p.d_skip.astype(jnp.float32)[None, None, :, None]
    o = o.reshape(b, s, d_inner)
    zf = z.astype(jnp.float32)
    o = o * jax.nn.silu(zf)
    var = jnp.mean(o * o, axis=-1, keepdims=True)
    o = o * jax.lax.rsqrt(var + eps) * p.norm.astype(jnp.float32)
    return jnp.einsum("bse,ed->bsd", o.astype(dt_), p.out_proj.astype(dt_)), state


# ----------------------------------------------------------- single-token step
def mamba2_mix_step(
    x: jax.Array,            # (B, D) current (already layer-normed)
    conv_state: jax.Array,   # (B, k-1, conv_ch) previous pre-conv inputs
    state: jax.Array,        # (B, H, P, N) f32
    p: Mamba2Params,
    *,
    d_inner: int,
    n_heads: int,
    d_state: int,
    eps: float = 1e-5,
):
    """One decode step.  Returns (out (B,D), new_conv_state, new_state)."""
    b, d = x.shape
    hp = d_inner // n_heads
    n = d_state
    dt_ = x.dtype

    zxbcdt = jnp.einsum("bd,de->be", x, p.in_proj.astype(dt_))
    z, xin, bc, dt_raw = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + 2 * n], axis=-1)
    xbc = jnp.concatenate([xin, bc], axis=-1)              # (B, conv_ch)

    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)   # (B, k, C)
    conv_out = jnp.einsum("bkc,kc->bc", window, p.conv_w.astype(dt_)) \
        + p.conv_b.astype(dt_)
    xbc_act = jax.nn.silu(conv_out)
    new_conv_state = window[:, 1:]

    xin2, bmat, cmat = jnp.split(xbc_act, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p.dt_bias.astype(jnp.float32))
    a = jnp.exp(dt * (-jnp.exp(p.a_log.astype(jnp.float32)))[None, :])  # (B,H)

    xh = xin2.reshape(b, n_heads, hp).astype(jnp.float32)
    bmf = bmat.astype(jnp.float32)                         # (B, N)
    cmf = cmat.astype(jnp.float32)

    state = state * a[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, bmf)
    o = jnp.einsum("bn,bhpn->bhp", cmf, state)
    o = o + xh * p.d_skip.astype(jnp.float32)[None, :, None]
    o = o.reshape(b, d_inner)
    o = o * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(o * o, axis=-1, keepdims=True)
    o = o * jax.lax.rsqrt(var + eps) * p.norm.astype(jnp.float32)
    return jnp.einsum("be,ed->bd", o.astype(dt_), p.out_proj.astype(dt_)), \
        new_conv_state, state
