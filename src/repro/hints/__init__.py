"""repro.hints — compiler-derived hint providers + per-epoch hint pipeline.

The third leg of the paper's §VI triad (reactive placement, proactive
movement, **compiler hints**): providers that derive ``hint_rank`` arrays
from the workload's structure and the dataloader's batch queue instead of a
caller-supplied oracle, and the :class:`HintPipeline` that refreshes them
into the :class:`~repro.core.runtime.EpochRuntime` every epoch without
breaking its 2-dispatch/epoch invariant.
"""
from .pipeline import HintPipeline
from .providers import (HintLayout, LookaheadWindow, PhaseChangeDetector,
                        StaticTableHints)

__all__ = [
    "HintLayout", "HintPipeline", "LookaheadWindow", "PhaseChangeDetector",
    "StaticTableHints",
]
