"""Hint providers — the compiler/dataloader side of the §VI hint triad.

The paper's HMU case rests on reactive placement, proactive movement, and
*compiler hints*.  Until now the ``hinted`` lane consumed caller-provided
oracle ranks; these providers derive per-block ``hint_rank`` arrays in [0,1]
from what a compiler/dataloader legitimately knows about the workload:

* :class:`StaticTableHints` — static analysis of the embedding-table
  *structure*: the compiler laid the rows out, so it knows which popularity
  rank lands on which page (the table layout) and the row-popularity prior
  (the Zipf skew of the training distribution), including how
  ``rows_per_page`` rows alias into one page.  It knows **nothing** about
  runtime phase rotations — after a :class:`~repro.dlrm.datagen.
  PhaseShiftSampler` rotation its ranks point at the *old* hot head, which is
  exactly the failure mode the lookahead provider and the phase detector
  exist to cover.
* :class:`LookaheadWindow` — the "compiler knows the next minibatch's
  indices" model: a bounded queue of upcoming epoch batch arrays (the
  dataloader's prefetch queue), histogrammed and normalized.  This is what
  drives the ``prefetch`` policy lane.
* :class:`PhaseChangeDetector` — an EWMA over the epoch's host-side access
  histogram; a similarity collapse against the EWMA flags a hot-set rotation
  and permanently down-weights the static hints (their layout prior is stale
  from that point on).

Everything here is host-side numpy *by design*: providers model the
compiler/dataloader, which sees batch queues before they are dispatched.  The
resulting rank arrays ride into the fused epoch step as inputs — a transfer,
not a dispatch.
"""
from __future__ import annotations

import weakref
from typing import Optional, Sequence

import numpy as np

from ..dlrm.datagen import DLRMTraceSpec

__all__ = ["StaticTableHints", "LookaheadWindow", "PhaseChangeDetector",
           "epoch_histogram"]

# One-entry memo: with depth-1 lookahead the SAME epoch array is histogrammed
# twice — by the window at step e-1 (as lookahead) and by the detector at
# step e.  Keyed by weakref identity so a freed-and-reused address can never
# serve a stale histogram.
_hist_memo = (None, 0, None)            # (weakref, n_blocks, hist)


def epoch_histogram(batches: np.ndarray, n_blocks: int) -> np.ndarray:
    """Per-block float64 access histogram of one epoch's batches (ids outside
    [0, n_blocks) dropped).  Callers must not mutate the result."""
    global _hist_memo
    ref, n, h = _hist_memo
    if ref is not None and ref() is batches and n == n_blocks:
        return h
    h = np.bincount(np.asarray(batches).ravel(),
                    minlength=n_blocks)[:n_blocks].astype(np.float64)
    try:
        _hist_memo = (weakref.ref(batches), n_blocks, h)
    except TypeError:                    # non-weakrefable input: skip memo
        pass
    return h


class StaticTableHints:
    """Per-page hint ranks from the embedding table's compile-time structure.

    Page weight = sum of the row-level Zipf(alpha) prior over the
    ``rows_per_page`` rows aliased into that page (page-granular telemetry
    cannot separate rows that share a page; neither can a page hint), mapped
    through ``rank_to_page`` (the layout: which popularity rank the compiler
    placed on which page) and normalized so the hottest page ranks 1.0.

    ``clip_rank`` keeps only the hottest ``clip_rank`` pages' hints and zeroes
    the tail — a compiler annotates the hot head, not five million pages.
    """

    def __init__(self, spec: DLRMTraceSpec, rank_to_page: np.ndarray,
                 clip_rank: Optional[int] = None):
        n = spec.n_pages
        rank_to_page = np.asarray(rank_to_page)
        if rank_to_page.shape != (n,):
            raise ValueError(f"rank_to_page must be ({n},), "
                             f"got {rank_to_page.shape}")
        if clip_rank is not None and clip_rank < 1:
            raise ValueError(f"clip_rank must be >= 1 (clipping every hint "
                             f"makes the rank 0/0), got {clip_rank}")
        rpp = max(spec.rows_per_page, 1)
        # row-level prior aggregated per page-popularity rank: the page with
        # popularity rank r aliases rows [r*rpp, (r+1)*rpp); accumulated one
        # row-offset at a time so paper-scale tables (n*rpp ~ 20M rows) never
        # materialize an n*rpp-sized temporary
        base = np.arange(n, dtype=np.float64) * rpp
        page_w = np.zeros((n,), np.float64)
        for j in range(1, rpp + 1):
            page_w += (base + j) ** (-spec.alpha)
        if clip_rank is not None:
            page_w[int(clip_rank):] = 0.0
        rank = np.zeros((n,), np.float32)
        rank[rank_to_page] = (page_w / page_w[0]).astype(np.float32)
        self.spec = spec
        self.rank = rank

    def __call__(self) -> np.ndarray:
        return self.rank


class LookaheadWindow:
    """Bounded lookahead over the dataloader's batch queue.

    ``rank(upcoming)`` histograms up to ``depth`` upcoming epoch batch arrays
    (nearer epochs weighted by ``decay**distance``) and normalizes to [0,1];
    blocks outside the window rank 0 and are never prefetched.  An empty
    queue (end of stream) yields all-zeros — the prefetch lane goes idle.
    """

    def __init__(self, n_blocks: int, depth: int = 1, decay: float = 0.5):
        if depth < 1:
            raise ValueError(f"lookahead depth must be >= 1, got {depth}")
        self.n_blocks = int(n_blocks)
        self.depth = int(depth)
        self.decay = float(decay)
        # single cached empty rank, so an idle window returns the SAME object
        # every epoch and the runtime's identity-skip avoids re-uploading it
        self._zeros = np.zeros((self.n_blocks,), np.float32)

    def rank(self, upcoming: Sequence[np.ndarray]) -> np.ndarray:
        counts = np.zeros((self.n_blocks,), np.float64)
        for d, batches in enumerate(upcoming[: self.depth]):
            counts += (self.decay ** d) * epoch_histogram(batches,
                                                          self.n_blocks)
        top = counts.max()
        if top <= 0.0:
            return self._zeros
        return (counts / top).astype(np.float32)


class PhaseChangeDetector:
    """EWMA phase-change detector: re-weights static hints after rotations.

    Tracks an EWMA of the epoch's access histogram (the dataloader's own view
    of the batches it just queued — no telemetry readback) and compares each
    new epoch against it by cosine similarity.  A drop below ``threshold``
    flags a hot-set rotation: the static-hint scale is multiplied by
    ``penalty`` (the layout prior is stale from now on — there is no recovery
    path, a rotated workload does not rotate back on its own) and the EWMA
    snaps to the new phase so one rotation is detected once, not every epoch.
    """

    def __init__(self, n_blocks: int, alpha: float = 0.5,
                 threshold: float = 0.6, penalty: float = 0.25):
        self.n_blocks = int(n_blocks)
        self.alpha = float(alpha)
        self.threshold = float(threshold)
        self.penalty = float(penalty)
        self.scale = 1.0
        self.shifts_detected = 0
        self._ewma: Optional[np.ndarray] = None

    def update(self, batches: np.ndarray) -> float:
        """Fold one epoch's batches in; returns the current static-hint scale."""
        h = epoch_histogram(batches, self.n_blocks)
        if self._ewma is None:
            self._ewma = h
            return self.scale
        denom = np.linalg.norm(self._ewma) * np.linalg.norm(h)
        sim = float(self._ewma @ h / denom) if denom > 0.0 else 1.0
        if sim < self.threshold:
            self.shifts_detected += 1
            self.scale *= self.penalty
            self._ewma = h
        else:
            self._ewma = self.alpha * h + (1.0 - self.alpha) * self._ewma
        return self.scale
