"""Hint providers — the compiler/dataloader side of the §VI hint triad.

The paper's HMU case rests on reactive placement, proactive movement, and
*compiler hints*.  Until now the ``hinted`` lane consumed caller-provided
oracle ranks; these providers derive per-block ``hint_rank`` arrays in [0,1]
from what a compiler/dataloader legitimately knows about the workload:

* :class:`StaticTableHints` — static analysis of the embedding-table
  *structure*: the compiler laid the rows out, so it knows which popularity
  rank lands on which page (the table layout) and the row-popularity prior
  (the Zipf skew of the training distribution), including how
  ``rows_per_page`` rows alias into one page.  It knows **nothing** about
  runtime phase rotations — after a :class:`~repro.dlrm.datagen.
  PhaseShiftSampler` rotation its ranks point at the *old* hot head, which is
  exactly the failure mode the lookahead provider and the phase detector
  exist to cover.
* :class:`LookaheadWindow` — the "compiler knows the next minibatch's
  indices" model: a bounded queue of upcoming epoch batch arrays (the
  dataloader's prefetch queue), histogrammed and normalized.  This is what
  drives the ``prefetch`` policy lane.
* :class:`PhaseChangeDetector` — an EWMA over the epoch's host-side access
  histogram; a similarity collapse against the EWMA flags a hot-set rotation
  and permanently down-weights the static hints (their layout prior is stale
  from that point on).

Everything here is host-side numpy *by design*: providers model the
compiler/dataloader, which sees batch queues before they are dispatched.  The
resulting rank arrays ride into the fused epoch step as inputs — a transfer,
not a dispatch.
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Optional, Sequence, Union

import numpy as np

from ..dlrm.datagen import DLRMTraceSpec

__all__ = ["HintLayout", "StaticTableHints", "LookaheadWindow",
           "PhaseChangeDetector", "epoch_histogram"]

# One-entry memo: with depth-1 lookahead the SAME epoch array is histogrammed
# twice — by the window at step e-1 (as lookahead) and by the detector at
# step e.  Keyed by weakref identity so a freed-and-reused address can never
# serve a stale histogram, PLUS an O(1) content fingerprint so a dataloader
# that refills one preallocated buffer in place (same object, new epoch)
# invalidates the entry instead of silently replaying the old histogram
# (which would blind the phase detector to a rotation).  The fingerprint
# samples a fixed handful of elements — a refill that happens to match all
# of them is vanishingly unlikely but not impossible, so callers that mutate
# buffers in place and need a hard guarantee should pass fresh arrays.
_hist_memo = (None, 0, None, None)      # (weakref, n_blocks, fingerprint, hist)


def _fingerprint(arr: np.ndarray):
    flat = arr.reshape(-1)
    step = max(flat.size // 8, 1)
    return (arr.shape, arr.dtype.str, flat[::step].tobytes(),
            flat[-1:].tobytes())


def epoch_histogram(batches: np.ndarray, n_blocks: int) -> np.ndarray:
    """Per-block float64 access histogram of one epoch's batches (ids outside
    [0, n_blocks) dropped).  Callers must not mutate the result."""
    global _hist_memo
    batches = np.asarray(batches)
    ref, n, fp, h = _hist_memo
    if (ref is not None and ref() is batches and n == n_blocks
            and fp == _fingerprint(batches)):
        return h
    h = np.bincount(batches.ravel(),
                    minlength=n_blocks)[:n_blocks].astype(np.float64)
    try:
        _hist_memo = (weakref.ref(batches), n_blocks,
                      _fingerprint(batches), h)
    except TypeError:                    # non-weakrefable input: skip memo
        pass
    return h


@dataclasses.dataclass(frozen=True)
class HintLayout:
    """What a compiler knows *statically* about a scenario's block space.

    The workload-agnostic contract between a scenario (see
    :mod:`repro.scenarios`) and the hint providers: how many blocks there
    are, which popularity rank the compiler laid out on which block
    (``rank_to_page``), the skew of the popularity prior (``alpha``) and how
    many sub-blocks alias into one block (``rows_per_page`` — embedding rows
    per page for DLRM; 1 when blocks are the access granularity).

    ``rank_to_page=None`` means the scenario has no static layout at all —
    hotness is runtime-only, as for a KV cache whose per-page attention mass
    depends on the decoded text.  Pipelines built from such a layout run
    lookahead-only (:meth:`~repro.hints.HintPipeline.for_scenario`).
    """
    n_blocks: int
    rank_to_page: Optional[np.ndarray] = None
    alpha: float = 1.0
    rows_per_page: int = 1


class StaticTableHints:
    """Per-page hint ranks from a block space's compile-time structure.

    Page weight = sum of the row-level Zipf(alpha) prior over the
    ``rows_per_page`` rows aliased into that page (page-granular telemetry
    cannot separate rows that share a page; neither can a page hint), mapped
    through ``rank_to_page`` (the layout: which popularity rank the compiler
    placed on which page) and normalized so the hottest page ranks 1.0.

    The first argument is either a :class:`HintLayout` (the workload-agnostic
    form the scenario layer uses) or a DLRM trace spec plus its
    ``rank_to_page`` array (the original DLRM-shaped call, kept working).

    ``clip_rank`` keeps only the hottest ``clip_rank`` pages' hints and zeroes
    the tail — a compiler annotates the hot head, not five million pages.
    """

    def __init__(self, spec: Union[DLRMTraceSpec, HintLayout],
                 rank_to_page: Optional[np.ndarray] = None,
                 clip_rank: Optional[int] = None):
        if isinstance(spec, HintLayout):
            if rank_to_page is not None:
                raise ValueError("pass the layout's rank_to_page inside the "
                                 "HintLayout, not as a second argument")
            layout = spec
        else:
            layout = HintLayout(spec.n_pages, rank_to_page,
                                alpha=spec.alpha,
                                rows_per_page=spec.rows_per_page)
        n = layout.n_blocks
        if layout.rank_to_page is None:
            raise ValueError("static hints need a rank_to_page layout; "
                             "use a lookahead-only pipeline for scenarios "
                             "without one")
        rank_to_page = np.asarray(layout.rank_to_page)
        if rank_to_page.shape != (n,):
            raise ValueError(f"rank_to_page must be ({n},), "
                             f"got {rank_to_page.shape}")
        if clip_rank is not None and clip_rank < 1:
            raise ValueError(f"clip_rank must be >= 1 (clipping every hint "
                             f"makes the rank 0/0), got {clip_rank}")
        rpp = max(layout.rows_per_page, 1)
        # row-level prior aggregated per page-popularity rank: the page with
        # popularity rank r aliases rows [r*rpp, (r+1)*rpp); accumulated one
        # row-offset at a time so paper-scale tables (n*rpp ~ 20M rows) never
        # materialize an n*rpp-sized temporary
        base = np.arange(n, dtype=np.float64) * rpp
        page_w = np.zeros((n,), np.float64)
        for j in range(1, rpp + 1):
            page_w += (base + j) ** (-layout.alpha)
        if clip_rank is not None:
            page_w[int(clip_rank):] = 0.0
        rank = np.zeros((n,), np.float32)
        rank[rank_to_page] = (page_w / page_w[0]).astype(np.float32)
        self.spec = spec
        self.layout = layout
        self.rank = rank

    def __call__(self) -> np.ndarray:
        return self.rank


class LookaheadWindow:
    """Bounded lookahead over the dataloader's batch queue.

    ``rank(upcoming)`` histograms up to ``depth`` upcoming epoch batch arrays
    (nearer epochs weighted by ``decay**distance``) and normalizes to [0,1];
    blocks outside the window rank 0 and are never prefetched.  An empty
    queue (end of stream) yields all-zeros — the prefetch lane goes idle.
    """

    def __init__(self, n_blocks: int, depth: int = 1, decay: float = 0.5):
        if depth < 1:
            raise ValueError(f"lookahead depth must be >= 1, got {depth}")
        self.n_blocks = int(n_blocks)
        self.depth = int(depth)
        self.decay = float(decay)
        # single cached empty rank, so an idle window returns the SAME object
        # every epoch and the runtime's identity-skip avoids re-uploading it
        self._zeros = np.zeros((self.n_blocks,), np.float32)

    def rank(self, upcoming: Sequence[np.ndarray]) -> np.ndarray:
        counts = np.zeros((self.n_blocks,), np.float64)
        for d, batches in enumerate(upcoming[: self.depth]):
            counts += (self.decay ** d) * epoch_histogram(batches,
                                                          self.n_blocks)
        top = counts.max()
        if top <= 0.0:
            return self._zeros
        return (counts / top).astype(np.float32)


class PhaseChangeDetector:
    """EWMA phase-change detector: re-weights static hints after rotations.

    Tracks an EWMA of the epoch's access histogram (the dataloader's own view
    of the batches it just queued — no telemetry readback) and compares each
    new epoch against it by cosine similarity.  A drop below ``threshold``
    flags a hot-set rotation: the static-hint scale is multiplied by
    ``penalty`` (the layout prior is stale from now on — there is no recovery
    path, a rotated workload does not rotate back on its own) and the EWMA
    snaps to the new phase so one rotation is detected once, not every epoch.
    """

    def __init__(self, n_blocks: int, alpha: float = 0.5,
                 threshold: float = 0.6, penalty: float = 0.25):
        self.n_blocks = int(n_blocks)
        self.alpha = float(alpha)
        self.threshold = float(threshold)
        self.penalty = float(penalty)
        self.scale = 1.0
        self.shifts_detected = 0
        self._ewma: Optional[np.ndarray] = None

    def update(self, batches: np.ndarray) -> float:
        """Fold one epoch's batches in; returns the current static-hint scale."""
        h = epoch_histogram(batches, self.n_blocks)
        if self._ewma is None:
            self._ewma = h
            return self.scale
        denom = np.linalg.norm(self._ewma) * np.linalg.norm(h)
        sim = float(self._ewma @ h / denom) if denom > 0.0 else 1.0
        if sim < self.threshold:
            self.shifts_detected += 1
            self.scale *= self.penalty
            self._ewma = h
        else:
            self._ewma = self.alpha * h + (1.0 - self.alpha) * self._ewma
        return self.scale
