"""HintPipeline — per-epoch hint refresh for the EpochRuntime.

One pipeline owns the three providers and turns (this epoch's batches, the
lookahead queue) into the two rank arrays the runtime's hint-consuming lanes
read:

* ``hint_rank``      — the ``hinted`` lane's static priority: the
  :class:`~repro.hints.providers.StaticTableHints` ranks scaled by the
  :class:`~repro.hints.providers.PhaseChangeDetector`'s current weight.
* ``prefetch_rank``  — the ``prefetch`` lane's lookahead priority from the
  :class:`~repro.hints.providers.LookaheadWindow`.

The refresh is host-side (the providers model the compiler/dataloader) and
rides into the fused ``_epoch_step`` as replaced state leaves — a
host-to-device transfer, **not** a dispatch, so the 2-dispatch/epoch
invariant holds; ``runtime.DISPATCH_COUNTS["hint_refresh"]`` counts refreshes
separately so the accounting stays auditable.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import numpy as np

from ..dlrm.datagen import DLRMTraceSpec, ZipfPageSampler
from .providers import (HintLayout, LookaheadWindow, PhaseChangeDetector,
                        StaticTableHints)

__all__ = ["HintPipeline"]


class HintPipeline:
    """Providers -> per-epoch ``(hint_rank, prefetch_rank)`` refresh.

    Any provider may be omitted: without ``static`` the hinted lane sees
    zeros (pure telemetry), without ``lookahead`` the prefetch lane idles,
    without ``detector`` static hints are never re-weighted.
    """

    def __init__(
        self,
        n_blocks: int,
        static: Union[StaticTableHints, np.ndarray, None] = None,
        lookahead: Optional[LookaheadWindow] = None,
        detector: Optional[PhaseChangeDetector] = None,
    ):
        self.n_blocks = int(n_blocks)
        rank = static() if callable(static) else static
        self._static_rank = (np.zeros((self.n_blocks,), np.float32)
                             if rank is None
                             else np.asarray(rank, np.float32))
        if self._static_rank.shape != (self.n_blocks,):
            raise ValueError(f"static rank must be ({self.n_blocks},), "
                             f"got {self._static_rank.shape}")
        self.lookahead = lookahead
        self.detector = detector
        # (scale, scaled array) cache: epoch_ranks returns the SAME object
        # until the detector moves the scale, so the runtime can skip the
        # host-to-device re-upload of an unchanged hint_rank by identity
        self._scaled = (1.0, self._static_rank)
        self._no_lookahead = np.zeros((self.n_blocks,), np.float32)

    @property
    def lookahead_depth(self) -> int:
        """Epochs of batch queue the runtime must buffer ahead."""
        return self.lookahead.depth if self.lookahead is not None else 0

    @property
    def static_scale(self) -> float:
        return self.detector.scale if self.detector is not None else 1.0

    def epoch_ranks(
        self, batches: np.ndarray, upcoming: Sequence[np.ndarray] = (),
    ) -> Tuple[np.ndarray, np.ndarray]:
        """One epoch's refresh: fold ``batches`` into the phase detector and
        return ``(hint_rank, prefetch_rank)`` float32 arrays in [0,1]."""
        scale = (self.detector.update(batches)
                 if self.detector is not None else 1.0)
        if scale != self._scaled[0]:
            self._scaled = (scale, self._static_rank * np.float32(scale))
        hint_rank = self._scaled[1]
        # no-lookahead pipelines hand back the static rank's zero-filled
        # sibling — also cached, so the identity-skip holds there too
        prefetch_rank = (self.lookahead.rank(upcoming)
                         if self.lookahead is not None
                         else self._no_lookahead)
        return hint_rank, prefetch_rank

    @staticmethod
    def for_scenario(
        layout: HintLayout,
        depth: int = 1,
        clip_rank: Optional[int] = None,
        detector: bool = True,
    ) -> "HintPipeline":
        """Layout-driven default pipeline — the workload-agnostic form every
        scenario uses (see :meth:`repro.scenarios.AccessScenario.hint_layout`):
        static hints when the layout carries a ``rank_to_page`` map (a
        compiler that laid the blocks out), ``depth`` epochs of lookahead
        over the scenario's batch queue, and the phase detector.  A layout
        without a ``rank_to_page`` (runtime-only hotness, e.g. a KV cache)
        yields a lookahead-only pipeline: the hinted lane falls back to pure
        telemetry while the prefetch lane stays live.  ``clip_rank`` defaults
        to an eighth of the blocks — a compiler annotates the hot head only.
        """
        n = layout.n_blocks
        static = None
        if layout.rank_to_page is not None:
            clip = max(n // 8, 1) if clip_rank is None else clip_rank
            static = StaticTableHints(layout, clip_rank=clip)
        return HintPipeline(
            n,
            static=static,
            lookahead=LookaheadWindow(n, depth=depth),
            detector=PhaseChangeDetector(n) if detector else None,
        )

    @staticmethod
    def for_fleet(
        n_blocks: int,
        members: Sequence,
        depth: int = 1,
        clip_rank: Optional[int] = None,
        detector: bool = True,
    ) -> "HintPipeline":
        """Composed pipeline for a multi-tenant block space (``repro.fleet``).

        ``members`` is a sequence of ``(offset, layout_or_None)`` pairs, one
        per tenant, offsets into the concatenated global id space.  Each
        tenant that has a static layout gets its *own*
        :class:`StaticTableHints` rank — computed with the tenant's own
        ``alpha``/``rows_per_page`` prior and its own clip, then scattered
        into the global array at the tenant's offset.  Tenants are NOT
        concatenated in rank space: a global Zipf prior over concatenated
        ranks would push every later tenant's pages under the first
        tenant's tail (and the default clip would zero them outright), so
        each tenant's compiler annotates its own hot head and the scales
        stay comparable (every tenant's hottest block ranks 1.0).  Tenants
        without a layout contribute zeros — their hinted-lane share falls
        back to pure telemetry, exactly as solo.  The lookahead window and
        phase detector span the whole fleet stream (the dataloader queues
        the interleaved batches, so that IS what the compiler sees).
        ``clip_rank`` applies per tenant (default: an eighth of the
        *tenant's* blocks)."""
        static = np.zeros((int(n_blocks),), np.float32)
        any_static = False
        for offset, layout in members:
            if layout is None or layout.rank_to_page is None:
                continue
            clip = (max(layout.n_blocks // 8, 1) if clip_rank is None
                    else min(int(clip_rank), layout.n_blocks))
            rank = StaticTableHints(layout, clip_rank=clip).rank
            static[int(offset):int(offset) + layout.n_blocks] = rank
            any_static = True
        return HintPipeline(
            int(n_blocks),
            static=static if any_static else None,
            lookahead=LookaheadWindow(int(n_blocks), depth=depth),
            detector=PhaseChangeDetector(int(n_blocks)) if detector else None,
        )

    @staticmethod
    def for_dlrm(
        spec: DLRMTraceSpec,
        seed: int = 0,
        depth: int = 1,
        clip_rank: Optional[int] = None,
        detector: bool = True,
        layout: Optional[np.ndarray] = None,
    ) -> "HintPipeline":
        """Default pipeline for a DLRM trace — :meth:`for_scenario` on the
        table's :class:`~repro.hints.HintLayout`: static hints from the table
        structure (``layout`` = the trace sampler's rank->page map — the
        compiler that laid the table out; pass the actual sampler's
        ``rank_to_page`` when you have it, e.g.
        ``PhaseShiftSampler.rank_to_page``, else the ``seed``'s
        :class:`ZipfPageSampler` layout is rebuilt here), one-epoch
        lookahead, and the phase detector."""
        if layout is None:
            layout = ZipfPageSampler(spec, seed).rank_to_page
        return HintPipeline.for_scenario(
            HintLayout(spec.n_pages, rank_to_page=layout, alpha=spec.alpha,
                       rows_per_page=spec.rows_per_page),
            depth=depth, clip_rank=clip_rank, detector=detector,
        )
